"""Paper Table 1: topological properties of every allocation strategy.

Analytic values from the definitions (distance, convexity, locality, hull
links, PB with the per-dimension refinement) PLUS the measured MIN-routing
saturation throughput, which for symmetric partitions equals PB exactly.
"""

from repro.core.allocation import allocate_partition
from repro.core.properties import analyze_partition
from repro.core.routing import empirical_partition_bandwidth

from benchmarks.common import PAPER_TOPO, STRATEGIES, emit


def run(quick=False):
    rows = []
    for strat in STRATEGIES:
        part = allocate_partition(strat, PAPER_TOPO, 0, seed=1)
        p = analyze_partition(PAPER_TOPO, part)
        emp = empirical_partition_bandwidth(PAPER_TOPO, part.endpoints)
        rows.append({
            "strategy": strat,
            "avg_distance": round(p.avg_distance, 4),
            "max_distance": p.max_distance,
            "convexity": p.convexity,
            "locality_aware": p.switch_locality,
            "hull_links": p.hull_links,
            "PB": round(p.partition_bandwidth, 4),
            "PB_bound_eq3": round(p.partition_bandwidth_bound, 4),
            "min_saturation_measured": round(emp, 4),
        })
    emit(rows, "table1_properties (paper Table 1)")
    return rows


if __name__ == "__main__":
    run()
