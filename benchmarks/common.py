"""Shared helpers for the benchmark suite (one module per paper artifact)."""

from __future__ import annotations

import csv
import io
import sys
import time

import numpy as np

from repro.core.hyperx import HyperX
from repro.core.allocation import allocate_partition, machine_partitions
from repro.core import traffic as tr
from repro.core.simulator import build_simulator

STRATEGIES = [
    "row", "diagonal", "full_spread", "rectangular", "l_shape",
    "random_endpoint", "random_switch",
]

PAPER_TOPO = HyperX(n=8, q=2)


def emit(rows: list[dict], name: str):
    """Print rows as CSV with a '# <name>' header (the harness contract)."""
    if not rows:
        print(f"# {name}: no rows")
        return
    out = io.StringIO()
    w = csv.DictWriter(out, fieldnames=list(rows[0].keys()))
    w.writeheader()
    for r in rows:
        w.writerow(r)
    print(f"# {name}")
    sys.stdout.write(out.getvalue())
    sys.stdout.flush()


def kernel_app(kind: str, k: int, seed: int = 0):
    if kind == "all_to_all":
        return tr.all_to_all(k)
    if kind == "all_reduce":
        return tr.all_reduce(k, vector_packets=64)
    if kind == "stencil_von_neumann":
        return tr.stencil(k, "von_neumann")
    if kind == "stencil_moore":
        return tr.stencil(k, "moore")
    if kind == "random_involution":
        return tr.random_involution(k, packets=63, seed=seed)
    if kind == "uniform":
        return tr.uniform(k, packets=64)
    if kind == "random_permutation":
        return tr.random_permutation(k, packets=64, seed=seed)
    if kind == "random_switch_permutation":
        return tr.random_switch_permutation(k, group=PAPER_TOPO.n,
                                            packets=64, seed=seed)
    raise ValueError(kind)


def escalation_makespan(strategy: str, kind: str, replicas: int, k: int = 64,
                        mode: str = "omniwar", seed: int = 0,
                        horizon: int = 60000) -> dict:
    """k-rank app x replicas on the paper machine; all replicas targets."""
    per_job = k
    parts = machine_partitions(strategy, PAPER_TOPO,
                               num_jobs=512 // per_job, job_size=per_job)
    apps = [(kernel_app(kind, k, seed + j), parts[j]) for j in range(replicas)]
    wl = tr.compose_workload(PAPER_TOPO, apps)
    res = build_simulator(PAPER_TOPO, wl, mode=mode, horizon=horizon)(seed)
    return {
        "strategy": strategy, "kernel": kind, "replicas": replicas, "k": k,
        "makespan": res.makespan if res.completed else -1,
        "makespan_cycles": res.makespan_cycles if res.completed else -1,
        "avg_latency": round(res.avg_latency, 2),
        "avg_hops": round(res.avg_hops, 3),
        "completed": res.completed,
    }


def interference_makespan(strategy: str, kind: str, k: int = 64,
                          fabric: str = "shared", with_bg: bool = True,
                          warmup: int = 400, seed: int = 0,
                          horizon: int = 80000) -> dict:
    part = allocate_partition(strategy, PAPER_TOPO, 0,
                              size=k)
    apps = [(kernel_app(kind, k, seed), part)]
    bgs = []
    if with_bg:
        free = np.setdiff1d(np.arange(PAPER_TOPO.num_endpoints),
                            part.endpoints)
        bgs = [tr.background_noise(PAPER_TOPO, free, seed=seed + 99)]
    wl = tr.compose_workload(PAPER_TOPO, apps, background=bgs,
                             fabric_partitioning=fabric,
                             warmup=warmup if with_bg else 0)
    res = build_simulator(PAPER_TOPO, wl, horizon=horizon)(seed)
    return {
        "strategy": strategy, "kernel": kind, "k": k, "fabric": fabric,
        "bg": with_bg,
        "makespan": res.makespan if res.completed else -1,
        "completed": res.completed,
    }
