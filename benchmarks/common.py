"""Shared helpers for the benchmark suite (one module per paper artifact).

The simulation-backed benchmarks build their scenario grids as *workloads
first*, then execute them through :func:`sweep`, which groups same-config
scenarios and dispatches each group as **one** vmapped device call via
``SimEngine.run_batch`` — a strategy grid that used to be a serial Python
loop of per-scenario compiles is now one compile + one call per shape
bucket.

Module-level knobs set by ``benchmarks.run``:

  * ``NUM_SEEDS`` — every scenario is fanned across this many seeds (the
    seed axis rides in the same batched call); rows report means over
    completed seeds;
  * ``CSV_DIR``  — when set, :func:`emit` also writes each table to
    ``<CSV_DIR>/<name>.csv`` so perf trajectories land in versionable
    files.
"""

from __future__ import annotations

import csv
import io
import os
import re
import sys

import numpy as np

from repro.core.hyperx import HyperX
from repro.core.allocation import allocate_partition, machine_partitions
from repro.core.engine import SimResult, get_engine
from repro.obs import TelemetrySpec
from repro.obs import trace as obs_trace
from repro.traffic import (
    AppSpec,
    BackgroundSpec,
    PhaseSpec,
    ScenarioSpec,
    Workload,
    build_workload,
    get_pattern,
)

STRATEGIES = [
    "row", "diagonal", "full_spread", "rectangular", "l_shape",
    "random_endpoint", "random_switch",
]

PAPER_TOPO = HyperX(n=8, q=2)

NUM_SEEDS = 1          # set by benchmarks.run --seeds
CSV_DIR: str | None = None  # set by benchmarks.run --csv
QUICK = True           # set by benchmarks.run --quick/--full
ROUTING = "omniwar"    # set by benchmarks.run --routing (any registered policy)
PATTERN = "all_to_all"  # set by benchmarks.run --pattern (any registered pattern)


def resolve_routing(mode: str | None = None) -> str:
    """Routing-policy switch, same contract as :func:`resolve_quick`:
    ``benchmarks.run --routing`` sets :data:`ROUTING` once and the
    simulation-backed modules resolve through it unless a caller
    overrides explicitly."""
    return ROUTING if mode is None else mode


def resolve_pattern(kind: str | None = None) -> str:
    """Traffic-pattern switch, same contract as :func:`resolve_routing`:
    ``benchmarks.run --pattern`` sets :data:`PATTERN` once and the
    pattern-parameterized modules (e.g. ``traffic_grid``) resolve
    through it unless a caller overrides explicitly."""
    return PATTERN if kind is None else kind


def resolve_quick(quick) -> bool:
    """Shared CI-sizing switch.  Benchmark modules take ``run(quick=None)``
    and resolve through this, so :data:`QUICK` (set once by
    ``benchmarks.run``) is the single source of truth unless a caller
    overrides explicitly — no more half-quick/half-full grids."""
    return QUICK if quick is None else bool(quick)


def render_csv(rows: list[dict]) -> str:
    """Render dict rows as CSV text (header from the first row's keys)."""
    out = io.StringIO()
    w = csv.DictWriter(out, fieldnames=list(rows[0].keys()))
    w.writeheader()
    for r in rows:
        w.writerow(r)
    return out.getvalue()


def write_grid_csv(rows: list[dict], name: str,
                   csv_dir: str | None = None) -> str:
    """The one CSV-writing path for every grid benchmark.

    Prints the table with a '# <name>' header (the harness contract) and,
    when ``csv_dir`` is set, also writes it to ``<csv_dir>/<slug>.csv``.
    Returns the rendered CSV text.  ``emit`` delegates here with the
    suite-wide ``CSV_DIR``; call this directly to target another dir.
    """
    if not rows:
        print(f"# {name}: no rows")
        return ""
    text = render_csv(rows)
    print(f"# {name}")
    sys.stdout.write(text)
    sys.stdout.flush()
    if csv_dir:
        os.makedirs(csv_dir, exist_ok=True)
        slug = re.sub(r"[^A-Za-z0-9_.-]+", "_", name.split(" ")[0]).strip("_")
        with open(os.path.join(csv_dir, f"{slug}.csv"), "w", newline="") as f:
            f.write(text)
    return text


def emit(rows: list[dict], name: str):
    """Print rows as CSV with a '# <name>' header (the harness contract).

    When ``CSV_DIR`` is set the same table is also written to
    ``<CSV_DIR>/<slug>.csv``.
    """
    write_grid_csv(rows, name, csv_dir=CSV_DIR)


# ------------------------------------------------------------------ traffic
def pattern_phase(kind: str) -> PhaseSpec:
    """Registry phase for ``kind`` with the suite's historical params
    (switch-permutation groups sized to the paper machine's switches)."""
    if kind == "random_switch_permutation":
        return PhaseSpec(kind, {"group": PAPER_TOPO.n})
    return PhaseSpec(kind)


def kernel_app(kind: str, k: int, seed: int = 0):
    """One registry pattern over ``k`` ranks (kept for spot checks)."""
    phase = pattern_phase(kind)
    return get_pattern(kind).build(k, seed=seed, **dict(phase.params))


# ------------------------------------------------------- workload builders
def escalation_workload(strategy: str, kind: str, replicas: int, k: int = 64,
                        seed: int = 0) -> Workload:
    """k-rank app x replicas on the paper machine; all replicas targets."""
    per_job = k
    parts = machine_partitions(strategy, PAPER_TOPO,
                               num_jobs=512 // per_job, job_size=per_job)
    spec = ScenarioSpec(apps=tuple(
        AppSpec(phases=pattern_phase(kind), placement=parts[j], ranks=k,
                seed=seed + j)
        for j in range(replicas)
    ))
    return build_workload(PAPER_TOPO, spec)


def interference_workload(strategy: str, kind: str, k: int = 64,
                          fabric: str = "shared", with_bg: bool = True,
                          warmup: int = 400, seed: int = 0) -> Workload:
    """One target job (+ optional random-permutation background)."""
    part = allocate_partition(strategy, PAPER_TOPO, 0, size=k)
    spec = ScenarioSpec(
        apps=(AppSpec(phases=pattern_phase(kind), placement=part, ranks=k,
                      seed=seed),),
        background=BackgroundSpec(seed=seed + 99) if with_bg else None,
        fabric_partitioning=fabric,
        warmup=warmup if with_bg else 0,
    )
    return build_workload(PAPER_TOPO, spec)


def phased_workload(strategy: str, kinds, k: int = 64, seed: int = 0,
                    window: int | None = None) -> Workload:
    """One job running an ordered phase list (e.g. stencil + all-reduce)."""
    part = allocate_partition(strategy, PAPER_TOPO, 0, size=k)
    spec = ScenarioSpec(apps=(
        AppSpec(phases=tuple(pattern_phase(kd) for kd in kinds),
                placement=part, ranks=k, seed=seed, window=window),
    ))
    return build_workload(PAPER_TOPO, spec)


# --------------------------------------------------------- batched execution
def sweep(workloads: list[Workload], mode: str | None = None,
          horizon: int = 60_000, seeds=None,
          topo: HyperX = PAPER_TOPO) -> list[list[SimResult]]:
    """Run every (workload, seed) pair batched; returns [workload][seed].

    Workloads are grouped by engine configuration (pool count) and shape
    bucket; each group executes through ``SimEngine.run_grid``, which
    flattens the grid into device-sharded lanes (``shard_map``/``pmap``
    across all local devices; the nested-vmap call on one device) — so
    every grid benchmark gains multi-device execution with no changes.
    The routing policy defaults to the suite-wide ``--routing`` choice.
    """
    mode = resolve_routing(mode)
    if seeds is None:
        seeds = list(range(NUM_SEEDS))
    seeds = list(seeds)
    by_pools: dict[int, list[int]] = {}
    for i, wl in enumerate(workloads):
        by_pools.setdefault(wl.num_pools, []).append(i)
    results: list[list[SimResult] | None] = [None] * len(workloads)
    with obs_trace.span("bench.sweep", mode=mode,
                        workloads=len(workloads), seeds=len(seeds)):
        for num_pools, idxs in by_pools.items():
            engine = get_engine(topo, mode=mode, num_pools=num_pools)
            per_wl = engine.run_grid(
                [workloads[i] for i in idxs], seeds=seeds, horizon=horizon
            )
            for i, res in zip(idxs, per_wl):
                results[i] = res
    return results  # type: ignore[return-value]


def telemetry_probe(strategies=("diagonal", "rectangular"),
                    kind: str | None = None, k: int = 64,
                    horizon: int = 60_000, seed: int = 0,
                    spec: TelemetrySpec | None = None) -> dict:
    """Run a small telemetry-enabled grid and log one ``sim.telemetry``
    event per strategy.

    This is the suite's traced-run payload (``benchmarks.run --trace``):
    the per-link utilization / occupancy / latency series behind the
    report generator's heatmap and latency tables.  Telemetry joins the
    engine compile key, so these engines are separate cache entries from
    the untraced sweeps and leave their compile counts untouched.
    Returns ``{strategy: Telemetry}``.
    """
    kind = resolve_pattern(kind)
    spec = spec or TelemetrySpec()
    out = {}
    for strategy in strategies:
        wl = interference_workload(strategy, kind, k=k, with_bg=False,
                                   warmup=0, seed=seed)
        engine = get_engine(PAPER_TOPO, mode=resolve_routing(None),
                            num_pools=wl.num_pools, telemetry=spec)
        with obs_trace.span("bench.telemetry_probe", strategy=strategy,
                            kernel=kind):
            res = engine.run(wl, seed=seed, horizon=horizon)
        obs_trace.log_telemetry(strategy, res.telemetry, kernel=kind, k=k)
        out[strategy] = res.telemetry
    return out


def summarize(per_seed: list[SimResult]) -> dict:
    """Mean metrics over completed seeds (-1 when any seed hit the horizon)."""
    done = [r for r in per_seed if r.completed]
    completed = len(done) == len(per_seed)
    if not done:
        return {"makespan": -1, "makespan_cycles": -1, "avg_latency": -1.0,
                "avg_hops": -1.0, "completed": False, "seeds": len(per_seed)}
    return {
        "makespan": round(float(np.mean([r.makespan for r in done])), 1)
        if completed else -1,
        "makespan_cycles": round(
            float(np.mean([r.makespan_cycles for r in done])), 1)
        if completed else -1,
        "avg_latency": round(float(np.mean([r.avg_latency for r in done])), 2),
        "avg_hops": round(float(np.mean([r.avg_hops for r in done])), 3),
        "completed": completed,
        "seeds": len(per_seed),
    }


# -------------------------------------------- single-scenario conveniences
def escalation_makespan(strategy: str, kind: str, replicas: int, k: int = 64,
                        mode: str | None = None, seed: int = 0,
                        horizon: int = 60000) -> dict:
    """One escalation scenario (kept for spot checks; sweeps use sweep())."""
    wl = escalation_workload(strategy, kind, replicas, k=k, seed=seed)
    res = get_engine(PAPER_TOPO, mode=resolve_routing(mode),
                     num_pools=wl.num_pools).run(
        wl, seed=seed, horizon=horizon)
    return {
        "strategy": strategy, "kernel": kind, "replicas": replicas, "k": k,
        "makespan": res.makespan if res.completed else -1,
        "makespan_cycles": res.makespan_cycles if res.completed else -1,
        "avg_latency": round(res.avg_latency, 2),
        "avg_hops": round(res.avg_hops, 3),
        "completed": res.completed,
    }


def interference_makespan(strategy: str, kind: str, k: int = 64,
                          fabric: str = "shared", with_bg: bool = True,
                          warmup: int = 400, seed: int = 0,
                          horizon: int = 80000) -> dict:
    wl = interference_workload(strategy, kind, k=k, fabric=fabric,
                               with_bg=with_bg, warmup=warmup, seed=seed)
    res = get_engine(PAPER_TOPO, mode=resolve_routing(),
                     num_pools=wl.num_pools).run(
        wl, seed=seed, horizon=horizon)
    return {
        "strategy": strategy, "kernel": kind, "k": k, "fabric": fabric,
        "bg": with_bg,
        "makespan": res.makespan if res.completed else -1,
        "completed": res.completed,
    }
