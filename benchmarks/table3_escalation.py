"""Paper Fig. 9 / Table 3: kernel escalation, normalized to Diagonal
(values > 1 mean faster than Diagonal, as in the paper; the paper uses
Omni-WAR — the suite default — and ``--routing`` swaps the policy).

Each (kernel, load) strategy grid is built as workloads first and executed
through ``sweep`` — one vmapped device call per shape bucket instead of the
seed's serial per-scenario loop."""

from benchmarks.common import (
    STRATEGIES,
    emit,
    escalation_workload,
    summarize,
    sweep,
)

KERNELS = ["all_to_all", "all_reduce", "stencil_von_neumann",
           "stencil_moore", "random_involution"]


def run(quick=False):
    kernels = KERNELS[:3] if quick else KERNELS
    loads = [4, 8] if quick else [1, 4, 8]  # 50% and 100% occupancy
    raw = []
    for kind in kernels:
        for r in loads:
            wls = [escalation_workload(s, kind, r) for s in STRATEGIES]
            per_wl = sweep(wls, horizon=60000)
            for strat, per_seed in zip(STRATEGIES, per_wl):
                row = {"strategy": strat, "kernel": kind, "replicas": r,
                       "k": 64}
                row.update(summarize(per_seed))
                raw.append(row)
    emit(raw, "fig9_kernel_escalation_raw (paper Fig. 9)")
    # normalized table (mean over kernels, per load)
    rows = []
    for r in loads:
        sums = {s: [] for s in STRATEGIES}
        for kind in kernels:
            base = next(x["makespan"] for x in raw
                        if x["strategy"] == "diagonal"
                        and x["kernel"] == kind and x["replicas"] == r)
            for s in STRATEGIES:
                m = next(x["makespan"] for x in raw
                         if x["strategy"] == s and x["kernel"] == kind
                         and x["replicas"] == r)
                sums[s].append(base / max(m, 1))
        row = {"replicas": r, "occupancy": f"{r*64*100//512}%"}
        row.update({s: round(sum(v) / len(v), 3) for s, v in sums.items()})
        rows.append(row)
    emit(rows, "table3_normalized_to_diagonal (paper Table 3)")
    return rows


if __name__ == "__main__":
    run()
