"""Paper Figs. 11-12: interference with the background on its own VC set
(fabric partitioning) vs shared VCs.

Shared-VC and partitioned-VC grids need different engines (the pool count
is compile-time structure), so ``sweep`` groups them automatically: one
batched device call per (pool-count, bucket) group per kernel."""

from benchmarks.common import (
    STRATEGIES,
    emit,
    interference_workload,
    summarize,
    sweep,
)

KERNELS = ["all_to_all", "stencil_von_neumann", "random_involution"]


def run(quick=False):
    kernels = KERNELS[:2] if quick else KERNELS
    rows = []
    for kind in kernels:
        shared_wls = [interference_workload(s, kind, fabric="shared")
                      for s in STRATEGIES]
        sep_wls = [interference_workload(s, kind, fabric="background")
                   for s in STRATEGIES]
        per_wl = sweep(shared_wls + sep_wls, horizon=80000)
        shared_res = per_wl[:len(STRATEGIES)]
        sep_res = per_wl[len(STRATEGIES):]
        for strat, shared, sep in zip(STRATEGIES, shared_res, sep_res):
            shared_m = summarize(shared)["makespan"]
            sep_m = summarize(sep)["makespan"]
            rows.append({
                "kernel": kind, "strategy": strat,
                "makespan_shared_vcs": shared_m,
                "makespan_bg_own_vcs": sep_m,
                "vc_isolation_gain": round(shared_m / max(sep_m, 1), 3),
            })
    emit(rows, "fig11_fabric_partitioning (paper Figs. 11-12)")
    return rows


if __name__ == "__main__":
    run()
