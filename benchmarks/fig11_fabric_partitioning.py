"""Paper Figs. 11-12: interference with the background on its own VC set
(fabric partitioning) vs shared VCs."""

from benchmarks.common import STRATEGIES, emit, interference_makespan

KERNELS = ["all_to_all", "stencil_von_neumann", "random_involution"]


def run(quick=False):
    kernels = KERNELS[:2] if quick else KERNELS
    rows = []
    for kind in kernels:
        for strat in STRATEGIES:
            shared = interference_makespan(strat, kind, fabric="shared")
            sep = interference_makespan(strat, kind, fabric="background")
            rows.append({
                "kernel": kind, "strategy": strat,
                "makespan_shared_vcs": shared["makespan"],
                "makespan_bg_own_vcs": sep["makespan"],
                "vc_isolation_gain": round(
                    shared["makespan"] / max(sep["makespan"], 1), 3),
            })
    emit(rows, "fig11_fabric_partitioning (paper Figs. 11-12)")
    return rows


if __name__ == "__main__":
    run()
