"""Framework roofline: (a) the dry-run matrix table from results/dryrun.jsonl,
(b) allocation-aware collective pricing per strategy (the paper's technique
applied to the mesh collectives)."""

import json
import os

from benchmarks.common import STRATEGIES, emit


def run(quick=False, path="results/dryrun.jsonl"):
    rows = []
    if os.path.exists(path):
        best = {}
        with open(path) as f:
            for line in f:
                try:
                    r = json.loads(line)
                except json.JSONDecodeError:
                    continue
                key = (r.get("arch"), r.get("shape"), r.get("mesh"))
                best[key] = r  # last occurrence wins (re-runs)
        for r in best.values():
            if r.get("status") == "ok":
                rows.append({
                    "arch": r["arch"], "shape": r["shape"], "mesh": r["mesh"],
                    "bottleneck": r["bottleneck"],
                    "compute_s": round(r["compute_s"], 4),
                    "memory_s": round(r["memory_s"], 4),
                    "collective_s": round(r["collective_s"], 4),
                    "useful_ratio": round(r["useful_ratio"], 4),
                    "roofline_fraction": round(r["roofline_fraction"], 4),
                })
            elif r.get("status") == "skip":
                rows.append({
                    "arch": r["arch"], "shape": r["shape"], "mesh": r["mesh"],
                    "bottleneck": "SKIP", "compute_s": "", "memory_s": "",
                    "collective_s": "", "useful_ratio": "",
                    "roofline_fraction": r.get("reason", "")[:40],
                })
        rows.sort(key=lambda r: (r["arch"], r["shape"], r["mesh"]))
    emit(rows, "roofline_matrix (from launch/dryrun.py)")

    # allocation-aware collective pricing: one training step's collective
    # schedule (DP grad all-reduce + TP all-gathers) priced per strategy
    from repro.fabric.collective_model import rank_strategies_for_schedule

    schedule = [
        ("all_reduce", "data", 64e6),    # grad shard reduction
        ("all_gather", "model", 8e6),    # TP activation gathers
        ("all_to_all", "model", 16e6),   # MoE expert dispatch
    ]
    priced = rank_strategies_for_schedule((16, 16), ("data", "model"),
                                          schedule)
    prows = [{
        "strategy": p["strategy"],
        "total_ms": round(p["total_s"] * 1e3, 3),
        "bandwidth_ms": round(p["bandwidth_s"] * 1e3, 3),
        "latency_ms": round(p["latency_s"] * 1e3, 3),
    } for p in priced]
    emit(prows, "allocation_aware_collective_pricing (Lesson 2 -> mesh)")
    return rows


if __name__ == "__main__":
    run()
