"""Routing x strategy x fault-rate grid (the DESIGN.md §Routing sweep).

Every registered routing policy runs the same allocation-strategy grid on
the same progressively-degraded machine: per fault rate one seeded set of
dead cables (identical across policies and strategies, so deltas are pure
routing/placement effects).  Within one policy the whole
strategy x fault x seed grid batches through ``sweep`` — fault masks are
per-workload device data, so every fault scenario shares the healthy
grid's shape bucket and the policy pays one compilation total.

The ``max_hops`` column doubles as a live deadlock-freedom check: it must
stay below the policy's declared VC budget (``vc_budget`` in
``repro.route``), faults included.
"""

from benchmarks.common import (
    PAPER_TOPO,
    STRATEGIES,
    emit,
    interference_workload,
    resolve_quick,
    summarize,
    sweep,
)

from repro.route import (
    apply_faults,
    available_policies,
    get_policy,
    is_connected,
    random_link_faults,
)

FAULT_RATES = (0.0, 0.01, 0.02)   # ~0 / 4 / 9 dead cables on the paper machine
FAULT_SEED = 77


def run(quick=None):
    quick = resolve_quick(quick)
    strategies = ("row", "diagonal") if quick else STRATEGIES
    rates = (FAULT_RATES[0], FAULT_RATES[2]) if quick else FAULT_RATES
    kind = "all_to_all"
    # the vmapped while-loop runs lanes in lockstep, so one strangled lane
    # (a packet out of budget at a dead link never delivers) bills the
    # whole bucket its horizon — keep it tight; incomplete lanes report
    # completed=False / makespan -1.  Rates beyond ~2% strand the
    # budget-bounded minimal-phase policies routinely (the failure mode
    # 2404.04315 provisions extra VCs for); they are deliberately out of
    # this grid's range.
    horizon = 6_000 if quick else 8_000

    masks = {}
    for rate in rates:
        if rate == 0.0:
            masks[rate] = None
            continue
        mask = random_link_faults(PAPER_TOPO, rate, seed=FAULT_SEED)
        assert is_connected(PAPER_TOPO, mask), "fault draw disconnected machine"
        masks[rate] = mask

    base = {s: interference_workload(s, kind, with_bg=False)
            for s in strategies}
    rows = []
    for mode in available_policies():
        wls, grid = [], []   # (strategy, rate) in workload order
        for strat in strategies:
            for rate in rates:
                wl = base[strat]
                if masks[rate] is not None:
                    wl = apply_faults(wl, masks[rate])
                wls.append(wl)
                grid.append((strat, rate))
        per_wl = sweep(wls, mode=mode, horizon=horizon)
        policy = get_policy(mode)
        budget = policy.vc_budget(
            PAPER_TOPO.q, policy.default_deroutes(PAPER_TOPO.q)
        )
        for (strat, rate), per_seed in zip(grid, per_wl):
            s = summarize(per_seed)
            hop_peak = max(r.max_hops for r in per_seed)
            rows.append({
                "routing": mode, "strategy": strat, "fault_rate": rate,
                "makespan": s["makespan"],
                "avg_latency": s["avg_latency"],
                "avg_hops": s["avg_hops"],
                "max_hops": hop_peak,
                "vc_budget": budget,
                "completed": s["completed"],
            })
            assert hop_peak < budget, (
                f"{mode}/{strat}@{rate}: observed {hop_peak} hops "
                f">= VC budget {budget}"
            )
    emit(rows, "routing_grid (routing x strategy x fault-rate)")
    return rows


if __name__ == "__main__":
    run()
