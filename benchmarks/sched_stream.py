"""Online job-stream scheduling on the paper machine (Section 7 "lessons
learned", operationalized).

Scenarios (all seven allocation strategies see the SAME deterministic
stream, so per-strategy deltas are placement effects, not arrival noise):

  * ``poisson``    — Poisson arrivals, exponential service, ~85% offered
    load: queueing + fragmentation under light-tailed churn;
  * ``heavy_tail`` — bounded-Pareto service times (full mode only);
  * ``churn``      — the poisson stream plus endpoint failures/repairs:
    the SAME physical failures knock out different block slots under
    different strategies, so utilization/wait/migrations finally diverge
    per strategy (on a healthy machine slot dynamics are strategy-blind
    and only the realized-PB/locality columns differ).

Interference: co-resident snapshots from the poisson run are lowered to
machine workloads and the whole strategy x snapshot x seed grid executes
through ``SimEngine.run_batch_seeds`` — one compile + one device call per
shape bucket (the compile-stats table reports the counters).
"""

from __future__ import annotations

import numpy as np

from benchmarks import common
from benchmarks.common import PAPER_TOPO, STRATEGIES, emit, resolve_quick

from repro.core.engine.workload_tables import shape_bucket
from repro.sched import (
    FailureEvent,
    OnlineScheduler,
    evaluate_snapshots,
    evaluate_snapshots_by_routing,
    heavy_tailed_stream,
    poisson_stream,
    snapshot_workload,
)
from repro.sched.bridge import pick_snapshots

NUM_JOBS = 240  # 200+ job stream (the acceptance scenario) even in quick


def _snap_bucket(topo, snap):
    """Shape bucket a snapshot's workload lands in, from the real lowering
    (cheap numpy — no device tables are built)."""
    wl = snapshot_workload(topo, snap)
    return shape_bucket(wl.R, wl.T, wl.maxd)


def _select_snapshots(topo, per_strategy: dict, per_strat_count: int,
                      quick: bool):
    """Sample snapshots per strategy; in quick mode restrict to the most
    common shape bucket so CI pays for at most one compilation."""
    if quick:
        eligible = {k: [s for s in snaps if s.num_jobs >= 2]
                    for k, snaps in per_strategy.items()}
        buckets = {k: [_snap_bucket(topo, s) for s in snaps]
                   for k, snaps in eligible.items()}
        counts: dict = {}
        for bs in buckets.values():
            for b in bs:
                counts[b] = counts.get(b, 0) + 1
        if not counts:
            return {k: [] for k in per_strategy}
        target = max(counts, key=counts.get)
        per_strategy = {
            k: [s for s, b in zip(eligible[k], buckets[k]) if b == target]
            for k in per_strategy
        }
    return {
        k: pick_snapshots(snaps, per_strat_count)
        for k, snaps in per_strategy.items()
    }


def run(quick=None):
    quick = resolve_quick(quick)
    topo = PAPER_TOPO
    # offered load ~ rate * mean_service * E[blocks] / n  ~ 0.85
    jobs = poisson_stream(NUM_JOBS, rate=0.45, mean_service=8.0, seed=11)
    streams = {"poisson": (jobs, ())}
    if not quick:
        streams["heavy_tail"] = (
            heavy_tailed_stream(NUM_JOBS, rate=0.45, service_scale=3.0, seed=12),
            (),
        )
    # churn: endpoint failures mid-stream; repair returns half of them.
    # The same physical endpoints hit different block slots per strategy.
    rng = np.random.default_rng(5)
    dead = rng.choice(topo.num_endpoints, size=6, replace=False)
    span_est = NUM_JOBS / 0.45
    streams["churn"] = (jobs, (
        FailureEvent(time=0.25 * span_est, endpoints=tuple(int(e) for e in dead[:4]),
                     repair_at=0.55 * span_est),
        FailureEvent(time=0.40 * span_est, endpoints=tuple(int(e) for e in dead[4:])),
    ))

    rows = []
    poisson_snaps = {}
    churn_snaps = {}
    for scen, (stream, failures) in streams.items():
        for strat in STRATEGIES:
            sched = OnlineScheduler(topo, strategy=strat, policy="first_fit")
            res = sched.run_stream(stream, failures=failures)
            rows.append({"scenario": scen, **res.summary()})
            if scen == "poisson":
                poisson_snaps[strat] = res.snapshots
            elif scen == "churn":
                churn_snaps[strat] = res.snapshots
    emit(rows, "sched_stream_summary (online scheduling, 7 strategies)")

    # scheduling-policy ablation: placement policy x backfilling (the
    # strategy is fixed; these knobs are the scheduler's own)
    ablation = []
    for policy in ("first_fit", "best_fit"):
        for backfill in ((True,) if quick else (True, False)):
            res = OnlineScheduler(
                topo, strategy="diagonal", policy=policy, backfill=backfill,
            ).run_stream(jobs)
            s = res.summary()
            ablation.append({
                "policy": policy, "backfill": backfill,
                "utilization": s["utilization"], "mean_wait": s["mean_wait"],
                "p95_wait": s["p95_wait"], "frag_mean": s["frag_mean"],
                "scattered_frac": s["scattered_frac"],
            })
    emit(ablation, "sched_policy_ablation (diagonal)")

    # interference: strategy x snapshot x seed through the batched engine
    selected = _select_snapshots(topo, poisson_snaps, 2 if quick else 6, quick)
    seeds = list(range(common.NUM_SEEDS))
    snap_rows, stats = evaluate_snapshots(
        topo, selected, seeds=seeds, horizon=30_000 if quick else 60_000,
        mode=common.ROUTING,
    )
    emit(snap_rows, "sched_snapshots_interference (co-resident jobs, batched)")
    if stats["engine"] is not None:
        buckets = sorted({r["bucket"] for r in snap_rows})
        emit([{
            "workloads": len(snap_rows) // max(len(seeds), 1),
            "seeds": len(seeds),
            "shape_buckets": len(buckets),
            "traces": stats["traces"],
            "device_calls": stats["device_calls"],
        }], "sched_compile_stats (one compile + call per bucket)")

    # routing x churn-fault grid: snapshots taken while endpoints were
    # failed lower to link-fault masks (failure domains are co-packaged);
    # each routing policy then runs the SAME degraded machine.  Quick mode
    # keeps two policies / two strategies so CI pays for ~one extra
    # compile (the omniwar engine + bucket is shared with the table above).
    faulty = {
        k: [s for s in snaps if s.failed_endpoints]
        for k, snaps in churn_snaps.items()
    }
    if quick:
        faulty = {k: faulty.get(k, []) for k in ("diagonal", "rectangular")}
    modes = ("omniwar", "ugal") if quick else ("min", "omniwar", "val", "ugal")
    selected_f = _select_snapshots(topo, faulty, 1 if quick else 3, quick)
    churn_rows, stats_by_mode = evaluate_snapshots_by_routing(
        topo, selected_f, modes=modes, seeds=seeds,
        horizon=30_000 if quick else 60_000, churn_faults=True,
    )
    emit(churn_rows, "sched_routing_churn (routing x strategy x churn faults)")
    emit([
        {"routing": m, "traces": st["traces"],
         "device_calls": st["device_calls"]}
        for m, st in stats_by_mode.items() if st["engine"] is not None
    ], "sched_routing_compile_stats (one compile set per policy)")
    return rows


if __name__ == "__main__":
    run()
