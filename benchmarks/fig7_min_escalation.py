"""Paper Figure 7: escalation under MIN routing, uniform + random
permutation, 1..8 replicas of 64-rank apps.  Each (pattern, load) strategy
grid runs as one batched ``sweep`` dispatch."""

from benchmarks.common import (
    STRATEGIES,
    emit,
    escalation_workload,
    summarize,
    sweep,
)


def run(quick=False):
    loads = [1, 4, 8] if quick else [1, 2, 4, 6, 8]
    rows = []
    for kind in ("uniform", "random_permutation"):
        for r in loads:
            wls = [escalation_workload(s, kind, r) for s in STRATEGIES]
            per_wl = sweep(wls, mode="min", horizon=60000)
            for strat, per_seed in zip(STRATEGIES, per_wl):
                row = {"strategy": strat, "kernel": kind, "replicas": r,
                       "k": 64}
                row.update(summarize(per_seed))
                rows.append(row)
    emit(rows, "fig7_min_escalation (paper Fig. 7)")
    return rows


if __name__ == "__main__":
    run()
