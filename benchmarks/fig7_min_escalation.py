"""Paper Figure 7: escalation under MIN routing, uniform + random
permutation, 1..8 replicas of 64-rank apps."""

from benchmarks.common import STRATEGIES, emit, escalation_makespan


def run(quick=False):
    loads = [1, 4, 8] if quick else [1, 2, 4, 6, 8]
    rows = []
    for kind in ("uniform", "random_permutation"):
        for strat in STRATEGIES:
            for r in loads:
                rows.append(escalation_makespan(strat, kind, r, mode="min"))
    emit(rows, "fig7_min_escalation (paper Fig. 7)")
    return rows


if __name__ == "__main__":
    run()
