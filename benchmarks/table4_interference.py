"""Paper Fig. 10 / Table 4: per-kernel interference (random-permutation
background), slowdown relative to Diagonal."""

from benchmarks.common import STRATEGIES, emit, interference_makespan

KERNELS = ["all_to_all", "all_reduce", "stencil_von_neumann",
           "stencil_moore", "random_involution"]


def run(quick=False):
    kernels = KERNELS[:3] if quick else KERNELS
    raw = []
    for kind in kernels:
        for strat in STRATEGIES:
            iso = interference_makespan(strat, kind, with_bg=False)
            bg = interference_makespan(strat, kind, with_bg=True)
            raw.append({
                "kernel": kind, "strategy": strat,
                "iso": iso["makespan"], "bg": bg["makespan"],
                "extra": bg["makespan"] - iso["makespan"],
            })
    emit(raw, "fig10_kernel_interference_raw (paper Fig. 10)")
    rows = []
    sums = {s: [] for s in STRATEGIES}
    for kind in kernels:
        base = next(x["bg"] for x in raw
                    if x["strategy"] == "diagonal" and x["kernel"] == kind)
        for s in STRATEGIES:
            m = next(x["bg"] for x in raw
                     if x["strategy"] == s and x["kernel"] == kind)
            sums[s].append(base / max(m, 1))
    rows.append({s: round(sum(v) / len(v), 3) for s, v in sums.items()})
    emit(rows, "table4_interference_normalized (paper Table 4)")
    return rows


if __name__ == "__main__":
    run()
