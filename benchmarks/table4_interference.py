"""Paper Fig. 10 / Table 4: per-kernel interference (random-permutation
background), slowdown relative to Diagonal.

Per kernel, the full strategy grid (isolated + with-background workloads)
goes through one ``sweep`` call: the background grid shares one shape
bucket, so it executes as a single vmapped ``run_batch`` device call."""

from benchmarks.common import (
    STRATEGIES,
    emit,
    interference_workload,
    summarize,
    sweep,
)

KERNELS = ["all_to_all", "all_reduce", "stencil_von_neumann",
           "stencil_moore", "random_involution"]


def run(quick=False):
    kernels = KERNELS[:3] if quick else KERNELS
    raw = []
    for kind in kernels:
        iso_wls = [interference_workload(s, kind, with_bg=False)
                   for s in STRATEGIES]
        bg_wls = [interference_workload(s, kind, with_bg=True)
                  for s in STRATEGIES]
        per_wl = sweep(iso_wls + bg_wls, horizon=80000)
        iso_res, bg_res = per_wl[:len(STRATEGIES)], per_wl[len(STRATEGIES):]
        for strat, iso, bg in zip(STRATEGIES, iso_res, bg_res):
            iso_m = summarize(iso)["makespan"]
            bg_m = summarize(bg)["makespan"]
            raw.append({
                "kernel": kind, "strategy": strat,
                "iso": iso_m, "bg": bg_m,
                "extra": round(bg_m - iso_m, 1),
            })
    emit(raw, "fig10_kernel_interference_raw (paper Fig. 10)")
    rows = []
    sums = {s: [] for s in STRATEGIES}
    for kind in kernels:
        base = next(x["bg"] for x in raw
                    if x["strategy"] == "diagonal" and x["kernel"] == kind)
        for s in STRATEGIES:
            m = next(x["bg"] for x in raw
                     if x["strategy"] == s and x["kernel"] == kind)
            sums[s].append(base / max(m, 1))
    rows.append({s: round(sum(v) / len(v), 3) for s, v in sums.items()})
    emit(rows, "table4_interference_normalized (paper Table 4)")
    return rows


if __name__ == "__main__":
    run()
