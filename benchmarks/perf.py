"""Perf trajectory harness — times the engine and gates regressions.

    PYTHONPATH=src python -m benchmarks.perf [--quick|--full]
                                             [--out PATH] [--rev REV]
                                             [--compare [BASE.json]]
                                             [--threshold 0.10]
                                             [--grids a,b,...]
                                             [--kernel lax|pallas]
                                             [--chunk K] [--canon]
                                             [--cache DIR] [--profile DIR]

Runs the canonical grids (strategy / pattern / fault sweeps on the paper
machine) through a **fresh** ``SimEngine`` each — so compile time is
honestly attributed — and records, per grid:

  * ``compile_s``     — first-call wall time minus steady-state run time;
  * ``device_s``      — steady-state wall time of one full grid dispatch;
  * ``cycles``        — simulated flit-cycles summed over all lanes
    (post-warmup; horizon-clamped for incomplete lanes — deterministic,
    since simulation results are regression-pinned bitwise);
  * ``cycles_per_s``  — cycles / device_s, the headline throughput;
  * ``lanes``, ``lanes_per_s``, ``buckets``, ``traces``.

The snapshot lands in ``BENCH_<rev>.json`` at the repo root (``--out``
overrides) together with host metadata (backend, device count, lane
dispatch backend, jax version) and a full ``manifest`` provenance block
(:func:`repro.obs.trace.manifest_dict` — the same schema trace
directories carry, so BENCH files and traces join on ``config_hash``) —
the persistent perf trajectory ROADMAP calls for.  Every run also
*appends* one line to ``BENCH_history.jsonl`` at the repo root (rev,
UTC date, engine knobs, per-grid metrics) — the cumulative trajectory.

``--compare BASE.json`` re-measures and exits nonzero when any grid's
``device_s`` regresses more than ``--threshold`` (default 10%) against
the baseline; a bare ``--compare`` (no path) gates against the *latest
prior entry* of ``BENCH_history.jsonl`` instead.  This is the CI perf
gate (``BENCH_baseline.json`` is regenerated on the CI machine itself;
refresh the committed copy with ``--baseline`` when a speedup lands).
Exit codes: 2 = regression past the gate; 3 = the baseline file (or
history) is missing or corrupt (validated *before* any measurement).

Engine knobs under measurement: ``--arb`` / ``--kernel`` (Pallas
arbitration / fused route+arbitrate megakernel), ``--chunk K``
(early-exit granularity of the cycle loop), ``--canon`` (pow2 batch-axis
canonicalization; its compile-key hit rate lands in the snapshot), and
``--cache DIR`` (persistent XLA compile cache — repeat-process wall time
is the metric it moves; also reachable via ``REPRO_COMPILE_CACHE``).
``--profile DIR`` runs one extra, horizon-clamped dispatch per grid
under a ``jax.profiler`` trace inside an obs trace dir (timing itself is
never profiled), so ``repro.obs.report`` renders per-grid device
timelines next to the usual span tables.
"""

from __future__ import annotations

import argparse
import json
import os
import subprocess
import sys
import time

import jax

from benchmarks.common import (
    PAPER_TOPO,
    STRATEGIES,
    escalation_workload,
    interference_workload,
    write_grid_csv,
)

from repro.core.engine import PACKET_FLITS, SimEngine, enable_persistent_cache
from repro.obs import trace as obs_trace
from repro.obs.trace import manifest_dict
from repro.route import apply_faults, random_link_faults

REPO_ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
HISTORY_PATH = os.path.join(REPO_ROOT, "BENCH_history.jsonl")
SCHEMA = 1
# Horizon clamp for the extra profiled dispatch (see measure_grid).  The
# tracer records every HLO-op execution, and one engine cycle is a very
# large graph (~20 s and ~50 MB of xplane PER CYCLE on CPU), so the
# profiled dispatch samples just two cycles — enough for the op-level
# breakdown; headline timings never come from the profiled run.
PROFILE_HORIZON = 2
DEFAULT_THRESHOLD = 0.10
EXIT_REGRESSION = 2
EXIT_BAD_BASELINE = 3


# ------------------------------------------------------------ canonical grids
def _grid_escalation(quick: bool):
    strategies = ("row", "diagonal", "full_spread") if quick else STRATEGIES
    wls = [escalation_workload(s, "all_to_all", replicas=1)
           for s in strategies]
    return wls, (0,), "omniwar", 30_000


def _grid_traffic(quick: bool):
    patterns = ("tornado", "transpose") if quick else (
        "tornado", "transpose", "shuffle", "stencil_3d")
    strategies = ("row", "diagonal") if quick else (
        "row", "diagonal", "full_spread", "rectangular")
    wls = [interference_workload(s, p, with_bg=False)
           for p in patterns for s in strategies]
    return wls, (0,), "omniwar", 30_000


def _grid_routing_faults(quick: bool):
    strategies = ("row", "diagonal") if quick else (
        "row", "diagonal", "full_spread", "rectangular")
    mask = random_link_faults(PAPER_TOPO, 0.02, seed=77)
    wls = []
    for s in strategies:
        wl = interference_workload(s, "all_to_all", with_bg=False)
        wls.append(wl)
        wls.append(apply_faults(wl, mask))
    seeds = (0,) if quick else (0, 1)
    return wls, seeds, "omniwar", 6_000


GRIDS = {
    "escalation_a2a": _grid_escalation,
    "traffic_adversarial": _grid_traffic,
    "routing_faults": _grid_routing_faults,
}


# ----------------------------------------------------------------- measuring
def measure_grid(workloads, seeds, mode, horizon,
                 topo=PAPER_TOPO, arb: str = "lax", kernel: str = "lax",
                 chunk: int = 1, canon: bool = False,
                 profile_dir: str | None = None) -> dict:
    """Time one grid through a fresh engine: compile vs steady-state.

    The engine is constructed directly (bypassing the ``get_engine``
    memo) so the first ``run_grid`` call pays — and therefore measures —
    the real compilation cost; an identical second call measures the
    steady-state device time.  ``_to_result`` materialises every output
    on the host, so the wall clock brackets full device execution.
    ``wall_first_s`` / ``wall_repeat_s`` record the two raw calls — the
    pair the persistent compile cache moves (a cache-warm process pays
    steady-state on its *first* call).  ``profile_dir`` runs one EXTRA
    dispatch after timing under a ``jax.profiler`` trace, with the
    horizon clamped to ``PROFILE_HORIZON`` cycles: the tracer emits an
    event per HLO-op execution, so profiling a full-horizon dispatch
    balloons to hours and GBs.  The clamped dispatch has the same
    per-cycle op profile; timing is never taken under the profiler.
    """
    num_pools = {w.num_pools for w in workloads}
    if len(num_pools) != 1:
        raise ValueError(f"grid mixes VC pool counts {sorted(num_pools)}")
    engine = SimEngine(topo, mode=mode, num_pools=num_pools.pop(), arb=arb,
                       kernel=kernel, chunk=chunk, canon=canon)
    preps = [engine.prepare(w) for w in workloads]
    buckets = {p.tables.shape_bucket for p in preps}

    t0 = time.perf_counter()
    results = engine.run_grid(preps, seeds=seeds, horizon=horizon)
    t1 = time.perf_counter()
    engine.run_grid(preps, seeds=seeds, horizon=horizon)
    t2 = time.perf_counter()

    if profile_dir:
        os.makedirs(profile_dir, exist_ok=True)
        with jax.profiler.trace(profile_dir):
            engine.run_grid(preps, seeds=seeds,
                            horizon=min(horizon, PROFILE_HORIZON))

    device_s = t2 - t1
    compile_s = max((t1 - t0) - device_s, 0.0)
    lanes = len(workloads) * len(seeds)
    cycles = sum(
        (r.makespan if r.completed else horizon) * PACKET_FLITS
        for per_seed in results for r in per_seed
    )
    stats = engine.bucket_stats()
    return {
        "lanes": lanes,
        "buckets": len(buckets),
        "traces": engine.trace_count,
        "lane_backend": engine.lane_backend,
        "compile_s": round(compile_s, 3),
        "device_s": round(device_s, 3),
        "wall_first_s": round(t1 - t0, 3),
        "wall_repeat_s": round(t2 - t1, 3),
        "cycles": int(cycles),
        "cycles_per_s": round(cycles / max(device_s, 1e-9), 1),
        "lanes_per_s": round(lanes / max(device_s, 1e-9), 2),
        "bucket_hits": stats["hits"],
        "bucket_misses": stats["misses"],
        "bucket_hit_rate": round(stats["hit_rate"], 3),
    }


def current_rev() -> str:
    rev = os.environ.get("BENCH_REV")
    if rev:
        return rev
    try:
        return subprocess.run(
            ["git", "rev-parse", "--short", "HEAD"], cwd=REPO_ROOT,
            capture_output=True, text=True, check=True,
        ).stdout.strip()
    except Exception:
        return "dev"


def run_suite(quick: bool = True, grids=None, arb: str = "lax",
              kernel: str = "lax", chunk: int = 1, canon: bool = False,
              profile: str | None = None) -> dict:
    """Measure every requested grid; returns the BENCH json payload.

    ``profile`` is an obs trace directory: each grid is measured inside a
    ``perf.grid`` span with its ``jax.profiler`` trace under
    ``<profile>/xprof/<grid>/``, and a ``perf.grid_metrics`` event carries
    the headline numbers so :mod:`repro.obs.report` can render the
    device-timeline table without re-running anything.
    """
    names = list(GRIDS) if not grids else [g for g in GRIDS if g in grids]
    knobs = {"arb": arb, "kernel": kernel, "chunk": chunk, "canon": canon}
    bench = {
        "schema": SCHEMA,
        "rev": current_rev(),
        "quick": quick,
        "backend": jax.default_backend(),
        "devices": jax.local_device_count(),
        "jax": jax.__version__,
        **knobs,
        # full provenance block — same shape as a trace dir's manifest.json,
        # so BENCH snapshots and traces join on config_hash
        "manifest": manifest_dict(rev=current_rev(), quick=quick, **knobs),
        "grids": {},
    }
    for name in names:
        wls, seeds, mode, horizon = GRIDS[name](quick)
        pdir = os.path.join(profile, "xprof", name) if profile else None
        print(f"# measuring {name} ({len(wls)} workloads x "
              f"{len(seeds)} seeds)...", file=sys.stderr)
        with obs_trace.span("perf.grid", grid=name, **knobs):
            m = measure_grid(wls, seeds, mode, horizon, arb=arb,
                             kernel=kernel, chunk=chunk, canon=canon,
                             profile_dir=pdir)
        if profile:
            obs_trace.event(
                "perf.grid_metrics", grid=name, xprof=pdir or "",
                **{k: m[k] for k in ("lanes", "compile_s", "device_s",
                                     "wall_first_s", "wall_repeat_s",
                                     "cycles_per_s", "bucket_hit_rate")},
            )
        bench["grids"][name] = m
    return bench


# -------------------------------------------------------------------- history
def append_history(bench: dict, path: str | None = None) -> dict:
    """Append one run to the cumulative ``BENCH_history.jsonl`` trajectory.

    One JSON object per line: rev, UTC date, engine knobs, and the
    per-grid metric table (sans host manifest — the BENCH_<rev>.json
    snapshot keeps full provenance).  Returns the appended entry.
    """
    path = path or HISTORY_PATH
    entry = {
        k: bench[k]
        for k in ("schema", "rev", "quick", "backend", "devices", "jax",
                  "arb", "kernel", "chunk", "canon")
        if k in bench
    }
    entry["date"] = time.strftime("%Y-%m-%dT%H:%M:%SZ", time.gmtime())
    entry["grids"] = bench["grids"]
    with open(path, "a") as f:
        f.write(json.dumps(entry, sort_keys=True) + "\n")
    return entry


def latest_history(path: str | None = None,
                   quick: bool | None = None) -> dict | None:
    """The most recent prior history entry (optionally matching ``quick``).

    Unparsable lines are skipped, matching the report loader's contract:
    a truncated final line from a killed run must not poison the gate.
    """
    path = path or HISTORY_PATH
    if not os.path.exists(path):
        return None
    last = None
    with open(path) as f:
        for line in f:
            line = line.strip()
            if not line:
                continue
            try:
                entry = json.loads(line)
            except json.JSONDecodeError:
                continue
            if not isinstance(entry, dict) or not isinstance(
                    entry.get("grids"), dict):
                continue
            if quick is not None and entry.get("quick") != quick:
                continue
            last = entry
    return last


# ------------------------------------------------------------------ comparing
def compare_benchmarks(new: dict, base: dict,
                       threshold: float = DEFAULT_THRESHOLD) -> list[dict]:
    """Per-grid device-time comparison; returns rows with a 'regressed' flag.

    A grid regresses when its steady-state ``device_s`` exceeds the
    baseline's by more than ``threshold`` (compile time is reported but
    not gated — it is far noisier and dominated by XLA version churn).
    Grids present on only one side are reported but never gate.
    """
    rows = []
    for name in sorted(set(new.get("grids", {})) | set(base.get("grids", {}))):
        g_new = new.get("grids", {}).get(name)
        g_base = base.get("grids", {}).get(name)
        if g_new is None or g_base is None:
            rows.append({
                "grid": name, "base_device_s": g_base and g_base["device_s"],
                "new_device_s": g_new and g_new["device_s"],
                "ratio": "", "regressed": False,
                "note": "missing on one side",
            })
            continue
        ratio = g_new["device_s"] / max(g_base["device_s"], 1e-9)
        rows.append({
            "grid": name,
            "base_device_s": g_base["device_s"],
            "new_device_s": g_new["device_s"],
            "ratio": round(ratio, 3),
            "regressed": ratio > 1.0 + threshold,
            "note": "",
        })
    return rows


def main(argv=None) -> int:
    p = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    p.add_argument("--quick", action="store_true",
                   help="CI-sized grids (the default; --full overrides)")
    p.add_argument("--full", action="store_true")
    p.add_argument("--out", default=None, metavar="PATH",
                   help="output json (default: <repo>/BENCH_<rev>.json)")
    p.add_argument("--rev", default=None,
                   help="revision label (default: git short sha)")
    p.add_argument("--compare", nargs="?", const="history", default=None,
                   metavar="BASE",
                   help="baseline BENCH json; exit nonzero on regression "
                        "(bare --compare gates against the latest prior "
                        "BENCH_history.jsonl entry)")
    p.add_argument("--threshold", type=float, default=DEFAULT_THRESHOLD,
                   help="regression gate on device_s (default 0.10 = 10%%)")
    p.add_argument("--grids", default=None,
                   help=f"comma list from {sorted(GRIDS)}")
    p.add_argument("--arb", default="lax", choices=("lax", "pallas"),
                   help="arbitration backend to measure")
    p.add_argument("--kernel", default="lax", choices=("lax", "pallas"),
                   help="route+arbitrate block: lax reference or the fused "
                        "Pallas megakernel")
    p.add_argument("--chunk", type=int, default=1, metavar="K",
                   help="cycle-loop early-exit granularity (all_done "
                        "checked every K cycles; K=1 = reference)")
    p.add_argument("--canon", action="store_true",
                   help="pow2-canonicalize batch-axis lengths (compile "
                        "sharing across nearby grid sizes)")
    p.add_argument("--cache", default=None, metavar="DIR",
                   help="persistent XLA compile cache directory (also: "
                        "REPRO_COMPILE_CACHE env)")
    p.add_argument("--profile", default=None, metavar="DIR",
                   help="obs trace dir: wrap each grid in a jax.profiler "
                        "trace (<DIR>/xprof/<grid>/) + span/metric events "
                        "for repro.obs.report")
    p.add_argument("--history", default=None, metavar="PATH",
                   help="history jsonl to append/compare "
                        "(default <repo>/BENCH_history.jsonl)")
    p.add_argument("--baseline", action="store_true",
                   help="also refresh <repo>/BENCH_baseline.json")
    args = p.parse_args(argv)
    if args.quick and args.full:
        p.error("--quick and --full are mutually exclusive")
    if args.chunk < 1:
        p.error("--chunk must be >= 1")
    if args.rev:
        os.environ["BENCH_REV"] = args.rev
    grids = args.grids.split(",") if args.grids else None
    unknown = set(grids or []) - set(GRIDS)
    if unknown:
        p.error(f"unknown grids {sorted(unknown)}; have {sorted(GRIDS)}")
    if args.cache:
        enable_persistent_cache(args.cache)

    base = None
    base_label = args.compare
    if args.compare == "history":
        # gate against the latest prior trajectory entry of matching size
        base = latest_history(args.history, quick=not args.full)
        if base is None:
            print("# perf: --compare requested but "
                  f"{args.history or HISTORY_PATH} has no prior "
                  f"{'quick' if not args.full else 'full'} entry",
                  file=sys.stderr)
            return EXIT_BAD_BASELINE
        base_label = f"history:{base.get('rev')}@{base.get('date')}"
    elif args.compare:
        # validate the baseline BEFORE measuring: a missing or corrupt
        # file should fail in milliseconds with a distinct exit code, not
        # after minutes of measurement with a traceback
        try:
            with open(args.compare) as f:
                base = json.load(f)
        except (OSError, json.JSONDecodeError) as e:
            print(f"# perf: cannot read baseline {args.compare}: {e}",
                  file=sys.stderr)
            return EXIT_BAD_BASELINE
        if not isinstance(base, dict) or not isinstance(
                base.get("grids"), dict):
            print(f"# perf: baseline {args.compare} is not a BENCH "
                  "snapshot (missing 'grids' table)", file=sys.stderr)
            return EXIT_BAD_BASELINE

    tracer = None
    if args.profile:
        tracer = obs_trace.configure(args.profile, kind="perf_profile",
                                     rev=current_rev())
    try:
        bench = run_suite(quick=not args.full, grids=grids, arb=args.arb,
                          kernel=args.kernel, chunk=args.chunk,
                          canon=args.canon, profile=args.profile)
    finally:
        if tracer is not None:
            obs_trace.disable()
    out = args.out or os.path.join(REPO_ROOT, f"BENCH_{bench['rev']}.json")
    os.makedirs(os.path.dirname(os.path.abspath(out)), exist_ok=True)
    with open(out, "w") as f:
        json.dump(bench, f, indent=2, sort_keys=True)
        f.write("\n")
    if args.baseline:
        with open(os.path.join(REPO_ROOT, "BENCH_baseline.json"), "w") as f:
            json.dump(bench, f, indent=2, sort_keys=True)
            f.write("\n")
    append_history(bench, args.history)
    rows = [{"grid": g, **m} for g, m in bench["grids"].items()]
    write_grid_csv(rows, f"perf ({bench['rev']}, {bench['backend']} x "
                         f"{bench['devices']} dev) -> {out}")

    if base is not None:
        cmp_rows = compare_benchmarks(bench, base, threshold=args.threshold)
        write_grid_csv(cmp_rows,
                       f"perf_compare (vs {base_label}, "
                       f"gate +{args.threshold:.0%} device_s)")
        regressed = [r["grid"] for r in cmp_rows if r["regressed"]]
        if regressed:
            print(f"# PERF REGRESSION: {', '.join(regressed)} exceeded the "
                  f"+{args.threshold:.0%} device-time gate", file=sys.stderr)
            return EXIT_REGRESSION
        print("# perf gate passed", file=sys.stderr)
    return 0


if __name__ == "__main__":
    sys.exit(main())
