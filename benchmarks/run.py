"""Run every benchmark (one per paper table/figure + the roofline bench).

    PYTHONPATH=src python -m benchmarks.run [--quick|--full]
                                            [--seeds N] [--csv DIR]
                                            [--only NAME]
                                            [--routing POLICY]
                                            [--trace DIR]

--quick trims replica counts / kernel sets (1-core CPU friendly); --full
runs the complete paper grids.  Default: quick.
--seeds N fans every simulated scenario across N seeds — the seed axis is
batched through ``SimEngine.run_batch`` (same device call as the strategy
axis), and rows report means over seeds.
--csv DIR additionally writes every emitted table to DIR/<name>.csv so
perf trajectories land in versionable files.
--routing POLICY runs every simulation-backed module (fig8, table4,
table3, sched_stream, collective_sim_bench, ...) under that routing
policy (any name registered in ``repro.route``; default omniwar).  Two
modules are pinned by design: ``fig7_min_escalation`` is the paper's
MIN artifact, and ``routing_grid`` always sweeps all policies.
--pattern NAME focuses the pattern-parameterized modules (``traffic_grid``)
on that traffic pattern (any name registered in ``repro.traffic``;
default all_to_all).
--trace DIR activates the :mod:`repro.obs` tracer for the whole run:
every module executes inside a ``bench.<name>`` span, engine dispatches
and scheduler events land in ``DIR/events.jsonl`` next to the run
manifest, a telemetry-enabled probe grid records per-link utilization
series, and the fleet report (``DIR/report/report.md`` + CSVs) is
rendered at the end.
"""

import argparse
import sys
import time
import traceback


MODULES = [
    "table1_properties",
    "fig4_scalability",
    "fig7_min_escalation",
    "fig8_static_interference",
    "table3_escalation",
    "table4_interference",
    "fig11_fabric_partitioning",
    "routing_grid",
    "traffic_grid",
    "resilience_grid",
    "sched_stream",
    "collective_sim_bench",
    "roofline_bench",
]


def main(argv=None):
    from repro.route import available_policies
    from repro.traffic import available_patterns

    p = argparse.ArgumentParser()
    p.add_argument("--quick", action="store_true",
                   help="CI-sized grids (the default; --full overrides)")
    p.add_argument("--full", action="store_true")
    p.add_argument("--only", default=None)
    p.add_argument("--seeds", type=int, default=1,
                   help="seeds per scenario, fanned through run_batch")
    p.add_argument("--csv", default=None, metavar="DIR",
                   help="also write each table to DIR/<name>.csv")
    p.add_argument("--routing", default="omniwar",
                   choices=available_policies(),
                   help="routing policy for the simulation-backed modules")
    p.add_argument("--pattern", default="all_to_all",
                   choices=available_patterns(),
                   help="focus pattern for the pattern-parameterized modules")
    p.add_argument("--trace", default=None, metavar="DIR",
                   help="write a JSONL event trace + run manifest to DIR "
                        "and render the fleet report there")
    args = p.parse_args(argv)
    if args.quick and args.full:
        p.error("--quick and --full are mutually exclusive")
    quick = not args.full

    from benchmarks import common
    common.NUM_SEEDS = max(1, args.seeds)
    common.CSV_DIR = args.csv
    common.QUICK = quick
    common.ROUTING = args.routing
    common.PATTERN = args.pattern

    from repro.obs import report as obs_report
    from repro.obs import trace as obs_trace

    if args.trace:
        obs_trace.configure(
            args.trace, quick=quick, seeds=common.NUM_SEEDS,
            routing=args.routing, pattern=args.pattern,
            only=args.only or "all",
        )

    mods = [m for m in MODULES if args.only is None or args.only in m]
    t00 = time.time()
    timings: list[tuple[str, float]] = []
    failures: list[tuple[str, str]] = []
    try:
        for name in mods:
            # one raising module must not abort the suite: record it,
            # keep going, and make the whole run exit nonzero at the end
            t0 = time.time()
            try:
                mod = __import__(f"benchmarks.{name}", fromlist=["run"])
                with obs_trace.span(f"bench.{name}"):
                    mod.run(quick=quick)
            except Exception as e:
                failures.append((name, f"{type(e).__name__}: {e}"))
                traceback.print_exc()
                print(f"# [{name}] FAILED: {type(e).__name__}: {e}\n")
                obs_trace.event("bench.failed", module=name, error=str(e))
            timings.append((name, time.time() - t0))
            # per-module wall time as a gauge so fleet rollups can chart
            # where suite time goes without re-parsing stdout
            obs_trace.gauge("bench.module", round(timings[-1][1], 4),
                            module=name,
                            failed=bool(failures and failures[-1][0] == name))
            if not failures or failures[-1][0] != name:
                print(f"# [{name}] {timings[-1][1]:.1f}s\n")
        if args.trace:
            # telemetry-enabled probe grid: the per-link utilization /
            # latency series the fleet report renders into heatmap tables
            with obs_trace.span("bench.telemetry"):
                common.telemetry_probe(
                    horizon=20_000 if quick else 60_000)
    finally:
        if args.trace:
            obs_trace.disable()
    if args.trace:
        paths = obs_report.write_report(args.trace)
        print(f"# trace report: {paths['report']}")
    total = time.time() - t00
    # wall-time summary: where the suite's time actually goes, slowest first
    failed = {name for name, _ in failures}
    print("# timing summary (wall s)")
    for name, t in sorted(timings, key=lambda it: -it[1]):
        flag = "  FAILED" if name in failed else ""
        print(f"#   {name:<28s} {t:7.1f}s  "
              f"{100 * t / max(total, 1e-9):5.1f}%{flag}")
    print(f"# total {total:.1f}s over {len(timings)} modules"
          + (f", {len(failures)} FAILED" if failures else ""))
    for name, err in failures:
        print(f"# FAILED {name}: {err}")
    return 1 if failures else 0


if __name__ == "__main__":
    sys.exit(main())
