"""Run every benchmark (one per paper table/figure + the roofline bench).

    PYTHONPATH=src python -m benchmarks.run [--quick|--full]

--quick trims replica counts / kernel sets (1-core CPU friendly); --full
runs the complete paper grids.  Default: quick.
"""

import argparse
import sys
import time


MODULES = [
    "table1_properties",
    "fig4_scalability",
    "fig7_min_escalation",
    "fig8_static_interference",
    "table3_escalation",
    "table4_interference",
    "fig11_fabric_partitioning",
    "collective_sim_bench",
    "roofline_bench",
]


def main(argv=None):
    p = argparse.ArgumentParser()
    p.add_argument("--full", action="store_true")
    p.add_argument("--only", default=None)
    args = p.parse_args(argv)
    quick = not args.full
    mods = [m for m in MODULES if args.only is None or args.only in m]
    t00 = time.time()
    for name in mods:
        mod = __import__(f"benchmarks.{name}", fromlist=["run"])
        t0 = time.time()
        mod.run(quick=quick)
        print(f"# [{name}] {time.time()-t0:.1f}s\n")
    print(f"# total {time.time()-t00:.1f}s")
    return 0


if __name__ == "__main__":
    sys.exit(main())
