"""Closing the loop: mesh collectives SIMULATED on the HyperX fabric per
allocation strategy (cost-model validation against the cycle simulator)."""

from benchmarks.common import emit, resolve_routing
from repro.fabric.collective_sim import compare_strategies_simulated


def run(quick=False):
    if quick:
        mesh, groups = (8, 8), 4        # 64-chip job on the n=4 fleet
        strategies = ("row", "diagonal", "full_spread", "rectangular")
    else:
        mesh, groups = (16, 16), 8      # 256-chip pod on the n=8 fleet
        strategies = ("row", "diagonal", "full_spread", "rectangular",
                      "l_shape", "random_endpoint", "random_switch")
    rows = []
    for kind in ("all_to_all", "all_reduce"):
        out = compare_strategies_simulated(
            mesh_shape=mesh, axis="model", kind=kind,
            num_groups=groups, strategies=strategies,
            mode=resolve_routing(),
        )
        rows.extend(out)
    emit(rows, "collective_sim (mesh collectives measured on the fabric)")
    return rows


if __name__ == "__main__":
    run()
