"""Resilience grid: fault-rate x MTTR x strategy x routing under churn.

Unlike ``routing_grid`` (static dead cables), every faulty cell here runs
a *time-varying* failure-and-repair campaign: seeded exponential
MTBF/MTTR lifetimes over a sampled cable set, lowered to an engine epoch
schedule (:mod:`repro.resil`).  Per cell the grid reports

  * ``delivered_frac`` — delivered / offered target packets (1.0 when the
    run completes; under churn, how much traffic survived the horizon);
  * ``slowdown``       — makespan vs the same strategy/routing fault-free
    baseline;
  * ``blast_radius``   — fraction of fault epochs whose delivered/injected
    ratio collapsed below half the best epoch's (how far the damage
    spreads in time);
  * ``reescalated`` / ``stranded`` — forced fault-escape deroutes granted
    and packets still queued at the horizon.

Epoch schedules ride in the workload tables, so the whole
strategy x campaign x seed grid still batches per shape bucket; one
campaign per (rate, mttr) pair is shared by every strategy and routing,
making deltas pure placement/routing effects.
"""

from benchmarks.common import (
    PAPER_TOPO,
    STRATEGIES,
    emit,
    interference_workload,
    resolve_quick,
    summarize,
    sweep,
)

from repro.resil import apply_schedule, exponential_lifetimes, sample_components, to_epoch_schedule
from repro.route import is_connected

CAMPAIGN_SEED = 77
MTBF = 40.0             # cycles a churning cable stays up (mean)


def _campaign(n_links: int, mttr: float, horizon: int):
    """One seeded fail/repair schedule shared across the whole grid cell."""
    comps = sample_components(PAPER_TOPO, n_links=n_links, seed=CAMPAIGN_SEED)
    events = exponential_lifetimes(
        comps, mtbf=MTBF, mttr=mttr, horizon=horizon, seed=CAMPAIGN_SEED,
    )
    sched = to_epoch_schedule(PAPER_TOPO, events, max_epochs=16)
    for mask in sched.link_ok:
        assert is_connected(PAPER_TOPO, mask), "campaign disconnected machine"
    return sched


def blast_radius(per_seed) -> float:
    """Worst-seed fraction of active epochs that collapsed below half the
    best epoch's delivered/injected ratio."""
    worst = 0.0
    for r in per_seed:
        ratios = [d / i for d, i in zip(r.epoch_delivered, r.epoch_injected)
                  if i > 0]
        if len(ratios) <= 1:
            continue
        lo = 0.5 * max(ratios)
        worst = max(worst, sum(x < lo for x in ratios) / len(ratios))
    return round(worst, 4)


def run(quick=None):
    quick = resolve_quick(quick)
    strategies = ("diagonal", "rectangular") if quick else STRATEGIES
    routings = ("min", "omniwar") if quick else ("min", "val", "ugal", "omniwar")
    n_links = (24,) if quick else (24, 64)       # cables under churn
    mttrs = (60.0,) if quick else (30.0, 120.0)
    kind = "all_to_all"
    horizon = 6_000 if quick else 10_000
    # campaign horizon tracks the longest baseline makespan, not the sim
    # horizon: epochs past completion would never be observed
    span = 800 if quick else 1_500

    base = {s: interference_workload(s, kind, with_bg=False)
            for s in strategies}
    # one fault-free baseline cell + one campaign per (rate, mttr) pair
    cells = [(0, 0.0, None)] + [
        (nl, mttr, _campaign(nl, mttr, span))
        for nl in n_links if nl > 0 for mttr in mttrs
    ]

    rows = []
    for mode in routings:
        wls, grid = [], []   # (strategy, nl, mttr) in workload order
        for strat in strategies:
            for nl, mttr, sched in cells:
                wl = base[strat]
                if sched is not None:
                    wl = apply_schedule(wl, sched)
                wls.append(wl)
                grid.append((strat, nl, mttr))
        per_wl = sweep(wls, mode=mode, horizon=horizon)
        baselines = {
            strat: s["makespan"]
            for (strat, nl, _), per_seed in zip(grid, per_wl)
            if nl == 0
            for s in (summarize(per_seed),)
        }
        for (strat, nl, mttr), per_seed in zip(grid, per_wl):
            s = summarize(per_seed)
            offered = base[strat].target_packets
            dfrac = min(r.delivered / max(offered, 1) for r in per_seed)
            base_ms = baselines.get(strat, -1)
            slowdown = (
                round(s["makespan"] / base_ms, 3)
                if s["makespan"] > 0 and base_ms and base_ms > 0 else -1.0
            )
            rows.append({
                "routing": mode, "strategy": strat,
                "churn_links": nl, "mttr": mttr if nl else 0.0,
                "makespan": s["makespan"],
                "delivered_frac": round(dfrac, 4),
                "slowdown": slowdown,
                "blast_radius": blast_radius(per_seed),
                "reescalated": max(r.reescalated for r in per_seed),
                "stranded": max(r.stranded for r in per_seed),
                "completed": s["completed"],
            })
    emit(rows, "resilience_grid (routing x strategy x churn x mttr)")
    return rows


if __name__ == "__main__":
    run()
