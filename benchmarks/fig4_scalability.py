"""Paper Figure 4: endpoints supported per switch radix per topology."""

from repro.core.scalability import scalability_table, paper_examples

from benchmarks.common import emit


def run(quick=False):
    rows = scalability_table()
    emit(rows, "fig4_scalability (paper Fig. 4)")
    ex = paper_examples()
    emit([ex], "fig4_paper_examples (Sec 2.3 exact claims)")
    return rows


if __name__ == "__main__":
    run()
