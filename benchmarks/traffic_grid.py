"""Pattern x strategy (x routing) grid — the traffic-registry sweep.

Every swept traffic pattern runs the same allocation-strategy grid on the
paper machine (one 64-rank job, no background, identical seeds), so row
deltas are pure pattern x placement effects: tornado/transpose punish
locality-heavy placements under minimal routing, incast is
placement-insensitive (ejection-bound), collectives reward locality.

Workloads are built through the declarative scenario layer and executed
through ``sweep`` — every pattern whose padded step table lands in the
same ``WorkloadTables`` shape bucket shares one compilation and one
vmapped device call, which is what makes a pattern axis as cheap as a
strategy or seed axis (trace-counter-pinned in
``tests/test_traffic_patterns.py``).

Quick mode sweeps the adversarial additions plus the ``--pattern``
focus; full mode sweeps every registered pattern and adds a routing axis
over all registered policies.
"""

from benchmarks.common import (
    STRATEGIES,
    emit,
    interference_workload,
    resolve_pattern,
    resolve_quick,
    resolve_routing,
    summarize,
    sweep,
)

from repro.route import available_policies
from repro.traffic import available_patterns

QUICK_PATTERNS = ("transpose", "shuffle", "tornado", "incast",
                  "recursive_doubling", "stencil_3d")


def run(quick=None):
    quick = resolve_quick(quick)
    focus = resolve_pattern()
    if quick:
        patterns = tuple(dict.fromkeys((focus,) + QUICK_PATTERNS))
        strategies = ("row", "diagonal", "full_spread")
        modes = (resolve_routing(),)
    else:
        patterns = available_patterns()
        strategies = tuple(STRATEGIES)
        modes = available_policies()
    horizon = 30_000

    base = {
        (strat, pat): interference_workload(strat, pat, with_bg=False)
        for strat in strategies for pat in patterns
    }
    rows = []
    for mode in modes:
        grid = list(base)
        per_wl = sweep([base[g] for g in grid], mode=mode, horizon=horizon)
        for (strat, pat), per_seed in zip(grid, per_wl):
            s = summarize(per_seed)
            rows.append({
                "pattern": pat, "strategy": strat, "routing": mode,
                "target_packets": base[(strat, pat)].target_packets,
                "makespan": s["makespan"],
                "avg_latency": s["avg_latency"],
                "avg_hops": s["avg_hops"],
                "completed": s["completed"],
            })
    emit(rows, "traffic_grid (pattern x strategy x routing)")
    return rows


if __name__ == "__main__":
    run()
