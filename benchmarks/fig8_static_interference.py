"""Paper Figure 8: static patterns under Omni-WAR with random-permutation
background noise."""

from benchmarks.common import STRATEGIES, emit, interference_makespan


def run(quick=False):
    rows = []
    for kind in ("uniform", "random_switch_permutation"):
        for strat in STRATEGIES:
            iso = interference_makespan(strat, kind, with_bg=False)
            bg = interference_makespan(strat, kind, with_bg=True)
            rows.append({
                "kernel": kind, "strategy": strat,
                "makespan_isolated": iso["makespan"],
                "makespan_bg": bg["makespan"],
                "slowdown": round(bg["makespan"] / max(iso["makespan"], 1), 3),
            })
    emit(rows, "fig8_static_interference (paper Fig. 8)")
    return rows


if __name__ == "__main__":
    run()
