"""Paper Figure 8: static patterns under Omni-WAR with random-permutation
background noise.  Executes each pattern's strategy grid as batched
``sweep`` calls (isolated + background grids in one dispatch per bucket)."""

from benchmarks.common import (
    STRATEGIES,
    emit,
    interference_workload,
    resolve_quick,
    summarize,
    sweep,
)


def run(quick=None):
    quick = resolve_quick(quick)
    rows = []
    kinds = ("uniform",) if quick else ("uniform", "random_switch_permutation")
    for kind in kinds:
        iso_wls = [interference_workload(s, kind, with_bg=False)
                   for s in STRATEGIES]
        bg_wls = [interference_workload(s, kind, with_bg=True)
                  for s in STRATEGIES]
        per_wl = sweep(iso_wls + bg_wls, horizon=80000)
        iso_res, bg_res = per_wl[:len(STRATEGIES)], per_wl[len(STRATEGIES):]
        for strat, iso, bg in zip(STRATEGIES, iso_res, bg_res):
            iso_m = summarize(iso)["makespan"]
            bg_m = summarize(bg)["makespan"]
            rows.append({
                "kernel": kind, "strategy": strat,
                "makespan_isolated": iso_m,
                "makespan_bg": bg_m,
                "slowdown": round(bg_m / max(iso_m, 1), 3),
            })
    emit(rows, "fig8_static_interference (paper Fig. 8)")
    return rows


if __name__ == "__main__":
    run()
