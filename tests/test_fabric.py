"""Placement + collective cost model tests (the paper -> JAX bridge)."""

import numpy as np
import pytest

from repro.core.hyperx import HyperX
from repro.fabric.collective_model import (
    CollectiveModel,
    rank_strategies_for_schedule,
    steps,
    wire_bytes_per_chip,
)
from repro.fabric.placement import default_fleet, place_job


def test_default_fleet():
    assert default_fleet(512).n == 8
    assert default_fleet(512).num_endpoints == 512
    assert default_fleet(256).n == 8  # single pod = half the 8x8 machine
    assert default_fleet(64).n == 4
    with pytest.raises(ValueError):
        default_fleet(0)


@pytest.mark.parametrize("strat", ["row", "diagonal", "full_spread", "random_switch"])
def test_place_job_covers_mesh(strat):
    p = place_job(strat, (2, 16, 16), ("pod", "data", "model"))
    assert p.endpoints.shape == (2, 16, 16)
    assert len(np.unique(p.endpoints)) == 512  # bijective placement
    order = p.device_order()
    assert sorted(order.tolist()) == list(range(512))


def test_single_pod_placement_disjoint_from_second_job():
    p0 = place_job("diagonal", (16, 16), ("data", "model"), job_id=0)
    p1 = place_job("diagonal", (16, 16), ("data", "model"), job_id=1)
    assert not np.intersect1d(p0.endpoints, p1.endpoints).size


def test_axis_groups_shape():
    p = place_job("diagonal", (16, 16), ("data", "model"))
    g = p.axis_groups("model")
    assert g.shape == (16, 16)
    g2 = p.axis_groups("data")
    assert g2.shape == (16, 16)


def test_wire_bytes_formulas():
    assert wire_bytes_per_chip("all_reduce", 100.0, 4) == pytest.approx(150.0)
    assert wire_bytes_per_chip("all_gather", 100.0, 4) == pytest.approx(300.0)
    assert wire_bytes_per_chip("reduce_scatter", 100.0, 4) == pytest.approx(75.0)
    assert wire_bytes_per_chip("all_to_all", 100.0, 4) == pytest.approx(75.0)
    assert wire_bytes_per_chip("all_reduce", 100.0, 1) == 0.0
    assert steps("all_reduce", 4) == 6


def test_axis_pb_reflects_allocation_strategy():
    """Lesson 2 carried into the mesh: Diagonal data-axis groups have more
    fabric bandwidth than Row groups."""
    row = CollectiveModel(place_job("row", (16, 16), ("data", "model")))
    diag = CollectiveModel(place_job("diagonal", (16, 16), ("data", "model")))
    # data-axis groups stride across the partition blocks
    assert diag.axis_pb("data") > row.axis_pb("data") * 0.99


def test_collective_cost_orders_strategies():
    schedule = [("all_reduce", "data", 64e6), ("all_gather", "model", 8e6)]
    ranked = rank_strategies_for_schedule((16, 16), ("data", "model"), schedule)
    names = [r["strategy"] for r in ranked]
    # high-PB strategies must price cheaper than the rectangular tessellation
    assert names.index("diagonal") < names.index("rectangular")
    assert names.index("full_spread") < names.index("rectangular")
    for r in ranked:
        assert r["total_s"] > 0


def test_cost_monotone_in_bytes_and_groupsize():
    m = CollectiveModel(place_job("diagonal", (16, 16), ("data", "model")))
    c1 = m.cost("all_reduce", "model", 1e6)
    c2 = m.cost("all_reduce", "model", 2e6)
    assert c2.bandwidth_s > c1.bandwidth_s
    assert c1.latency_s == c2.latency_s


def test_multi_pod_placement_axis_properties():
    p = place_job("diagonal", (2, 16, 16), ("pod", "data", "model"))
    props = p.axis_properties("pod")
    assert props["groups"] == 256 and props["group_size"] == 2
    m = CollectiveModel(p)
    c = m.cost("all_reduce", "pod", 1e6)
    assert c.total_s > 0
