"""Traffic-pattern subsystem tests: registry contract, bit-identical pins
for every migrated pattern, invariants of the new patterns (conservation,
bijectivity, involution, reciprocity — hypothesis-backed), phased
composition, the declarative scenario layer, and the one-compile-per-
bucket pin for pattern x strategy x seed grids."""

import hashlib

import numpy as np
import pytest

try:  # optional test extra (pip install -e .[test]); property tests need it
    from hypothesis import given, settings, strategies as hst
except ImportError:  # pragma: no cover - exercised only without hypothesis
    given = settings = hst = None

from repro.core import traffic as tr
from repro.core.allocation import allocate_partition
from repro.core.engine import SimEngine, get_engine
from repro.core.engine.workload_tables import shape_bucket
from repro.core.hyperx import HyperX
from repro.traffic import (
    AppSpec,
    AppTraffic,
    BackgroundSpec,
    PhaseSpec,
    ScenarioSpec,
    TrafficPattern,
    available_patterns,
    build_phases,
    build_workload,
    compose_workload,
    concat_phases,
    empty_tables,
    get_pattern,
    grid_shape,
    register_pattern,
)

SMALL = HyperX(n=4, q=2)

ALL_PATTERNS = (
    "all_reduce", "all_to_all", "incast", "random_involution",
    "random_permutation", "random_switch_permutation", "recursive_doubling",
    "ring_allreduce", "shuffle", "stencil_3d", "stencil_moore",
    "stencil_von_neumann", "tornado", "transpose", "uniform",
)


# ------------------------------------------------------------------ registry
def test_available_patterns_lists_all():
    assert available_patterns() == ALL_PATTERNS


def test_available_patterns_kind_filter():
    adv = available_patterns(kind="adversarial")
    assert "tornado" in adv and "transpose" in adv and "shuffle" in adv
    assert "all_to_all" not in adv


def test_unknown_pattern_raises_with_registered_names():
    with pytest.raises(ValueError) as e:
        get_pattern("bogus")
    msg = str(e.value)
    for name in ("all_to_all", "tornado", "stencil_3d"):
        assert name in msg


def test_register_duplicate_rejected():
    with pytest.raises(ValueError):
        register_pattern(TrafficPattern("uniform", tr.uniform))


def test_seed_only_threads_into_seeded_patterns():
    # unseeded builders must stay bit-identical whatever seed is passed
    a = get_pattern("all_to_all").build(16, seed=7)
    b = tr.all_to_all(16)
    np.testing.assert_array_equal(a.sends_dst, b.sends_dst)
    # seeded builders pick the seed up, explicit params win
    p1 = get_pattern("random_permutation").build(16, seed=3)
    p2 = tr.random_permutation(16, seed=3)
    np.testing.assert_array_equal(p1.sends_dst, p2.sends_dst)
    # a phase that pins its own seed wins over the derived scenario seed
    p3 = build_phases([("random_permutation", {"seed": 5})], 16, seed=3)
    np.testing.assert_array_equal(
        p3.sends_dst, tr.random_permutation(16, seed=5).sends_dst
    )


# --------------------------------------------- bit-identical migration pins
def _tables_hash(app: AppTraffic) -> str:
    m = hashlib.sha256()
    for a in (app.sends_dst, app.npkts, app.deg, app.recv_need,
              app.sampled, app.lo, app.hi):
        m.update(np.ascontiguousarray(a).tobytes())
    m.update(str(app.window).encode())
    return m.hexdigest()[:16]


# recorded from the seed builders (core/traffic.py + collective_sim.py
# private builders) at k=16, seed=0, before the registry migration
MIGRATION_PINS = {
    "uniform": "3e6e35f86624a759",
    "random_permutation": "87f3425aaeb94c51",
    "random_switch_permutation": "106b703ef8094c96",
    "all_to_all": "4b37b9a8e3a844ed",
    "all_reduce": "862e1f9ba9557703",
    "stencil_von_neumann": "a9a8b28907fa382e",
    "stencil_moore": "6be0387947ba6167",
    "random_involution": "762293eac51454c6",
    "ring_allreduce": "80f93c4ed4036548",
}
PIN_ARGS = {
    "random_switch_permutation": {"group": 4},
    "ring_allreduce": {"packets_per_step": 4},
}


@pytest.mark.parametrize("name", sorted(MIGRATION_PINS))
def test_migrated_pattern_bit_identical_to_seed(name):
    app = get_pattern(name).build(16, seed=0, **PIN_ARGS.get(name, {}))
    assert _tables_hash(app) == MIGRATION_PINS[name]
    assert app.name == name


def test_ring_allreduce_matches_former_private_builder():
    """Parity pin for the collective_sim dedup: the registry pattern must
    reproduce fabric/collective_sim.py's deleted _ring_allreduce_app."""
    k, pps = 8, 4
    T = 2 * (k - 1)
    dst, npk, deg, recv = empty_tables(k, T, 1)
    r = np.arange(k)
    for t in range(T):
        dst[:, t, 0] = (r + 1) % k
        npk[:, t, 0] = pps
        deg[:, t] = 1
        recv[:, t] = pps
    ref = AppTraffic("ring_allreduce", k, dst, npk, deg, recv, window=1)
    app = get_pattern("ring_allreduce").build(k, packets_per_step=pps)
    assert _tables_hash(app) == _tables_hash(ref)


def test_axis_collective_workload_uses_registry():
    from repro.fabric.collective_sim import axis_collective_workload
    from repro.fabric.placement import place_job

    p = place_job("diagonal", (8, 8), ("data", "model"))
    wl = axis_collective_workload(p, "model", "all_reduce", num_groups=2)
    assert wl.names == ["ring_allreduce"] * 2


# ----------------------------------------------------- total_packets fix
def test_total_packets_ignores_padded_slots():
    """Regression: the old mask (sends_dst >= -1) was vacuously true and
    counted npkts sitting under padded (-1) destination slots."""
    dst = np.array([[[1, -1]], [[0, -1]]], dtype=np.int64)
    npk = np.array([[[2, 7]], [[3, 9]]], dtype=np.int64)  # 7/9 under pads
    deg = np.ones((2, 1), dtype=np.int64)
    recv = np.zeros((2, 1), dtype=np.int64)
    app = AppTraffic("t", 2, dst, npk, deg, recv, window=1)
    assert app.total_packets == 5  # not 21


def test_total_packets_after_phase_padding():
    """Phased concat pads the narrower phase's destination slots; the
    padded slots must not contribute."""
    a = get_pattern("stencil_von_neumann").build(16, rounds=2)  # maxd 4
    b = get_pattern("all_to_all").build(16)                     # maxd 1
    phased = concat_phases([a, b])
    assert phased.maxd == 4
    assert phased.total_packets == a.total_packets + b.total_packets


# -------------------------------------------------- new-pattern invariants
def _sent_per_step(app: AppTraffic) -> np.ndarray:
    """(k, T) packets arriving at each rank per step tag (fixed dsts)."""
    got = np.zeros((app.k, app.T), dtype=np.int64)
    for r in range(app.k):
        for t in range(app.T):
            for d in range(app.deg[r, t]):
                got[app.sends_dst[r, t, d], t] += app.npkts[r, t, d]
    return got


@pytest.mark.parametrize("name,params", [
    ("all_to_all", {}),
    ("all_reduce", {}),
    ("recursive_doubling", {}),
    ("ring_allreduce", {}),
    ("incast", {"targets": 2}),
    ("stencil_3d", {"rounds": 3}),
])
def test_send_recv_conservation(name, params):
    """Every packet a synchronized kernel sends is expected by exactly one
    receiver at the same step tag: arrivals == recv_need, step by step."""
    app = get_pattern(name).build(16, **params)
    np.testing.assert_array_equal(_sent_per_step(app), app.recv_need)


@pytest.mark.parametrize("name", ["transpose", "shuffle", "tornado"])
def test_adversarial_patterns_are_bijective(name):
    app = get_pattern(name).build(64)
    send = app.deg[:, 0] > 0
    dsts = app.sends_dst[send, 0, 0]
    assert len(np.unique(dsts)) == send.sum()  # injective on senders
    assert not np.isin(np.flatnonzero(send), dsts[dsts == np.flatnonzero(send)]).any()


def test_transpose_involution_on_square_grid():
    app = get_pattern("transpose").build(64)  # 8x8 grid
    target = np.arange(64)
    send = app.deg[:, 0] > 0
    target[send] = app.sends_dst[send, 0, 0]
    np.testing.assert_array_equal(target[target], np.arange(64))
    # diagonal ranks idle: 8 fixed points on an 8x8 transpose
    assert (~send).sum() == 8


def test_shuffle_is_bit_rotation():
    app = get_pattern("shuffle").build(16)
    send = app.deg[:, 0] > 0
    assert not send[0] and not send[15]  # all-zeros/all-ones fixed points
    for r in np.flatnonzero(send):
        assert app.sends_dst[r, 0, 0] == ((r << 1) | (r >> 3)) & 15


def test_tornado_offset_and_no_self_sends():
    app = get_pattern("tornado").build(16)  # 4x4 grid, offsets (2, 2)
    r = np.arange(16)
    y, x = r // 4, r % 4
    expect = ((y + 2) % 4) * 4 + (x + 2) % 4
    np.testing.assert_array_equal(app.sends_dst[:, 0, 0], expect)
    assert (app.sends_dst[:, :, 0] != r[:, None]).all()
    with pytest.raises(ValueError):
        get_pattern("tornado").build(16, offsets=(0, 0))


def test_incast_focuses_on_sinks():
    app = get_pattern("incast").build(16, packets=4, targets=2)
    assert (app.deg[:2] == 0).all()            # sinks never send
    assert (app.sends_dst[2:, :, 0] < 2).all()  # everyone targets a sink
    assert app.recv_need[:2].sum() == app.total_packets
    with pytest.raises(ValueError):
        get_pattern("incast").build(16, targets=16)


def test_recursive_doubling_vs_rabenseifner():
    rd = get_pattern("recursive_doubling").build(16, vector_packets=64)
    rab = get_pattern("all_reduce").build(16, vector_packets=64)
    assert rd.T == 4 and rab.T == 8  # half the steps...
    assert rd.total_packets == 16 * 4 * 64  # ...but full-vector exchanges
    assert rd.total_packets > rab.total_packets
    for t in range(rd.T):
        d = rd.sends_dst[:, t, 0]
        np.testing.assert_array_equal(d[d], np.arange(16))  # partner symmetry


def test_stencil_3d_neighbor_reciprocity():
    app = get_pattern("stencil_3d").build(64, rounds=2)  # 4x4x4 torus
    assert app.maxd == 6 and (app.deg == 6).all()
    # r sends to s exactly as often as s sends to r, per round
    sent = np.zeros((64, 64), dtype=np.int64)
    for r in range(64):
        for d in range(6):
            sent[r, app.sends_dst[r, 0, d]] += 1
    np.testing.assert_array_equal(sent, sent.T)
    # every 3D von-Neumann neighbour is at torus grid distance 1
    gz = gy = gx = 4
    for r in (0, 21, 63):
        z, y, x = r // 16, (r // 4) % 4, r % 4
        for d in range(6):
            nb = app.sends_dst[r, 0, d]
            nz, ny, nx = nb // 16, (nb // 4) % 4, nb % 4
            dist = (min((z - nz) % gz, (nz - z) % gz)
                    + min((y - ny) % gy, (ny - y) % gy)
                    + min((x - nx) % gx, (nx - x) % gx))
            assert dist == 1
    with pytest.raises(ValueError):
        get_pattern("stencil_3d").build(4)  # a dim of size 1


def test_grid_shape_2d_matches_seed_and_3d_factors():
    assert grid_shape(64) == (8, 8)
    assert grid_shape(32) == (4, 8)   # the seed 2D split
    assert grid_shape(12) == (2, 6)
    assert grid_shape(64, ndim=3) == (4, 4, 4)
    assert grid_shape(16, ndim=3) == (2, 2, 4)
    with pytest.raises(ValueError):
        grid_shape(9, ndim=3)


if hst is not None:
    @given(hst.sampled_from([4, 16, 64]), hst.integers(0, 2 ** 16))
    @settings(max_examples=20, deadline=None)
    def test_involution_property(k, seed):
        app = get_pattern("random_involution").build(k, seed=seed, packets=2)
        partner = app.sends_dst[:, 0, 0]
        np.testing.assert_array_equal(partner[partner], np.arange(k))
        assert not (partner == np.arange(k)).any()

    @given(
        hst.sampled_from(["transpose", "shuffle", "tornado"]),
        hst.sampled_from([8, 16, 32, 64]),
    )
    @settings(max_examples=20, deadline=None)
    def test_bijectivity_property(name, k):
        app = get_pattern(name).build(k, packets=1)
        send = app.deg[:, 0] > 0
        dsts = app.sends_dst[send, 0, 0]
        assert len(np.unique(dsts)) == int(send.sum())
        assert (dsts != np.flatnonzero(send)).all()  # no self-sends

    @given(
        hst.sampled_from(["all_to_all", "recursive_doubling",
                          "ring_allreduce"]),
        hst.sampled_from([4, 8, 16]),
    )
    @settings(max_examples=15, deadline=None)
    def test_conservation_property(name, k):
        app = get_pattern(name).build(k)
        np.testing.assert_array_equal(_sent_per_step(app), app.recv_need)
else:  # pragma: no cover
    def test_property_suite_needs_hypothesis():
        pytest.importorskip("hypothesis")


# ------------------------------------------------------ phased composition
def test_concat_phases_shapes_order_window():
    a = get_pattern("stencil_von_neumann").build(16, rounds=3)  # window 1
    b = get_pattern("all_to_all").build(16)                     # window 15
    phased = concat_phases([a, b])
    assert phased.name == "stencil_von_neumann+all_to_all"
    assert phased.T == a.T + b.T
    assert phased.maxd == max(a.maxd, b.maxd)
    assert phased.window == 1  # min over phases
    np.testing.assert_array_equal(phased.sends_dst[:, : a.T, : a.maxd],
                                  a.sends_dst)
    np.testing.assert_array_equal(phased.sends_dst[:, a.T:, : b.maxd],
                                  b.sends_dst)
    # padded destination slots of the narrow phase stay pad
    assert (phased.sends_dst[:, a.T:, b.maxd:] == -1).all()
    assert concat_phases([a, b], window=4).window == 4
    with pytest.raises(ValueError):
        concat_phases([a, get_pattern("all_to_all").build(8)])
    with pytest.raises(ValueError):
        concat_phases([])


def test_single_phase_passthrough_is_bit_identical():
    app = build_phases(["all_to_all"], 16)
    ref = tr.all_to_all(16)
    assert _tables_hash(app) == _tables_hash(ref)


def test_phased_workload_runs_to_completion():
    """The canonical HPC iteration: stencil exchange rounds, then an
    all-reduce — one app, one ordered step table, every packet of both
    phases delivered."""
    part = allocate_partition("row", SMALL, 0)
    spec = ScenarioSpec(apps=(
        AppSpec(phases=(PhaseSpec("stencil_von_neumann", {"rounds": 2}),
                        PhaseSpec("all_reduce", {"vector_packets": 8})),
                placement=part),
    ))
    wl = build_workload(SMALL, spec)
    assert wl.names == ["stencil_von_neumann+all_reduce"]
    res = get_engine(SMALL, mode="omniwar").run(wl, seed=0, horizon=20_000)
    assert res.completed
    assert res.delivered == wl.target_packets


# -------------------------------------------------------- scenario layer
def test_build_workload_matches_manual_compose():
    part = allocate_partition("diagonal", SMALL, 0)
    spec = ScenarioSpec(apps=(AppSpec(phases="all_to_all", placement=part),))
    wl = build_workload(SMALL, spec)
    ref = compose_workload(SMALL, [(tr.all_to_all(16), part)])
    np.testing.assert_array_equal(wl.sends_dst, ref.sends_dst)
    np.testing.assert_array_equal(wl.npkts, ref.npkts)
    np.testing.assert_array_equal(wl.rank_ep, ref.rank_ep)
    np.testing.assert_array_equal(wl.window, ref.window)


def test_scenario_strategy_names_take_consecutive_blocks():
    spec = ScenarioSpec(apps=(
        AppSpec(phases="all_to_all", placement="row"),
        AppSpec(phases="all_to_all", placement="row"),
    ))
    wl = build_workload(SMALL, spec)
    assert wl.R == 32
    assert len(np.unique(wl.rank_ep)) == 32  # disjoint partitions


def test_scenario_background_and_warmup():
    part = allocate_partition("row", SMALL, 0)
    spec = ScenarioSpec(
        apps=(AppSpec(phases="uniform", placement=part),),
        background=BackgroundSpec(),
        warmup=50,
    )
    wl = build_workload(SMALL, spec)
    n_free = SMALL.num_endpoints - part.size
    assert wl.infinite.sum() == n_free
    assert (wl.start[~wl.infinite] == 50).all()
    assert wl.names[-1] == "bg:random_permutation"


def test_scenario_unknown_pattern_lists_registered():
    part = allocate_partition("row", SMALL, 0)
    with pytest.raises(ValueError, match="registered patterns"):
        build_workload(SMALL, ScenarioSpec(
            apps=(AppSpec(phases="nope", placement=part),)
        ))
    with pytest.raises(ValueError, match="registered patterns"):
        build_workload(SMALL, ScenarioSpec(
            apps=(AppSpec(phases="uniform", placement=part),),
            background=BackgroundSpec(pattern="nope"),
        ))


def test_scenario_seed_derivation():
    spec = ScenarioSpec(apps=(
        AppSpec(phases="random_permutation", placement="row"),
        AppSpec(phases="random_permutation", placement="row"),
    ), seed=7)
    wl = build_workload(SMALL, spec)
    # per-app derived seeds: the two permutations differ
    assert (wl.sends_dst[:16, 0, 0] - 0 != wl.sends_dst[16:, 0, 0] - 16).any()
    pinned = ScenarioSpec(apps=(
        AppSpec(phases="random_permutation", placement="row", seed=3),
    ))
    wl2 = build_workload(SMALL, pinned)
    np.testing.assert_array_equal(
        wl2.sends_dst[:, 0, 0],
        tr.random_permutation(16, seed=3).sends_dst[:, 0, 0],
    )


# ------------------------------------------------ compile economics pin
def test_pattern_grid_one_compile_per_bucket():
    """A pattern x strategy x seed grid over the NEW patterns through
    run_batch_seeds costs ONE trace and ONE device call per shape
    bucket: pattern tables are workload *data*, not compile keys."""
    engine = SimEngine(SMALL, mode="omniwar")
    patterns = ("transpose", "tornado", "shuffle", "incast", "stencil_3d")
    wls = [
        build_workload(SMALL, ScenarioSpec(apps=(
            AppSpec(phases=pat, placement=allocate_partition(s, SMALL, 0)),
        )))
        for s in ("row", "diagonal") for pat in patterns
    ]
    buckets = {shape_bucket(wl.R, wl.T, wl.maxd) for wl in wls}
    assert len(buckets) < len(wls)  # the axis genuinely shares buckets
    grid = engine.run_batch_seeds(wls, seeds=(0, 1), horizon=20_000)
    assert engine.trace_count == len(buckets)
    assert engine.device_calls == len(buckets)
    assert all(r.completed for per_seed in grid for r in per_seed)
    # the batched grid returns exactly the per-scenario results
    assert grid[2][1] == engine.run(wls[2], seed=1, horizon=20_000)


# ------------------------------------------------------- compat surface
def test_core_traffic_shim_keeps_seed_surface():
    for name in ("AppTraffic", "Workload", "compose_workload",
                 "background_noise", "uniform", "all_to_all", "all_reduce",
                 "stencil", "random_involution", "KERNELS",
                 "STATIC_PATTERNS", "_empty", "_grid_shape"):
        assert hasattr(tr, name), name
    assert set(tr.KERNELS) == {
        "all_to_all", "all_reduce", "stencil_von_neumann", "stencil_moore",
        "random_involution",
    }
