"""Routing-policy subsystem tests: registry, vectorized-table parity,
bit-identical min/omniwar pins vs the seed engine, VAL/UGAL delivery +
conservation (with and without fault masks), hop-indexed VC budget
invariants, and the one-compile-per-bucket pin for routing x fault grids."""

import numpy as np
import pytest

try:  # optional test extra (pip install -e .[test]); property tests need it
    from hypothesis import given, settings, strategies as hst
except ImportError:  # pragma: no cover - exercised only without hypothesis
    given = settings = hst = None

from repro.core import traffic as tr
from repro.core.allocation import allocate_partition
from repro.core.engine import SimEngine, get_engine, make_workload_tables
from repro.core.hyperx import HyperX
from repro.core.routing import LinkSpace
from repro import route
from repro.route import (
    RoutingPolicy,
    apply_faults,
    available_policies,
    fail_links,
    fail_switches,
    faults_from_endpoints,
    get_policy,
    intermediate_pool,
    is_connected,
    neighbor_tables,
    no_faults,
    random_link_faults,
    self_port_mask,
)

SMALL = HyperX(n=4, q=2)


def _a2a_workload(strategy: str, link_ok=None):
    part = allocate_partition(strategy, SMALL, 0)
    return tr.compose_workload(
        SMALL, [(tr.all_to_all(16), part)], link_ok=link_ok
    )


def _one_link_mask():
    return fail_links(SMALL, [(0, 1)])


def _two_link_mask():
    return fail_links(SMALL, [(0, 1), (5, 9)])


# ------------------------------------------------------------------ registry
def test_available_policies_lists_all_four():
    assert available_policies() == ("min", "omniwar", "ugal", "val")


def test_unknown_mode_raises_with_registered_names():
    with pytest.raises(ValueError) as e:
        get_policy("bogus")
    msg = str(e.value)
    for name in available_policies():
        assert name in msg
    with pytest.raises(ValueError):
        SimEngine(SMALL, mode="bogus")
    with pytest.raises(ValueError):
        get_engine(SMALL, mode="not_a_policy")


def test_register_duplicate_rejected():
    with pytest.raises(ValueError):
        route.register_policy(RoutingPolicy("min", False, False, False))


def test_vc_budget_declarations():
    q = SMALL.q
    # default deroute budget: one per dimension per minimal phase
    assert get_policy("min").default_deroutes(q) == q       # seed m
    assert get_policy("omniwar").default_deroutes(q) == q   # seed m
    assert get_policy("val").default_deroutes(q) == 2 * q
    assert get_policy("ugal").default_deroutes(q) == 2 * q
    assert get_policy("min").vc_budget(q, q) == 2 * q + 1       # seed V
    assert get_policy("omniwar").vc_budget(q, q) == 2 * q + 1   # seed V
    assert get_policy("val").vc_budget(q, 2 * q) == 4 * q + 1
    assert get_policy("ugal").vc_budget(q, 2 * q) == 4 * q + 1
    # the engine sizes its queue space from the declaration
    assert get_engine(SMALL, mode="val").static.V == 4 * q + 1
    assert get_engine(SMALL, mode="min").static.V == 2 * q + 1


# ------------------------------------------- vectorized-table parity (loops)
def _loop_neighbor_tables(topo: HyperX):
    """The seed engine's O(S*q*n) nested-loop construction, verbatim."""
    n, q, S = topo.n, topo.q, topo.num_switches
    coords_np = topo.all_switch_coords()
    nbr = np.empty((S, q * n), dtype=np.int64)
    in_port = np.empty((S, q * n), dtype=np.int64)
    for d in range(q):
        for v in range(n):
            nc = coords_np.copy()
            nc[:, d] = v
            ids = np.zeros(S, dtype=np.int64)
            for d2 in range(q):
                ids = ids * n + nc[:, d2]
            nbr[:, d * n + v] = ids
            in_port[:, d * n + v] = d * n + coords_np[:, d]
    return nbr, in_port


@pytest.mark.parametrize("topo", [SMALL, HyperX(n=3, q=3), HyperX(n=8, q=2)])
def test_neighbor_tables_match_loop_construction(topo):
    nbr, ipnb = neighbor_tables(topo.all_switch_coords(), topo.n, topo.q)
    ref_nbr, ref_ip = _loop_neighbor_tables(topo)
    np.testing.assert_array_equal(nbr, ref_nbr)
    np.testing.assert_array_equal(ipnb, ref_ip)


@pytest.mark.parametrize("topo", [SMALL, HyperX(n=3, q=3)])
def test_linkspace_dst_switch_matches_loop_construction(topo):
    ls = LinkSpace(topo)
    coords = topo.all_switch_coords()
    S = topo.num_switches
    ref = np.empty((S, topo.q, topo.n), dtype=np.int64)
    valid_ref = np.ones((S, topo.q, topo.n), dtype=bool)
    s = np.arange(S)
    for dim in range(topo.q):
        for v in range(topo.n):
            nc = coords.copy()
            nc[:, dim] = v
            ids = np.zeros(S, dtype=np.int64)
            for d2 in range(topo.q):
                ids = ids * topo.n + nc[:, d2]
            ref[:, dim, v] = ids
        valid_ref[s, dim, coords[:, dim]] = False
    np.testing.assert_array_equal(ls.dst_switch, ref)
    np.testing.assert_array_equal(ls.valid, valid_ref)


# ------------------------------------------------------------ fault masking
def test_fail_links_kills_both_directions():
    mask = _one_link_mask()
    coords = SMALL.all_switch_coords()
    n = SMALL.n
    d = int(np.flatnonzero(coords[0] != coords[1])[0])
    assert not mask[0, d * n + coords[1, d]]
    assert not mask[1, d * n + coords[0, d]]
    assert mask.sum() == mask.size - 2
    assert is_connected(SMALL, mask)


def test_fail_links_rejects_non_neighbours():
    with pytest.raises(ValueError):
        fail_links(SMALL, [(0, 5)])  # diagonal: Hamming distance 2


def test_fail_switches_removes_intermediate():
    healthy_pool, healthy_n = intermediate_pool(SMALL, no_faults(SMALL))
    assert healthy_n == SMALL.num_switches
    mask = fail_switches(SMALL, [3])
    assert not mask[3].any()
    pool, n_mid = intermediate_pool(SMALL, mask)
    assert n_mid == SMALL.num_switches - 1
    assert 3 not in pool.tolist()
    assert not is_connected(SMALL, mask)  # switch 3 is unreachable


def test_random_link_faults_rate_zero_and_bounds():
    assert random_link_faults(SMALL, 0.0).all()
    with pytest.raises(ValueError):
        random_link_faults(SMALL, 1.5)
    m1 = random_link_faults(SMALL, 0.2, seed=4)
    m2 = random_link_faults(SMALL, 0.2, seed=4)
    np.testing.assert_array_equal(m1, m2)  # deterministic in the seed


def test_faults_from_endpoints_deterministic_and_whole_switch():
    m1 = faults_from_endpoints(SMALL, [5, 9], seed=1)
    m2 = faults_from_endpoints(SMALL, [5, 9], seed=1)
    np.testing.assert_array_equal(m1, m2)
    assert not m1.all()  # each failed endpoint took a cable with it
    # all endpoints of switch 2 dead -> switch powered off
    eps = [2 * SMALL.concentration + c for c in range(SMALL.concentration)]
    mask = faults_from_endpoints(SMALL, eps, seed=1)
    assert not mask[2].any()


def test_workload_carries_mask_into_tables():
    mask = _one_link_mask()
    wl = apply_faults(_a2a_workload("row"), mask)
    prep = make_workload_tables(wl)
    np.testing.assert_array_equal(np.asarray(prep.tables.link_ok[0]), mask)
    assert int(prep.tables.n_mid[0]) == SMALL.num_switches
    healthy = make_workload_tables(_a2a_workload("row"))
    assert np.asarray(healthy.tables.link_ok).all()
    # same shape bucket: fault scenarios batch with healthy ones
    assert prep.tables.shape_bucket == healthy.tables.shape_bucket


def test_apply_faults_rejects_wrong_shape():
    with pytest.raises(ValueError):
        apply_faults(_a2a_workload("row"), np.ones((3, 3), dtype=bool))


# --------------------------------------------- seed-pinned min / omniwar
def test_min_omniwar_bit_identical_to_seed_outputs():
    """The registry-driven kernel must reproduce the recorded outputs of
    the seed (pre-subsystem) simulator exactly — same trajectories, same
    PRNG draws (policies without intermediates split 3 keys like the
    seed did)."""
    wl = _a2a_workload("row")
    r = get_engine(SMALL, mode="omniwar").run(wl, seed=0, horizon=5000)
    assert (r.makespan, r.delivered, r.injected) == (26, 240, 240)
    assert r.avg_latency == pytest.approx(5.6625)
    assert r.avg_hops == pytest.approx(1.0958333333333334)

    r = get_engine(SMALL, mode="min").run(wl, seed=0, horizon=5000)
    assert (r.makespan, r.delivered, r.injected) == (34, 240, 240)
    assert r.avg_latency == pytest.approx(8.525)
    assert r.avg_hops == pytest.approx(0.8)


def test_explicit_all_healthy_mask_is_identity():
    """A workload carrying an all-True mask must land in the same bucket
    and produce the same results as one carrying none."""
    wl = _a2a_workload("diagonal")
    wl_mask = apply_faults(wl, no_faults(SMALL))
    eng = get_engine(SMALL, mode="omniwar")
    assert eng.run(wl, seed=3, horizon=5000) == eng.run(
        wl_mask, seed=3, horizon=5000
    )


# --------------------------------- VAL / UGAL delivery + conservation
MASKS = {
    "healthy": None,
    "one_link": _one_link_mask,
    "two_links": _two_link_mask,
}


@pytest.mark.parametrize("mode", ["val", "ugal"])
@pytest.mark.parametrize("mask_name", list(MASKS))
def test_val_ugal_deliver_and_conserve(mode, mask_name):
    """Every injected packet is delivered exactly once (conservation) and
    all ranks complete — healthy and around dead links (escalation)."""
    mask = MASKS[mask_name]() if MASKS[mask_name] else None
    if mask is not None:
        assert is_connected(SMALL, mask)
    eng = get_engine(SMALL, mode=mode)
    wls = [_a2a_workload(s, link_ok=mask) for s in ("row", "diagonal")]
    for res in eng.run_batch(wls, seeds=[0, 1], horizon=20_000):
        assert res.completed
        assert res.delivered == 240          # == wl.target_packets
        assert res.injected == res.delivered  # no duplication, no loss
        assert res.max_hops < eng.static.V   # hop-indexed VC invariant


@pytest.mark.parametrize("mode", ["min", "omniwar", "val", "ugal"])
def test_hop_budget_invariant_under_faults(mode):
    """Observed worst-case hops stay inside the policy's declared VC
    budget (deadlock freedom, 2404.04315's constraint) even when routing
    around faults forces escalated deroutes."""
    eng = get_engine(SMALL, mode=mode)
    wl = _a2a_workload("row", link_ok=_two_link_mask())
    res = eng.run(wl, seed=2, horizon=20_000)
    assert res.completed
    policy = get_policy(mode)
    budget = policy.vc_budget(SMALL.q, policy.default_deroutes(SMALL.q))
    assert eng.static.V == budget
    assert res.max_hops < budget


def test_min_mode_fault_escalation_actually_deroutes():
    """Under min routing a dead minimal link forces non-minimal hops:
    the row partition's traffic is single-dimension (1 hop minimal), so
    routing around the dead (0, 1) cable must lengthen some path."""
    eng = get_engine(SMALL, mode="min")
    healthy = eng.run(_a2a_workload("row"), seed=0, horizon=20_000)
    assert healthy.max_hops == 1  # row a2a: strictly minimal, one dim
    faulty = eng.run(
        _a2a_workload("row", link_ok=_one_link_mask()), seed=0,
        horizon=20_000,
    )
    assert faulty.completed
    assert faulty.max_hops > healthy.max_hops  # escalated deroutes happened


if hst is not None:
    @given(
        hst.sampled_from(["val", "ugal"]),
        hst.sampled_from(["row", "diagonal", "l_shape"]),
        hst.integers(0, 2 ** 16),
        hst.integers(0, 2),
    )
    @settings(max_examples=10, deadline=None)
    def test_delivery_conservation_property(mode, strategy, seed, n_faults):
        """Property: for any seed and up to two dead cables (the n=4, q=2
        Hamming graph has min cut 6, so it stays connected), VAL/UGAL
        deliver every packet exactly once within the VC budget."""
        mask = None
        if n_faults:
            rng = np.random.default_rng(seed)
            cables = SMALL.link_array()
            pick = rng.choice(len(cables), size=n_faults, replace=False)
            mask = fail_links(
                SMALL, [tuple(map(int, cables[i])) for i in pick]
            )
            assert is_connected(SMALL, mask)
        eng = get_engine(SMALL, mode=mode)
        wl = _a2a_workload(strategy, link_ok=mask)
        res = eng.run(wl, seed=seed % 97, horizon=20_000)
        assert res.completed
        assert res.delivered == res.injected == 240
        assert res.max_hops < eng.static.V
else:
    def test_delivery_conservation_property():
        pytest.importorskip("hypothesis")


# ------------------------------------------------ compile economics pins
def test_routing_fault_grid_one_compile_per_bucket():
    """A routing x strategy x fault x seed grid through run_batch_seeds is
    ONE trace and ONE device call per shape bucket: fault masks and
    intermediate pools are workload *data*, not compile keys."""
    engine = SimEngine(SMALL, mode="ugal")
    masks = [None, _one_link_mask(), _two_link_mask()]
    wls = [
        _a2a_workload(s, link_ok=m)
        for s in ("row", "diagonal") for m in masks
    ]
    grid = engine.run_batch_seeds(wls, seeds=(0, 1), horizon=20_000)
    assert engine.trace_count == 1
    assert engine.device_calls == 1
    assert all(r.completed for per_seed in grid for r in per_seed)
    # the batched grid returns exactly the per-scenario results
    assert grid[1][1] == engine.run(wls[1], seed=1, horizon=20_000)


# --------------------------------------------- scheduler churn integration
def test_snapshot_churn_faults_lower_to_masks():
    from repro.sched import FailureEvent, Job, OnlineScheduler
    from repro.sched.bridge import snapshot_workload

    jobs = [
        Job(job_id=0, arrival=0.0, blocks=2, service=30.0),
        Job(job_id=1, arrival=1.0, blocks=1, service=30.0),
    ]
    sched = OnlineScheduler(SMALL, strategy="diagonal")
    res = sched.run_stream(
        jobs, failures=(FailureEvent(time=5.0, endpoints=(40,)),)
    )
    churned = [s for s in res.snapshots if s.failed_endpoints]
    assert churned, "failure produced no churned snapshot"
    snap = churned[-1]
    assert snap.failed_endpoints == (40,)
    wl = snapshot_workload(SMALL, snap, churn_faults=True)
    assert wl.link_ok is not None and not wl.link_ok.all()
    assert is_connected(SMALL, wl.link_ok)
    plain = snapshot_workload(SMALL, snap)
    assert plain.link_ok is None
