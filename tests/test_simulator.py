"""Simulator behaviour tests — conservation, determinism, PB ordering."""

import numpy as np
import pytest

from repro.core import traffic as tr
from repro.core.allocation import allocate_partition, machine_partitions
from repro.core.hyperx import HyperX
from repro.core.simulator import build_simulator, simulate

SMALL = HyperX(n=4, q=2)
PAPER = HyperX(n=8, q=2)


def _expect_packets(wl):
    return int(wl.npkts[~wl.infinite].sum())


@pytest.mark.parametrize("mode", ["min", "omniwar"])
def test_conservation_all_to_all(mode):
    part = allocate_partition("row", SMALL, 0)
    wl = tr.compose_workload(SMALL, [(tr.all_to_all(16), part)])
    res = simulate(SMALL, wl, mode=mode, horizon=5000)
    assert res.completed
    assert res.delivered == res.injected == _expect_packets(wl)


@pytest.mark.parametrize(
    "app",
    [
        tr.all_reduce(16, vector_packets=16),
        tr.stencil(16, "von_neumann", rounds=4),
        tr.stencil(16, "moore", rounds=2),
        tr.random_involution(16, packets=8),
        tr.uniform(16, packets=16),
        tr.random_permutation(16, packets=16),
    ],
    ids=lambda a: a.name,
)
def test_conservation_each_pattern(app):
    part = allocate_partition("diagonal", SMALL, 0)
    wl = tr.compose_workload(SMALL, [(app, part)])
    res = simulate(SMALL, wl, mode="omniwar", horizon=8000)
    assert res.completed
    assert res.delivered == res.injected == _expect_packets(wl)


def test_deterministic_same_seed():
    part = allocate_partition("l_shape", SMALL, 0)
    wl = tr.compose_workload(SMALL, [(tr.uniform(16, packets=8), part)])
    run = build_simulator(SMALL, wl, horizon=4000)
    a, b = run(seed=7), run(seed=7)
    assert a == b
    c = run(seed=8)
    assert c.completed  # different seed still completes


def test_min_mode_never_deroutes():
    part = allocate_partition("diagonal", SMALL, 0)
    wl = tr.compose_workload(SMALL, [(tr.uniform(16, packets=16), part)])
    res = simulate(SMALL, wl, mode="min", horizon=5000)
    # diagonal switches are mutually unaligned in both dims: avg minimal
    # distance is 2 - 2/n at switch level; MIN hop counts can never exceed it
    assert res.avg_hops <= 2.0 + 1e-6


def test_window_enforced_for_synchronous_kernels():
    """All-reduce (window=1) must be slower than its packet count alone:
    each of the 2*log2(k) steps serializes behind partner receives."""
    part = allocate_partition("row", SMALL, 0)
    ar = tr.all_reduce(16, vector_packets=8)
    wl = tr.compose_workload(SMALL, [(ar, part)])
    res = simulate(SMALL, wl, horizon=5000)
    assert res.completed
    assert res.makespan >= ar.T  # at least one cycle per synchronous step


@pytest.mark.slow
def test_pb_ordering_under_min_uniform_paper_scale():
    """The paper's central claim chain: PB predicts uniform-traffic makespan
    under MIN (Fig. 7 / Lesson 2): rectangular (PB=0.25) is clearly worst,
    diagonal/full-spread (PB>=2) in the best group."""
    makespans = {}
    for strat in ["row", "diagonal", "full_spread", "rectangular"]:
        parts = machine_partitions(strat, PAPER, num_jobs=8)
        apps = [(tr.uniform(64, packets=64), p) for p in parts]
        wl = tr.compose_workload(PAPER, apps)
        res = simulate(PAPER, wl, mode="min", horizon=30000)
        assert res.completed, strat
        makespans[strat] = res.makespan
    assert makespans["rectangular"] > 1.5 * makespans["row"]
    assert makespans["diagonal"] < makespans["row"]
    assert makespans["full_spread"] < makespans["row"]


@pytest.mark.slow
def test_background_interference_slows_target():
    part = allocate_partition("diagonal", PAPER, 0)
    app = tr.uniform(64, packets=64)
    iso = simulate(
        PAPER, tr.compose_workload(PAPER, [(app, part)]), horizon=30000
    )
    free = np.setdiff1d(np.arange(PAPER.num_endpoints), part.endpoints)
    bg = tr.background_noise(PAPER, free)
    wl = tr.compose_workload(PAPER, [(app, part)], background=[bg], warmup=400)
    noisy = simulate(PAPER, wl, horizon=60000)
    assert iso.completed and noisy.completed
    assert noisy.makespan > iso.makespan  # interference costs something


def test_fabric_partitioning_pools_isolate_state():
    """per_app pools give each app private FIFOs; workload still completes."""
    parts = machine_partitions("random_switch", SMALL, num_jobs=2)
    apps = [(tr.all_to_all(16), p) for p in parts]
    wl = tr.compose_workload(SMALL, apps, fabric_partitioning="per_app")
    assert wl.num_pools == 2
    res = simulate(SMALL, wl, horizon=8000)
    assert res.completed
    assert res.delivered == _expect_packets(wl)
