"""Packed-table tests: dtype selection, overflow guards, and the property
that int8/int16 packing is invisible in every SimResult field.

The property test runs under hypothesis when the host has it and falls
back to a fixed seeded sample of the same space otherwise (the container
image may not ship hypothesis; the property must still be exercised).
"""

import numpy as np
import pytest

from repro.core import traffic as tr
from repro.core.allocation import allocate_partition
from repro.core.engine import SimEngine, pack, pack_dtype
from repro.core.engine.tables import build_static_tables
from repro.core.hyperx import HyperX

try:
    from hypothesis import given, settings
    from hypothesis import strategies as st

    HAVE_HYPOTHESIS = True
except ImportError:  # pragma: no cover - image-dependent
    HAVE_HYPOTHESIS = False


# ------------------------------------------------------------ dtype selection
def test_pack_dtype_boundaries():
    assert pack_dtype(0) == np.int8
    assert pack_dtype(127) == np.int8
    assert pack_dtype(128) == np.int16
    assert pack_dtype(32767) == np.int16
    assert pack_dtype(32768) == np.int32


def test_pack_dtype_rejects_negative_bound():
    with pytest.raises(ValueError):
        pack_dtype(-1)


def test_pack_casts_and_keeps_sentinels():
    a = pack(np.array([-1, 0, 100]), 100)
    assert a.dtype == np.int8
    assert a.tolist() == [-1, 0, 100]
    assert pack(np.array([1000]), 1000).dtype == np.int16


def test_pack_overflow_guard():
    """Values beyond the declared bound must be refused, not wrapped."""
    with pytest.raises(OverflowError):
        pack(np.array([128]), 127)
    with pytest.raises(OverflowError):
        pack(np.array([-129]), 127)  # past the -bound-1 sentinel headroom


# ----------------------------------------------------- largest-k overflow path
def test_largest_k_machines_widen_to_int32():
    """The overflow guard at scale: bounds past int16 must select int32.

    A HyperX with S > 32767 switches (n=200, q=2 -> 40000) exceeds every
    packed dtype for switch-id tables; pack_dtype must fall back to int32
    rather than wrap.  (Bound-derived, so no table needs to be built.)
    """
    big = HyperX(n=200, q=2)
    assert big.num_switches == 40_000
    assert pack_dtype(big.num_switches - 1) == np.int32
    a = pack(np.array([big.num_switches - 1]), big.num_switches - 1)
    assert a.dtype == np.int32 and int(a[0]) == 39_999


def test_static_tables_pack_by_topology_bounds():
    """Mid-size machine: switch ids need int16, coordinates fit int8."""
    topo = HyperX(n=16, q=2)  # S = 256, n = 16
    st_tables = build_static_tables(topo, mode="omniwar", num_pools=1,
                                    max_deroutes=None, cap=8,
                                    penalty_packets=4, pack_tables=True)
    assert np.asarray(st_tables.nbr).dtype == np.int16    # bound S-1 = 255
    assert np.asarray(st_tables.coords).dtype == np.int8  # bound n-1 = 15
    unpacked = build_static_tables(topo, mode="omniwar", num_pools=1,
                                   max_deroutes=None, cap=8,
                                   penalty_packets=4, pack_tables=False)
    assert np.array_equal(np.asarray(st_tables.nbr, dtype=np.int32),
                          np.asarray(unpacked.nbr, dtype=np.int32))


# ------------------------------------------------------------- the property
def _packed_matches_reference(n, q, strategy, kind, seed):
    """Packed and int32-reference engines must agree on every field."""
    topo = HyperX(n=n, q=q)
    k = min(8, topo.num_endpoints)
    part = allocate_partition(strategy, topo, 0, size=k)
    app = tr.all_to_all(k) if kind == "a2a" else tr.uniform(k, packets=3)
    wl = tr.compose_workload(topo, [(app, part)])
    packed = SimEngine(topo, mode="omniwar", pack=True).run(
        wl, seed=seed, horizon=4000)
    ref = SimEngine(topo, mode="omniwar", pack=False).run(
        wl, seed=seed, horizon=4000)
    assert packed == ref  # dataclass equality: every field bit-identical


if HAVE_HYPOTHESIS:

    @settings(max_examples=10, deadline=None)
    @given(
        n=st.sampled_from([3, 4]),
        q=st.just(2),  # the allocator's supported envelope (paper machines)
        strategy=st.sampled_from(["row", "diagonal", "full_spread"]),
        kind=st.sampled_from(["a2a", "uniform"]),
        seed=st.integers(min_value=0, max_value=3),
    )
    def test_packed_tables_bit_identical_property(n, q, strategy, kind, seed):
        _packed_matches_reference(n, q, strategy, kind, seed)

else:

    @pytest.mark.parametrize(
        "n,q,strategy,kind,seed",
        [
            (3, 2, "row", "a2a", 0),
            (3, 2, "diagonal", "uniform", 1),
            (4, 2, "full_spread", "a2a", 2),
            (4, 2, "row", "uniform", 3),
            (4, 2, "diagonal", "a2a", 0),
            (3, 2, "full_spread", "uniform", 2),
        ],
    )
    def test_packed_tables_bit_identical_property(n, q, strategy, kind, seed):
        _packed_matches_reference(n, q, strategy, kind, seed)
