"""Per-architecture smoke tests (deliverable f) + decode-cache equivalence.

Every assigned architecture instantiates a REDUCED config of the same
family and runs one forward/train step on CPU, asserting output shapes and
finiteness; decodable families additionally verify that prefill+decode with
caches reproduces the full forward exactly (fp32, no MoE capacity drops).
"""

import dataclasses

import jax
import jax.numpy as jnp
import pytest

from repro.configs import ARCHS, get_config
from repro.models import transformer as M
from repro.models.module import abstract, count_params, init

RNG = jax.random.PRNGKey(0)
B, S = 2, 24


def make_batch(cfg, rng=RNG, batch=B, seq=S):
    out = {}
    if cfg.frame_input:
        out["frames"] = jax.random.normal(rng, (batch, seq, cfg.d_model),
                                          jnp.float32)
    else:
        out["tokens"] = jax.random.randint(rng, (batch, seq), 0, cfg.vocab)
    if cfg.family == "vlm":
        out["image_embeds"] = jax.random.normal(
            rng, (batch, cfg.frontend_tokens, cfg.d_model)
        )
    out["labels"] = jax.random.randint(rng, (batch, seq), 0, cfg.vocab)
    return out


@pytest.mark.parametrize("arch", ARCHS)
def test_smoke_train_step(arch):
    cfg = get_config(arch, reduced=True)
    specs = M.model_specs(cfg)
    params = init(RNG, specs)
    batch = make_batch(cfg)
    logits, aux = M.forward_train(cfg, params, batch, remat=False)
    assert logits.shape == (B, S, cfg.vocab_padded)
    assert bool(jnp.isfinite(logits).all()), "NaN/inf in logits"
    loss, parts = M.train_loss(cfg, params, batch)
    assert bool(jnp.isfinite(loss))
    # the gradient is a descent direction: some small step decreases loss
    grads = jax.grad(lambda p: M.train_loss(cfg, p, batch)[0])(params)
    decreased = False
    for lr in (0.05, 0.01, 0.002):
        params2 = jax.tree_util.tree_map(lambda p, g: p - lr * g, params, grads)
        loss2, _ = M.train_loss(cfg, params2, batch)
        if float(loss2) < float(loss):
            decreased = True
            break
    assert decreased, f"no step size decreased loss from {float(loss)}"


@pytest.mark.parametrize("arch", ARCHS)
def test_grad_finite(arch):
    cfg = get_config(arch, reduced=True)
    params = init(RNG, M.model_specs(cfg))
    batch = make_batch(cfg)
    grads = jax.grad(lambda p: M.train_loss(cfg, p, batch)[0])(params)
    leaves = jax.tree_util.tree_leaves(grads)
    assert all(bool(jnp.isfinite(g).all()) for g in leaves)


@pytest.mark.parametrize(
    "arch", [a for a in ARCHS if not get_config(a, reduced=True).encoder_only]
)
def test_decode_matches_forward(arch):
    """prefill + token-by-token decode == full forward (fp32, no drops)."""
    cfg = dataclasses.replace(
        get_config(arch, reduced=True), capacity_factor=16.0, dtype="float32"
    )
    params = init(RNG, M.model_specs(cfg))
    batch = make_batch(cfg)
    ref, _ = M.forward_train(cfg, params, batch, remat=False)
    pre = 16
    pre_batch = dict(batch)
    pre_batch["tokens"] = batch["tokens"][:, :pre]
    logits, caches = M.prefill(cfg, params, pre_batch, max_len=S)
    errs = [float(jnp.abs(logits[:, 0] - ref[:, pre - 1]).max())]
    for t in range(pre, S - 1):
        logits, caches = M.decode_step(
            cfg, params, batch["tokens"][:, t : t + 1], caches, t
        )
        errs.append(float(jnp.abs(logits[:, 0] - ref[:, t]).max()))
    assert max(errs) < 2e-4, f"decode/forward mismatch: {max(errs)}"


def test_windowed_cache_is_ring_buffer():
    """recurrentgemma's attention cache length equals its window, not the
    context length — the point of local attention at 500k."""
    cfg = get_config("recurrentgemma_9b", reduced=True)
    caches = M.init_caches(cfg, batch=1, max_len=4096)
    k = caches["hybrid"]["attn"]["k"]
    assert k.shape[2] == cfg.window  # (layers, batch, window, kv, dh)


def test_mamba_state_constant_in_context():
    cfg = get_config("mamba2_1_3b", reduced=True)
    c1 = M.init_caches(cfg, batch=1, max_len=1024)
    c2 = M.init_caches(cfg, batch=1, max_len=524288)
    assert (
        c1["ssm"]["state"].shape == c2["ssm"]["state"].shape
    )  # O(1) in context


def test_published_param_counts():
    expected = {
        "deepseek_67b": 67.4e9,
        "qwen3_0_6b": 0.6e9,
        "internlm2_1_8b": 1.89e9,
        "olmo_1b": 1.18e9,
        "mamba2_1_3b": 1.34e9,
        "deepseek_v2_236b": 239e9,
        "qwen3_moe_30b_a3b": 30.5e9,
        "llama_3_2_vision_90b": 87.7e9,
        "recurrentgemma_9b": 10.4e9,
    }
    for arch, want in expected.items():
        got = get_config(arch).param_count()
        assert abs(got - want) / want < 0.05, f"{arch}: {got/1e9:.2f}B"
    # MoE active params
    assert get_config("qwen3_moe_30b_a3b").active_param_count() < 4e9
    assert get_config("deepseek_v2_236b").active_param_count() < 25e9


def test_abstract_specs_no_allocation():
    cfg = get_config("deepseek_67b")  # FULL 67B config — zero bytes allocated
    ab = abstract(M.model_specs(cfg))
    leaves = jax.tree_util.tree_leaves(ab)
    assert all(isinstance(l, jax.ShapeDtypeStruct) for l in leaves)
    assert count_params(M.model_specs(cfg)) > 60e9


def test_shape_cells_and_skips():
    cfg = get_config("hubert_xlarge")
    skips = {c.name: c.skip for c in cfg.shapes()}
    assert skips["train_4k"] is None and skips["prefill_32k"] is None
    assert skips["decode_32k"] and skips["long_500k"]
    cfg = get_config("mamba2_1_3b")
    assert all(c.skip is None for c in cfg.shapes())
    cfg = get_config("deepseek_67b")
    assert cfg.shape("long_500k").skip is not None
