"""Sharding-rule tests: logical axes -> PartitionSpecs, divisibility."""

import jax
import numpy as np
import pytest
from jax.sharding import Mesh, PartitionSpec as P

from repro.models.module import spec
from repro.sharding.partitioning import (
    RULE_SETS,
    activation_mesh,
    constraint,
    logical_to_pspec,
    tree_shardings,
)


def mesh2(d=2, m=4):
    devs = np.array(jax.devices("cpu") * (d * m))[: d * m]
    # single-device CPU: build a logical mesh over repeated device is not
    # allowed; use a 1x1 mesh for API-level tests instead
    return Mesh(np.array(jax.devices()[:1]).reshape(1, 1), ("data", "model"))


def test_logical_to_pspec_basic():
    rules = RULE_SETS["base"]
    ps = logical_to_pspec(("vocab", "embed"), rules, {"data", "model"},
                          (1024, 512), {"data": 4, "model": 8})
    assert ps == P("model", None)


def test_logical_to_pspec_divisibility_guard():
    rules = RULE_SETS["base"]
    # kv_heads = 8 on a model=16 mesh must stay replicated
    ps = logical_to_pspec(("embed", "kv_heads", "head_dim"), rules,
                          {"data", "model"}, (1024, 8, 128),
                          {"data": 16, "model": 16})
    assert ps == P(None, None, None)
    # but kv_heads = 16 shards
    ps = logical_to_pspec(("embed", "kv_heads", "head_dim"), rules,
                          {"data", "model"}, (1024, 16, 128),
                          {"data": 16, "model": 16})
    assert ps == P(None, "model", None)


def test_fsdp_shards_embed_over_data():
    rules = RULE_SETS["fsdp"]
    ps = logical_to_pspec(("embed", "ff"), rules, {"data", "model"},
                          (8192, 28672), {"data": 16, "model": 16})
    assert ps == P("data", "model")


def test_batch_axis_uses_pod_and_data():
    rules = RULE_SETS["base"]
    ps = logical_to_pspec(("batch", "seq"), rules, {"pod", "data", "model"},
                          (256, 4096), {"pod": 2, "data": 16, "model": 16})
    assert ps == P(("pod", "data"), None)
    # batch=1 cannot shard
    ps = logical_to_pspec(("batch", "seq"), rules, {"pod", "data", "model"},
                          (1, 4096), {"pod": 2, "data": 16, "model": 16})
    assert ps == P(None, None)


def test_duplicate_mesh_axis_not_reused():
    rules = RULE_SETS["base"]
    # experts and ff both want 'model': first dim that fits wins
    ps = logical_to_pspec(("experts", "embed", "ff"), rules,
                          {"data", "model"}, (160, 5120, 1536),
                          {"data": 16, "model": 16})
    assert ps == P("model", None, None)


def test_tree_shardings_respects_shapes():
    m = mesh2()
    specs = {
        "wq": spec((64, 8, 16), ("embed", "heads", "head_dim")),
        "norm": spec((64,), ("embed",), init="ones"),
    }
    sh = tree_shardings(specs, m, "base")
    assert sh["wq"].spec == P(None, "model", None) or sh["wq"].spec == P(
        None, None, None
    )  # 1x1 mesh: everything effectively replicated but spec is well-formed
    assert isinstance(sh["norm"].spec, P)


def test_constraint_noop_without_mesh():
    import jax.numpy as jnp

    x = jnp.ones((4, 8))
    assert constraint(x, "batch", "embed") is x


def test_constraint_applies_inside_context():
    import jax.numpy as jnp

    m = mesh2()
    with activation_mesh(m, "base"):
        y = constraint(jnp.ones((4, 8)), "batch", "embed")
    assert y.shape == (4, 8)
