"""Topology-level tests (paper Section 2)."""

import numpy as np
import pytest

try:  # optional test extra (pip install -e .[test]); property tests need it
    from hypothesis import given, settings, strategies as st
except ImportError:  # pragma: no cover - exercised only without hypothesis
    given = settings = st = None

from repro.core.hyperx import HyperX


def test_sizes_2d_paper_machine():
    hx = HyperX(n=8, q=2)
    assert hx.num_switches == 64
    assert hx.num_endpoints == 512
    assert hx.num_links == 2 * 7 * 64 // 2  # q(n-1)n^q/2 = 448
    assert hx.diameter == 2
    assert hx.switch_radix == 2 * 7 + 8


def test_average_distance_formula():
    for n, q in [(4, 2), (8, 2), (4, 3)]:
        hx = HyperX(n=n, q=q)
        d = hx.distance_matrix()
        avg = d.mean()  # includes self pairs, the paper's convention
        assert avg == pytest.approx(q - q / n)
        assert d.max() == q


def test_coord_roundtrip():
    hx = HyperX(n=5, q=3)
    for s in range(hx.num_switches):
        assert hx.switch_id(hx.switch_coords(s)) == s


def test_links_bidirectional_unique():
    hx = HyperX(n=4, q=2)
    links = hx.link_array()
    assert len(links) == hx.num_links
    assert (links[:, 0] < links[:, 1]).all()
    # every link joins switches at Hamming distance exactly 1
    for a, b in links:
        assert hx.distance(int(a), int(b)) == 1


def test_neighbors_count():
    hx = HyperX(n=6, q=2)
    for s in [0, 7, 35]:
        nbrs = hx.neighbors(s)
        assert len(nbrs) == hx.q * (hx.n - 1)
        assert len(set(nbrs)) == len(nbrs)


if st is not None:
    @given(st.integers(2, 6), st.integers(1, 3),
           st.integers(0, 10**6), st.integers(0, 10**6))
    @settings(max_examples=50, deadline=None)
    def test_distance_is_hamming(n, q, a, b):
        hx = HyperX(n=n, q=q)
        s1, s2 = a % hx.num_switches, b % hx.num_switches
        c1, c2 = hx.switch_coords(s1), hx.switch_coords(s2)
        assert hx.distance(s1, s2) == sum(x != y for x, y in zip(c1, c2))
else:
    def test_distance_is_hamming():
        pytest.importorskip("hypothesis")


def test_minimal_paths_count_and_validity():
    hx = HyperX(n=4, q=2)
    # unaligned in both dims -> 2 minimal paths of length 2
    paths = hx.minimal_paths(hx.switch_id((0, 0)), hx.switch_id((2, 3)))
    assert len(paths) == 2
    for p in paths:
        assert len(p) == 3
        for u, v in zip(p, p[1:]):
            assert hx.distance(u, v) == 1
    # aligned -> single minimal path of length 1
    paths = hx.minimal_paths(hx.switch_id((0, 0)), hx.switch_id((0, 3)))
    assert len(paths) == 1 and len(paths[0]) == 2


def test_endpoint_addressing():
    hx = HyperX(n=4, q=2)
    e = hx.endpoint_id((1, 2), 3)
    assert hx.endpoint_switch(e) == hx.switch_id((1, 2))
    assert hx.endpoint_offset(e) == 3
