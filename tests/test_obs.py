"""Observability tests.

The load-bearing pins:

  * **telemetry neutrality** — a disabled ``TelemetrySpec`` (the default)
    produces bitwise-identical ``SimResult`` values AND identical compile
    counts to the pre-telemetry engine, across ``run_batch_seeds`` and
    ``run_grid``, on every registered routing policy;
  * enabled telemetry leaves the physics untouched (results still equal
    the reference bitwise) and its accumulators satisfy conservation
    invariants (injected = delivered = latency-histogram mass);
  * ``TelemetrySpec`` is part of the ``get_engine`` memo key;
  * the tracer writes parseable JSONL + manifest and the report renders;
  * tracing off is zero-cost: one shared nullcontext, no allocation.
"""

import json
import os

import numpy as np
import pytest

from repro.core import traffic as tr
from repro.core.allocation import allocate_partition
from repro.core.engine import SimEngine, get_engine
from repro.core.hyperx import HyperX
from repro.obs import TelemetrySpec
from repro.obs import report as obs_report
from repro.obs import trace as obs_trace
from repro.obs.probes import Telemetry
from repro.route import available_policies

SMALL = HyperX(n=4, q=2)


def _a2a(strategy: str):
    part = allocate_partition(strategy, SMALL, 0)
    return tr.compose_workload(SMALL, [(tr.all_to_all(16), part)])


# ------------------------------------------------------------- neutrality
@pytest.mark.parametrize("mode", available_policies())
def test_telemetry_off_bitwise_and_compile_neutral(mode):
    """The acceptance pin: default-off telemetry is invisible — same
    results bit-for-bit, same trace counts — on every routing policy."""
    base = SimEngine(SMALL, mode=mode)
    off = SimEngine(SMALL, mode=mode, telemetry=None)
    wls = [_a2a(s) for s in ("row", "diagonal")]
    seeds = (0, 3)

    ref_bs = base.run_batch_seeds(wls, seeds=seeds, horizon=4000)
    assert off.run_batch_seeds(wls, seeds=seeds, horizon=4000) == ref_bs
    ref_grid = base.run_grid(wls, seeds=seeds, horizon=4000)
    assert off.run_grid(wls, seeds=seeds, horizon=4000) == ref_grid
    assert off.trace_count == base.trace_count
    assert off.device_calls == base.device_calls
    for per_seed in ref_bs + ref_grid:
        for r in per_seed:
            assert r.telemetry is None


@pytest.mark.parametrize("mode", ["omniwar", "min"])
def test_telemetry_on_does_not_change_results(mode):
    """Enabled probes observe the simulation without perturbing it:
    SimResult equality (telemetry is compare=False) must still hold."""
    base = SimEngine(SMALL, mode=mode)
    on = SimEngine(SMALL, mode=mode, telemetry=TelemetrySpec())
    wls = [_a2a(s) for s in ("row", "diagonal")]
    seeds = (0, 3)
    ref = base.run_grid(wls, seeds=seeds, horizon=4000)
    got = on.run_grid(wls, seeds=seeds, horizon=4000)
    assert got == ref
    assert on.trace_count == base.trace_count  # one per bucket, still
    for per_seed in got:
        for r in per_seed:
            assert isinstance(r.telemetry, Telemetry)


def test_telemetry_invariants_and_grid_parity():
    """Conservation: every delivered packet lands in exactly one window
    and one latency bin; occupancy histograms sample every queue every
    cycle; run() and run_grid() accumulate identical series."""
    spec = TelemetrySpec()
    engine = SimEngine(SMALL, mode="omniwar", telemetry=spec)
    wl = _a2a("row")
    res = engine.run(wl, seed=0, horizon=4000)
    tel = res.telemetry
    assert tel is not None and tel.spec == spec

    packets = 16 * 15  # 16-rank all-to-all
    assert int(tel.injected.sum()) == packets
    assert int(tel.delivered.sum()) == packets
    assert int(tel.lat_hist.sum()) == packets
    assert int(tel.cycles.sum()) == tel.total_cycles > 0
    # occupancy histograms: one sample per (pool-queue, cycle)
    occ = tel.vc_occ  # (W, P*(CAP+1))
    num_queues = int(occ.sum()) // max(tel.total_cycles, 1)
    assert occ.sum() == num_queues * tel.total_cycles
    util = tel.link_utilization()
    assert util.shape == (tel.S, tel.net_ports)
    # the 2x crossbar speedup bounds a link at 2 grants/cycle
    assert float(util.max()) <= 2.0 + 1e-6
    assert len(tel.hottest_links(5)) == 5
    assert np.nanmax(tel.mean_latency()) > 0
    # the summary digest is JSON-serializable as emitted
    json.dumps(tel.summary("row"), default=obs_trace._json_default)

    # grid lanes accumulate the same series as the single run
    grid = engine.run_grid([wl], seeds=(0,), horizon=4000)
    gtel = grid[0][0].telemetry
    assert np.array_equal(gtel.link_util, tel.link_util)
    assert np.array_equal(gtel.lat_hist, tel.lat_hist)
    assert np.array_equal(gtel.vc_occ, tel.vc_occ)


def test_get_engine_telemetry_in_key():
    e0 = get_engine(SMALL, mode="omniwar")
    e1 = get_engine(SMALL, mode="omniwar", telemetry=TelemetrySpec())
    e2 = get_engine(SMALL, mode="omniwar", telemetry=TelemetrySpec())
    assert e0 is not e1
    assert e1 is e2  # spec is a frozen dataclass: equal specs share
    assert get_engine(SMALL, mode="omniwar") is e0
    assert e1.telemetry == TelemetrySpec()


def test_telemetry_spec_validation():
    with pytest.raises(ValueError):
        TelemetrySpec(n_windows=0)
    with pytest.raises(ValueError):
        TelemetrySpec(window=0)
    with pytest.raises(ValueError):
        TelemetrySpec(lat_bins=0)


# ----------------------------------------------------------------- tracing
def test_tracer_jsonl_manifest_and_report(tmp_path):
    d = str(tmp_path / "trace")
    try:
        obs_trace.configure(d, run_id="t1", suite="unit")
        with obs_trace.span("unit.work", grid="g"):
            obs_trace.event("unit.mark", job=7)
        obs_trace.counter("unit.count", 3)
        obs_trace.gauge("sched.frag", 0.25, stream="s/p", t_sim=1.0)
        obs_trace.event("sched.start", stream="s/p", job=1, backfilled=True)
        obs_trace.event("sched.arrive", stream="s/p", job=1)
    finally:
        obs_trace.disable()

    with open(os.path.join(d, "manifest.json")) as f:
        manifest = json.load(f)
    assert manifest["run_id"] == "t1"
    assert manifest["suite"] == "unit"
    assert manifest["schema"] == obs_trace.SCHEMA
    assert manifest["config_hash"]
    assert manifest["lane_backend"] in ("vmap", "pmap", "shard_map")

    with open(os.path.join(d, "events.jsonl")) as f:
        events = [json.loads(line) for line in f]
    names = [e["name"] for e in events]
    assert names[0] == "trace.start" and names[-1] == "trace.end"
    spans = [e for e in events if e["type"] == "span"]
    assert spans and spans[0]["name"] == "unit.work"
    assert spans[0]["dur_s"] >= 0 and spans[0]["grid"] == "g"

    paths = obs_report.write_report(d)
    assert os.path.exists(paths["report"])
    assert os.path.exists(paths["spans"])
    sched = obs_report.sched_rows(events)
    assert sched == [{
        "stream": "s/p", "arrived": 1, "started": 1, "backfilled": 1,
        "finished": 0, "migrations": 0, "requeues": 0, "failures": 0,
        "frag_mean": 0.25, "frag_max": 0.25, "utilization": "",
    }]


def test_engine_dispatch_spans(tmp_path):
    d = str(tmp_path / "trace")
    engine = SimEngine(SMALL, mode="omniwar")
    wl = _a2a("row")
    try:
        obs_trace.configure(d)
        engine.run(wl, seed=0, horizon=4000)
    finally:
        obs_trace.disable()
    with open(os.path.join(d, "events.jsonl")) as f:
        events = [json.loads(line) for line in f]
    spans = [e for e in events if e.get("name") == "engine.dispatch"]
    assert spans and spans[0]["api"] == "run"
    compiles = [e for e in events if e.get("name") == "engine.compile"]
    assert len(compiles) == engine.trace_count == 1


def test_span_off_is_shared_nullcontext():
    obs_trace.disable()
    assert obs_trace.active() is None
    s1 = obs_trace.span("a")
    s2 = obs_trace.span("b", attr=1)
    assert s1 is s2  # the shared singleton: no per-call allocation
    with s1:
        pass
    # emitters are silent no-ops with no tracer
    obs_trace.event("noop")
    obs_trace.counter("noop", 1)
    obs_trace.gauge("noop", 1.0)
    obs_trace.log_telemetry("noop", None)


def test_scheduler_emits_stream_events(tmp_path):
    from repro.sched.jobs import poisson_stream
    from repro.sched.scheduler import OnlineScheduler

    d = str(tmp_path / "trace")
    jobs = poisson_stream(8, seed=3)
    try:
        obs_trace.configure(d)
        res = OnlineScheduler(SMALL, strategy="diagonal",
                              analyze=False).run_stream(jobs)
    finally:
        obs_trace.disable()
    with open(os.path.join(d, "events.jsonl")) as f:
        events = [json.loads(line) for line in f]
    rows = obs_report.sched_rows(events)
    assert len(rows) == 1
    row = rows[0]
    assert row["stream"] == "diagonal/first_fit"
    assert row["arrived"] == len(jobs)
    assert row["finished"] == len(jobs)
    assert row["utilization"] == round(res.utilization, 4)
    assert row["frag_max"] == round(res.frag_max, 4)
