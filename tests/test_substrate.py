"""Substrate tests: optimizer, train step, data, checkpoint, serving."""

import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.checkpoint import Checkpointer
from repro.data.pipeline import SyntheticLM, make_batch_specs
from repro.models import transformer as M
from repro.models.module import init
from repro.serve import ServeEngine
from repro.train.optimizer import (
    AdamWConfig,
    adamw_init,
    adamw_update,
    cosine_schedule,
    global_norm,
)
from repro.train.train_step import TrainSettings, build_train_step, loss_and_grads

RNG = jax.random.PRNGKey(0)


def small():
    return get_config("qwen3_0_6b", reduced=True)


# ---------------------------------------------------------------- optimizer
def test_cosine_schedule_shape():
    c = AdamWConfig(lr_peak=1e-3, warmup_steps=10, total_steps=100)
    lrs = [float(cosine_schedule(c, jnp.int32(s))) for s in [0, 5, 10, 50, 100]]
    assert lrs[0] == 0.0
    assert lrs[1] == pytest.approx(5e-4)
    assert lrs[2] == pytest.approx(1e-3)
    assert lrs[3] < lrs[2]
    assert lrs[4] == pytest.approx(1e-4, rel=0.01)


def test_adamw_converges_quadratic():
    params = {"w": jnp.array([5.0, -3.0])}
    opt = adamw_init(params)
    c = AdamWConfig(lr_peak=0.2, warmup_steps=0, total_steps=200,
                    weight_decay=0.0, clip_norm=100.0)
    for _ in range(150):
        g = {"w": 2 * params["w"]}
        params, opt, m = adamw_update(c, params, g, opt)
    assert float(jnp.abs(params["w"]).max()) < 0.05


def test_grad_clipping():
    c = AdamWConfig(clip_norm=1.0)
    params = {"w": jnp.zeros(3)}
    opt = adamw_init(params)
    _, _, m = adamw_update(c, params, {"w": jnp.full(3, 100.0)}, opt)
    assert float(m["grad_norm"]) > 100.0  # pre-clip norm reported


# --------------------------------------------------------------- train step
def test_train_step_loss_decreases():
    cfg = small()
    params = init(RNG, M.model_specs(cfg))
    step = build_train_step(cfg, TrainSettings(
        microbatches=1, remat=False,
        opt=AdamWConfig(lr_peak=5e-3, warmup_steps=2, total_steps=50),
    ))
    step = jax.jit(step)
    opt = adamw_init(params)
    data = SyntheticLM(cfg)
    losses = []
    for _ in range(16):
        batch = jax.tree_util.tree_map(jnp.asarray, data.next_batch(4, 32))
        params, opt, metrics = step(params, opt, batch)
        losses.append(float(metrics["loss"]))
    assert min(losses[-3:]) < losses[0] - 0.15, losses


def test_microbatching_matches_full_batch():
    cfg = small()
    params = init(RNG, M.model_specs(cfg))
    data = SyntheticLM(cfg)
    batch = jax.tree_util.tree_map(jnp.asarray, data.next_batch(8, 16))
    l1, g1, _ = loss_and_grads(cfg, TrainSettings(microbatches=1, remat=False),
                               params, batch)
    l2, g2, _ = loss_and_grads(cfg, TrainSettings(microbatches=4, remat=False),
                               params, batch)
    assert float(jnp.abs(l1 - l2)) < 5e-2
    d = jax.tree_util.tree_map(
        lambda a, b: float(jnp.abs(a - b).max()), g1, g2
    )
    assert max(jax.tree_util.tree_leaves(d)) < 5e-2


def test_grad_compression_halves_bytes():
    cfg = small()
    params = init(RNG, M.model_specs(cfg))
    data = SyntheticLM(cfg)
    batch = jax.tree_util.tree_map(jnp.asarray, data.next_batch(4, 16))
    _, g_fp32, _ = loss_and_grads(cfg, TrainSettings(remat=False), params, batch)
    _, g_bf16, _ = loss_and_grads(
        cfg, TrainSettings(remat=False, grad_compression=True), params, batch
    )
    assert all(
        g.dtype == jnp.bfloat16
        for g in jax.tree_util.tree_leaves(g_bf16)
        if g.ndim > 0
    )
    # compressed grads approximate the fp32 grads
    n1, n2 = global_norm(g_fp32), global_norm(g_bf16)
    assert float(jnp.abs(n1 - n2) / n1) < 0.05


# --------------------------------------------------------------------- data
def test_data_deterministic_and_restartable():
    cfg = small()
    d1 = SyntheticLM(cfg, seed=7)
    batches = [d1.next_batch(4, 16) for _ in range(3)]
    d2 = SyntheticLM(cfg, seed=7)
    d2.load_state_dict({"seed": 7, "step": 2})
    np.testing.assert_array_equal(batches[2]["tokens"], d2.next_batch(4, 16)["tokens"])


def test_data_host_sharding_slices():
    cfg = small()
    d = SyntheticLM(cfg, seed=1)
    full = d.batch_at(0, 8, 16)
    part = d.batch_at(0, 8, 16, lo=2, hi=5)
    np.testing.assert_array_equal(full["tokens"][2:5], part["tokens"])


def test_batch_specs_match_real_batches():
    for arch in ("qwen3_0_6b", "hubert_xlarge", "llama_3_2_vision_90b"):
        cfg = get_config(arch, reduced=True)
        specs = make_batch_specs(cfg, 4, 16, "train")
        real = SyntheticLM(cfg).next_batch(4, 16)
        assert set(specs) == set(real), arch
        for k in specs:
            assert tuple(specs[k].shape) == tuple(real[k].shape), (arch, k)


# --------------------------------------------------------------- checkpoint
def test_checkpoint_roundtrip(tmp_path):
    cfg = small()
    params = init(RNG, M.model_specs(cfg))
    opt = adamw_init(params)
    ck = Checkpointer(str(tmp_path), keep_n=2)
    ck.save(3, {"params": params, "opt": opt}, extra={"data": {"seed": 7, "step": 9}})
    restored, extra = ck.restore({"params": params, "opt": opt})
    assert extra["data"]["step"] == 9
    a = jax.tree_util.tree_leaves(params)
    b = jax.tree_util.tree_leaves(restored["params"])
    for x, y in zip(a, b):
        np.testing.assert_array_equal(np.asarray(x), np.asarray(y))
    # opt state namedtuple survives
    assert int(restored["opt"].step) == 0


def test_checkpoint_gc_and_latest(tmp_path):
    ck = Checkpointer(str(tmp_path), keep_n=2)
    for s in (1, 2, 3, 4):
        ck.save(s, {"x": jnp.ones(3) * s})
    assert ck.latest_step() == 4
    assert len(os.listdir(tmp_path)) == 2  # GC kept 2


def test_checkpoint_async(tmp_path):
    ck = Checkpointer(str(tmp_path), async_save=True)
    ck.save(1, {"x": jnp.arange(5)})
    ck.wait()
    r, _ = ck.restore({"x": jnp.zeros(5, jnp.int32)})
    np.testing.assert_array_equal(np.asarray(r["x"]), np.arange(5))


def test_checkpoint_ignores_uncommitted(tmp_path):
    ck = Checkpointer(str(tmp_path))
    ck.save(1, {"x": jnp.ones(2)})
    os.makedirs(tmp_path / "step_000000002")
    assert ck.latest_step() == 1


# ------------------------------------------------------------------ serving
def test_serve_engine_generates():
    cfg = small()
    params = init(RNG, M.model_specs(cfg))
    eng = ServeEngine(cfg, params, max_len=64)
    data = SyntheticLM(cfg)
    batch = {"tokens": jnp.asarray(data.next_batch(2, 16)["tokens"])}
    out = eng.generate(batch, steps=8)
    assert out.shape == (2, 8)
    assert int(out.min()) >= 0 and int(out.max()) < cfg.vocab


def test_serve_rejects_encoder_only():
    cfg = get_config("hubert_xlarge", reduced=True)
    params = init(RNG, M.model_specs(cfg))
    with pytest.raises(ValueError):
        ServeEngine(cfg, params)
