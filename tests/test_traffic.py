"""Traffic/workload generation tests (paper Section 6.1)."""

import numpy as np
import pytest

from repro.core import traffic as tr
from repro.core.allocation import allocate_partition, machine_partitions
from repro.core.hyperx import HyperX

TOPO = HyperX(n=8, q=2)


def test_all_to_all_covers_everyone():
    app = tr.all_to_all(16)
    assert app.T == 15
    for r in range(16):
        dsts = set(app.sends_dst[r, :, 0].tolist())
        assert dsts == set(range(16)) - {r}
    assert app.window == 15  # asynchronous


def test_all_reduce_rabenseifner_structure():
    app = tr.all_reduce(16, vector_packets=64)
    assert app.T == 8  # 2 * log2(16)
    assert app.window == 1  # synchronous
    # partners are symmetric: if r sends to s at step t, s sends to r
    for t in range(app.T):
        d = app.sends_dst[:, t, 0]
        assert np.array_equal(d[d], np.arange(16))
    # scatter sizes halve: 32,16,8,4 then gather mirrors 4,8,16,32
    sizes = app.npkts[0, :, 0].tolist()
    assert sizes == [32, 16, 8, 4, 4, 8, 16, 32]
    with pytest.raises(ValueError):
        tr.all_reduce(12)


def test_stencil_neighbors():
    vn = tr.stencil(64, "von_neumann", rounds=2)
    assert vn.maxd == 4 and (vn.deg == 4).all()
    mo = tr.stencil(64, "moore", rounds=2)
    assert mo.maxd == 8 and (mo.deg == 8).all()
    # von Neumann neighbors are at grid distance 1 (torus wrap)
    gy = gx = 8
    for r in [0, 7, 63]:
        y, x = r // gx, r % gx
        for d in range(4):
            nb = vn.sends_dst[r, 0, d]
            ny, nx = nb // gx, nb % gx
            dy = min((y - ny) % gy, (ny - y) % gy)
            dx = min((x - nx) % gx, (nx - x) % gx)
            assert dy + dx == 1


def test_random_involution_is_involution():
    app = tr.random_involution(64, packets=4, seed=9)
    partner = app.sends_dst[:, 0, 0]
    assert np.array_equal(partner[partner], np.arange(64))
    assert not (partner == np.arange(64)).any()


def test_random_permutation_is_permutation_no_fixed_point():
    app = tr.random_permutation(64, packets=4, seed=3)
    perm = app.sends_dst[:, 0, 0]
    assert sorted(perm.tolist()) == list(range(64))
    assert not (perm == np.arange(64)).any()


def test_switch_permutation_groups():
    app = tr.random_switch_permutation(64, group=8, packets=4, seed=1)
    assert app.sampled.all()
    lo = app.lo[:, 0, 0]
    # each group of 8 ranks targets one 8-rank range, and it is not its own
    for g in range(8):
        blk = lo[8 * g : 8 * (g + 1)]
        assert len(set(blk.tolist())) == 1
        assert blk[0] != 8 * g
    # target groups form a permutation of the group set
    assert sorted(set((lo // 8).tolist())) == list(range(8))


def test_compose_rejects_overlap():
    part = allocate_partition("row", TOPO, 0)
    a1 = tr.uniform(64, packets=2)
    a2 = tr.uniform(64, packets=2)
    with pytest.raises(ValueError, match="disjoint"):
        tr.compose_workload(TOPO, [(a1, part), (a2, part)])


def test_compose_global_rank_space_and_pools():
    parts = machine_partitions("diagonal", TOPO, num_jobs=2)
    apps = [(tr.all_to_all(64), p) for p in parts]
    wl = tr.compose_workload(TOPO, apps, fabric_partitioning="per_app")
    assert wl.R == 128
    assert wl.num_pools == 2
    assert (wl.pool[:64] == 0).all() and (wl.pool[64:] == 1).all()
    # second app's destinations shifted into global rank space
    assert wl.sends_dst[64:, : wl.T, 0].min() >= 64


def test_background_noise_infinite():
    part = allocate_partition("row", TOPO, 0)
    free = np.setdiff1d(np.arange(TOPO.num_endpoints), part.endpoints)
    bg = tr.background_noise(TOPO, free)
    wl = tr.compose_workload(TOPO, [(tr.uniform(64, 2), part)], background=[bg],
                             warmup=100)
    assert wl.infinite.sum() == len(free)
    assert (wl.start[~wl.infinite] == 100).all()
    assert (wl.start[wl.infinite] == 0).all()
