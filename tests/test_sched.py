"""Online scheduler subsystem tests: ledger invariants, replay
determinism, event-loop behavior, FleetRuntime drop-in, and the
batched-SimEngine interference bridge (one compile per shape bucket)."""

import dataclasses

import numpy as np
import pytest

try:  # optional test extra (pip install -e .[test]); property tests need it
    from hypothesis import given, settings, strategies as st
except ImportError:  # pragma: no cover - exercised only without hypothesis
    given = settings = st = None

from repro.core.allocation import ALLOCATIONS, allocate_blocks, allocate_partition
from repro.core.hyperx import HyperX
from repro.runtime import FleetRuntime
from repro.sched import (
    BlockLedger,
    FailureEvent,
    Job,
    OnlineScheduler,
    evaluate_snapshots,
    heavy_tailed_stream,
    load_trace,
    poisson_stream,
    save_trace,
)
from repro.sched.bridge import pick_snapshots, snapshot_workload

STRATS = sorted(ALLOCATIONS)
SMALL = HyperX(n=4, q=2)
PAPER = HyperX(n=8, q=2)


# ------------------------------------------------------------ allocate_blocks
@pytest.mark.parametrize("strat", STRATS)
def test_allocate_blocks_matches_consecutive(strat):
    """Consecutive block lists reproduce allocate_partition exactly."""
    a = allocate_partition(strat, PAPER, 0, size=128, seed=3)
    b = allocate_blocks(strat, PAPER, [0, 1], size=128, seed=3)
    np.testing.assert_array_equal(a.endpoints, b.endpoints)


@pytest.mark.parametrize("strat", STRATS)
def test_allocate_blocks_arbitrary_sets_disjoint(strat):
    """Any disjoint block subsets yield disjoint endpoint sets."""
    p1 = allocate_blocks(strat, PAPER, [0, 5], seed=7)
    p2 = allocate_blocks(strat, PAPER, [2, 7], seed=7)
    assert len(np.unique(p1.endpoints)) == 128
    assert not np.intersect1d(p1.endpoints, p2.endpoints).size


def test_allocate_blocks_validates():
    with pytest.raises(ValueError):
        allocate_blocks("row", PAPER, [])
    with pytest.raises(ValueError):
        allocate_blocks("row", PAPER, [0, 0])
    with pytest.raises(ValueError):
        allocate_blocks("row", PAPER, [8])
    with pytest.raises(ValueError):
        allocate_blocks("row", PAPER, [0], size=65)


# ------------------------------------------------------------------- ledger
@pytest.mark.parametrize("strat", STRATS)
def test_ledger_fills_machine_disjoint(strat):
    led = BlockLedger(SMALL, strategy=strat)
    for _ in range(SMALL.n):
        led.place(1)
    led.check_conservation()
    assert led.capacity() == 0
    with pytest.raises(RuntimeError):
        led.place(1)


def test_ledger_policies_and_scatter():
    led = BlockLedger(SMALL, strategy="row", policy="first_fit")
    a = led.place(1)           # slot 0
    b = led.place(2)           # slots 1-2
    led.release(a.job_id)
    led.release(b.job_id)      # free: 0,1,2,3 contiguous
    c = led.place(2)           # first fit -> 0,1
    assert led.jobs[c.job_id].slots == (0, 1)
    led.place(1)               # slot 2
    led.release(c.job_id)      # free: 0,1 and 3 -> fragmented
    assert led.fragmentation() > 0
    d = led.place(3)           # no contiguous run of 3 -> scatter
    assert not led.jobs[d.job_id].contiguous
    led.check_conservation()


def test_ledger_best_fit_prefers_tight_run():
    led = BlockLedger(PAPER, strategy="row", policy="best_fit")
    holes = [led.place(1, job_id=100 + i) for i in range(8)]
    # free slots: a run of 2 (slots 1-2) and a run of 4 (slots 4-7)
    for jid in (101, 102, 104, 105, 106, 107):
        led.release(jid)
    part = led.place(2)
    assert led.jobs[part.job_id].slots == (1, 2)  # tightest run, not lowest-4
    del holes


def test_ledger_mixed_strategies_stay_disjoint():
    """Jobs placed under different strategies coexist because the slot
    views are derived from endpoint-level ground truth: a Rectangular job
    only sees rectangular blocks whose endpoints are actually free."""
    led = BlockLedger(PAPER, strategy="row")
    a = led.place(1)                           # row 0
    b = led.place(2, strategy="rectangular")   # rect blocks avoiding row 0
    assert led.jobs[b.job_id].slots == (2, 3)  # p=0,1 cover rows 0-1: held
    c = led.place(2)                           # row frame: rows 2-3 now held
    assert led.jobs[c.job_id].slots == (4, 5)
    led.check_conservation()  # raises on overlap
    assert not np.intersect1d(a.endpoints, b.endpoints).size
    assert not np.intersect1d(b.endpoints, c.endpoints).size


def test_ledger_failure_and_repair_cycle():
    led = BlockLedger(SMALL, strategy="row")
    part = led.place(1)
    dead = int(part.endpoints[0])
    affected = led.fail_endpoints([dead])
    assert affected == [part.job_id]
    led.check_conservation()
    # replace on the survivors: a different slot, disjoint from the dead ep
    newp = led.replace_job(part.job_id)
    assert dead not in newp.endpoints
    led.check_conservation()
    led.repair_endpoints([dead])
    led.check_conservation()
    assert led.free[dead]  # repaired and unheld -> back in the pool


if st is not None:
    @given(
        st.sampled_from(STRATS),
        st.lists(
            st.tuples(st.integers(1, 3), st.booleans()), min_size=1, max_size=24
        ),
        st.integers(0, 99),
    )
    @settings(max_examples=40, deadline=None)
    def test_ledger_conservation_property(strat, ops, seed):
        """Property: across random alloc/free cycles the ledger conserves
        endpoints and all placed partitions stay pairwise disjoint."""
        led = BlockLedger(SMALL, strategy=strat, seed=seed)
        placed = []
        for blocks, do_free in ops:
            if do_free and placed:
                led.release(placed.pop(0))
            else:
                try:
                    placed.append(led.place(blocks).job_id)
                except RuntimeError:
                    pass
            led.check_conservation()
            held = sum(len(led.jobs[j].slot_endpoints) for j in placed)
            assert led.capacity() + held == SMALL.num_endpoints
else:
    def test_ledger_conservation_property():
        pytest.importorskip("hypothesis")


# ------------------------------------------------------------- job streams
def test_stream_replay_bit_identical(tmp_path):
    a = poisson_stream(50, rate=0.5, seed=42)
    b = poisson_stream(50, rate=0.5, seed=42)
    assert a == b  # generation is deterministic in the seed
    path = str(tmp_path / "trace.csv")
    save_trace(a, path)
    assert load_trace(path) == a  # CSV round-trip is exact
    c = heavy_tailed_stream(50, seed=42)
    assert c == heavy_tailed_stream(50, seed=42)
    assert a != c


def test_scheduler_replay_bit_identical():
    """The whole scheduling run is deterministic given (stream, config)."""
    jobs = poisson_stream(80, rate=0.5, seed=9)
    runs = [
        OnlineScheduler(SMALL, strategy="diagonal").run_stream(jobs)
        for _ in range(2)
    ]
    assert [dataclasses.asdict(r) for r in runs[0].records] == \
           [dataclasses.asdict(r) for r in runs[1].records]
    assert runs[0].summary() == runs[1].summary()


if st is not None:
    @given(st.sampled_from(STRATS), st.integers(0, 999))
    @settings(max_examples=15, deadline=None)
    def test_scheduled_partitions_always_disjoint(strat, seed):
        """Property: at every scheduling event, placed partitions are
        pairwise disjoint and the ledger conserves endpoints (checked
        inside the loop via check_invariants)."""
        jobs = poisson_stream(
            30, rate=0.8, mean_service=4.0,
            block_weights=((1, 0.5), (2, 0.3), (3, 0.2)), seed=seed,
        )
        sched = OnlineScheduler(SMALL, strategy=strat, seed=seed)
        res = sched.run_stream(jobs, check_invariants=True)
        assert len(res.finished()) == 30
else:
    def test_scheduled_partitions_always_disjoint():
        pytest.importorskip("hypothesis")


# ---------------------------------------------------------------- event loop
def test_two_job_wait():
    """A job that cannot coexist with a running one waits exactly until
    the departure."""
    jobs = [
        Job(job_id=0, arrival=0.0, blocks=3, service=10.0),
        Job(job_id=1, arrival=1.0, blocks=2, service=5.0),
    ]
    res = OnlineScheduler(SMALL, strategy="diagonal").run_stream(jobs)
    r0, r1 = res.records
    assert r0.wait == 0.0
    assert r1.start == 10.0 and r1.wait == 9.0
    assert res.span == 15.0


def test_backfill_jumps_short_job_ahead():
    """EASY: a short small job backfills around a blocked big head job
    without delaying the head's reservation."""
    jobs = [
        Job(job_id=0, arrival=0.0, blocks=3, service=10.0),
        Job(job_id=1, arrival=1.0, blocks=4, service=5.0),   # blocked head
        Job(job_id=2, arrival=2.0, blocks=1, service=6.0),   # backfills
    ]
    res = OnlineScheduler(SMALL, strategy="row", backfill=True).run_stream(jobs)
    r = {x.job_id: x for x in res.records}
    assert r[2].start == 2.0          # fits the spare slot immediately
    assert r[1].start == 10.0         # head starts exactly at its shadow time
    no_bf = OnlineScheduler(SMALL, strategy="row", backfill=False).run_stream(jobs)
    r2 = {x.job_id: x for x in no_bf.records}
    assert r2[1].start == 10.0
    assert r2[2].start == 15.0        # FCFS: waits behind the whole-machine head


def test_backfill_does_not_delay_reservation():
    """A long backfill candidate that would consume the head's reserved
    slots is NOT started."""
    jobs = [
        Job(job_id=0, arrival=0.0, blocks=3, service=10.0),
        Job(job_id=1, arrival=1.0, blocks=4, service=5.0),    # blocked head
        Job(job_id=2, arrival=2.0, blocks=1, service=100.0),  # too long
    ]
    res = OnlineScheduler(SMALL, strategy="row").run_stream(jobs)
    r = {x.job_id: x for x in res.records}
    # the head needs every slot at its shadow time (t=10); job 2 outlives
    # the shadow and would steal one, so it must NOT be backfilled
    assert r[1].start == 10.0
    assert r[2].start == 15.0  # only after the whole-machine head departs


def test_failure_migration_and_requeue():
    """Failures re-place affected jobs (migration); when the survivors
    cannot host one, it is evicted and re-queued with remaining service."""
    jobs = [Job(job_id=0, arrival=0.0, blocks=2, service=20.0)]
    fail = FailureEvent(time=5.0, endpoints=(0,), repair_at=None)
    res = OnlineScheduler(SMALL, strategy="row").run_stream(
        jobs, failures=[fail], check_invariants=True
    )
    rec = res.records[0]
    assert rec.migrations == 1 and rec.requeues == 0
    assert rec.finish == 20.0  # migration is instantaneous (checkpoint model)

    # now kill a whole row's endpoints under every slot: job must requeue
    # until repair returns capacity
    big = [Job(job_id=0, arrival=0.0, blocks=4, service=20.0)]
    all_but_one_slot = tuple(range(16, 64))  # rows 1..3 of the n=4 machine
    ev = FailureEvent(time=5.0, endpoints=all_but_one_slot, repair_at=30.0)
    res = OnlineScheduler(SMALL, strategy="row").run_stream(
        big, failures=[ev], check_invariants=True
    )
    rec = res.records[0]
    assert rec.requeues == 1
    assert rec.finish == pytest.approx(45.0)  # 5 run + repair at 30 + 15 left


def test_oversized_job_rejected():
    with pytest.raises(ValueError):
        OnlineScheduler(SMALL).run_stream(
            [Job(job_id=0, arrival=0.0, blocks=5, service=1.0)]
        )


# --------------------------------------------------------- runtime drop-in
def test_fleet_runtime_accepts_block_ledger():
    """The ledger is a JobAllocator-compatible fleet allocator: repair and
    elastic shrink run through it, conserving endpoints throughout."""
    ledger = BlockLedger(PAPER, strategy="diagonal")
    rt = FleetRuntime((16, 16), ("data", "model"), strategy="diagonal",
                      allocator=ledger)
    assert rt.topo == PAPER
    dead = int(rt.placement.endpoints.reshape(-1)[0])
    ev = rt.fail([dead])
    assert ev["action"] == "reallocated"
    ledger.check_conservation()
    ev = rt.fail(np.arange(300))  # degrade -> elastic shrink
    assert "rescaled" in ev["action"]
    assert rt.healthy_devices() == 128
    ledger.check_conservation()


def test_ledger_seed_mutation_keeps_disjointness():
    """FleetRuntime's stochastic fallback mutates allocator.seed between
    placements; the slot-view cache must follow the seed or cached views
    disagree with what allocate_blocks actually places (overlap)."""
    led = BlockLedger(SMALL, strategy="random_switch", seed=0)
    a = led.place(1)
    led.seed = 1000  # what FleetRuntime._try_allocate does
    b = led.place(1)
    assert not np.intersect1d(a.endpoints, b.endpoints).size
    # partition endpoints must be exactly the held slot endpoints
    np.testing.assert_array_equal(
        np.sort(b.endpoints), np.sort(led.jobs[b.job_id].slot_endpoints)
    )
    led.check_conservation()


def test_shared_ledger_repair_spares_other_tenants():
    """A FleetRuntime repair on a shared ledger must only release the
    runtime's own job, never other tenants' allocations."""
    ledger = BlockLedger(SMALL, strategy="row")
    tenant = ledger.place(1, job_id=777)  # e.g. a stream job
    rt = FleetRuntime((3, 16), ("data", "model"), strategy="row",
                      allocator=ledger)
    dead = int(rt.placement.endpoints.reshape(-1)[0])
    ev = rt.fail([dead])
    assert ev["job_affected"]
    assert 777 in ledger.jobs  # the co-tenant survived the repair
    assert not ledger.free[tenant.endpoints].any()  # still held
    ledger.check_conservation()
    ledger.release(777)  # and its lifecycle still works


def test_ledger_topo_mismatch_rejected():
    with pytest.raises(ValueError):
        FleetRuntime((8, 8), ("data", "model"), topo=SMALL,
                     allocator=BlockLedger(PAPER))


# ------------------------------------------------------- interference bridge
def _small_stream_snapshots(strategies, num_jobs=200):
    jobs = poisson_stream(
        num_jobs, rate=0.45, mean_service=8.0,
        block_weights=((1, 0.6), (2, 0.4)), seed=7,
    )
    out = {}
    for strat in strategies:
        res = OnlineScheduler(SMALL, strategy=strat).run_stream(jobs)
        assert len(res.finished()) == num_jobs
        out[strat] = res.snapshots
    return out


def test_200_job_stream_all_strategies_end_to_end():
    """The acceptance scenario at test scale: a 200-job stream runs end to
    end for all 7 strategies and every summary emits the full metric set."""
    snaps = _small_stream_snapshots(STRATS)
    assert set(snaps) == set(STRATS)
    for strat in STRATS:
        wl = snapshot_workload(SMALL, pick_snapshots(snaps[strat], 1)[0])
        assert wl.R >= 32  # at least two co-resident jobs lowered


def test_snapshot_grid_one_compile_per_bucket():
    """Trace-counter pin: a strategy x snapshot x seed grid through the
    bridge costs one XLA trace and one device call per shape bucket.
    (The bridge reports deltas, because get_engine memoizes engines
    across the session.)"""
    from repro.core.engine import get_engine

    snaps = _small_stream_snapshots(("row", "diagonal", "full_spread"))
    selected = {k: pick_snapshots(v, 2) for k, v in snaps.items()}
    rows, stats = evaluate_snapshots(
        SMALL, selected, seeds=(0, 1), horizon=20_000
    )
    # memoised: one engine per configuration
    assert stats["engine"] is get_engine(SMALL, mode="omniwar", num_pools=1)
    buckets = {r["bucket"] for r in rows}
    assert stats["traces"] == len(buckets)
    assert stats["device_calls"] == len(buckets)
    assert len(rows) == 3 * 2 * 2  # strategies x snapshots x seeds
    assert all(r["completed"] for r in rows)
