"""Routing model tests (paper Section 2.2)."""

import numpy as np
import pytest

from repro.core.allocation import allocate_partition
from repro.core.hyperx import HyperX
from repro.core.properties import partition_bandwidth
from repro.core.routing import (
    LinkSpace,
    candidate_ports,
    empirical_partition_bandwidth,
    minimal_link_loads,
    saturation_throughput,
    uniform_partition_traffic,
)

TOPO = HyperX(n=8, q=2)


def test_linkspace_roundtrip():
    ls = LinkSpace(TOPO)
    src = np.array([0, 5, 63])
    dim = np.array([0, 1, 1])
    val = np.array([3, 0, 7])
    ids = ls.link_id(src, dim, val)
    s, d, v = ls.decode(ids)
    assert np.array_equal(s, src) and np.array_equal(d, dim) and np.array_equal(v, val)


def test_minimal_link_loads_conserve_flow():
    # total link load == sum of traffic * distance
    rng = np.random.default_rng(0)
    S = TOPO.num_switches
    t = rng.random((S, S)) * (rng.random((S, S)) < 0.1)
    np.fill_diagonal(t, 0)
    load = minimal_link_loads(TOPO, t)
    dist = TOPO.distance_matrix()
    assert load.sum() == pytest.approx((t * dist).sum())


def test_uniform_full_machine_saturates_at_one():
    """A well-balanced HyperX sustains 1 phit/cycle/endpoint under uniform
    random traffic with minimal routing (paper Sec. 2.1)."""
    all_eps = np.arange(TOPO.num_endpoints)
    t = uniform_partition_traffic(TOPO, all_eps)
    assert saturation_throughput(TOPO, t) == pytest.approx(1.0, rel=0.02)


@pytest.mark.parametrize("strat", ["row", "diagonal", "full_spread"])
def test_empirical_pb_equals_analytic(strat):
    part = allocate_partition(strat, TOPO, 0)
    pb, _ = partition_bandwidth(TOPO, part.endpoints)
    emp = empirical_partition_bandwidth(TOPO, part.endpoints)
    assert emp == pytest.approx(pb, rel=0.05)


def test_candidate_ports_min_mode():
    ls = LinkSpace(TOPO)
    cur = np.array([TOPO.switch_id((0, 0))])
    dst = np.array([TOPO.switch_id((3, 5))])
    der = np.array([2])
    lid, is_min, valid = candidate_ports(ls, cur, dst, der, mode="min")
    # exactly two minimal ports (one per unaligned dimension)
    assert valid.sum() == 2
    assert (valid == is_min).all()


def test_candidate_ports_omniwar_deroutes():
    ls = LinkSpace(TOPO)
    cur = np.array([TOPO.switch_id((0, 0))])
    dst = np.array([TOPO.switch_id((3, 5))])
    lid, is_min, valid = candidate_ports(ls, cur, dst, np.array([2]), mode="omniwar")
    # every non-self port in each unaligned dimension: 2 * (n - 1) = 14
    assert valid.sum() == 2 * (TOPO.n - 1)
    assert is_min[valid].sum() == 2
    # without deroute budget, only minimal hops remain
    _, _, valid0 = candidate_ports(ls, cur, dst, np.array([0]), mode="omniwar")
    assert valid0.sum() == 2


def test_candidate_ports_aligned_dimension_closed():
    ls = LinkSpace(TOPO)
    cur = np.array([TOPO.switch_id((0, 0))])
    dst = np.array([TOPO.switch_id((0, 5))])  # aligned in dim 0
    lid, is_min, valid = candidate_ports(ls, cur, dst, np.array([2]))
    v = valid.reshape(TOPO.q, TOPO.n)
    assert v[0].sum() == 0  # no moves in the aligned dimension (Omni-WAR rule)
    assert v[1].sum() == TOPO.n - 1
