"""Pallas kernel sweeps vs pure-jnp oracles (interpret mode on CPU).

Per the deliverable: every kernel sweeps shapes/dtypes and asserts
allclose against the ref.py oracle.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ref
from repro.kernels.flash_attention import flash_attention
from repro.kernels.flash_ops import flash_attention as flash_model_layout
from repro.kernels.ssd_ops import ssd
from repro.kernels.ssd_scan import ssd_scan

RNG = jax.random.PRNGKey(42)


def _tol(dtype):
    return dict(atol=2e-2, rtol=2e-2) if dtype == jnp.bfloat16 else dict(
        atol=2e-5, rtol=2e-5
    )


# ------------------------------------------------------------------- flash
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize(
    "B,H,KV,S,D,bq,bk",
    [
        (1, 2, 2, 128, 64, 64, 64),     # MHA
        (2, 4, 2, 256, 64, 64, 64),     # GQA rep=2
        (1, 8, 1, 128, 128, 128, 128),  # MQA, MXU-aligned dh
        (1, 2, 2, 192, 32, 64, 64),     # S not a multiple of bq*? (192=3x64)
    ],
)
@pytest.mark.parametrize("causal,window", [(True, 0), (False, 0), (True, 64)])
def test_flash_attention_sweep(dtype, B, H, KV, S, D, bq, bk, causal, window):
    ks = jax.random.split(RNG, 3)
    q = jax.random.normal(ks[0], (B, H, S, D), dtype)
    k = jax.random.normal(ks[1], (B, KV, S, D), dtype)
    v = jax.random.normal(ks[2], (B, KV, S, D), dtype)
    out = flash_attention(q, k, v, causal=causal, window=window, bq=bq, bk=bk)
    want = ref.attention_ref(q, k, v, causal=causal, window=window)
    np.testing.assert_allclose(
        np.asarray(out, np.float32), np.asarray(want, np.float32), **_tol(dtype)
    )


def test_flash_model_layout_matches_chunked_sdpa():
    """ops.py wrapper (model layout, padding) vs the model's jnp path."""
    from repro.models.layers import chunked_sdpa

    B, S, G, rep, dh = 2, 96, 2, 3, 32
    ks = jax.random.split(RNG, 3)
    q = jax.random.normal(ks[0], (B, S, G, rep, dh), jnp.float32)
    k = jax.random.normal(ks[1], (B, S, G, dh), jnp.float32)
    v = jax.random.normal(ks[2], (B, S, G, dh), jnp.float32)
    pos = jnp.broadcast_to(jnp.arange(S)[None], (B, S))
    want = chunked_sdpa(q, k, v, pos, pos, causal=True, window=0, chunk=32)
    got = flash_model_layout(q, k, v, pos, pos, causal=True, window=0,
                             bq=32, bk=32)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), atol=2e-5,
                               rtol=2e-5)


# --------------------------------------------------------------------- ssd
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize(
    "B,S,H,P,N,chunk",
    [
        (1, 64, 2, 16, 32, 16),
        (2, 128, 3, 32, 64, 32),
        (1, 96, 1, 8, 16, 32),     # S multiple of chunk, odd dims
    ],
)
def test_ssd_sweep(dtype, B, S, H, P, N, chunk):
    ks = jax.random.split(RNG, 5)
    x = (jax.random.normal(ks[0], (B, S, H, P)) * 0.5).astype(dtype)
    dt = jax.nn.softplus(jax.random.normal(ks[1], (B, S, H))).astype(dtype)
    A = -jnp.exp(jax.random.normal(ks[2], (H,)) * 0.3).astype(dtype)
    Bm = (jax.random.normal(ks[3], (B, S, N)) * 0.3).astype(dtype)
    Cm = (jax.random.normal(ks[4], (B, S, N)) * 0.3).astype(dtype)
    y, st = ssd_scan(x, dt, A, Bm, Cm, chunk=chunk)
    yr, sr = ref.ssd_ref(x, dt, A, Bm, Cm)
    tol = dict(atol=5e-2, rtol=5e-2) if dtype == jnp.bfloat16 else dict(
        atol=5e-5, rtol=5e-4
    )
    np.testing.assert_allclose(
        np.asarray(y, np.float32), np.asarray(yr, np.float32), **tol
    )
    np.testing.assert_allclose(
        np.asarray(st.transpose(0, 1, 3, 2), np.float32),
        np.asarray(sr, np.float32), **tol,
    )


def test_ssd_ops_padding_path():
    """S not divisible by chunk goes through the zero-dt padding path."""
    B, S, H, P, N = 1, 50, 2, 8, 16
    ks = jax.random.split(RNG, 5)
    x = jax.random.normal(ks[0], (B, S, H, P)) * 0.5
    dt = jax.nn.softplus(jax.random.normal(ks[1], (B, S, H)))
    A = -jnp.exp(jax.random.normal(ks[2], (H,)) * 0.3)
    Bm = jax.random.normal(ks[3], (B, S, N)) * 0.3
    Cm = jax.random.normal(ks[4], (B, S, N)) * 0.3
    y, st = ssd(x, dt, A, Bm, Cm, chunk=16)
    yr, sr = ref.ssd_ref(x, dt, A, Bm, Cm)
    np.testing.assert_allclose(np.asarray(y), np.asarray(yr), atol=5e-5,
                               rtol=5e-4)
    np.testing.assert_allclose(np.asarray(st), np.asarray(sr), atol=5e-5,
                               rtol=5e-4)


def test_ssd_kernel_matches_model_reference():
    """kernel == models.ssm.ssd_chunked == sequential recurrence."""
    from repro.models.ssm import ssd_chunked

    B, S, H, P, N = 2, 64, 2, 16, 32
    ks = jax.random.split(RNG, 5)
    x = jax.random.normal(ks[0], (B, S, H, P)) * 0.5
    dt = jax.nn.softplus(jax.random.normal(ks[1], (B, S, H)))
    A = -jnp.exp(jax.random.normal(ks[2], (H,)) * 0.3)
    Bm = jax.random.normal(ks[3], (B, S, N)) * 0.3
    Cm = jax.random.normal(ks[4], (B, S, N)) * 0.3
    yk, _ = ssd(x, dt, A, Bm, Cm, chunk=16)
    yc, _ = ssd_chunked(x, dt, A, Bm, Cm, chunk=16)
    np.testing.assert_allclose(np.asarray(yk), np.asarray(yc), atol=5e-5,
                               rtol=5e-4)
