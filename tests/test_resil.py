"""Resilience subsystem tests (engine side): epoch-schedule lowering,
the E=1 bit-identity + trace-count pins vs the static fault path (across
``run``, ``run_batch_seeds`` AND ``run_grid``, all routing policies),
dynamic mid-flight mask flips, fault edge cases (fully-dead switch, dead
self-ports), telemetry fault counters, and the packet-conservation
property under arbitrary epoch schedules."""

import numpy as np
import pytest

try:  # optional test extra (pip install -e .[test]); property tests need it
    from hypothesis import given, settings, strategies as hst
except ImportError:  # pragma: no cover - exercised only without hypothesis
    given = settings = hst = None

from repro.core import traffic as tr
from repro.core.allocation import allocate_partition
from repro.core.engine import SimEngine
from repro.core.hyperx import HyperX
from repro.obs import TelemetrySpec
from repro.resil import (
    FaultSchedule,
    apply_schedule,
    exponential_lifetimes,
    sample_components,
    schedule_from_masks,
    scripted_campaign,
    static_schedule,
    to_epoch_schedule,
    to_failure_events,
)
from repro.route import (
    apply_faults,
    fail_links,
    fail_switches,
    no_faults,
    self_port_mask,
)

SMALL = HyperX(n=4, q=2)
POLICIES = ("min", "omniwar", "ugal", "val")


def _a2a(strategy="diagonal", link_ok=None, schedule=None):
    part = allocate_partition(strategy, SMALL, 0)
    wl = tr.compose_workload(
        SMALL, [(tr.all_to_all(16), part)], link_ok=link_ok
    )
    if schedule is not None:
        wl = apply_schedule(wl, schedule)
    return wl


def _conserved(r):
    assert r.injected == r.ejected + r.stranded
    assert sum(r.epoch_injected) == r.injected
    assert sum(r.epoch_delivered) == r.delivered
    assert r.delivered <= r.injected


# ---------------------------------------------------------- schedule objects
def test_fault_schedule_validation():
    mask = no_faults(SMALL)[None]
    with pytest.raises(ValueError, match="start at cycle 0"):
        FaultSchedule(epoch_start=np.array([5]), link_ok=mask)
    with pytest.raises(ValueError, match="strictly increasing"):
        FaultSchedule(
            epoch_start=np.array([0, 9, 9]),
            link_ok=np.repeat(mask, 3, axis=0),
        )
    with pytest.raises(ValueError, match="NE=2"):
        FaultSchedule(epoch_start=np.array([0, 4]), link_ok=mask)
    s = FaultSchedule(epoch_start=np.array([0, 10]),
                      link_ok=np.repeat(mask, 2, axis=0))
    assert s.NE == 2
    assert s.epoch_at(0) == 0 and s.epoch_at(9) == 0 and s.epoch_at(10) == 1
    assert s.mask_at(10_000).shape == (SMALL.num_switches, SMALL.q * SMALL.n)


def test_schedule_from_masks_prepends_healthy_epoch0():
    m = fail_links(SMALL, [(0, 1)])
    s = schedule_from_masks(SMALL, [(7, m)])
    assert s.NE == 2 and s.epoch_start.tolist() == [0, 7]
    assert s.link_ok[0].all()                 # synthesized healthy epoch 0
    assert (s.link_ok[1] == m).all()
    # duplicate start cycles: last-given mask wins (event sourcing)
    m2 = fail_links(SMALL, [(5, 9)])
    s2 = schedule_from_masks(SMALL, [(0, m), (0, m2)])
    assert s2.NE == 1 and (s2.link_ok[0] == m2).all()
    with pytest.raises(ValueError, match="mask shape"):
        schedule_from_masks(SMALL, [(0, np.ones((3, 3), dtype=bool))])


def test_apply_schedule_rejects_topology_mismatch():
    other = HyperX(n=3, q=2)
    with pytest.raises(ValueError, match="workload topology"):
        apply_schedule(_a2a(), static_schedule(other))


# ----------------------------------------------------- E=1 bit-identity pins
@pytest.mark.parametrize("mode", POLICIES)
def test_one_epoch_schedule_bit_identical_to_static_path(mode):
    """A 1-epoch schedule must lower to the engine's static fault path:
    every SimResult field exact, and no extra XLA trace (same bucket)."""
    engine = SimEngine(SMALL, mode=mode)
    mask = fail_links(SMALL, [(0, 1), (5, 9)])
    r_static = engine.run(_a2a(link_ok=mask), seed=3, horizon=5000)
    r_sched = engine.run(
        _a2a(schedule=static_schedule(SMALL, mask)), seed=3, horizon=5000
    )
    assert r_static == r_sched  # dataclass equality: every field exact
    assert engine.trace_count == 1  # E=1 shares the static compilation
    assert engine.device_calls == 2


@pytest.mark.parametrize("mode", POLICIES)
def test_e1_pin_run_batch_seeds_and_run_grid(mode):
    """The E=1 pin holds through both batch dispatchers: static-mask and
    1-epoch-schedule workloads land in one bucket, one trace, and produce
    bit-identical grids."""
    engine = SimEngine(SMALL, mode=mode)
    mask = fail_links(SMALL, [(0, 1)])
    wls = [
        _a2a(link_ok=mask),
        _a2a(schedule=static_schedule(SMALL, mask)),
    ]
    seeds = (0, 3)
    bs = engine.run_batch_seeds(wls, seeds=seeds, horizon=4000)
    assert engine.trace_count == 1
    assert engine.device_calls == 1
    grid = engine.run_grid(wls, seeds=seeds, horizon=4000)
    assert grid == bs                    # grid == batch_seeds, bitwise
    assert bs[1] == bs[0]                # schedule lane == static lane
    assert engine.trace_count == 1       # no re-trace across dispatchers


def test_unscheduled_workload_tables_stay_single_epoch():
    engine = SimEngine(SMALL, mode="min")
    prep = engine.prepare(_a2a())
    assert prep.NE == 1
    assert prep.tables.NE == 1
    assert prep.tables.epoch_start.tolist() == [0]


# ------------------------------------------------------------ dynamic epochs
def test_mid_flight_flip_counts_per_epoch():
    """A fail/repair campaign opens three epochs; the per-epoch counters
    tile the totals and the run still completes after the repair."""
    events = scripted_campaign([
        (5, "fail", "link", (0, 1)),
        (15, "repair", "link", (0, 1)),
    ])
    sched = to_epoch_schedule(SMALL, events)
    assert sched.NE == 3
    assert sched.epoch_start.tolist() == [0, 5, 15]
    assert sched.link_ok[0].all() and sched.link_ok[2].all()
    assert not sched.link_ok[1].all()

    engine = SimEngine(SMALL, mode="min")
    r = engine.run(_a2a(schedule=sched), seed=0, horizon=8000)
    _conserved(r)
    assert len(r.epoch_delivered) == 3
    assert r.completed
    assert sum(1 for x in r.epoch_delivered if x > 0) >= 2


def test_epoch_padding_is_semantics_free():
    """NE pads to a power of two; a 3-epoch schedule (padded to 4) must
    attribute zero traffic to the pad epoch."""
    events = scripted_campaign([
        (30, "fail", "link", (2, 6)),
        (90, "repair", "link", (2, 6)),
    ])
    engine = SimEngine(SMALL, mode="omniwar")
    r = engine.run(_a2a(schedule=to_epoch_schedule(SMALL, events)),
                   seed=1, horizon=8000)
    _conserved(r)
    assert len(r.epoch_delivered) == 3  # trimmed back to the real NE


def test_fully_dead_switch_strands_but_conserves():
    """A switch that powers off mid-run strands its traffic; nothing is
    double-counted and the sim terminates cleanly at the horizon."""
    events = scripted_campaign([(20, "fail", "switch", (0,))])
    sched = to_epoch_schedule(SMALL, events)
    assert sched.NE == 2
    assert not sched.link_ok[1][0].any()      # all outgoing ports dead
    engine = SimEngine(SMALL, mode="min")
    target = _a2a().target_packets
    r = engine.run(_a2a(schedule=sched), seed=0, horizon=3000)
    _conserved(r)
    assert not r.completed
    assert r.stranded > 0
    assert r.delivered < target


def test_dead_self_ports_are_invariant():
    """Self-ports are never valid links; additionally marking them dead in
    every epoch mask must not change any simulated field."""
    coords = SMALL.all_switch_coords()
    valid = self_port_mask(coords, SMALL.n, SMALL.q)
    mask = fail_links(SMALL, [(0, 1)])
    sched_a = schedule_from_masks(SMALL, [(0, mask), (50, no_faults(SMALL))])
    sched_b = schedule_from_masks(
        SMALL, [(0, mask & valid), (50, no_faults(SMALL) & valid)]
    )
    engine = SimEngine(SMALL, mode="omniwar")
    ra = engine.run(_a2a(schedule=sched_a), seed=5, horizon=5000)
    rb = engine.run(_a2a(schedule=sched_b), seed=5, horizon=5000)
    assert ra == rb
    assert engine.trace_count == 1


def test_schedule_stacks_with_static_mask():
    """apply_schedule composes with a permanent wl.link_ok mask: the
    engine ANDs both, so a run with (static dead cable) + (healthy
    schedule) equals the static-only run."""
    mask = fail_links(SMALL, [(5, 9)])
    engine = SimEngine(SMALL, mode="ugal")
    r_static = engine.run(_a2a(link_ok=mask), seed=2, horizon=5000)
    r_both = engine.run(
        _a2a(link_ok=mask, schedule=static_schedule(SMALL)), seed=2,
        horizon=5000,
    )
    assert r_static == r_both


# -------------------------------------------------------- telemetry counters
def test_telemetry_counts_epoch_flips_and_dead_links():
    spec = TelemetrySpec(n_windows=8, window=512)
    events = scripted_campaign([
        (5, "fail", "link", (0, 1)),
        (15, "repair", "link", (0, 1)),
    ])
    engine = SimEngine(SMALL, mode="min", telemetry=spec)
    r = engine.run(_a2a(schedule=to_epoch_schedule(SMALL, events)),
                   seed=0, horizon=8000)
    tel = r.telemetry
    assert int(tel.epoch_flips.sum()) == 2      # one flip per boundary
    assert float(tel.mean_dead_links().max()) > 0.0
    assert tel.summary()["epoch_flips"] == 2
    r0 = engine.run(_a2a(), seed=0, horizon=8000)
    assert int(r0.telemetry.epoch_flips.sum()) == 0
    assert float(r0.telemetry.dead_links.sum()) == 0.0


# ----------------------------------------------------------- fault processes
def test_exponential_lifetimes_deterministic_and_alternating():
    comps = sample_components(SMALL, n_links=3, seed=7)
    assert len(comps) == 3 and all(k == "link" for k, _ in comps)
    ev1 = exponential_lifetimes(comps, mtbf=30, mttr=10, horizon=500, seed=7)
    ev2 = exponential_lifetimes(comps, mtbf=30, mttr=10, horizon=500, seed=7)
    assert ev1 == ev2
    assert ev1 == sorted(ev1)
    for comp in comps:
        kinds = [e.up for e in ev1 if (e.kind, e.ident) == comp]
        # per component: strict fail/repair alternation starting at a fail
        assert kinds == [bool(i % 2) for i in range(len(kinds))]
    with pytest.raises(ValueError, match="positive"):
        exponential_lifetimes(comps, mtbf=-1, mttr=10, horizon=100)


def test_to_epoch_schedule_coarsens_deterministically():
    comps = sample_components(SMALL, n_links=8, seed=3)
    events = exponential_lifetimes(comps, mtbf=20, mttr=8, horizon=2000,
                                   seed=3)
    full = to_epoch_schedule(SMALL, events, max_epochs=1024)
    coarse = to_epoch_schedule(SMALL, events, max_epochs=6)
    assert full.NE > 6 >= coarse.NE
    assert coarse.epoch_start[0] == 0
    assert (np.diff(coarse.epoch_start) > 0).all()
    # coarse boundaries are a subset of the full replay's boundaries
    assert set(coarse.epoch_start.tolist()) <= set(full.epoch_start.tolist())
    with pytest.raises(ValueError, match="max_epochs"):
        to_epoch_schedule(SMALL, events, max_epochs=0)


def test_scripted_campaign_validates_and_switch_mask_matches():
    with pytest.raises(ValueError, match="unknown action"):
        scripted_campaign([(0, "explode", "link", (0, 1))])
    with pytest.raises(ValueError, match="unknown component kind"):
        scripted_campaign([(0, "fail", "cable", (0, 1))])
    sched = to_epoch_schedule(
        SMALL, scripted_campaign([(10, "fail", "switch", (3,))])
    )
    assert (sched.link_ok[1] == fail_switches(SMALL, [3])).all()


def test_to_failure_events_pairs_repairs():
    events = scripted_campaign([
        (5, "fail", "endpoint", (2,)),
        (9, "repair", "endpoint", (2,)),
        (20, "fail", "endpoint", (7,)),
        (11, "fail", "link", (0, 1)),   # non-endpoint kinds are skipped
    ])
    fes = to_failure_events(events, time_scale=0.5)
    assert len(fes) == 2
    assert (fes[0].time, fes[0].endpoints, fes[0].repair_at) == (2.5, (2,), 4.5)
    assert (fes[1].time, fes[1].endpoints, fes[1].repair_at) == (10.0, (7,), None)


# ------------------------------------------------------- conservation property
if given is not None:
    _CABLES = [(0, 1), (0, 4), (5, 9), (2, 6), (10, 11), (12, 8)]

    @settings(max_examples=8, deadline=None)
    @given(
        starts=hst.lists(hst.integers(1, 400), min_size=0, max_size=3,
                         unique=True),
        picks=hst.lists(hst.sets(hst.integers(0, len(_CABLES) - 1)),
                        min_size=4, max_size=4),
        seed=hst.integers(0, 3),
    )
    def test_packet_conservation_any_epoch_schedule(starts, picks, seed):
        """injected == ejected + stranded under ANY epoch schedule —
        including ones that disconnect parts of the machine."""
        entries = [
            (t, fail_links(SMALL, [_CABLES[i] for i in sorted(pick)]))
            for t, pick in zip([0] + sorted(starts), picks)
        ]
        sched = schedule_from_masks(SMALL, entries)
        engine = _property_engine()
        r = engine.run(_a2a(schedule=sched), seed=seed, horizon=2500)
        _conserved(r)
        assert len(r.epoch_delivered) == sched.NE
else:  # pragma: no cover - hypothesis not installed
    def test_packet_conservation_any_epoch_schedule():
        pytest.importorskip("hypothesis")


_PROPERTY_ENGINE = None


def _property_engine():
    """One engine for every hypothesis example: compilations are reused
    across examples (buckets key on padded NE only)."""
    global _PROPERTY_ENGINE
    if _PROPERTY_ENGINE is None:
        _PROPERTY_ENGINE = SimEngine(SMALL, mode="min")
    return _PROPERTY_ENGINE
