"""SimEngine tests: batch/single equivalence, compile sharing, facade
regression against recorded seed-simulator outputs."""

import numpy as np
import pytest

from repro.core import traffic as tr
from repro.core.allocation import allocate_partition
from repro.core.engine import (
    SimEngine,
    make_workload_tables,
    shape_bucket,
    stack_tables,
)
from repro.core.hyperx import HyperX
from repro.core.simulator import build_simulator, simulate

SMALL = HyperX(n=4, q=2)


def _a2a_workload(strategy: str):
    part = allocate_partition(strategy, SMALL, 0)
    return tr.compose_workload(SMALL, [(tr.all_to_all(16), part)])


# ------------------------------------------------------------------ batching
def test_run_batch_bitwise_matches_run():
    """Vmapped batch execution returns exactly the per-scenario results."""
    engine = SimEngine(SMALL, mode="omniwar")
    wls = [_a2a_workload(s) for s in ("row", "diagonal", "full_spread")]
    seeds = [0, 1, 2]
    solo = [engine.run(wl, seed=s, horizon=5000)
            for wl, s in zip(wls, seeds)]
    batch = engine.run_batch(wls, seeds=seeds, horizon=5000)
    assert batch == solo  # SimResult dataclass equality: every field exact


def test_run_batch_seeds_matches_run():
    """Workload x seed cross product (seeds broadcast, no table
    replication) returns exactly the per-scenario results."""
    engine = SimEngine(SMALL, mode="omniwar")
    wls = [_a2a_workload(s) for s in ("row", "diagonal")]
    seeds = (0, 7)
    grid = engine.run_batch_seeds(wls, seeds=seeds, horizon=5000)
    assert grid == [
        [engine.run(wl, seed=s, horizon=5000) for s in seeds] for wl in wls
    ]
    assert engine.trace_count == 2  # one cross-product trace + one single


def test_run_seeds_matches_run():
    engine = SimEngine(SMALL, mode="omniwar")
    wl = _a2a_workload("row")
    solo = [engine.run(wl, seed=s, horizon=5000) for s in (0, 5, 9)]
    fanned = engine.run_seeds(wl, seeds=(0, 5, 9), horizon=5000)
    assert fanned == solo


# ----------------------------------------------------------- compile sharing
def test_same_shape_workloads_share_one_compilation():
    """Two workloads (different strategies, same shapes) must not re-trace:
    the tables are jit arguments, so the cache keys on shape buckets only."""
    engine = SimEngine(SMALL, mode="omniwar")
    engine.run(_a2a_workload("row"), seed=0, horizon=5000)
    assert engine.trace_count == 1
    engine.run(_a2a_workload("diagonal"), seed=0, horizon=5000)
    engine.run(_a2a_workload("l_shape"), seed=3, horizon=4000)
    assert engine.trace_count == 1  # no new trace for same-bucket workloads
    assert engine.device_calls == 3


def test_strategy_grid_is_single_batched_device_call():
    """A whole strategy grid = one run_batch dispatch; a second grid of the
    same shapes reuses the compilation (trace count stays flat)."""
    engine = SimEngine(SMALL, mode="omniwar")
    grid1 = [_a2a_workload(s) for s in ("row", "diagonal", "full_spread")]
    engine.run_batch(grid1, horizon=5000)
    assert engine.device_calls == 1          # one dispatch for the grid
    traces_after_first = engine.trace_count  # one batched trace
    assert traces_after_first == 1
    # same batch size + same bucket => the compilation is reused (the jit
    # cache keys on the stacked shapes, which include the batch dim)
    grid2 = [_a2a_workload(s) for s in ("rectangular", "l_shape", "row")]
    engine.run_batch(grid2, seeds=[4, 5, 6], horizon=5000)
    assert engine.device_calls == 2
    assert engine.trace_count == traces_after_first  # compilation reused


def test_bucketing_does_not_change_results():
    """Shape-bucket padding (extra ranks/steps/slots) is semantics-free."""
    padded = SimEngine(SMALL, mode="omniwar", bucket=True)
    exact = SimEngine(SMALL, mode="omniwar", bucket=False)
    wl = _a2a_workload("diagonal")
    assert padded.run(wl, seed=2, horizon=5000) == exact.run(
        wl, seed=2, horizon=5000
    )


def test_stack_tables_rejects_mixed_buckets():
    big = tr.compose_workload(
        SMALL, [(tr.all_to_all(16), allocate_partition("row", SMALL, 0))]
    )
    small = tr.compose_workload(
        SMALL, [(tr.uniform(4, packets=4),
                 allocate_partition("row", SMALL, 0))]
    )
    ta = make_workload_tables(big).tables
    tb = make_workload_tables(small).tables
    assert ta.shape_bucket != tb.shape_bucket
    with pytest.raises(ValueError):
        stack_tables([ta, tb])


def test_shape_bucket_rounds_up_to_pow2():
    assert shape_bucket(16, 15, 1) == (16, 16, 1)
    assert shape_bucket(17, 4, 3) == (32, 4, 4)
    assert shape_bucket(3, 1, 1) == (8, 4, 1)


# ------------------------------------------------------------------- facade
def test_facade_simulate_unchanged_vs_seed():
    """Regression: simulate() must reproduce the recorded outputs of the
    seed (pre-engine) simulator for a small HyperX(n=4, q=2) case."""
    part = allocate_partition("row", SMALL, 0)
    wl = tr.compose_workload(SMALL, [(tr.all_to_all(16), part)])

    r = simulate(SMALL, wl, mode="omniwar", seed=0, horizon=5000)
    assert (r.makespan, r.delivered, r.injected) == (26, 240, 240)
    assert r.makespan_cycles == 416
    assert r.avg_latency == pytest.approx(5.6625)
    assert r.avg_hops == pytest.approx(1.0958333333333334)
    assert r.completed

    r = simulate(SMALL, wl, mode="min", seed=0, horizon=5000)
    assert (r.makespan, r.delivered, r.injected) == (34, 240, 240)
    assert r.avg_latency == pytest.approx(8.525)
    assert r.avg_hops == pytest.approx(0.8)

    part2 = allocate_partition("diagonal", SMALL, 0)
    wl2 = tr.compose_workload(SMALL, [(tr.uniform(16, packets=8), part2)])
    r = simulate(SMALL, wl2, mode="omniwar", seed=3, horizon=4000)
    assert (r.makespan, r.delivered, r.injected) == (14, 128, 128)
    assert r.avg_latency == pytest.approx(3.078125)
    assert r.avg_hops == pytest.approx(1.46875)


def test_facade_build_simulator_debug_hook():
    wl = _a2a_workload("row")
    run = build_simulator(SMALL, wl, horizon=5000)
    final, d, i, qs = run.debug(seed=0, steps=64, stride=16)
    assert len(d) == len(i) == len(qs) == 4
    assert int(i[-1]) > 0  # packets were injected within 64 cycles


def test_engine_rejects_pool_mismatch():
    engine = SimEngine(SMALL, mode="omniwar", num_pools=1)
    parts = [allocate_partition("row", SMALL, 0),
             allocate_partition("row", SMALL, 1)]
    wl = tr.compose_workload(
        SMALL, [(tr.all_to_all(16), p) for p in parts],
        fabric_partitioning="per_app",
    )
    assert wl.num_pools == 2
    with pytest.raises(ValueError):
        engine.run(wl, seed=0, horizon=1000)
