"""Scalability math vs the paper's Section 2.3 concrete claims."""

from repro.core.scalability import (
    dragonfly_endpoints,
    fat_tree_endpoints,
    hyperx_cables_per_endpoint,
    hyperx_endpoints,
    hyperx_side_for_radix,
    paper_examples,
    scalability_table,
)


def test_paper_section_2_3_numbers():
    ex = paper_examples()
    assert ex["ft2_r64"] == 2048
    assert ex["hx2_r64_side"] == 22
    assert ex["hx2_r64"] == 10648
    assert ex["ft2_r128"] == 8192
    assert ex["hx2_r128_side"] == 43
    assert ex["hx2_r128"] == 79507
    assert ex["hx3_r64_side"] == 16
    assert ex["hx3_r64"] == 65536  # 4096 switches x 16 endpoints


def test_cables_per_endpoint_approaches_q_over_2():
    assert hyperx_cables_per_endpoint(256, 2) < 1.0
    assert 0.9 < hyperx_cables_per_endpoint(1024, 2) < 1.0
    assert 1.4 < hyperx_cables_per_endpoint(1024, 3) < 1.5


def test_2d_hyperx_beats_two_level_fat_tree():
    for radix in (32, 64, 128):
        assert hyperx_endpoints(radix, 2) > fat_tree_endpoints(radix, 2)


def test_table_structure():
    rows = scalability_table()
    assert {r["radix"] for r in rows} >= {64, 128}
    for r in rows:
        assert r["hyperx_3d"] > r["hyperx_2d"] or r["radix"] < 24


def test_dragonfly_trunking_reduces_size():
    assert dragonfly_endpoints(64, trunking=4) < dragonfly_endpoints(64, trunking=1)
