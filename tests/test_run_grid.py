"""Device-sharded lane dispatch: run_grid parity, trace counting, and the
multi-device path (emulated via XLA host-device splitting in a subprocess).
"""

import json
import os
import subprocess
import sys

import pytest

from repro.core import traffic as tr
from repro.core.allocation import allocate_partition
from repro.core.engine import SimEngine, default_lane_backend
from repro.core.hyperx import HyperX

SMALL = HyperX(n=4, q=2)
REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def _a2a_workload(strategy: str):
    part = allocate_partition(strategy, SMALL, 0)
    return tr.compose_workload(SMALL, [(tr.all_to_all(16), part)])


def _uniform_workload(strategy: str):
    part = allocate_partition(strategy, SMALL, 0)
    return tr.compose_workload(SMALL, [(tr.uniform(4, packets=4), part)])


def test_run_grid_matches_run_batch_seeds_bitwise():
    """On one device run_grid IS the nested-vmap cross product — results
    must be equal field-for-field, including with duplicate seeds."""
    engine = SimEngine(SMALL, mode="omniwar")
    wls = [_a2a_workload(s) for s in ("row", "diagonal", "full_spread")]
    seeds = (0, 7, 7)  # duplicate seed: lane indexing must not collapse it
    assert engine.run_grid(wls, seeds=seeds, horizon=5000) == \
        engine.run_batch_seeds(wls, seeds=seeds, horizon=5000)
    assert engine.lane_backend == "vmap"


def test_run_grid_default_seed_zero():
    engine = SimEngine(SMALL, mode="omniwar")
    wl = _a2a_workload("row")
    assert engine.run_grid([wl], horizon=5000) == [
        [engine.run(wl, seed=0, horizon=5000)]
    ]


def test_run_grid_compiles_once_per_shape_bucket():
    """The trace-counter pin: a grid compiles once per shape bucket, and a
    second grid of the same buckets re-traces nothing."""
    engine = SimEngine(SMALL, mode="omniwar")
    a2a = [_a2a_workload(s) for s in ("row", "diagonal")]
    uni = [_uniform_workload(s) for s in ("row", "diagonal")]
    engine.run_grid(a2a + uni, seeds=(0, 1), horizon=5000)
    assert engine.trace_count == 2    # exactly one trace per bucket
    assert engine.device_calls == 2   # one dispatch per bucket
    engine.run_grid(
        [_a2a_workload("full_spread"), _a2a_workload("l_shape"),
         _uniform_workload("full_spread"), _uniform_workload("l_shape")],
        seeds=(4, 5), horizon=5000,
    )
    assert engine.trace_count == 2    # same buckets -> compilations reused
    assert engine.device_calls == 4


def test_lane_backend_reported_at_construction():
    """Regression pin: ``lane_backend`` must be populated from engine
    construction, not lazily after the first ``run_grid`` — on a
    single-device host it is "vmap" immediately and stays "vmap"."""
    engine = SimEngine(SMALL, mode="omniwar")
    assert engine.lane_backend == default_lane_backend()
    assert engine.lane_backend is not None
    before = engine.lane_backend
    engine.run_grid([_a2a_workload("row")], horizon=5000)
    assert engine.lane_backend == before


_SHARDED_SCRIPT = """
import json
import jax
from repro.core import traffic as tr
from repro.core.allocation import allocate_partition
from repro.core.engine import SimEngine
from repro.core.hyperx import HyperX

assert jax.local_device_count() == 4, jax.local_device_count()
SMALL = HyperX(n=4, q=2)
wls = [
    tr.compose_workload(
        SMALL, [(tr.all_to_all(16), allocate_partition(s, SMALL, 0))]
    )
    for s in ("row", "diagonal", "full_spread")  # 3 x 2 lanes: needs padding
]
engine = SimEngine(SMALL, mode="omniwar")
pre_backend = engine.lane_backend  # populated at construction (no run yet)
grid = engine.run_grid(wls, seeds=(0, 7), horizon=5000)
print(json.dumps({
    "pre_backend": pre_backend,
    "backend": engine.lane_backend,
    "traces": engine.trace_count,
    "grid": [[{k: v for k, v in r.__dict__.items() if k != "telemetry"}
              for r in per_seed] for per_seed in grid],
}))
"""


@pytest.mark.slow
def test_run_grid_sharded_matches_single_device():
    """4 emulated devices (lane padding exercised: 6 lanes -> 8) must give
    bitwise the same grid as this process's single-device reference."""
    env = dict(os.environ)
    env["XLA_FLAGS"] = (env.get("XLA_FLAGS", "") +
                        " --xla_force_host_platform_device_count=4").strip()
    env["PYTHONPATH"] = os.path.join(REPO, "src")
    r = subprocess.run([sys.executable, "-c", _SHARDED_SCRIPT],
                       capture_output=True, text=True, env=env, timeout=600)
    assert r.returncode == 0, r.stderr
    payload = json.loads(r.stdout.strip().splitlines()[-1])
    assert payload["backend"] in ("shard_map", "pmap")
    # lane_backend is reported from construction and the first run_grid
    # must dispatch through that same backend
    assert payload["pre_backend"] == payload["backend"]
    assert payload["traces"] == 1  # SPMD: still one trace for the bucket

    engine = SimEngine(SMALL, mode="omniwar")
    wls = [_a2a_workload(s) for s in ("row", "diagonal", "full_spread")]
    ref = engine.run_grid(wls, seeds=(0, 7), horizon=5000)
    # tuples (per-epoch counters) round-trip through JSON as lists
    assert payload["grid"] == [
        [{k: list(v) if isinstance(v, tuple) else v
          for k, v in r.__dict__.items() if k != "telemetry"}
         for r in per_seed] for per_seed in ref]
