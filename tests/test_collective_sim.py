"""Collective-on-HyperX simulation: the cost model vs the real simulator."""

import pytest

from repro.fabric.collective_sim import (
    compare_strategies_simulated,
    simulate_axis_collective,
)
from repro.fabric.placement import place_job


def test_one_collective_completes():
    p = place_job("diagonal", (8, 8), ("data", "model"))
    r = simulate_axis_collective(p, "model", "all_reduce", num_groups=2)
    assert r["completed"]
    assert r["makespan"] > 0
    assert r["group_size"] == 8


@pytest.mark.slow
def test_simulated_ordering_matches_pb_prediction():
    """Lesson 2, closed loop: the placement the PB cost model prices
    cheapest for the model-axis all-to-all (full_spread: axis-PB 2.0 vs
    0.25-0.5 for the others) is also MEASURED fastest under concurrent
    groups on the cycle simulator.

    Note the deliberate scope: at 16-rank axis-group granularity the
    group-level PB differs from the job-level Table-1 values (e.g. a
    Diagonal job's model-axis groups are 2 unaligned switches — all
    2-hop), and the analytic model under-prices INTER-group contention
    for such distance-2 placements (the paper's Lesson 3 regime); the
    robust invariant asserted here is the cheapest-placement agreement,
    which is what the launcher acts on.
    """
    out = compare_strategies_simulated(
        mesh_shape=(16, 16), axis="model", kind="all_to_all", num_groups=8,
        strategies=("row", "diagonal", "full_spread", "rectangular"),
    )
    assert all(r["completed"] for r in out)
    # analytic cheapest == measured fastest
    assert out[0]["strategy"] == "full_spread"
