"""Fleet observability service tests: the persistent EventStore (tailing,
rollups, checkpoints), the FleetWatcher (alert rules, one-shot/follow
parity, kill-and-resume), the insights API (strategy ranking, memoization,
queue recommendation from checkpointed rollups), dashboard rendering,
multi-run report splitting, post-close tracer safety, and the
traced-vs-untraced scheduler neutrality pin."""

import json
import os
import shutil
import subprocess
import sys
import textwrap
import time

import pytest

from repro.core.hyperx import HyperX
from repro.obs import report as obs_report
from repro.obs import trace as obs_trace
from repro.obs.insights import (
    clear_memo,
    recommend,
    recommend_queue,
    queue_outlook,
)
from repro.obs.store import EventStore, StoreSpec, open_store
from repro.obs.watch import AlertRule, FleetWatcher, default_rules
from repro.sched.jobs import poisson_stream
from repro.sched.ledger import BlockLedger
from repro.sched.scheduler import FailureEvent, OnlineScheduler

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
SMALL = HyperX(n=4, q=2)


def _traced_stream(trace_dir, jobs=20, seed=3, churn=True, **kw):
    """Run one checkpoint-free scheduler stream under tracing."""
    stream = poisson_stream(jobs, rate=0.8, seed=seed)
    failures = []
    if churn:
        led = OnlineScheduler(SMALL, strategy="diagonal").ledger
        hit = tuple(int(e) for e in led.slot_endpoints(0))
        failures = [FailureEvent(time=4.0, endpoints=hit, repair_at=8.0)]
    try:
        obs_trace.configure(str(trace_dir), run_id=f"s{seed}")
        res = OnlineScheduler(
            SMALL, strategy="diagonal", policy="first_fit", seed=seed,
            mttr=10.0, backoff_base=0.5, analyze=False,
        ).run_stream(stream, failures=failures, **kw)
    finally:
        obs_trace.disable()
    return res


# ------------------------------------------------------------------ rollups
def test_store_rollups_match_trace(tmp_path):
    """Stream totals folded by the store equal the report generator's
    per-stream digest of the same trace — two independent consumers."""
    d = tmp_path / "run"
    res = _traced_stream(d)
    store = open_store([str(d)])
    n = store.poll()
    with open(d / "events.jsonl") as f:
        events = [json.loads(line) for line in f]
    assert n == len(events)
    assert store.poll() == 0  # nothing new: offsets are sticky

    (run,) = store.runs.values()
    assert run.ended and run.config_hash
    sr = run.streams["diagonal/first_fit"]
    (row,) = obs_report.sched_rows(events)
    assert sr.totals["arrive"] == row["arrived"]
    assert sr.totals["depart"] == row["finished"]
    assert sr.totals["fail"] == row["failures"]
    assert sr.totals["requeue"] == row["requeues"]
    assert sr.summary["utilization"] == round(res.utilization, 6)
    assert run.heartbeats > 0  # the scheduler's liveness beacons landed
    # windowed counters conserve the totals (last window absorbs overflow)
    for kind, field in (("arrive", "arrived"), ("depart", "finished")):
        assert sum(sr.counts[kind]) == row[field]


def test_store_ignores_torn_final_line(tmp_path):
    """A live writer's torn tail is invisible until its newline arrives."""
    d = tmp_path / "run"
    os.makedirs(d)
    full = json.dumps({"t": 0.0, "type": "event", "name": "trace.start",
                       "run_id": "r1"})
    torn = json.dumps({"t": 0.1, "type": "event", "name": "sched.arrive",
                       "stream": "s", "job": 1, "t_sim": 0.5})
    path = d / "events.jsonl"
    with open(path, "w") as f:
        f.write(full + "\n" + torn[:10])  # mid-write crash / in-flight write
    store = open_store([str(d)])
    assert store.poll() == 1
    assert store.total_events == 1
    with open(path, "a") as f:
        f.write(torn[10:] + "\n")
    assert store.poll() == 1  # the completed line folds exactly once
    (run,) = store.runs.values()
    assert run.streams["s"].totals["arrive"] == 1


def test_one_shot_vs_incremental_parity(tmp_path):
    """Folding a trace in arbitrary byte increments produces rollups
    identical to one-shot ingestion — chunking never changes the result."""
    src = tmp_path / "src"
    _traced_stream(src)
    blob = (src / "events.jsonl").read_bytes()

    live = tmp_path / "run"
    os.makedirs(live)
    shutil.copy(src / "manifest.json", live / "manifest.json")
    inc = open_store([str(live)])
    path = live / "events.jsonl"
    step = 97  # deliberately not line-aligned
    for off in range(0, len(blob), step):
        with open(path, "ab") as f:
            f.write(blob[off:off + step])
        inc.poll()
    inc.poll()

    shot = open_store([str(live)])
    shot.poll()
    assert inc.total_events == shot.total_events == len(blob.splitlines())
    assert inc.rollup_rows() == shot.rollup_rows()


def test_follow_live_subprocess_writer(tmp_path):
    """The watcher follows a trace being written by another process and
    lands on the same rollups as a one-shot pass over the finished file."""
    src = tmp_path / "src"
    _traced_stream(src)
    live = tmp_path / "run"
    os.makedirs(live)
    shutil.copy(src / "manifest.json", live / "manifest.json")
    writer = tmp_path / "writer.py"
    writer.write_text(textwrap.dedent("""\
        import sys, time
        blob = open(sys.argv[1], "rb").read()
        out = open(sys.argv[2], "ab")
        for off in range(0, len(blob), 256):   # torn, un-aligned appends
            out.write(blob[off:off + 256])
            out.flush()
            time.sleep(0.002)
        out.close()
    """))
    proc = subprocess.Popen(
        [sys.executable, str(writer), str(src / "events.jsonl"),
         str(live / "events.jsonl")],
    )
    try:
        store = open_store([str(live)])
        watcher = FleetWatcher(store, echo=False)
        total = watcher.follow(interval=0.02, idle_timeout=30.0,
                               max_wall=120.0)
    finally:
        proc.wait(timeout=60)
    assert store.ended()

    shot = open_store([str(live)])
    FleetWatcher(shot, echo=False)
    shot.poll()
    assert total == shot.total_events
    assert store.rollup_rows() == shot.rollup_rows()
    assert [a for a in store.alerts] == [a for a in shot.alerts]


# ------------------------------------------------------- checkpoint / resume
def test_watch_kill_and_resume_byte_identical_csvs(tmp_path):
    """Hard-kill (137) a checkpointed watch mid-ingest, resume it, and the
    rollup CSVs + durable alert log are byte-identical to an uninterrupted
    watch of the same trace."""
    d = tmp_path / "run"
    _traced_stream(d, jobs=30)
    env = dict(os.environ, PYTHONPATH=os.path.join(REPO, "src"))

    def watch(csv, store, extra=(), rc=0):
        proc = subprocess.run(
            [sys.executable, "-m", "repro.obs.watch", str(d),
             "--csv", str(csv), "--store", str(store), "--every", "25",
             "--fails", "1", "--quiet", *extra],
            env=env, cwd=str(tmp_path), capture_output=True, text=True,
            timeout=300,
        )
        assert proc.returncode == rc, proc.stderr
        return proc

    watch(tmp_path / "c1", tmp_path / "s1")
    watch(tmp_path / "c2", tmp_path / "s2",
          extra=["--crash-after", "60"], rc=137)
    watch(tmp_path / "c2", tmp_path / "s2", extra=["--resume"])

    names = sorted(os.listdir(tmp_path / "c1"))
    assert names == sorted(os.listdir(tmp_path / "c2")) and names
    for name in names:
        a = (tmp_path / "c1" / name).read_bytes()
        b = (tmp_path / "c2" / name).read_bytes()
        assert a == b, f"{name} diverged after kill-and-resume"
    assert (tmp_path / "s1" / "alerts.jsonl").read_bytes() == \
           (tmp_path / "s2" / "alerts.jsonl").read_bytes()


def test_checkpointed_insights_without_raw_log(tmp_path):
    """A 1000+-job stream's store checkpoint answers queue recommendations
    after the raw event log is deleted — rollups, not re-reads."""
    d = tmp_path / "run"
    _traced_stream(d, jobs=1000, churn=False)
    store = open_store([str(d)], checkpoint_dir=str(tmp_path / "ck"),
                       checkpoint_every=500)
    n = store.poll()
    assert n > 1000  # arrivals alone exceed 1000
    store.save_checkpoint()

    os.remove(d / "events.jsonl")  # the raw log is gone for good
    restored = open_store([str(d)], checkpoint_dir=str(tmp_path / "ck"),
                          resume=True)
    assert restored.restored
    assert restored.total_events == n
    assert restored.poll() == 0  # nothing to (re-)read

    best = recommend_queue(restored, blocks=2)
    assert best is not None
    assert best["stream"] == "diagonal/first_fit"
    assert best["arrived"] == 1000
    assert best["blocks"] == 2 and "lowest pressure" in best["reason"]
    outlook = queue_outlook(restored)
    assert outlook and outlook[0]["score"] == best["score"]


# -------------------------------------------------------------- alert rules
def _synthetic_run(tmp_path, lines):
    d = tmp_path / "synth"
    os.makedirs(d, exist_ok=True)
    with open(d / "events.jsonl", "w") as f:
        f.write(json.dumps({"t": 0.0, "type": "event",
                            "name": "trace.start", "run_id": "r"}) + "\n")
        for ev in lines:
            f.write(json.dumps(ev) + "\n")
        f.write(json.dumps({"t": 99.0, "type": "event",
                            "name": "trace.end"}) + "\n")
    return d


def test_alert_rule_validation():
    with pytest.raises(ValueError, match="unknown alert-rule kind"):
        AlertRule("x", "nope", 1.0)
    with pytest.raises(ValueError, match="threshold"):
        AlertRule("x", "frag", 0.0)
    assert len(default_rules()) == 4


def test_util_rule_hysteresis(tmp_path):
    """Fire on the below→above crossing only; re-arm after the dip."""
    tel = [{"t": float(i), "type": "telemetry", "name": "sim.telemetry",
            "label": "L", "util_max": u}
           for i, u in enumerate([0.5, 0.97, 0.99, 0.5, 0.98])]
    d = _synthetic_run(tmp_path, tel)
    store = open_store([str(d)])
    FleetWatcher(store, rules=[AlertRule("sat", "util_max", 0.95)],
                 echo=False)
    store.poll()
    assert [a["value"] for a in store.alerts] == [0.97, 0.98]
    assert all(a["rule"] == "sat" and a["label"] == "L"
               for a in store.alerts)
    (run,) = store.runs.values()
    assert run.alerts == 2


def test_stall_rule_fires_on_heartbeat_gap(tmp_path):
    hbs = [{"t": t, "type": "event", "name": "sched.heartbeat",
            "stream": "s", "t_sim": t} for t in (0.0, 1.0, 9.0, 9.5)]
    d = _synthetic_run(tmp_path, hbs)
    store = open_store([str(d)])
    FleetWatcher(store, rules=[AlertRule("stall", "stall", 5.0)],
                 echo=False)
    store.poll()
    (alert,) = store.alerts
    assert alert["rule"] == "stall" and alert["value"] == 8.0
    (run,) = store.runs.values()
    assert run.heartbeats == 4
    assert run.max_heartbeat_gap == pytest.approx(8.0)


# ----------------------------------------------------------------- insights
def test_recommend_ranks_and_memoizes():
    clear_memo()
    topo = SMALL
    ledger = BlockLedger(topo, strategy="diagonal", policy="first_fit",
                         seed=0)
    ledger.place(1, job_id=1)
    before = {jid: ledger.jobs[jid].slots for jid in ledger.jobs}

    ins = recommend(topo, ledger, blocks=1, seeds=(0,), horizon=4000)
    assert not ins.cached and ins.simulated
    assert ins.best is not None and ins.best.placeable
    assert all(c.avg_latency is not None for c in ins.candidates
               if c.placeable)
    # within the contiguous-placeable tier, ranking is by predicted latency
    lats = [c.avg_latency for c in ins.candidates
            if c.placeable and c.contiguous]
    assert lats == sorted(lats)
    # the query never mutates the live ledger
    assert {jid: ledger.jobs[jid].slots for jid in ledger.jobs} == before

    again = recommend(topo, ledger, blocks=1, seeds=(0,), horizon=4000)
    assert again.cached and again.key == ins.key
    assert again.candidates == ins.candidates

    ledger.place(1, job_id=2)  # occupancy changed: the memo misses
    moved = recommend(topo, ledger, blocks=1, seeds=(0,), horizon=4000)
    assert not moved.cached and moved.key != ins.key


def test_recommend_full_machine_and_validation():
    clear_memo()
    ledger = BlockLedger(SMALL, strategy="diagonal", policy="first_fit",
                         seed=0)
    ledger.place(ledger.num_slots, job_id=1)  # machine is full
    ins = recommend(SMALL, ledger, blocks=1, simulate=False)
    assert not ins.simulated
    assert all(not c.placeable for c in ins.candidates)
    assert ins.best is not None and not ins.best.placeable
    with pytest.raises(ValueError, match="positive block count"):
        recommend(SMALL, ledger, blocks=0)


def test_recommend_queue_empty_store():
    assert recommend_queue(EventStore()) is None


# ------------------------------------------------------ dashboard + report
def test_dashboard_renders_store(tmp_path):
    from repro.obs.dashboard import render_html, sparkline, write_dashboard

    d = tmp_path / "run"
    _traced_stream(d)
    store = open_store([str(d)], store_dir=str(tmp_path / "store"))
    FleetWatcher(store, rules=[AlertRule("f", "fails", 1.0)], echo=False)
    store.poll()
    assert store.alerts  # churn fired the failure rule

    paths = write_dashboard(store, str(tmp_path / "dash"), refresh=5.0)
    md = open(paths["markdown"]).read()
    assert "# Fleet dashboard" in md
    assert "diagonal/first_fit" in md and "Alerts" in md
    html = open(paths["html"]).read()
    assert 'http-equiv="refresh" content="5"' in html
    assert "class=\"alert\"" in html
    assert render_html(store).count("refresh") == 0
    assert sparkline([0.0, 0.5, 1.0], hi=1.0) == "▁▄█"
    assert sparkline([]) == ""


def test_report_splits_multi_run_trace(tmp_path):
    """Append-mode traces holding several runs split on trace.start: the
    markdown surfaces the run count and CSVs gain a leading run column."""
    d = str(tmp_path / "trace")
    for rid in ("a1", "a2"):
        try:
            obs_trace.configure(d, run_id=rid)
            obs_trace.event("sched.arrive", stream="s/p", job=1, t_sim=0.1)
            obs_trace.event("sched.start", stream="s/p", job=1, t_sim=0.2)
        finally:
            obs_trace.disable()
    _, events = obs_report.load_trace(d)
    runs = obs_report.split_runs(events)
    assert [rid for rid, _ in runs] == ["a1", "a2"]
    assert all(evs[0]["name"] == "trace.start" for _, evs in runs)

    paths = obs_report.write_report(d)
    md = open(paths["report"]).read()
    assert "## Runs (2)" in md
    assert "## Run a1" in md and "## Run a2" in md
    assert "across 2 run(s)" in md
    with open(paths["sched"]) as f:
        lines = f.read().splitlines()
    assert lines[0].startswith("run,")
    assert len(lines) == 3  # header + one stream row per run
    assert lines[1].startswith("a1,") and lines[2].startswith("a2,")
    # each run's counters stay unblended
    assert ",1,1," in lines[1] and ",1,1," in lines[2]


def test_report_single_run_has_no_run_column(tmp_path):
    d = str(tmp_path / "trace")
    try:
        obs_trace.configure(d, run_id="only")
        obs_trace.event("sched.arrive", stream="s/p", job=1)
    finally:
        obs_trace.disable()
    paths = obs_report.write_report(d)
    with open(paths["sched"]) as f:
        header = f.readline()
    assert not header.startswith("run,")  # single-run layout is unchanged


# ------------------------------------------------------ tracer close safety
def test_post_close_emits_are_noops(tmp_path):
    """An in-flight span() held across disable()/configure() must finish
    as a silent no-op, never an I/O-on-closed-file error."""
    d1, d2 = str(tmp_path / "t1"), str(tmp_path / "t2")
    tracer = obs_trace.configure(d1, run_id="r1")
    span = tracer.span("unit.leaky")
    span.__enter__()
    obs_trace.configure(d2, run_id="r2")  # closes the first tracer
    assert tracer.closed
    span.__exit__(None, None, None)  # would have raised before the guard
    tracer.event("late")
    tracer.close()  # idempotent
    obs_trace.disable()
    obs_trace.disable()  # also idempotent

    for d, rid in ((d1, "r1"), (d2, "r2")):
        with open(os.path.join(d, "events.jsonl")) as f:
            events = [json.loads(line) for line in f]
        names = [e["name"] for e in events]
        assert names[0] == "trace.start" and names[-1] == "trace.end"
        assert "unit.leaky" not in names and "late" not in names


# --------------------------------------------------- tracing neutrality pin
def test_scheduler_output_identical_traced_vs_untraced(tmp_path):
    """Tracing (heartbeats included) must not perturb scheduling: records
    and summary are identical with the tracer on and off."""
    jobs = poisson_stream(16, rate=0.8, seed=5)

    def run():
        return OnlineScheduler(SMALL, strategy="diagonal", seed=5,
                               mttr=8.0, backoff_base=0.5,
                               analyze=False).run_stream(jobs)

    obs_trace.disable()
    plain = run()
    d = str(tmp_path / "trace")
    try:
        obs_trace.configure(d)
        traced = run()
    finally:
        obs_trace.disable()
    assert traced.records == plain.records
    assert traced.summary() == plain.summary()
    with open(os.path.join(d, "events.jsonl")) as f:
        events = [json.loads(line) for line in f]
    assert any(e["name"] == "sched.heartbeat" for e in events)


def test_store_spec_validation():
    with pytest.raises(ValueError, match="degenerate"):
        StoreSpec(window=0.0)
    with pytest.raises(ValueError, match="degenerate"):
        StoreSpec(n_windows=0)
    spec = StoreSpec(window=10.0, n_windows=4)
    assert spec.window_of(0.0) == 0
    assert spec.window_of(39.9) == 3
    assert spec.window_of(1e9) == 3  # overflow clamps to the last window
