"""Fused route+arbitrate megakernel parity pins.

``SimEngine(kernel="pallas")`` must reproduce the lax reference block in
``step.py`` bit for bit — the packed arbitration keys make every masked
min tie-free, so any drift is a bug, not noise.  Pinned here: all four
routing policies under faults (faults exercise the escalation candidate
sets and the reescalation counter), the batched grid path, and telemetry
probes (which tap g1/g2/best_min straight out of the fused block).
Off-TPU the kernel runs in Pallas interpret mode, so these pins run on
CPU CI (the ``kernel-parity`` CI step).
"""

import dataclasses

import numpy as np
import pytest

from repro.core import traffic as tr
from repro.core.allocation import allocate_partition
from repro.core.engine import SimEngine, make_fused_router
from repro.core.engine.tables import build_static_tables
from repro.core.hyperx import HyperX
from repro.obs.probes import TelemetrySpec
from repro.route import random_link_faults

SMALL = HyperX(n=4, q=2)
HORIZON = 5000


def _a2a_workload(strategy: str = "row", link_ok=None):
    part = allocate_partition(strategy, SMALL, 0)
    return tr.compose_workload(
        SMALL, [(tr.all_to_all(16), part)], link_ok=link_ok,
    )


def _telemetry_equal(a, b) -> bool:
    for f in a.__dataclass_fields__:
        if not np.array_equal(np.asarray(getattr(a, f)),
                              np.asarray(getattr(b, f))):
            return False
    return True


@pytest.mark.parametrize("mode", ["min", "omniwar", "val", "ugal"])
def test_fused_kernel_bit_identical_under_faults(mode):
    """The headline pin: every routing policy, with dead links in the
    candidate sets (escalation/reserve paths live), bit-exact."""
    lok = random_link_faults(SMALL, 0.15, seed=7)
    wl = _a2a_workload(link_ok=lok)
    ref = SimEngine(SMALL, mode=mode, num_pools=wl.num_pools)
    fused = SimEngine(SMALL, mode=mode, num_pools=wl.num_pools,
                      kernel="pallas")
    assert fused.run(wl, seed=5, horizon=HORIZON) == ref.run(
        wl, seed=5, horizon=HORIZON)


def test_fused_kernel_bit_identical_batched():
    """Grid dispatch (vmapped cross product) through the fused kernel."""
    wls = [_a2a_workload(s) for s in ("row", "diagonal", "full_spread")]
    ref = SimEngine(SMALL, mode="omniwar")
    fused = SimEngine(SMALL, mode="omniwar", kernel="pallas")
    assert fused.run_batch_seeds(wls, seeds=(0, 7), horizon=HORIZON) == \
        ref.run_batch_seeds(wls, seeds=(0, 7), horizon=HORIZON)


def test_fused_kernel_bit_identical_with_telemetry():
    """Telemetry probes consume fused-kernel outputs (link grants, chosen
    minimality); every window accumulator must match the lax engine."""
    lok = random_link_faults(SMALL, 0.1, seed=3)
    wl = _a2a_workload(link_ok=lok)
    spec = TelemetrySpec(window=64, n_windows=8)
    ref = SimEngine(SMALL, mode="omniwar", num_pools=wl.num_pools,
                    telemetry=spec)
    fused = SimEngine(SMALL, mode="omniwar", num_pools=wl.num_pools,
                      telemetry=spec, kernel="pallas")
    a = ref.run(wl, seed=2, horizon=HORIZON)
    b = fused.run(wl, seed=2, horizon=HORIZON)
    assert a == b  # simulated fields
    assert dataclasses.is_dataclass(a.telemetry)
    assert _telemetry_equal(a.telemetry, b.telemetry)


def test_fused_kernel_composes_with_chunked_loop():
    """kernel="pallas" + chunk=K stack: still bit-exact vs the reference
    cycle-granular lax engine."""
    lok = random_link_faults(SMALL, 0.15, seed=7)
    wl = _a2a_workload(link_ok=lok)
    ref = SimEngine(SMALL, mode="val", num_pools=wl.num_pools)
    fused = SimEngine(SMALL, mode="val", num_pools=wl.num_pools,
                      kernel="pallas", chunk=16)
    assert fused.run(wl, seed=9, horizon=HORIZON) == ref.run(
        wl, seed=9, horizon=HORIZON)


def test_make_fused_router_requires_switch_major_layout():
    st = build_static_tables(SMALL, mode="omniwar")
    fr = make_fused_router(st)
    assert callable(fr)
    bad = st._replace(H=st.H - 1)  # no longer divisible by S
    with pytest.raises(ValueError):
        make_fused_router(bad)
