"""Integration tests for the launch layer (drivers + dry-run machinery)."""

import json
import os
import subprocess
import sys

import pytest

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
ENV = dict(os.environ, PYTHONPATH=os.path.join(REPO, "src"))


def test_train_driver_with_failure_injection(tmp_path):
    """Full loop: train -> checkpoint -> inject failure -> repair -> resume."""
    from repro.launch import train as T

    losses = T.main([
        "--arch", "qwen3_0_6b", "--reduced", "--steps", "10",
        "--batch", "4", "--seq", "32", "--ckpt", str(tmp_path),
        "--ckpt-every", "4", "--fail-at", "6", "--log-every", "100",
    ])
    assert len(losses) == 10
    assert losses[-1] < losses[0] + 0.5  # survived the failure sanely


def test_serve_driver():
    from repro.launch import serve as S

    out = S.main(["--arch", "qwen3_0_6b", "--reduced", "--batch", "2",
                  "--prompt-len", "16", "--gen", "4"])
    assert out.shape == (2, 4)


@pytest.mark.slow
def test_dryrun_subprocess_one_cell(tmp_path):
    """The real dry-run path in a clean process (512 host devices, 16x16
    mesh, lower+compile+roofline) for the smallest cell."""
    out = tmp_path / "cell.jsonl"
    r = subprocess.run(
        [sys.executable, "-m", "repro.launch.dryrun", "--arch", "qwen3_0_6b",
         "--shape", "decode_32k", "--mesh", "single", "--out", str(out)],
        env=ENV, cwd=REPO, capture_output=True, text=True, timeout=1200,
    )
    assert r.returncode == 0, r.stdout[-2000:] + r.stderr[-2000:]
    row = json.loads(out.read_text().splitlines()[-1])
    assert row["status"] == "ok"
    assert row["chips"] == 256
    assert row["bottleneck"] in ("compute", "memory", "collective")
    assert row["coll_counts"]  # collectives were found and counted


def test_hlo_analysis_trip_counts():
    """The analyzer multiplies while-body costs by known_trip_count."""
    from repro.launch.hlo_analysis import analyze

    hlo = """
HloModule test

%body (p: (s32[], f32[8,8])) -> (s32[], f32[8,8]) {
  %p = (s32[], f32[8,8]) parameter(0)
  %i = s32[] get-tuple-element(%p), index=0
  %x = f32[8,8] get-tuple-element(%p), index=1
  %d = f32[8,8] dot(%x, %x), lhs_contracting_dims={1}, rhs_contracting_dims={0}
  %one = s32[] constant(1)
  %i2 = s32[] add(%i, %one)
  ROOT %t = (s32[], f32[8,8]) tuple(%i2, %d)
}

%cond (p2: (s32[], f32[8,8])) -> pred[] {
  %p2 = (s32[], f32[8,8]) parameter(0)
  %i3 = s32[] get-tuple-element(%p2), index=0
  %n = s32[] constant(7)
  ROOT %lt = pred[] compare(%i3, %n), direction=LT
}

ENTRY %main (a: f32[8,8]) -> f32[8,8] {
  %a = f32[8,8] parameter(0)
  %z = s32[] constant(0)
  %t0 = (s32[], f32[8,8]) tuple(%z, %a)
  %w = (s32[], f32[8,8]) while(%t0), condition=%cond, body=%body, backend_config={"known_trip_count":{"n":"7"}}
  ROOT %r = f32[8,8] get-tuple-element(%w), index=1
}
"""
    a = analyze(hlo)
    # dot: 2*8*8*8 = 1024 flops x 7 trips
    assert a["flops"] == 1024 * 7


def test_roofline_math():
    from repro.launch.roofline import Roofline

    r = Roofline(
        arch="x", shape="train_4k", mesh="16x16", chips=256,
        hlo_flops=197e12, hlo_bytes=819e9, coll_bytes=25e9,
        coll_breakdown={}, coll_counts={}, model_flops=197e12 * 128,
    )
    assert r.compute_s == pytest.approx(1.0)
    assert r.memory_s == pytest.approx(1.0)
    assert r.collective_s == pytest.approx(0.5)
    assert r.bottleneck in ("compute", "memory")
    assert r.useful_ratio == pytest.approx(0.5)
    assert r.roofline_fraction == pytest.approx(0.5)
    assert r.collective_s_allocated(0.25) == pytest.approx(2.0)
