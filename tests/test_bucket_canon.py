"""Bucket canonicalization + persistent-cache plumbing pins.

``SimEngine(canon=True)`` pow2-pads the stacked batch axes (workload
count, seed count, lane count) so nearby grid sizes land on one compiled
executable.  Padded lanes repeat real ones and are discarded — so the
property under test is that canonicalization NEVER changes a SimResult,
and the trace-counter pin is that two nearby grid sizes now share one
compile (plus hit/miss counters that surface the amortization rate).
"""

import pytest

from repro.core import traffic as tr
from repro.core.allocation import allocate_partition
from repro.core.engine import SimEngine
from repro.core.engine import cache as engine_cache
from repro.core.hyperx import HyperX

SMALL = HyperX(n=4, q=2)
HORIZON = 5000
STRATS = ("row", "diagonal", "full_spread", "rectangular", "column")


def _wl(strategy: str):
    part = allocate_partition(strategy, SMALL, 0)
    return tr.compose_workload(SMALL, [(tr.all_to_all(16), part)])


def test_canon_never_changes_results():
    """Property: pow2 padding of every batch axis is result-invariant —
    delivered / latency / hops / makespan bit-identical, on odd-sized
    workload lists, seed lists, and the single-run path."""
    wls = [_wl(s) for s in STRATS[:3]]          # 3 -> pads to 4
    seeds = (0, 3, 11)                          # 3 -> pads to 4
    plain = SimEngine(SMALL, mode="omniwar")
    canon = SimEngine(SMALL, mode="omniwar", canon=True)
    assert canon.run_grid(wls, seeds=seeds, horizon=HORIZON) == \
        plain.run_grid(wls, seeds=seeds, horizon=HORIZON)
    assert canon.run_batch(wls, seeds=[1, 2, 3], horizon=HORIZON) == \
        plain.run_batch(wls, seeds=[1, 2, 3], horizon=HORIZON)
    assert canon.run_seeds(wls[0], seeds=seeds, horizon=HORIZON) == \
        plain.run_seeds(wls[0], seeds=seeds, horizon=HORIZON)
    assert canon.run(wls[0], seed=5, horizon=HORIZON) == \
        plain.run(wls[0], seed=5, horizon=HORIZON)


def test_canon_shares_compiles_across_nearby_sizes():
    """The trace-counter pin: 3-workload and 4-workload grids (same shape
    bucket) hit one compiled executable under canon — and the second
    dispatch is recorded as a bucket hit."""
    canon = SimEngine(SMALL, mode="omniwar", canon=True)
    canon.run_grid([_wl(s) for s in STRATS[:3]], seeds=(0,),
                   horizon=HORIZON)
    t0 = canon.trace_count
    assert canon.bucket_stats()["misses"] == 1
    canon.run_grid([_wl(s) for s in STRATS[:4]], seeds=(0,),
                   horizon=HORIZON)
    assert canon.trace_count == t0  # no new compile: 3 padded to 4
    assert canon.bucket_stats() == {
        "hits": 1, "misses": 1, "hit_rate": 0.5}

    # control: the uncanonicalized engine re-traces for the new size
    plain = SimEngine(SMALL, mode="omniwar")
    plain.run_grid([_wl(s) for s in STRATS[:3]], seeds=(0,),
                   horizon=HORIZON)
    t0 = plain.trace_count
    plain.run_grid([_wl(s) for s in STRATS[:4]], seeds=(0,),
                   horizon=HORIZON)
    assert plain.trace_count == t0 + 1
    assert plain.bucket_stats()["hits"] == 0


def test_canon_pad_sizes():
    eng = SimEngine(SMALL, mode="omniwar", canon=True)
    assert [eng._canon_pad(n) for n in (1, 2, 3, 5, 8, 9)] == \
        [1, 2, 4, 8, 8, 16]
    off = SimEngine(SMALL, mode="omniwar")
    assert [off._canon_pad(n) for n in (3, 5)] == [3, 5]


# ------------------------------------------------------- persistent cache
def test_enable_persistent_cache_env_gated(tmp_path, monkeypatch):
    """Default-off contract + idempotence + the re-point guard."""
    monkeypatch.setattr(engine_cache, "_configured", None)
    monkeypatch.delenv(engine_cache.ENV_VAR, raising=False)
    assert engine_cache.enable_persistent_cache() is None
    assert engine_cache.cache_dir() is None

    d = str(tmp_path / "xla-cache")
    assert engine_cache.enable_persistent_cache(d) == d
    assert engine_cache.cache_dir() == d
    assert engine_cache.enable_persistent_cache(d) == d      # idempotent
    assert engine_cache.enable_persistent_cache() == d       # no-arg: keeps
    with pytest.raises(ValueError):
        engine_cache.enable_persistent_cache(str(tmp_path / "other"))


def test_enable_persistent_cache_reads_env(tmp_path, monkeypatch):
    monkeypatch.setattr(engine_cache, "_configured", None)
    d = str(tmp_path / "env-cache")
    monkeypatch.setenv(engine_cache.ENV_VAR, d)
    assert engine_cache.enable_persistent_cache() == d
