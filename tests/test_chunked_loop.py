"""Chunked early-exit cycle loop pins (``SimEngine(chunk=K)``).

The while-of-scan-chunks loop checks ``all_done`` every K cycles instead
of every cycle; because the exit predicate is monotone and the carry is
frozen per cycle once it fires, results are *cycle-exact* for any K —
including the completion cycle (makespan), which must not round up to a
chunk boundary.  K=1 is the cycle-granular reference loop itself
(trace-counter-pinned below, not just result-pinned).
"""

import pytest

from repro.core import traffic as tr
from repro.core.allocation import allocate_partition
from repro.core.engine import SimEngine
from repro.core.hyperx import HyperX
from repro.obs.probes import TelemetrySpec
from repro.route import random_link_faults

SMALL = HyperX(n=4, q=2)
HORIZON = 5000


def _a2a_workload(strategy: str = "row", link_ok=None):
    part = allocate_partition(strategy, SMALL, 0)
    return tr.compose_workload(
        SMALL, [(tr.all_to_all(16), part)], link_ok=link_ok,
    )


def test_chunk_one_is_the_reference_loop():
    """K=1 must be bit-identical to the default engine AND trace the same
    number of times — it dispatches the very same while_loop core."""
    wl = _a2a_workload()
    ref = SimEngine(SMALL, mode="omniwar")
    k1 = SimEngine(SMALL, mode="omniwar", chunk=1)
    r_ref = ref.run(wl, seed=4, horizon=HORIZON)
    r_k1 = k1.run(wl, seed=4, horizon=HORIZON)
    assert r_ref == r_k1
    assert k1.trace_count == ref.trace_count == 1


@pytest.mark.parametrize("K", [4, 7, 64])
def test_chunked_loop_cycle_exact(K):
    """Any K reproduces the reference result exactly — in particular the
    makespan is the true completion cycle, not a multiple of K."""
    wl = _a2a_workload()
    ref = SimEngine(SMALL, mode="omniwar").run(wl, seed=9, horizon=HORIZON)
    rk = SimEngine(SMALL, mode="omniwar", chunk=K).run(
        wl, seed=9, horizon=HORIZON)
    assert rk == ref
    assert rk.completed  # the exit fired mid-horizon, not at the clamp


def test_chunked_loop_with_faults_and_telemetry():
    """Telemetry accumulators are part of the frozen carry: past the
    completion cycle the in-chunk tail must not keep accumulating."""
    lok = random_link_faults(SMALL, 0.1, seed=3)
    wl = _a2a_workload(link_ok=lok)
    spec = TelemetrySpec(window=64, n_windows=8)
    ref = SimEngine(SMALL, mode="omniwar", num_pools=wl.num_pools,
                    telemetry=spec)
    chunked = SimEngine(SMALL, mode="omniwar", num_pools=wl.num_pools,
                        telemetry=spec, chunk=32)
    a = ref.run(wl, seed=2, horizon=HORIZON)
    b = chunked.run(wl, seed=2, horizon=HORIZON)
    assert a == b
    import numpy as np
    for f in ("link_util", "vc_occ", "deroutes", "cycles", "delivered"):
        assert np.array_equal(np.asarray(getattr(a.telemetry, f)),
                              np.asarray(getattr(b.telemetry, f))), f


def test_chunked_loop_horizon_clamp():
    """An incomplete run must stop at exactly `horizon` cycles even when
    the horizon is not a chunk multiple (the frozen-carry tail again)."""
    wl = _a2a_workload()
    horizon = 10  # far too small to complete; 10 % 7 != 0
    ref = SimEngine(SMALL, mode="omniwar").run(wl, seed=0, horizon=horizon)
    rk = SimEngine(SMALL, mode="omniwar", chunk=7).run(
        wl, seed=0, horizon=horizon)
    assert not rk.completed and rk == ref


def test_chunk_validates():
    with pytest.raises(ValueError):
        SimEngine(SMALL, mode="omniwar", chunk=0)
