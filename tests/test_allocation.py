"""Allocation-function tests (paper Section 4) — invariants + properties."""

import numpy as np
import pytest

try:  # optional test extra (pip install -e .[test]); property tests need it
    from hypothesis import given, settings, strategies as st
except ImportError:  # pragma: no cover - exercised only without hypothesis
    given = settings = st = None

from repro.core.allocation import (
    ALLOCATIONS,
    JobAllocator,
    allocate_partition,
    endpoint_owner,
    machine_partitions,
)
from repro.core.hyperx import HyperX
from repro.core.properties import has_switch_locality

STRATS = sorted(ALLOCATIONS)


@pytest.mark.parametrize("strat", STRATS)
@pytest.mark.parametrize("n", [4, 8])
def test_partition_size_and_validity(strat, n):
    topo = HyperX(n=n, q=2)
    part = allocate_partition(strat, topo, 0)
    assert len(part.endpoints) == n * n
    assert (part.endpoints >= 0).all()
    assert (part.endpoints < topo.num_endpoints).all()
    # a partition never assigns two ranks to one endpoint
    assert len(np.unique(part.endpoints)) == n * n


@pytest.mark.parametrize("strat", STRATS)
@pytest.mark.parametrize("n", [4, 8])
def test_machine_partitions_disjoint(strat, n):
    """The machine supports exactly n disjoint partitions (paper Sec. 4)."""
    topo = HyperX(n=n, q=2)
    parts = machine_partitions(strat, topo, num_jobs=n)
    owner = endpoint_owner(parts, topo.num_endpoints)  # raises on overlap
    assert (owner >= 0).all()  # n partitions of n^2 fill the n^3 machine


@pytest.mark.parametrize("strat", STRATS)
def test_switch_locality_matches_table1(strat):
    topo = HyperX(n=8, q=2)
    part = allocate_partition(strat, topo, 0, seed=3)
    expected = ALLOCATIONS[strat].locality_aware
    assert has_switch_locality(topo, part.endpoints) == expected


if st is not None:
    @given(st.integers(0, 3), st.sampled_from(STRATS), st.integers(0, 99))
    @settings(max_examples=60, deadline=None)
    def test_allocation_job_property(job, strat, seed):
        """Property: any job id / seed yields a valid in-range 64-endpoint block."""
        topo = HyperX(n=8, q=2)
        part = allocate_partition(strat, topo, job, seed=seed)
        assert len(np.unique(part.endpoints)) == 64
        assert part.endpoints.min() >= 0 and part.endpoints.max() < 512
else:
    def test_allocation_job_property():
        pytest.importorskip("hypothesis")


@pytest.mark.parametrize("strat", STRATS)
def test_multiblock_jobs(strat):
    """128/256-process jobs take unions of consecutive blocks (Sec. 6.2)."""
    topo = HyperX(n=8, q=2)
    for size, njobs in [(128, 4), (256, 2)]:
        parts = machine_partitions(strat, topo, num_jobs=njobs, job_size=size)
        endpoint_owner(parts, topo.num_endpoints)
        for p in parts:
            assert len(np.unique(p.endpoints)) == size


def test_row_is_identity():
    topo = HyperX(n=8, q=2)
    part = allocate_partition("row", topo, 3)
    sw = part.endpoints // topo.concentration
    assert set(sw // 8) == {3}  # all in row 3


def test_diagonal_one_switch_per_row_and_col():
    topo = HyperX(n=8, q=2)
    part = allocate_partition("diagonal", topo, 2)
    sw = np.unique(part.endpoints // topo.concentration)
    ys, xs = sw // 8, sw % 8
    assert len(set(ys.tolist())) == 8 and len(set(xs.tolist())) == 8


def test_full_spread_touches_every_switch():
    topo = HyperX(n=8, q=2)
    part = allocate_partition("full_spread", topo, 5)
    assert len(np.unique(part.endpoints // 8)) == 64


def test_rectangular_tiles_are_2x4():
    topo = HyperX(n=8, q=2)
    for p in range(8):
        part = allocate_partition("rectangular", topo, p)
        sw = np.unique(part.endpoints // 8)
        ys, xs = np.unique(sw // 8), np.unique(sw % 8)
        assert len(ys) == 2 and len(xs) == 4
        assert np.all(np.diff(ys) == 1)  # contiguous rows
        assert np.all(np.diff(xs) == 1)  # contiguous cols


def test_job_allocator_lifecycle():
    topo = HyperX(n=8, q=2)
    alloc = JobAllocator(topo, strategy="diagonal")
    jobs = [alloc.allocate() for _ in range(8)]
    assert alloc.capacity() == 0
    with pytest.raises(RuntimeError):
        alloc.allocate()
    alloc.release(jobs[3].job_id)
    assert alloc.capacity() == 64
    j2 = alloc.allocate()
    assert len(j2.endpoints) == 64


def test_job_allocator_failure_tracking():
    topo = HyperX(n=8, q=2)
    alloc = JobAllocator(topo, strategy="row")
    j = alloc.allocate()
    affected = alloc.fail_endpoints(j.endpoints[:2])
    assert affected == [j.job_id]
