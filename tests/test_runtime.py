"""Fault-tolerance / elastic / straggler runtime tests."""

import numpy as np
import pytest

from repro.runtime import FleetRuntime, StragglerMonitor


def test_failure_triggers_reallocation():
    rt = FleetRuntime((16, 16), ("data", "model"), strategy="diagonal")
    before = rt.placement.endpoints.copy()
    dead = int(before.reshape(-1)[0])
    ev = rt.fail([dead])
    assert ev["job_affected"] and ev["action"] == "reallocated"
    after = rt.placement.endpoints
    assert dead not in after
    assert rt.job.generation == 1
    assert after.shape == (16, 16)  # same-size repair succeeded


def test_unrelated_failure_no_action():
    rt = FleetRuntime((16, 16), ("data", "model"))
    outside = np.setdiff1d(
        np.arange(rt.topo.num_endpoints), rt.placement.endpoints
    )
    ev = rt.fail([int(outside[0])])
    assert not ev["job_affected"] and ev["action"] == "none"
    assert rt.job.generation == 0


def test_fallback_strategy_repairs_fragmented_fleet():
    """One dead endpoint per row defeats the Row allocation at every block
    position; the runtime falls back to a stochastic strategy (the random
    allocations exist exactly for fragmented fleets) at FULL size."""
    rt = FleetRuntime((16, 16), ("data", "model"), strategy="row")
    n = rt.topo.n
    dead = [rt.topo.endpoint_id((r, 0), 0) for r in range(n)]
    ev = rt.fail(dead)
    assert ev["action"].startswith("reallocated:")  # fallback strategy used
    assert rt.healthy_devices() == 256
    assert not np.intersect1d(rt.placement.endpoints, dead).size


def test_elastic_shrink_when_fleet_degraded():
    """Killing most of the fleet forces an elastic halving of the data axis."""
    rt = FleetRuntime((16, 16), ("data", "model"), strategy="diagonal")
    dead = np.arange(300)  # 512 - 300 = 212 < 256 endpoints left
    ev = rt.fail(dead)
    assert "rescaled_to_(8, 16)" in ev["action"]
    assert rt.healthy_devices() == 128
    assert rt.job.generation == 1
    assert not np.intersect1d(rt.placement.endpoints, dead).size


def test_repair_restores_capacity():
    rt = FleetRuntime((16, 16), ("data", "model"))
    dead = [int(rt.placement.endpoints.reshape(-1)[0])]
    rt.fail(dead)
    cap_degraded = rt.allocator.capacity()
    rt.allocator.repair_endpoints(np.asarray(dead))
    assert rt.allocator.capacity() == cap_degraded + 1


def test_straggler_monitor_flags_and_evicts():
    mon = StragglerMonitor(threshold=1.5, evict_after=3)
    for step in range(6):
        for h in range(4):
            t = 1.0 if h != 2 else 3.0  # host 2 is 3x slower
            mon.record(h, t)
    assert 2 in mon.evictions()
    assert all(h not in mon.evictions() for h in (0, 1, 3))


def test_straggler_recovers():
    mon = StragglerMonitor(threshold=1.5, evict_after=3)
    for _ in range(2):
        for h in range(4):
            mon.record(h, 3.0 if h == 2 else 1.0)
    for _ in range(2):
        for h in range(4):
            mon.record(h, 1.0)  # host 2 back to normal
    assert mon.evictions() == []
