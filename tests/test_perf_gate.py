"""Perf-gate tests: BENCH json comparison logic and the nonzero exit on a
synthetic >10% device-time regression (no measurement is run — run_suite
is stubbed; the measuring path is covered by the CI perf-smoke job)."""

import json
import os
import sys

import pytest

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
if REPO not in sys.path:  # benchmarks/ is a namespace package at repo root
    sys.path.insert(0, REPO)

from benchmarks import perf  # noqa: E402


@pytest.fixture(autouse=True)
def _isolate_history(tmp_path, monkeypatch):
    """Every main() run appends to the history trajectory — point it at a
    scratch file so tests never pollute the committed BENCH_history.jsonl."""
    monkeypatch.setattr(perf, "HISTORY_PATH",
                        str(tmp_path / "BENCH_history.jsonl"))


def _bench(device_s_by_grid, rev="test"):
    return {
        "schema": perf.SCHEMA, "rev": rev, "quick": True, "backend": "cpu",
        "devices": 1, "jax": "x", "arb": "lax", "kernel": "lax",
        "chunk": 1, "canon": False,
        "grids": {
            g: {"lanes": 4, "buckets": 1, "traces": 1, "lane_backend": "vmap",
                "compile_s": 1.0, "device_s": d, "cycles": 1000,
                "cycles_per_s": 1000 / d, "lanes_per_s": 4 / d}
            for g, d in device_s_by_grid.items()
        },
    }


def test_compare_flags_only_past_threshold():
    base = _bench({"a": 1.0, "b": 2.0, "c": 3.0})
    new = _bench({"a": 1.05, "b": 2.3, "c": 2.0})  # +5%, +15%, -33%
    rows = perf.compare_benchmarks(new, base, threshold=0.10)
    flagged = {r["grid"]: r["regressed"] for r in rows}
    assert flagged == {"a": False, "b": True, "c": False}


def test_compare_tolerates_missing_grids():
    rows = perf.compare_benchmarks(
        _bench({"a": 1.0}), _bench({"b": 1.0}), threshold=0.10)
    assert all(not r["regressed"] for r in rows)
    assert {r["grid"] for r in rows} == {"a", "b"}


def test_main_exits_nonzero_on_synthetic_regression(tmp_path, monkeypatch):
    """The acceptance pin: a synthetic 10%+ slowdown vs the baseline makes
    `perf.py --compare` return nonzero; an equal run returns zero."""
    base_path = tmp_path / "BENCH_base.json"
    base_path.write_text(json.dumps(_bench({"g": 1.0}, rev="base")))

    def fake_suite(slow):
        def run_suite(quick=True, grids=None, arb="lax", **kw):
            return _bench({"g": 1.1 * 1.001 if slow else 1.0}, rev="new")
        return run_suite

    out = tmp_path / "BENCH_new.json"
    monkeypatch.setattr(perf, "run_suite", fake_suite(slow=True))
    rc = perf.main(["--quick", "--out", str(out), "--compare",
                    str(base_path)])
    assert rc != 0
    assert json.loads(out.read_text())["rev"] == "new"  # snapshot still lands

    monkeypatch.setattr(perf, "run_suite", fake_suite(slow=False))
    rc = perf.main(["--quick", "--out", str(out), "--compare",
                    str(base_path)])
    assert rc == 0


def test_compare_missing_baseline_fails_fast(tmp_path, monkeypatch, capsys):
    """A missing baseline exits with the distinct bad-baseline code and a
    one-line error BEFORE any measurement runs."""
    def boom(*a, **k):
        raise AssertionError("run_suite must not run with a bad baseline")
    monkeypatch.setattr(perf, "run_suite", boom)
    rc = perf.main(["--quick", "--out", str(tmp_path / "o.json"),
                    "--compare", str(tmp_path / "nope.json")])
    assert rc == perf.EXIT_BAD_BASELINE
    assert rc != perf.EXIT_REGRESSION
    err = capsys.readouterr().err
    assert "cannot read baseline" in err and len(err.strip().splitlines()) == 1


@pytest.mark.parametrize("payload", ["{not json", '{"schema": 1}', '[1,2]'])
def test_compare_corrupt_baseline_fails_fast(tmp_path, monkeypatch, capsys,
                                             payload):
    base = tmp_path / "BENCH_bad.json"
    base.write_text(payload)
    monkeypatch.setattr(
        perf, "run_suite",
        lambda *a, **k: (_ for _ in ()).throw(AssertionError("measured")))
    rc = perf.main(["--quick", "--out", str(tmp_path / "o.json"),
                    "--compare", str(base)])
    assert rc == perf.EXIT_BAD_BASELINE
    assert "baseline" in capsys.readouterr().err


def test_main_writes_bench_json_and_baseline(tmp_path, monkeypatch):
    monkeypatch.setattr(perf, "run_suite",
                        lambda quick=True, grids=None, arb="lax", **kw:
                        _bench({"g": 1.0}, rev="abc123"))
    out = tmp_path / "BENCH_abc123.json"
    rc = perf.main(["--quick", "--out", str(out)])
    assert rc == 0
    payload = json.loads(out.read_text())
    assert payload["grids"]["g"]["device_s"] == 1.0
    assert payload["schema"] == perf.SCHEMA


# ------------------------------------------------------------------- history
def test_every_run_appends_history(tmp_path, monkeypatch):
    """The trajectory contract: each main() run adds exactly one jsonl
    entry carrying rev, date, and the per-grid metric table."""
    monkeypatch.setattr(perf, "run_suite",
                        lambda *a, **k: _bench({"g": 1.0}, rev="r1"))
    assert perf.main(["--quick", "--out", str(tmp_path / "a.json")]) == 0
    monkeypatch.setattr(perf, "run_suite",
                        lambda *a, **k: _bench({"g": 1.0}, rev="r2"))
    assert perf.main(["--quick", "--out", str(tmp_path / "b.json")]) == 0
    lines = [json.loads(ln) for ln in
             open(perf.HISTORY_PATH).read().splitlines() if ln]
    assert [e["rev"] for e in lines] == ["r1", "r2"]
    assert all("date" in e and "grids" in e for e in lines)
    assert perf.latest_history()["rev"] == "r2"


def test_bare_compare_gates_against_latest_history(tmp_path, monkeypatch):
    """`--compare` with no path reads the latest prior history entry: a
    matching run passes, a >10% device_s slowdown fails the gate."""
    monkeypatch.setattr(perf, "run_suite",
                        lambda *a, **k: _bench({"g": 1.0}, rev="base"))
    assert perf.main(["--quick", "--out", str(tmp_path / "a.json")]) == 0

    monkeypatch.setattr(perf, "run_suite",
                        lambda *a, **k: _bench({"g": 1.0}, rev="same"))
    assert perf.main(["--quick", "--out", str(tmp_path / "b.json"),
                      "--compare"]) == 0

    monkeypatch.setattr(perf, "run_suite",
                        lambda *a, **k: _bench({"g": 1.2}, rev="slow"))
    rc = perf.main(["--quick", "--out", str(tmp_path / "c.json"),
                    "--compare"])
    assert rc == perf.EXIT_REGRESSION


def test_bare_compare_without_history_fails_fast(tmp_path, monkeypatch,
                                                 capsys):
    monkeypatch.setattr(
        perf, "run_suite",
        lambda *a, **k: (_ for _ in ()).throw(AssertionError("measured")))
    rc = perf.main(["--quick", "--out", str(tmp_path / "o.json"),
                    "--compare"])
    assert rc == perf.EXIT_BAD_BASELINE
    assert "no prior" in capsys.readouterr().err


def test_latest_history_skips_corrupt_lines(tmp_path, monkeypatch):
    hist = tmp_path / "BENCH_history.jsonl"
    good = json.dumps({"rev": "ok", "quick": True, "grids": {"g": {}}})
    hist.write_text(good + "\n{truncated", encoding="utf-8")
    assert perf.latest_history(str(hist))["rev"] == "ok"
    # quick filter: a full-suite entry never gates a quick run
    full = json.dumps({"rev": "full", "quick": False, "grids": {"g": {}}})
    hist.write_text(good + "\n" + full + "\n")
    assert perf.latest_history(str(hist), quick=True)["rev"] == "ok"
    assert perf.latest_history(str(hist), quick=False)["rev"] == "full"


def test_grid_builders_produce_workloads():
    """Every canonical grid lowers to nonempty same-pool workloads (cheap
    structural check; actual measurement runs in CI perf-smoke)."""
    for name, build in perf.GRIDS.items():
        wls, seeds, mode, horizon = build(quick=True)
        assert wls and seeds and horizon > 0, name
        assert len({w.num_pools for w in wls}) == 1, name
