"""Pallas arbitration kernel: bit-exactness pin against the lax reference.

The packed keys make ties impossible (low 17 bits are the unique global
head index), so a masked integer min per output is deterministic on every
backend — the kernel must match the scatter-min reference *bitwise*, both
at the round level (random request matrices) and end-to-end through the
engine.  On CPU CI the kernel runs in Pallas interpret mode; on TPU the
same `make_arbiter(..., interpret=None)` resolves to a compiled kernel.
"""

import numpy as np
import pytest

from repro.core import traffic as tr
from repro.core.allocation import allocate_partition
from repro.core.engine import SimEngine, make_arbiter
from repro.core.hyperx import HyperX

SMALL = HyperX(n=4, q=2)


def _random_round(rng, S, OUT, HS, invalid_frac=0.3):
    """Switch-local random requests + unique packed keys (engine layout)."""
    H = S * HS
    sw = np.arange(H) // HS
    port = rng.integers(0, OUT, size=H)
    req = (sw * OUT + port).astype(np.int32)
    off = rng.random(H) < invalid_frac
    req[off] = S * OUT + rng.integers(0, 5, size=off.sum())  # "not requesting"
    packed = ((rng.integers(0, 1 << 15, size=H).astype(np.uint32) << 17)
              | np.arange(H, dtype=np.uint32))
    return req, packed


@pytest.mark.parametrize("seed", [0, 1, 2, 3])
def test_pallas_round_matches_lax_reference(seed):
    S, OUT, HS = 5, 7, 12
    lax_arb = make_arbiter(S, OUT, S * HS, "lax")
    pallas_arb = make_arbiter(S, OUT, S * HS, "pallas", interpret=True)
    rng = np.random.default_rng(seed)
    for _ in range(5):
        req, packed = _random_round(rng, S, OUT, HS)
        won_l, g_l = lax_arb(req, packed)
        won_p, g_p = pallas_arb(req, packed)
        assert np.array_equal(np.asarray(won_l), np.asarray(won_p))
        assert np.array_equal(np.asarray(g_l), np.asarray(g_p))
        # sanity: exactly one winner per granted output, none elsewhere
        assert int(np.asarray(won_p).sum()) == int(np.asarray(g_p).sum())
        assert np.asarray(g_p).max(initial=0) <= 1


def test_pallas_round_all_idle_and_full_contention():
    S, OUT, HS = 3, 4, 6
    H = S * HS
    lax_arb = make_arbiter(S, OUT, H, "lax")
    pallas_arb = make_arbiter(S, OUT, H, "pallas", interpret=True)
    packed = ((np.full(H, 7, dtype=np.uint32) << 17)
              | np.arange(H, dtype=np.uint32))
    # nobody requests
    idle = np.full(H, S * OUT, dtype=np.int32)
    for a, b in zip(lax_arb(idle, packed), pallas_arb(idle, packed)):
        assert np.array_equal(np.asarray(a), np.asarray(b))
    # every head of each switch fights for the same output 0
    clash = ((np.arange(H) // HS) * OUT).astype(np.int32)
    won_l, g_l = lax_arb(clash, packed)
    won_p, g_p = pallas_arb(clash, packed)
    assert np.array_equal(np.asarray(won_l), np.asarray(won_p))
    assert np.array_equal(np.asarray(g_l), np.asarray(g_p))
    assert int(np.asarray(won_p).sum()) == S  # one winner per switch


def test_make_arbiter_rejects_unknown_backend():
    with pytest.raises(ValueError):
        make_arbiter(2, 2, 4, "scatter")
    with pytest.raises(ValueError):
        make_arbiter(3, 2, 7, "pallas")  # H not switch-major divisible


# --------------------------------------------------------------- end-to-end
def _a2a_workload(strategy: str):
    part = allocate_partition(strategy, SMALL, 0)
    return tr.compose_workload(SMALL, [(tr.all_to_all(16), part)])


def test_engine_pallas_arb_bit_identical():
    """The regression pin: arb='pallas' must reproduce arb='lax' exactly —
    single runs, the batched grid, and a deroute-heavy policy ('val', which
    stresses the second arbitration round via intermediate hops)."""
    lax_eng = SimEngine(SMALL, mode="omniwar", arb="lax")
    pal_eng = SimEngine(SMALL, mode="omniwar", arb="pallas")
    wls = [_a2a_workload(s) for s in ("row", "diagonal", "full_spread")]
    for wl, seed in zip(wls, (0, 3, 9)):
        assert pal_eng.run(wl, seed=seed, horizon=5000) == lax_eng.run(
            wl, seed=seed, horizon=5000)
    assert pal_eng.run_batch_seeds(wls, seeds=(0, 7), horizon=5000) == \
        lax_eng.run_batch_seeds(wls, seeds=(0, 7), horizon=5000)

    wl = _a2a_workload("row")
    lax_val = SimEngine(SMALL, mode="val", num_pools=wl.num_pools)
    pal_val = SimEngine(SMALL, mode="val", num_pools=wl.num_pools,
                        arb="pallas")
    assert pal_val.run(wl, seed=1, horizon=5000) == lax_val.run(
        wl, seed=1, horizon=5000)
