"""Resilience subsystem tests (scheduler + crash-safety side): MTTR
repair timers, backoff requeue / give-up, shrink-to-fit degradation,
straggler-eviction wiring, checkpointed stream resume (in-process and the
kill-and-resume subprocess pin), the async Checkpointer failure
regression, and the benchmark suite's failure isolation."""

import json
import os
import subprocess
import sys
import types

import numpy as np
import pytest

from repro.core.hyperx import HyperX
from repro.checkpoint import Checkpointer
from repro.runtime.fault_tolerance import StragglerMonitor
from repro.sched.jobs import Job, poisson_stream
from repro.sched.scheduler import FailureEvent, OnlineScheduler

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
SMALL = HyperX(n=4, q=2)  # 4 slots x 16 endpoints


def _sched(**kw):
    return OnlineScheduler(SMALL, strategy="diagonal", policy="first_fit",
                           **kw)


def _slot_endpoints(slots):
    led = _sched().ledger
    return tuple(int(e) for s in slots for e in led.slot_endpoints(s))


# ------------------------------------------------------------- MTTR repairs
def test_mttr_repair_timers_restore_capacity():
    """A permanent (repair_at=None) failure leaves 3/4 of the machine down
    forever without mttr; with mttr the drawn repair timer restores it and
    strictly more jobs finish."""
    jobs = poisson_stream(16, rate=1.0, seed=0)
    hit = _slot_endpoints([0, 1, 2])
    failures = [FailureEvent(time=2.0, endpoints=hit, repair_at=None)]
    plain = _sched().run_stream(jobs, failures=failures)
    robust = _sched(mttr=4.0).run_stream(jobs, failures=failures)
    n_plain = len(plain.finished())
    n_robust = len(robust.finished())
    assert n_plain < len(jobs)           # the failure actually bites
    assert n_robust > n_plain
    assert robust.summary()["failed"] == 0


def test_mttr_validation():
    with pytest.raises(ValueError, match="mttr"):
        _sched(mttr=0.0)
    with pytest.raises(ValueError, match="backoff_base"):
        _sched(backoff_base=-1.0)


# ------------------------------------------------------ backoff and give-up
def test_backoff_requeue_rearrives_after_delay():
    """blocks=4 fills the machine; failing every endpoint forces a requeue
    (no survivors to migrate to).  With backoff the job re-arrives at
    t+base, waits for the scripted repair, and finishes with its remaining
    service — deterministic end to end."""
    job = Job(job_id=0, arrival=0.0, blocks=4, service=5.0)
    failures = [FailureEvent(time=2.0, endpoints=_slot_endpoints(range(4)),
                             repair_at=4.0)]
    res = _sched(backoff_base=1.0).run_stream([job], failures=failures)
    rec = res.records[0]
    assert rec.retries == 1 and rec.requeues == 1
    assert not rec.failed
    # re-placed at the t=4 repair with 3.0 service units remaining
    assert rec.finish == pytest.approx(7.0)


def test_max_retries_gives_up_and_marks_failed():
    job = Job(job_id=0, arrival=0.0, blocks=4, service=5.0)
    failures = [FailureEvent(time=2.0, endpoints=_slot_endpoints(range(4)),
                             repair_at=4.0)]
    res = _sched(backoff_base=1.0, max_retries=0).run_stream(
        [job], failures=failures
    )
    rec = res.records[0]
    assert rec.failed and rec.finish is None
    assert res.summary()["failed"] == 1
    assert res.summary()["finished"] == 0


# --------------------------------------------------------- shrink to fit
def test_shrink_to_fit_degrades_instead_of_evicting():
    """Losing one slot under a 4-block job: migration cannot fit, so the
    shrink fallback halves the job onto the survivors and marks it
    degraded — it keeps its original departure time."""
    job = Job(job_id=0, arrival=0.0, blocks=4, service=5.0)
    failures = [FailureEvent(time=2.0, endpoints=_slot_endpoints([0]),
                             repair_at=100.0)]
    res = _sched(shrink_to_fit=True).run_stream([job], failures=failures)
    rec = res.records[0]
    assert rec.degraded and not rec.failed
    assert rec.requeues == 0
    assert rec.finish == pytest.approx(5.0)  # departure event survives
    assert res.summary()["degraded"] == 1


def test_shrink_disabled_requeues_instead():
    job = Job(job_id=0, arrival=0.0, blocks=4, service=5.0)
    failures = [FailureEvent(time=2.0, endpoints=_slot_endpoints([0]),
                             repair_at=6.0)]
    res = _sched().run_stream([job], failures=failures)
    rec = res.records[0]
    assert rec.requeues == 1 and not rec.degraded
    assert rec.finish == pytest.approx(9.0)  # repair at 6 + 3.0 remaining


# ------------------------------------------------- straggler eviction wiring
def test_straggler_eviction_feeds_failure_path():
    """A persistently slow host reported through the monitor is evicted
    and flows through the same migrate/requeue/repair machinery as a
    failure (satellite: StragglerMonitor -> scheduler integration)."""
    job = Job(job_id=0, arrival=0.0, blocks=4, service=10.0)
    monitor = StragglerMonitor(threshold=1.2, window=8, evict_after=1)
    stragglers = [(1.0, 40, 1.0), (2.0, 0, 50.0)]  # host 0 is 50x slower
    res = _sched(mttr=3.0).run_stream(
        [job], stragglers=stragglers, straggler_monitor=monitor,
    )
    rec = res.records[0]
    assert monitor.evictions() == [0]
    assert rec.requeues == 1      # whole-machine job cannot migrate off 0
    assert rec.finish is not None  # the mttr repair let it run again
    assert rec.finish > 10.0


def test_straggler_noise_without_eviction_is_harmless():
    job = Job(job_id=0, arrival=0.0, blocks=4, service=10.0)
    res = _sched().run_stream(
        [job], stragglers=[(1.0, 0, 1.0), (2.0, 1, 1.01)],
    )
    rec = res.records[0]
    assert rec.requeues == 0 and rec.finish == pytest.approx(10.0)


# ------------------------------------------------------- checkpointed resume
def test_stream_checkpoint_and_resume_in_process(tmp_path):
    """Checkpointing must not perturb the stream, and resuming from the
    latest snapshot must replay to the same final records."""
    jobs = poisson_stream(20, rate=0.8, seed=1)
    hit = _slot_endpoints([1, 2])
    failures = [FailureEvent(time=3.0, endpoints=hit, repair_at=9.0)]
    base = _sched(mttr=5.0, backoff_base=0.5).run_stream(
        jobs, failures=failures
    )
    ck = str(tmp_path / "ck")
    with_ckpt = _sched(mttr=5.0, backoff_base=0.5).run_stream(
        jobs, failures=failures, checkpoint_dir=ck, checkpoint_every=2,
    )
    assert with_ckpt.records == base.records
    assert with_ckpt.summary() == base.summary()
    assert Checkpointer(ck).latest_step() is not None
    resumed = _sched(mttr=5.0, backoff_base=0.5).run_stream(
        jobs, failures=failures, checkpoint_dir=ck, resume=True,
    )
    assert resumed.records == base.records
    assert resumed.summary() == base.summary()


def _stream_cli(extra, tmp_path, expect_rc=0):
    env = dict(os.environ, PYTHONPATH=os.path.join(REPO, "src"))
    args = [sys.executable, "-m", "repro.resil.stream",
            "--jobs", "30", "--rate", "0.5", "--seed", "3",
            "--mttr", "15", "--backoff", "0.5", "--churn", "3",
            "--every", "2"] + extra
    proc = subprocess.run(args, env=env, cwd=str(tmp_path),
                          capture_output=True, text=True, timeout=300)
    assert proc.returncode == expect_rc, proc.stderr
    return proc


def test_kill_and_resume_stream_bit_identical(tmp_path):
    """The crash-safety pin: hard-kill (exit 137) a checkpointed stream
    mid-flight, resume it, and the final summary JSON is byte-identical
    to an uninterrupted run's."""
    a = str(tmp_path / "a.json")
    b = str(tmp_path / "b.json")
    ck = str(tmp_path / "ck")
    _stream_cli(["--out", a], tmp_path)
    _stream_cli(["--ckpt", ck, "--crash-at", "20"], tmp_path,
                expect_rc=137)
    assert Checkpointer(ck).latest_step() is not None
    _stream_cli(["--ckpt", ck, "--resume", "--out", b], tmp_path)
    with open(a) as fa, open(b) as fb:
        da, db = fa.read(), fb.read()
    assert da == db
    assert json.loads(da)["finished"] > 0


def test_stream_cli_resume_without_checkpoint_starts_fresh(tmp_path):
    out = str(tmp_path / "o.json")
    _stream_cli(["--ckpt", str(tmp_path / "empty"), "--resume",
                 "--out", out], tmp_path)
    assert json.load(open(out))["jobs"] == 30


# ------------------------------------------------ async Checkpointer failure
def test_async_checkpointer_save_failure_surfaces(tmp_path, monkeypatch):
    """Regression: a background _write that dies must raise on wait() (and
    on the next save()), not silently drop the checkpoint."""
    ckpt = Checkpointer(str(tmp_path / "ck"), async_save=True)

    def boom(step, host, extra):
        raise OSError("disk full")

    monkeypatch.setattr(ckpt, "_write", boom)
    ckpt.save(0, {"a": np.zeros(3)})
    with pytest.raises(RuntimeError, match="async checkpoint save failed"):
        ckpt.wait()
    # the error is consumed: the substrate is usable again afterwards
    ckpt.wait()

    ckpt.save(1, {"a": np.zeros(3)})  # fails in the background again...
    with pytest.raises(RuntimeError, match="disk full"):
        ckpt.save(2, {"a": np.zeros(3)})  # ...and surfaces on the NEXT save
    monkeypatch.undo()
    ckpt.save(3, {"a": np.ones(3)})
    ckpt.wait()
    assert ckpt.latest_step() == 3
    tree, _ = ckpt.restore({"a": None})
    assert (np.asarray(tree["a"]) == 1).all()


# ------------------------------------------------- benchmark suite isolation
def test_benchmark_suite_survives_failing_module(monkeypatch, capsys):
    """One raising benchmark module must not abort the suite: later
    modules still run, the failure lands in the wall-time summary, and
    main() exits nonzero (satellite: benchmarks/run.py isolation)."""
    from benchmarks import run as bench_run

    ran = []
    ok = types.ModuleType("benchmarks.fake_ok")
    ok.run = lambda quick=None: ran.append(("ok", quick))
    bad = types.ModuleType("benchmarks.fake_fail")

    def _explode(quick=None):
        raise RuntimeError("synthetic benchmark failure")

    bad.run = _explode
    monkeypatch.setitem(sys.modules, "benchmarks.fake_ok", ok)
    monkeypatch.setitem(sys.modules, "benchmarks.fake_fail", bad)
    monkeypatch.setattr(bench_run, "MODULES",
                        ["fake_fail", "fake_ok"])
    rc = bench_run.main(["--quick"])
    out = capsys.readouterr().out
    assert rc == 1
    assert ran == [("ok", True)]          # the suite kept going
    assert "FAILED" in out and "synthetic benchmark failure" in out
    assert "fake_ok" in out

    rc_ok = bench_run.main(["--quick", "--only", "fake_ok"])
    assert rc_ok == 0                      # no failure -> zero exit
