"""Partition-property tests — validates paper Table 1 EXACTLY (Section 5)."""

import math

import numpy as np
import pytest

from repro.core.allocation import allocate_partition
from repro.core.hyperx import HyperX
from repro.core.properties import (
    analyze_partition,
    convex_hull_links,
    convexity_class,
    dilation,
    endpoint_distance_stats,
    has_switch_locality,
    partition_bandwidth,
)
from repro.core.routing import empirical_partition_bandwidth

N = 8
TOPO = HyperX(n=N, q=2)


def part(strat, p=0, seed=0):
    return allocate_partition(strat, TOPO, p, seed=seed)


# ------------------------------------------------------------------ distances
@pytest.mark.parametrize(
    "strat,avg,mx",
    [
        ("row", 1 - 1 / N, 1),
        ("diagonal", 2 - 2 / N, 2),
        ("full_spread", 2 - 2 / N, 2),
        ("rectangular", 2 - 1 / 4 - 1 / 2, 2),  # n_a=4, n_b=2
    ],
)
def test_table1_distances_exact(strat, avg, mx):
    a, m = endpoint_distance_stats(TOPO, part(strat).endpoints)
    assert a == pytest.approx(avg)
    assert m == mx


def test_lshape_distance_about_one_and_a_half():
    a, m = endpoint_distance_stats(TOPO, part("l_shape").endpoints)
    assert m == 2
    # paper Table 1 reports the ROUGH value 1 + 1/2; the exact self-pair-
    # inclusive value is 1.25 (32 same-ray pairs at d=1 + 24 cross-ray at
    # d=2 + 8 self over 64 ordered pairs), 1.43 excluding self pairs.
    assert a == pytest.approx(1.25)


def test_random_distances_near_topology_average():
    # Random Endpoint inherits the topology average 2 - 2/n = 1.75.
    a, m = endpoint_distance_stats(TOPO, part("random_endpoint", seed=1).endpoints)
    assert m == 2
    assert a == pytest.approx(2 - 2 / N, abs=0.1)
    # Random Switch keeps switch locality: n^2 same-switch endpoint pairs at
    # d=0 scale the expectation to (1 - 1/n) * 1.75 ~ 1.53 (Table 1's "2"
    # is the rough approximation).
    vals = [
        endpoint_distance_stats(TOPO, part("random_switch", seed=s).endpoints)[0]
        for s in range(5)
    ]
    import numpy as _np

    assert _np.mean(vals) == pytest.approx((1 - 1 / N) * (2 - 2 / N), abs=0.15)


# ------------------------------------------------------------------ convexity
@pytest.mark.parametrize(
    "strat,cls",
    [
        ("row", "convex"),
        ("full_spread", "convex"),
        ("rectangular", "convex"),
        ("diagonal", "non-convex"),
        ("l_shape", "weakly-convex"),
    ],
)
def test_table1_convexity(strat, cls):
    assert convexity_class(TOPO, part(strat).switches) == cls


def test_random_partitions_non_convex():
    for strat in ("random_endpoint", "random_switch"):
        assert convexity_class(TOPO, part(strat, seed=2).switches) == "non-convex"


# ------------------------------------------------------- partition bandwidth
def test_table1_pb_row():
    pb, bound = partition_bandwidth(TOPO, part("row").endpoints)
    assert bound == pytest.approx(1.0)
    assert pb == pytest.approx(1.0)


def test_table1_pb_diagonal():
    pb, bound = partition_bandwidth(TOPO, part("diagonal").endpoints)
    assert bound == pytest.approx(2.0)
    assert pb == pytest.approx(2.0)


def test_table1_pb_full_spread():
    pb, bound = partition_bandwidth(TOPO, part("full_spread").endpoints)
    assert bound == pytest.approx(N)
    assert pb == pytest.approx(N)


def test_table1_pb_rectangular():
    # PB = 1/sqrt(2n) = 0.25 for n=8 (per-dimension refinement, Sec. 5.3)
    pb, bound = partition_bandwidth(TOPO, part("rectangular").endpoints)
    assert pb == pytest.approx(1 / math.sqrt(2 * N))
    assert bound > pb  # the aggregate bound overestimates anisotropic shapes


def test_table1_pb_l_shape():
    pb, _ = partition_bandwidth(TOPO, part("l_shape").endpoints)
    assert pb == pytest.approx(1.0, abs=0.35)  # paper: ~1 asymptotically


def test_table1_pb_random_switch():
    # ~ 2(1 - e^-1) ~ 1.26 asymptotically; finite-n samples fluctuate
    vals = [
        partition_bandwidth(TOPO, part("random_switch", seed=s).endpoints)[0]
        for s in range(5)
    ]
    assert 0.9 < float(np.mean(vals)) < 1.9


def test_table1_pb_random_endpoint():
    # ~ n(1 - e^-2) ~ 6.9 asymptotically
    vals = [
        partition_bandwidth(TOPO, part("random_endpoint", seed=s).endpoints)[0]
        for s in range(5)
    ]
    assert 4.0 < float(np.mean(vals)) < 8.0


# ------------------------------------------- PB vs measured MIN saturation
@pytest.mark.parametrize("strat", ["row", "diagonal", "full_spread"])
def test_pb_matches_min_routing_saturation(strat):
    """For symmetric partitions Eq. (3) is an equality: the analytical
    link-load model under MIN routing saturates exactly at PB."""
    p = part(strat)
    pb, _ = partition_bandwidth(TOPO, p.endpoints)
    emp = empirical_partition_bandwidth(TOPO, p.endpoints)
    assert emp == pytest.approx(pb, rel=0.05)


def test_pb_ordering_matches_paper():
    """PB(FullSpread) > PB(RandomEndpoint) > PB(Diagonal) > PB(RandomSwitch)
    > PB(Row) ~ PB(Lshape) > PB(Rect) — the machine the paper's Lesson 2
    turns on."""
    vals = {}
    for strat in ("row", "diagonal", "full_spread", "rectangular", "l_shape",
                  "random_endpoint", "random_switch"):
        vals[strat] = partition_bandwidth(TOPO, part(strat, seed=0).endpoints)[0]
    assert vals["full_spread"] > vals["random_endpoint"] > vals["diagonal"]
    assert vals["diagonal"] > vals["random_switch"]
    assert vals["random_switch"] > vals["rectangular"]
    assert vals["rectangular"] < 1.0 <= vals["row"] + 1e-9


# ------------------------------------------------------------------ dilation
def test_dilation_bounded_by_partition_max_distance():
    p = part("diagonal")
    edges = np.stack(
        [np.arange(63), np.arange(1, 64)], axis=1
    )  # a ring application
    avg, mx = dilation(TOPO, edges, p.rank_to_endpoint)
    assert mx <= 2
    assert 0 <= avg <= 2


def test_convex_hull_of_row_is_complete_graph():
    hull = convex_hull_links(TOPO, part("row").switches)
    assert len(hull) == N * (N - 1) // 2  # K8: 28 links


def test_convex_hull_of_diagonal():
    hull = convex_hull_links(TOPO, part("diagonal").switches)
    assert len(hull) == 2 * N * (N - 1)  # paper Sec 5.3: 4x the Row case
