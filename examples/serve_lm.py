"""Serve a small LM with batched requests: prefill + jitted greedy decode.

    PYTHONPATH=src python examples/serve_lm.py
"""

import sys

sys.path.insert(0, "src")

from repro.launch import serve as S


def main():
    S.main(["--arch", "qwen3_0_6b", "--reduced",
            "--batch", "4", "--prompt-len", "32", "--gen", "16"])


if __name__ == "__main__":
    main()
