"""End-to-end driver: train a ~100M-parameter LM with the full substrate
(synthetic data, AdamW, checkpointing, straggler monitor, fault injection).

Full setting (a few hundred steps of a 110M model; several hours on this
1-core CPU container, minutes on a real accelerator):

    PYTHONPATH=src python examples/train_lm.py

Smoke setting (~1 minute):

    PYTHONPATH=src python examples/train_lm.py --tiny
"""

import argparse
import dataclasses
import sys

sys.path.insert(0, "src")

from repro.models.config import ArchConfig
from repro.launch import train as T


def lm_100m() -> ArchConfig:
    return ArchConfig(
        name="repro-lm-110m", family="dense",
        n_layers=12, d_model=768, n_heads=12, n_kv=12,
        d_ff=3072, vocab=32768, tie_embeddings=True,
    )


def main():
    p = argparse.ArgumentParser()
    p.add_argument("--tiny", action="store_true")
    p.add_argument("--steps", type=int, default=None)
    p.add_argument("--ckpt", default="/tmp/repro_lm_ckpt")
    args = p.parse_args()

    import repro.configs as C

    cfg = lm_100m()
    if args.tiny:
        cfg = dataclasses.replace(cfg, n_layers=4, d_model=128, n_heads=4,
                                  n_kv=4, d_ff=512, vocab=2048,
                                  name="repro-lm-tiny")
    # register so the launch driver can find it
    import repro.configs

    mod = type(sys)("repro.configs._example_lm")
    mod.config = lambda: cfg
    mod.reduced = lambda: cfg
    sys.modules["repro.configs._example_lm"] = mod

    steps = args.steps or (60 if args.tiny else 300)
    batch, seq = (8, 128) if args.tiny else (16, 256)
    losses = T.main([
        "--arch", "_example_lm", "--steps", str(steps),
        "--batch", str(batch), "--seq", str(seq),
        "--ckpt", args.ckpt, "--ckpt-every", "25",
        "--lr", "1e-3",
    ])
    assert losses[-1] < losses[0], "loss did not decrease"
    print("OK: loss decreased", losses[0], "->", losses[-1])


if __name__ == "__main__":
    main()
