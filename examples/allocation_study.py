"""Allocation study: pick the best placement for a workload's communication
profile — the paper's contribution as a launcher feature.

    PYTHONPATH=src python examples/allocation_study.py
"""

import sys

sys.path.insert(0, "src")

from repro.fabric.collective_model import rank_strategies_for_schedule


def main():
    profiles = {
        "dense DP training (grad all-reduce heavy)": [
            ("all_reduce", "data", 256e6),
            ("all_gather", "model", 16e6),
        ],
        "MoE training (expert all-to-all heavy)": [
            ("all_reduce", "data", 64e6),
            ("all_to_all", "model", 128e6),
        ],
        "TP serving (all-gather latency bound)": [
            ("all_gather", "model", 2e6),
            ("collective_permute", "model", 1e6),
        ],
    }
    for name, schedule in profiles.items():
        ranked = rank_strategies_for_schedule((16, 16), ("data", "model"),
                                              schedule)
        print(f"\n== {name} ==")
        for r in ranked[:4]:
            print(f"  {r['strategy']:16s} {r['total_s']*1e3:8.3f} ms "
                  f"(bw {r['bandwidth_s']*1e3:.3f} + lat {r['latency_s']*1e3:.3f})")
        print(f"  -> launcher picks: {ranked[0]['strategy']}")


if __name__ == "__main__":
    main()
