"""Quickstart: the paper's resource allocation machinery in 60 seconds.

    PYTHONPATH=src python examples/quickstart.py
"""

from repro.core.hyperx import HyperX
from repro.core.allocation import allocate_partition, machine_partitions
from repro.core.properties import analyze_partition
from repro.core import traffic as tr
from repro.core.engine import SimEngine
from repro.fabric.placement import place_job
from repro.fabric.collective_model import CollectiveModel
from repro.route import apply_faults, fail_links
from repro.sched import Job, OnlineScheduler
from repro.traffic import AppSpec, PhaseSpec, ScenarioSpec, build_workload


def main():
    # 1) the paper machine: 8x8 HyperX, 8 endpoints/switch
    topo = HyperX(n=8, q=2)
    print(f"machine: {topo} — {topo.num_endpoints} endpoints, "
          f"{topo.num_links} links, diameter {topo.diameter}")

    # 2) allocate one 64-rank job under two strategies and compare (Table 1)
    strategies = ("row", "diagonal")
    for strat in strategies:
        part = allocate_partition(strat, topo, 0)
        p = analyze_partition(topo, part)
        print(f"{strat:10s} avg_dist={p.avg_distance:.3f} "
              f"convex={p.convexity:13s} PB={p.partition_bandwidth:.2f}")

    # 3) simulate an All-to-All on each allocation (the paper's evaluation).
    # Both scenarios share one compilation and run as ONE batched device
    # call: the engine takes workload tables as vmapped pytree arguments.
    engine = SimEngine(topo, mode="omniwar")
    workloads = []
    for strat in strategies:
        parts = machine_partitions(strat, topo, num_jobs=8)
        workloads.append(
            tr.compose_workload(topo, [(tr.all_to_all(64), p) for p in parts])
        )
    results = engine.run_batch(workloads, horizon=40000)
    for strat, res in zip(strategies, results):
        print(f"{strat:10s} 8x all-to-all makespan = "
              f"{res.makespan_cycles} cycles (avg hops {res.avg_hops:.2f})")

    # 4) the framework side: place a 256-chip training mesh by strategy and
    # price its collectives with the partition-bandwidth cost model
    for strat in ("rectangular", "diagonal"):
        placement = place_job(strat, (16, 16), ("data", "model"))
        model = CollectiveModel(placement)
        c = model.cost("all_reduce", "data", 64e6)
        print(f"{strat:12s} data-axis PB={c.pb:5.2f} -> "
              f"64MB grad all-reduce {c.total_s*1e3:.2f} ms")

    # 5) online scheduling: two jobs contend for the machine.  Job B needs
    # 4 base blocks while job A holds 6 of the 8, so B queues until A
    # departs — the scheduler reports its wait, the fragmentation it saw,
    # and the realized PB of the partitions actually placed.
    print("\ntwo-job stream, Diagonal vs Rectangular:")
    jobs = [
        Job(job_id=0, arrival=0.0, blocks=6, service=30.0),
        Job(job_id=1, arrival=5.0, blocks=4, service=20.0),
    ]
    for strat in ("diagonal", "rectangular"):
        res = OnlineScheduler(topo, strategy=strat).run_stream(jobs)
        s = res.summary()
        waits = {r.job_id: r.wait for r in res.records}
        print(f"{strat:12s} waits={{A: {waits[0]:.0f}, B: {waits[1]:.0f}}} "
              f"frag_mean={s['frag_mean']:.3f} util={s['utilization']:.2f} "
              f"realized_PB={s['realized_pb_mean']:.2f}")

    # 6) fault-aware routing: the same Diagonal-vs-Rectangular comparison
    # under UGAL with one dead cable.  The mask rides in the workload
    # tables, so both strategies (and the fault) share one compilation
    # and one batched device call; routing steers around the dead link.
    print("\n64-rank all-to-all under ugal, one failed link (0 <-> 1):")
    ugal = SimEngine(topo, mode="ugal")
    mask = fail_links(topo, [(0, 1)])
    faulty = [
        apply_faults(
            tr.compose_workload(
                topo, [(tr.all_to_all(64), allocate_partition(strat, topo, 0))]
            ),
            mask,
        )
        for strat in ("diagonal", "rectangular")
    ]
    for strat, res in zip(("diagonal", "rectangular"),
                          ugal.run_batch(faulty, horizon=40000)):
        print(f"{strat:12s} makespan = {res.makespan_cycles} cycles "
              f"(avg hops {res.avg_hops:.2f}, max hops {res.max_hops} "
              f"< VC budget {ugal.static.V})")

    # 7) declarative phased scenarios: the canonical HPC iteration —
    # stencil compute-exchange rounds followed by an all-reduce — as ONE
    # app built through the traffic-pattern registry (repro.traffic).
    # Both strategies again share one compilation and one device call.
    print("\nphased stencil+all-reduce job, Diagonal vs Rectangular:")
    engine = SimEngine(topo, mode="omniwar")
    phased = [
        build_workload(topo, ScenarioSpec(apps=(
            AppSpec(
                phases=(PhaseSpec("stencil_von_neumann", {"rounds": 8}),
                        PhaseSpec("all_reduce", {"vector_packets": 64})),
                placement=strat,
            ),
        )))
        for strat in ("diagonal", "rectangular")
    ]
    for strat, res in zip(("diagonal", "rectangular"),
                          engine.run_batch(phased, horizon=40000)):
        print(f"{strat:12s} stencil+all_reduce makespan = "
              f"{res.makespan_cycles} cycles (avg hops {res.avg_hops:.2f})")


if __name__ == "__main__":
    main()
