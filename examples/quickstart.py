"""Quickstart: the paper's resource allocation machinery in 60 seconds.

    PYTHONPATH=src python examples/quickstart.py
"""

from repro.core.hyperx import HyperX
from repro.core.allocation import allocate_partition, machine_partitions
from repro.core.properties import analyze_partition
from repro.core import traffic as tr
from repro.core.engine import SimEngine
from repro.fabric.placement import place_job
from repro.fabric.collective_model import CollectiveModel


def main():
    # 1) the paper machine: 8x8 HyperX, 8 endpoints/switch
    topo = HyperX(n=8, q=2)
    print(f"machine: {topo} — {topo.num_endpoints} endpoints, "
          f"{topo.num_links} links, diameter {topo.diameter}")

    # 2) allocate one 64-rank job under two strategies and compare (Table 1)
    strategies = ("row", "diagonal")
    for strat in strategies:
        part = allocate_partition(strat, topo, 0)
        p = analyze_partition(topo, part)
        print(f"{strat:10s} avg_dist={p.avg_distance:.3f} "
              f"convex={p.convexity:13s} PB={p.partition_bandwidth:.2f}")

    # 3) simulate an All-to-All on each allocation (the paper's evaluation).
    # Both scenarios share one compilation and run as ONE batched device
    # call: the engine takes workload tables as vmapped pytree arguments.
    engine = SimEngine(topo, mode="omniwar")
    workloads = []
    for strat in strategies:
        parts = machine_partitions(strat, topo, num_jobs=8)
        workloads.append(
            tr.compose_workload(topo, [(tr.all_to_all(64), p) for p in parts])
        )
    results = engine.run_batch(workloads, horizon=40000)
    for strat, res in zip(strategies, results):
        print(f"{strat:10s} 8x all-to-all makespan = "
              f"{res.makespan_cycles} cycles (avg hops {res.avg_hops:.2f})")

    # 4) the framework side: place a 256-chip training mesh by strategy and
    # price its collectives with the partition-bandwidth cost model
    for strat in ("rectangular", "diagonal"):
        placement = place_job(strat, (16, 16), ("data", "model"))
        model = CollectiveModel(placement)
        c = model.cost("all_reduce", "data", 64e6)
        print(f"{strat:12s} data-axis PB={c.pb:5.2f} -> "
              f"64MB grad all-reduce {c.total_s*1e3:.2f} ms")


if __name__ == "__main__":
    main()
