"""Online job-stream scheduling demo: churn, failures, and interference.

    PYTHONPATH=src python examples/sched_stream_demo.py

Schedules one deterministic 120-job Poisson stream under three allocation
strategies, injects a mid-stream endpoint failure burst, and evaluates a
few co-resident snapshots through the batched cycle simulator — the whole
strategy x snapshot x seed grid runs as one device call per shape bucket.
"""

import numpy as np

from repro.core.hyperx import HyperX
from repro.sched import (
    FailureEvent,
    OnlineScheduler,
    evaluate_snapshots,
    poisson_stream,
)
from repro.sched.bridge import pick_snapshots


def main():
    topo = HyperX(n=8, q=2)
    jobs = poisson_stream(120, rate=0.45, mean_service=8.0, seed=11)
    rng = np.random.default_rng(3)
    failures = [FailureEvent(
        time=80.0,
        endpoints=tuple(int(e) for e in
                        rng.choice(topo.num_endpoints, 5, replace=False)),
        repair_at=160.0,
    )]

    print(f"machine {topo}: {topo.n} base blocks of {topo.n**2} endpoints")
    print(f"{'strategy':14s} {'util':>6s} {'wait':>7s} {'frag':>6s} "
          f"{'migr':>4s} {'PB(real)':>8s} {'local':>5s}")
    snaps = {}
    for strat in ("row", "diagonal", "rectangular"):
        res = OnlineScheduler(topo, strategy=strat).run_stream(
            jobs, failures=failures)
        s = res.summary()
        print(f"{strat:14s} {s['utilization']:6.2f} {s['mean_wait']:7.2f} "
              f"{s['frag_mean']:6.3f} {s['migrations']:4d} "
              f"{s['realized_pb_mean']:8.2f} {s['locality_frac']:5.2f}")
        snaps[strat] = pick_snapshots(res.snapshots, 2)

    print("\nco-resident interference (batched SimEngine):")
    rows, stats = evaluate_snapshots(topo, snaps, seeds=(0,), horizon=30_000)
    for r in rows:
        print(f"  {r['key']:14s} t={r['time']:7.1f} jobs={r['co_jobs']} "
              f"ranks={r['ranks']:3d} makespan={r['makespan']:5d} "
              f"hops={r['avg_hops']:.2f}")
    print(f"{len(rows)} scenarios -> {stats['traces']} compile(s), "
          f"{stats['device_calls']} device call(s)")


if __name__ == "__main__":
    main()
