"""Fleet service demo: traced streams under churn -> watcher -> insights.

    PYTHONPATH=src python examples/fleet_watch_demo.py [FLEET_DIR]

Drives the whole fleet-observability loop end to end on one machine:

  1. run two traced scheduler streams (different allocation strategies)
     under an endpoint-churn failure campaign — each lands a
     store-friendly trace directory under ``FLEET_DIR``;
  2. point a one-shot :class:`~repro.obs.watch.FleetWatcher` at both
     dirs: rollups compact the events, alert rules flag the churn;
  3. render the :mod:`~repro.obs.dashboard` (markdown + HTML);
  4. ask :mod:`~repro.obs.insights` two questions — which *queue* should
     absorb a new job (from the watched history) and which *strategy*
     should place a job right now (from live ledger state, one batched
     interference simulation across all candidates).
"""

import sys
import tempfile

from repro.core.hyperx import HyperX
from repro.obs import dashboard, insights, trace
from repro.obs.store import open_store
from repro.obs.watch import FleetWatcher, default_rules
from repro.resil.processes import (
    exponential_lifetimes,
    sample_components,
    to_failure_events,
)
from repro.sched import OnlineScheduler, poisson_stream

STRATEGIES = ("diagonal", "rectangular")


def main():
    topo = HyperX(n=8, q=2)
    fleet = sys.argv[1] if len(sys.argv) > 1 else tempfile.mkdtemp(
        prefix="fleet_")
    jobs = poisson_stream(60, rate=0.5, mean_service=8.0, seed=11)
    comps = sample_components(topo, n_endpoints=6, seed=11)
    failures = to_failure_events(exponential_lifetimes(
        comps, mtbf=40.0, mttr=10.0, horizon=200, seed=11))

    # 1. two traced streams under the same churn campaign
    dirs = []
    for strat in STRATEGIES:
        d = f"{fleet}/{strat}"
        dirs.append(d)
        trace.configure(d, demo="fleet_watch", strategy=strat)
        try:
            res = OnlineScheduler(topo, strategy=strat, mttr=10.0,
                                  backoff_base=0.5).run_stream(
                jobs, failures=failures)
        finally:
            trace.disable()
        s = res.summary()
        print(f"{strat:12s} util={s['utilization']:.2f} "
              f"frag={s['frag_mean']:.3f} failed={s['failed']}")

    # 2. one-shot watch: rollups + alert rules over both traces
    store = open_store(dirs, store_dir=f"{fleet}/store")
    FleetWatcher(store, rules=default_rules(frag=0.5, fails=3), echo=False)
    store.poll()
    print(f"\nwatch: {store.status_line()}")
    for alert in store.alerts[:5]:
        print(f"  ALERT {alert['rule']}: {alert['value']} "
              f"> {alert['threshold']} ({alert['run']})")

    # 3. dashboard artifacts
    paths = dashboard.write_dashboard(store, f"{fleet}/dash")
    print(f"\ndashboard: {paths['html']}")

    # 4a. which queue absorbs the next job best, from watched history?
    best = insights.recommend_queue(store, blocks=2)
    print(f"\nqueue recommendation: {best['stream']} — {best['reason']}")

    # 4b. which strategy places a job best right now, from live state?
    ledger = OnlineScheduler(topo, strategy="diagonal").ledger
    ledger.place(2, job_id=1)
    ledger.place(1, job_id=2)
    ins = insights.recommend(topo, ledger, blocks=1, seeds=(0,),
                             horizon=20_000)
    print(f"strategy recommendation for a 1-block job "
          f"(simulated={ins.simulated}):")
    for c in ins.candidates:
        lat = f"{c.avg_latency:.2f}" if c.avg_latency is not None else "-"
        print(f"  {c.strategy:12s} placeable={c.placeable!s:5s} "
              f"contiguous={c.contiguous!s:5s} frag={c.frag:.3f} "
              f"latency={lat}")
    print(f"-> place with {ins.best.strategy}")


if __name__ == "__main__":
    main()
