"""Observability demo: traced scheduling + in-sim telemetry + report API.

    PYTHONPATH=src python examples/obs_trace_demo.py [TRACE_DIR]

Runs one deterministic Poisson job stream under two allocation strategies
with the :mod:`repro.obs` tracer active (scheduler events and engine
dispatch spans land in ``TRACE_DIR/events.jsonl``), then re-runs each
strategy's hottest scenario with in-sim telemetry probes enabled and
prints the top-5 hottest network links per strategy through the report
API — the per-link view of why Diagonal beats Rectangular.
"""

import sys
import tempfile

from repro.core.hyperx import HyperX
from repro.core.engine import get_engine
from repro.obs import TelemetrySpec, report, trace
from repro.sched import OnlineScheduler, poisson_stream
from repro.sched.bridge import pick_snapshots, snapshot_workload

STRATEGIES = ("diagonal", "rectangular")


def main():
    topo = HyperX(n=8, q=2)
    trace_dir = sys.argv[1] if len(sys.argv) > 1 else tempfile.mkdtemp(
        prefix="obs_trace_")
    trace.configure(trace_dir, demo="obs_trace_demo")
    print(f"tracing to {trace_dir}")

    jobs = poisson_stream(80, rate=0.45, mean_service=8.0, seed=11)
    spec = TelemetrySpec(n_windows=32, window=128)
    telemetry = {}
    try:
        for strat in STRATEGIES:
            with trace.span("demo.stream", strategy=strat):
                res = OnlineScheduler(topo, strategy=strat).run_stream(jobs)
            s = res.summary()
            print(f"{strat:12s} util={s['utilization']:.2f} "
                  f"wait={s['mean_wait']:.2f} frag={s['frag_mean']:.3f}")
            # probe the busiest co-resident snapshot with telemetry on
            snap = max(pick_snapshots(res.snapshots, 4),
                       key=lambda sn: sn.num_jobs)
            wl = snapshot_workload(topo, snap)
            engine = get_engine(topo, mode="omniwar",
                                num_pools=wl.num_pools, telemetry=spec)
            tel = engine.run(wl, seed=0, horizon=30_000).telemetry
            trace.log_telemetry(strat, tel, co_jobs=snap.num_jobs)
            telemetry[strat] = tel
    finally:
        trace.disable()

    for strat in STRATEGIES:
        print(f"\n{strat}: top-5 hottest links "
              f"(mean util {telemetry[strat].link_utilization().mean():.3f})")
        for row in report.hottest_links(telemetry[strat], 5):
            print(f"  switch {row['switch']:3d} port {row['port']:2d} "
                  f"(dim {row['dim']} -> {row['val']}): "
                  f"util {row['util']:.3f} ({row['grants']} grants)")

    paths = report.write_report(trace_dir)
    print(f"\nfleet report: {paths['report']}")


if __name__ == "__main__":
    main()
