from repro.fabric.placement import HyperXPlacement, make_placed_mesh  # noqa: F401
from repro.fabric.collective_model import CollectiveModel  # noqa: F401
from repro.fabric.collective_sim import compare_strategies_simulated  # noqa: F401
