"""Collective cost model on HyperX under an allocation-aware placement.

Prices the collectives a JAX program issues (all-reduce, all-gather,
reduce-scatter, all-to-all, collective-permute) over the mesh axes of a
:class:`~repro.fabric.placement.HyperXPlacement`, using the paper's
machinery:

  * **bandwidth term** — a collective over a mesh-axis group moves
    ``wire_bytes(kind, size, k)`` per chip.  The group's sustainable
    per-chip injection bandwidth on the fabric is ``min(1, PB(group))``
    of the chip link bandwidth, where PB is the paper's partition
    bandwidth (Sec. 5.3) computed for that group's endpoint set.  Groups
    placed by high-PB strategies (Diagonal, Full Spread) price cheaper
    than Row/Rectangular groups — this is Lesson 2 as a cost model.
  * **latency term** — ``steps(kind, k) x (avg_group_distance x hop_ns +
    fixed_ns)``, the dilation bound of Sec. 5.1.

The model serves three framework roles: (1) the roofline's
allocation-aware collective term; (2) the launcher's placement search
(pick the strategy that minimizes the priced collective schedule of a
step); (3) regression tests that the paper's Table-1 ordering carries
through to end-to-end collective pricing.
"""

from __future__ import annotations

import dataclasses
from typing import Sequence

import numpy as np

from repro.fabric.placement import HyperXPlacement


# TPU-v5e-class constants (per chip); see EXPERIMENTS.md §Roofline.
DEFAULT_LINK_GBPS = 50e9      # per ICI link, one direction
DEFAULT_HOP_NS = 500.0        # per-hop switch+wire latency
DEFAULT_FIXED_NS = 2000.0     # collective software launch overhead


def wire_bytes_per_chip(kind: str, bytes_per_chip: float, k: int) -> float:
    """Bytes each chip must move over the fabric for one collective.

    ``bytes_per_chip`` is the shard size living on each chip (the operand
    size divided over participants where applicable); ``k`` the group size.
    Ring-algorithm conventions (what XLA emits on TPU meshes):

      all_reduce      : 2 * (k-1)/k * payload   (reduce-scatter + all-gather)
      all_gather      : (k-1)/k * k * shard = (k-1) * shard
      reduce_scatter  : (k-1)/k * payload
      all_to_all      : (k-1)/k * payload
      collective_permute : payload
    """
    if k <= 1:
        return 0.0
    if kind == "all_reduce":
        return 2.0 * (k - 1) / k * bytes_per_chip
    if kind == "all_gather":
        return (k - 1) * bytes_per_chip
    if kind == "reduce_scatter":
        return (k - 1) / k * bytes_per_chip
    if kind == "all_to_all":
        return (k - 1) / k * bytes_per_chip
    if kind == "collective_permute":
        return bytes_per_chip
    raise ValueError(f"unknown collective kind {kind!r}")


def steps(kind: str, k: int) -> int:
    if k <= 1:
        return 0
    if kind in ("all_reduce",):
        return 2 * (k - 1)
    if kind in ("all_gather", "reduce_scatter", "all_to_all"):
        return k - 1
    return 1  # collective_permute


@dataclasses.dataclass(frozen=True)
class CollectiveCost:
    kind: str
    axis: str
    group_size: int
    wire_bytes: float
    pb: float                 # group partition bandwidth (paper metric)
    bandwidth_s: float        # bandwidth term, seconds
    latency_s: float          # latency (dilation) term, seconds

    @property
    def total_s(self) -> float:
        return max(self.bandwidth_s, 0.0) + self.latency_s


class CollectiveModel:
    """Price collectives over the axes of one placement."""

    def __init__(
        self,
        placement: HyperXPlacement,
        link_bw: float = DEFAULT_LINK_GBPS,
        hop_ns: float = DEFAULT_HOP_NS,
        fixed_ns: float = DEFAULT_FIXED_NS,
    ):
        self.placement = placement
        self.link_bw = link_bw
        self.hop_ns = hop_ns
        self.fixed_ns = fixed_ns
        self._axis_props = {
            a: placement.axis_properties(a) for a in placement.axis_names
        }

    def axis_pb(self, axis: str) -> float:
        return self._axis_props[axis]["pb_min"]

    def axis_distance(self, axis: str) -> float:
        return self._axis_props[axis]["avg_distance"]

    def cost(self, kind: str, axis: str, bytes_per_chip: float) -> CollectiveCost:
        props = self._axis_props[axis]
        k = props["group_size"]
        wb = wire_bytes_per_chip(kind, bytes_per_chip, k)
        pb = props["pb_min"]
        eff_bw = min(1.0, pb) * self.link_bw
        bw_s = wb / eff_bw if wb else 0.0
        lat_s = steps(kind, k) * (
            props["avg_distance"] * self.hop_ns + self.fixed_ns
        ) * 1e-9
        return CollectiveCost(
            kind=kind, axis=axis, group_size=k, wire_bytes=wb, pb=pb,
            bandwidth_s=bw_s, latency_s=lat_s,
        )

    def price_schedule(
        self, schedule: Sequence[tuple[str, str, float]]
    ) -> dict:
        """Total priced time of a list of (kind, axis, bytes_per_chip).

        Returns the per-collective breakdown plus serial total — the
        allocation-aware collective roofline term.
        """
        items = [self.cost(*entry) for entry in schedule]
        return {
            "strategy": self.placement.strategy,
            "items": items,
            "total_s": float(sum(c.total_s for c in items)),
            "bandwidth_s": float(sum(c.bandwidth_s for c in items)),
            "latency_s": float(sum(c.latency_s for c in items)),
        }


def rank_strategies_for_schedule(
    mesh_shape: Sequence[int],
    axis_names: Sequence[str],
    schedule: Sequence[tuple[str, str, float]],
    strategies: Sequence[str] = (
        "row", "diagonal", "full_spread", "rectangular", "l_shape",
        "random_endpoint", "random_switch",
    ),
    seed: int = 0,
) -> list[dict]:
    """Price one collective schedule under every allocation strategy.

    The launcher uses this to pick the placement for a job's communication
    profile; ties broken toward locality-aware strategies (Lesson 3).
    """
    from repro.fabric.placement import place_job

    out = []
    for strat in strategies:
        placement = place_job(strat, mesh_shape, axis_names, seed=seed)
        model = CollectiveModel(placement)
        priced = model.price_schedule(schedule)
        priced["locality_aware"] = all(
            placement.axis_properties(a)["group_size"] > 0 for a in axis_names
        )
        out.append(priced)
    out.sort(key=lambda d: d["total_s"])
    return out
