"""Allocation-aware device placement: the paper's functions as mesh policy.

This is the bridge between the paper (resource allocation on a HyperX
machine) and the JAX runtime.  The fleet model: TPU-class chips are the
*endpoints* of a 2D HyperX fabric — the paper's canonical 8x8 HyperX with
concentration 8 hosts 512 chips, i.e. exactly the 2-pod production machine
(2 x 256).  A training job asks the resource allocator for a partition; the
allocation strategy decides *which* physical endpoints host the job, and
therefore how much fabric bandwidth (the paper's PB metric) every mesh-axis
collective can draw on.

``HyperXPlacement`` materializes one job placement:

  * ``mesh_position -> endpoint``: logical device (i_pod, i_data, i_model)
    to a physical HyperX endpoint, through an allocation function.  The
    fastest-varying mesh axis (``model``) walks consecutive ranks of the
    partition, so TP groups land where the allocation function puts
    consecutive ranks (e.g. for Diagonal: one switch per TP group).
  * ``device_order``: a permutation of ``jax.devices()`` realizing that
    mapping, handed to ``jax.sharding.Mesh``.  On real hardware the device
    list order is the physical order; in the CPU dry-run the permutation is
    structural but exercises identical sharding machinery.

The elastic runtime re-runs the allocation on the surviving endpoint set
after failures (see repro.runtime), making the paper's functions the repair
policy as well as the launch policy.
"""

from __future__ import annotations

import dataclasses
import math
from typing import Sequence

import numpy as np

from repro.core.allocation import allocate_partition, get_strategy
from repro.core.hyperx import HyperX
from repro.core.properties import endpoint_distance_stats, partition_bandwidth


def default_fleet(num_chips: int) -> HyperX:
    """Smallest well-balanced even-side 2D HyperX that can host the job.

    A 512-chip job (the 2-pod production mesh) fills the paper's canonical
    8x8 machine exactly; a 256-chip single pod occupies half of it (4 of
    its 8 base partitions).  Even side keeps every allocation strategy
    (incl. the rectangular tessellation) applicable.
    """
    if num_chips < 1:
        raise ValueError(f"bad fleet size {num_chips}")
    n = 4
    while n**3 < num_chips:
        n += 2
    return HyperX(n=n, q=2)


@dataclasses.dataclass(frozen=True)
class HyperXPlacement:
    """A job's physical placement on the HyperX fleet."""

    topo: HyperX
    strategy: str
    mesh_shape: tuple[int, ...]
    axis_names: tuple[str, ...]
    endpoints: np.ndarray  # mesh_shape-shaped array of endpoint ids

    @property
    def num_devices(self) -> int:
        return int(np.prod(self.mesh_shape))

    @classmethod
    def from_partition(
        cls,
        part,
        mesh_shape: Sequence[int],
        axis_names: Sequence[str],
    ) -> "HyperXPlacement":
        """Lay a mesh onto an already-allocated partition (rank order).

        This is how dynamically-placed jobs (the online scheduler's ledger,
        the elastic runtime's repair path) become JAX meshes: whatever block
        set the allocator found free, its rank order carries the strategy's
        locality structure and the last mesh axis walks consecutive ranks.
        """
        mesh_shape = tuple(int(s) for s in mesh_shape)
        size = int(np.prod(mesh_shape))
        if len(part.endpoints) < size:
            raise ValueError(
                f"partition has {len(part.endpoints)} endpoints < mesh "
                f"{mesh_shape}"
            )
        return cls(
            topo=part.topo,
            strategy=part.strategy,
            mesh_shape=mesh_shape,
            axis_names=tuple(axis_names)[-len(mesh_shape):],
            endpoints=np.asarray(part.endpoints[:size]).reshape(mesh_shape),
        )

    def axis_groups(self, axis: str) -> np.ndarray:
        """(num_groups, group_size) endpoint ids of each group of ``axis``.

        A collective over mesh axis ``axis`` runs independently inside each
        group: all mesh positions that differ only along ``axis``.
        """
        i = self.axis_names.index(axis)
        e = np.moveaxis(self.endpoints, i, -1)
        return e.reshape(-1, self.mesh_shape[i])

    def axis_properties(self, axis: str) -> dict:
        """Distance / PB statistics of the groups of one mesh axis."""
        groups = self.axis_groups(axis)
        pbs, avgs, maxs = [], [], []
        for g in groups:
            avg, mx = endpoint_distance_stats(self.topo, g)
            pb, _ = partition_bandwidth(self.topo, g)
            pbs.append(pb)
            avgs.append(avg)
            maxs.append(mx)
        return {
            "axis": axis,
            "groups": len(groups),
            "group_size": groups.shape[1],
            "pb_min": float(np.min(pbs)),
            "pb_mean": float(np.mean(pbs)),
            "avg_distance": float(np.mean(avgs)),
            "max_distance": int(np.max(maxs)),
        }

    def device_order(self) -> np.ndarray:
        """Permutation p with p[flat_mesh_position] = device index.

        Device index == endpoint id rank order: we adopt the convention that
        ``jax.devices()[i]`` is cabled to endpoint ``sorted(endpoints)[i]``
        of the job's partition.  On a real fleet this permutation is what the
        launcher feeds to ``jax.sharding.Mesh``.
        """
        flat = self.endpoints.reshape(-1)
        order = np.argsort(np.argsort(flat))
        return order


def place_job(
    strategy: str,
    mesh_shape: Sequence[int],
    axis_names: Sequence[str],
    topo: HyperX | None = None,
    job_id: int = 0,
    seed: int = 0,
) -> HyperXPlacement:
    """Allocate a partition for a mesh-shaped job and lay mesh axes on it.

    The linear rank order of the partition is assigned to mesh positions in
    row-major order, so the LAST mesh axis (by convention ``model``, the
    most communication-intensive) maps to consecutive ranks — i.e. to
    whatever locality structure the allocation strategy gives consecutive
    ranks (same switch for locality-aware strategies with n | group size).
    """
    mesh_shape = tuple(int(s) for s in mesh_shape)
    axis_names = tuple(axis_names)
    size = int(np.prod(mesh_shape))
    if topo is None:
        topo = default_fleet(size)
    part = allocate_partition(strategy, topo, job_id, size=size, seed=seed)
    endpoints = part.endpoints.reshape(mesh_shape)
    return HyperXPlacement(
        topo=topo,
        strategy=get_strategy(strategy).name,
        mesh_shape=mesh_shape,
        axis_names=axis_names,
        endpoints=endpoints,
    )


def make_placed_mesh(
    strategy: str,
    mesh_shape: Sequence[int],
    axis_names: Sequence[str],
    topo: HyperX | None = None,
    job_id: int = 0,
    seed: int = 0,
):
    """(jax Mesh with allocation-ordered devices, HyperXPlacement).

    Imported lazily so that pure-analysis users never touch jax device
    state.  Requires ``len(jax.devices()) >= prod(mesh_shape)``.
    """
    import jax

    placement = place_job(strategy, mesh_shape, axis_names, topo, job_id, seed)
    devs = jax.devices()
    size = placement.num_devices
    if len(devs) < size:
        raise RuntimeError(
            f"need {size} devices for mesh {mesh_shape}, have {len(devs)} "
            "(dry-run launchers set XLA_FLAGS=--xla_force_host_platform_"
            "device_count before importing jax)"
        )
    order = placement.device_order()
    arr = np.array(devs[:size], dtype=object)[order].reshape(placement.mesh_shape)
    mesh = jax.sharding.Mesh(arr, placement.axis_names)
    return mesh, placement
