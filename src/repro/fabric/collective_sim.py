"""Simulate mesh collectives on the HyperX fabric — cost-model validation.

The CollectiveModel (collective_model.py) *prices* collectives analytically
from partition bandwidth.  This module grounds that price: it expresses a
mesh-axis collective as a step-table workload (ring all-reduce = the
paper's neighbour-exchange; all-to-all = the paper's All-to-All kernel)
over the placement's actual endpoints, runs it through the cycle-level
simulator engine, and returns measured makespans.  Benchmarks compare
analytic vs simulated ordering across allocation strategies — closing the
loop between the paper's simulator evidence and the framework's launcher
policy.

Strategy comparisons run through ``SimEngine.run_grid``: every strategy's
workload shares one shape bucket, so the whole comparison is a single
compilation and one device call — sharded across all local devices when
the host has more than one.
"""

from __future__ import annotations

import numpy as np

from repro.core.allocation import Partition
from repro.core.engine import get_engine
from repro.core.hyperx import HyperX
from repro.fabric.placement import HyperXPlacement
from repro.traffic import AppSpec, PhaseSpec, ScenarioSpec, build_workload
from repro.traffic.workload import Workload

# registry patterns expressing each mesh-axis collective (the former
# private _ring_allreduce_app/_alltoall_app builders, deduplicated onto
# repro.traffic.patterns — parity-pinned in tests/test_traffic_patterns.py)
COLLECTIVE_PHASES = {
    "all_reduce": PhaseSpec("ring_allreduce", {"packets_per_step": 4}),
    "all_to_all": PhaseSpec("all_to_all"),
}


def _axis_groups(placement: HyperXPlacement, axis: str,
                 num_groups: int | None) -> np.ndarray:
    groups = placement.axis_groups(axis)
    return groups if num_groups is None else groups[:num_groups]


def _result_row(placement: HyperXPlacement, axis: str, kind: str,
                num_groups: int | None, res) -> dict:
    groups = _axis_groups(placement, axis, num_groups)
    return {
        "strategy": placement.strategy, "axis": axis, "kind": kind,
        "groups": len(groups), "group_size": groups.shape[1],
        "makespan": res.makespan if res.completed else -1,
        "completed": res.completed,
        "avg_hops": round(res.avg_hops, 3),
    }


def axis_collective_workload(
    placement: HyperXPlacement,
    axis: str,
    kind: str = "all_reduce",
    num_groups: int | None = None,
) -> Workload:
    """Express ``kind`` over (a subset of) the axis groups as one workload.

    All groups run simultaneously — exactly how a mesh collective executes —
    so inter-group link contention is captured, which is what
    distinguishes allocation strategies (the paper's Lesson 2/3).  The
    collective itself is a registry pattern (``COLLECTIVE_PHASES``), so
    any registered kernel can be dropped in per axis.
    """
    topo: HyperX = placement.topo
    groups = _axis_groups(placement, axis, num_groups)
    k = groups.shape[1]
    phase = COLLECTIVE_PHASES[kind]
    apps = []
    for g in groups:
        part = Partition(
            strategy=placement.strategy, topo=topo, job_id=-1, size=k,
            endpoints=np.asarray(g, dtype=np.int64),
            switches=np.unique(np.asarray(g) // topo.concentration),
        )
        apps.append(AppSpec(phases=phase, placement=part, ranks=k))
    return build_workload(topo, ScenarioSpec(apps=tuple(apps)))


def simulate_axis_collective(
    placement: HyperXPlacement,
    axis: str,
    kind: str = "all_reduce",
    num_groups: int | None = None,
    seed: int = 0,
    horizon: int = 120_000,
    mode: str = "omniwar",
    link_ok=None,
) -> dict:
    """Run ``kind`` concurrently over (a subset of) the axis groups.

    ``mode`` selects any registered routing policy; ``link_ok`` optionally
    injects a link-fault mask (see :mod:`repro.route.faults`).
    """
    wl = axis_collective_workload(placement, axis, kind, num_groups)
    if link_ok is not None:
        from repro.route import apply_faults

        wl = apply_faults(wl, link_ok)
    engine = get_engine(placement.topo, mode=mode, num_pools=wl.num_pools)
    res = engine.run(wl, seed=seed, horizon=horizon)
    return _result_row(placement, axis, kind, num_groups, res)


def compare_strategies_simulated(
    mesh_shape=(16, 16),
    axis_names=("data", "model"),
    axis: str = "model",
    kind: str = "all_to_all",
    strategies=("row", "diagonal", "full_spread", "rectangular",
                "l_shape", "random_endpoint", "random_switch"),
    num_groups: int | None = 8,
    seed: int = 0,
    mode: str = "omniwar",
) -> list[dict]:
    """Measured makespan of one mesh collective per allocation strategy.

    All strategies execute as one batched ``run_batch`` device call (their
    workloads share a shape bucket).  ``mode`` selects the routing policy.
    """
    from repro.fabric.placement import place_job

    placements = [place_job(s, mesh_shape, axis_names, seed=seed)
                  for s in strategies]
    wls = [axis_collective_workload(p, axis, kind, num_groups)
           for p in placements]
    engine = get_engine(placements[0].topo, mode=mode,
                        num_pools=wls[0].num_pools)
    # run_grid: strategy lanes shard across devices when the host has them
    per_wl = engine.run_grid(wls, seeds=[seed], horizon=120_000)
    out = [_result_row(p, axis, kind, num_groups, res[0])
           for p, res in zip(placements, per_wl)]
    out.sort(key=lambda d: d["makespan"] if d["makespan"] > 0 else 10**9)
    return out
