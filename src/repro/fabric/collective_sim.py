"""Simulate mesh collectives on the HyperX fabric — cost-model validation.

The CollectiveModel (collective_model.py) *prices* collectives analytically
from partition bandwidth.  This module grounds that price: it expresses a
mesh-axis collective as a step-table workload (ring all-reduce = the
paper's neighbour-exchange; all-to-all = the paper's All-to-All kernel)
over the placement's actual endpoints, runs it through the cycle-level
simulator engine, and returns measured makespans.  Benchmarks compare
analytic vs simulated ordering across allocation strategies — closing the
loop between the paper's simulator evidence and the framework's launcher
policy.

Strategy comparisons run through ``SimEngine.run_batch``: every strategy's
workload shares one shape bucket, so the whole comparison is a single
compilation and one vmapped device call.
"""

from __future__ import annotations

import numpy as np

from repro.core import traffic as tr
from repro.core.allocation import Partition
from repro.core.engine import get_engine
from repro.core.hyperx import HyperX
from repro.fabric.placement import HyperXPlacement


def _ring_allreduce_app(k: int, packets_per_step: int = 4) -> tr.AppTraffic:
    """Ring reduce-scatter + all-gather: 2(k-1) steps of neighbour sends."""
    T = 2 * (k - 1)
    dst, npk, deg, recv = tr._empty(k, T, 1)
    r = np.arange(k)
    for t in range(T):
        dst[:, t, 0] = (r + 1) % k
        npk[:, t, 0] = packets_per_step
        deg[:, t] = 1
        recv[:, t] = packets_per_step
    return tr.AppTraffic("ring_allreduce", k, dst, npk, deg, recv, window=1)


def _alltoall_app(k: int) -> tr.AppTraffic:
    return tr.all_to_all(k)


def _axis_groups(placement: HyperXPlacement, axis: str,
                 num_groups: int | None) -> np.ndarray:
    groups = placement.axis_groups(axis)
    return groups if num_groups is None else groups[:num_groups]


def _result_row(placement: HyperXPlacement, axis: str, kind: str,
                num_groups: int | None, res) -> dict:
    groups = _axis_groups(placement, axis, num_groups)
    return {
        "strategy": placement.strategy, "axis": axis, "kind": kind,
        "groups": len(groups), "group_size": groups.shape[1],
        "makespan": res.makespan if res.completed else -1,
        "completed": res.completed,
        "avg_hops": round(res.avg_hops, 3),
    }


def axis_collective_workload(
    placement: HyperXPlacement,
    axis: str,
    kind: str = "all_reduce",
    num_groups: int | None = None,
) -> tr.Workload:
    """Express ``kind`` over (a subset of) the axis groups as one workload.

    All groups run simultaneously — exactly how a mesh collective executes —
    so inter-group link contention is captured, which is what
    distinguishes allocation strategies (the paper's Lesson 2/3).
    """
    topo: HyperX = placement.topo
    groups = _axis_groups(placement, axis, num_groups)
    k = groups.shape[1]
    app_fn = {"all_reduce": _ring_allreduce_app, "all_to_all": _alltoall_app}[kind]
    apps = []
    for g in groups:
        part = Partition(
            strategy=placement.strategy, topo=topo, job_id=-1, size=k,
            endpoints=np.asarray(g, dtype=np.int64),
            switches=np.unique(np.asarray(g) // topo.concentration),
        )
        apps.append((app_fn(k), part))
    return tr.compose_workload(topo, apps)


def simulate_axis_collective(
    placement: HyperXPlacement,
    axis: str,
    kind: str = "all_reduce",
    num_groups: int | None = None,
    seed: int = 0,
    horizon: int = 120_000,
    mode: str = "omniwar",
    link_ok=None,
) -> dict:
    """Run ``kind`` concurrently over (a subset of) the axis groups.

    ``mode`` selects any registered routing policy; ``link_ok`` optionally
    injects a link-fault mask (see :mod:`repro.route.faults`).
    """
    wl = axis_collective_workload(placement, axis, kind, num_groups)
    if link_ok is not None:
        from repro.route import apply_faults

        wl = apply_faults(wl, link_ok)
    engine = get_engine(placement.topo, mode=mode, num_pools=wl.num_pools)
    res = engine.run(wl, seed=seed, horizon=horizon)
    return _result_row(placement, axis, kind, num_groups, res)


def compare_strategies_simulated(
    mesh_shape=(16, 16),
    axis_names=("data", "model"),
    axis: str = "model",
    kind: str = "all_to_all",
    strategies=("row", "diagonal", "full_spread", "rectangular",
                "l_shape", "random_endpoint", "random_switch"),
    num_groups: int | None = 8,
    seed: int = 0,
    mode: str = "omniwar",
) -> list[dict]:
    """Measured makespan of one mesh collective per allocation strategy.

    All strategies execute as one batched ``run_batch`` device call (their
    workloads share a shape bucket).  ``mode`` selects the routing policy.
    """
    from repro.fabric.placement import place_job

    placements = [place_job(s, mesh_shape, axis_names, seed=seed)
                  for s in strategies]
    wls = [axis_collective_workload(p, axis, kind, num_groups)
           for p in placements]
    engine = get_engine(placements[0].topo, mode=mode,
                        num_pools=wls[0].num_pools)
    results = engine.run_batch(wls, seeds=[seed] * len(wls), horizon=120_000)
    out = [_result_row(p, axis, kind, num_groups, res)
           for p, res in zip(placements, results)]
    out.sort(key=lambda d: d["makespan"] if d["makespan"] > 0 else 10**9)
    return out
