"""Architecture configuration schema.

One ``ArchConfig`` describes any model family in the assigned pool: dense
GQA transformers, MoE (token-choice top-k, optionally MLA attention), SSM
(Mamba-2 / SSD), hybrid recurrent (RG-LRU + local attention), cross-attn
VLM decoders, and encoder-only audio stacks.  ``configs/<arch>.py`` files
instantiate these with the exact published dimensions.
"""

from __future__ import annotations

import dataclasses
from typing import Literal

Family = Literal["dense", "moe", "ssm", "hybrid", "vlm", "audio"]


@dataclasses.dataclass(frozen=True)
class ShapeSpec:
    """One (input-shape x step-kind) evaluation cell."""

    name: str                      # train_4k / prefill_32k / decode_32k / long_500k
    kind: Literal["train", "prefill", "decode"]
    seq_len: int                   # sequence length (KV/cache length for decode)
    global_batch: int
    skip: str | None = None        # reason if this arch skips the cell


@dataclasses.dataclass(frozen=True)
class ArchConfig:
    name: str
    family: Family
    n_layers: int
    d_model: int
    n_heads: int
    n_kv: int
    d_ff: int
    vocab: int
    d_head: int = 0                # default d_model // n_heads

    # --- attention details ---
    qk_norm: bool = False          # qwen3: RMSNorm on q/k per head
    nonparam_ln: bool = False      # olmo: non-parametric LayerNorm
    encoder_only: bool = False     # hubert: bidirectional, no decode
    rope_theta: float = 1e4
    window: int = 0                # local attention window (0 = global)

    # --- MoE ---
    n_experts: int = 0
    top_k: int = 0
    n_shared: int = 0              # shared experts (deepseek-v2)
    d_ff_expert: int = 0
    capacity_factor: float = 1.25
    router_aux_coef: float = 0.001
    # dispatch groups (= data-parallel shards): tokens are grouped, sorted
    # and capacity-dropped PER GROUP so the scatter stays shard-local and
    # only the dispatched expert buffer crosses the fabric (all-to-all)
    moe_groups: int = 1

    # --- MLA (deepseek-v2) ---
    kv_lora: int = 0               # compressed KV width (0 = standard GQA)
    q_lora: int = 0
    rope_head_dim: int = 64

    # --- SSM (mamba2 / SSD) ---
    ssm_state: int = 0
    ssm_expand: int = 2
    ssm_head_dim: int = 64
    ssm_chunk: int = 256
    conv_width: int = 4

    # --- hybrid (recurrentgemma: pattern = 2 recurrent + 1 local attn) ---
    rglru_pattern: int = 0         # recurrent layers per attention layer (2)
    lru_width: int = 0             # 0 = d_model

    # --- VLM (llama-3.2-vision) ---
    cross_attn_every: int = 0      # 1 cross-attn layer per this many layers
    frontend_tokens: int = 0       # stub modality tokens (image patches / frames)

    # --- stub modality frontend (audio) ---
    frame_input: bool = False      # inputs are precomputed frame embeddings

    # --- training details ---
    tie_embeddings: bool = False
    dtype: str = "bfloat16"

    def __post_init__(self):
        if self.d_head == 0:
            object.__setattr__(self, "d_head", self.d_model // self.n_heads)

    # ------------------------------------------------------------ derived
    @property
    def vocab_padded(self) -> int:
        """Vocab rounded up to 128 (MXU lane width / TP divisibility);
        padded logit columns are masked to -inf in unembed (Megatron-style)."""
        return -(-self.vocab // 128) * 128

    @property
    def d_inner_ssm(self) -> int:
        return self.ssm_expand * self.d_model

    @property
    def ssm_heads(self) -> int:
        return self.d_inner_ssm // self.ssm_head_dim

    @property
    def lru_dim(self) -> int:
        return self.lru_width or self.d_model

    @property
    def is_subquadratic(self) -> bool:
        """Can this arch decode at 500k context (SSM state / local window)?"""
        return self.family in ("ssm", "hybrid")

    def param_count(self) -> int:
        """Exact parameter count from the spec tree."""
        from repro.models.transformer import model_specs
        from repro.models.module import count_params

        return count_params(model_specs(self))

    def active_param_count(self) -> int:
        """Parameters touched per token (MoE: top_k + shared experts only)."""
        if self.family != "moe":
            return self.param_count()
        total = self.param_count()
        expert_p = 3 * self.d_model * self.d_ff_expert  # swiglu expert
        inactive = self.n_layers * (self.n_experts - self.top_k) * expert_p
        return total - inactive

    # ------------------------------------------------------------- shapes
    def shapes(self) -> list[ShapeSpec]:
        """The assigned LM shape set with per-family skip annotations."""
        cells = [
            ShapeSpec("train_4k", "train", 4096, 256),
            ShapeSpec("prefill_32k", "prefill", 32768, 32),
            ShapeSpec("decode_32k", "decode", 32768, 128),
            ShapeSpec("long_500k", "decode", 524288, 1),
        ]
        out = []
        for c in cells:
            skip = None
            if self.encoder_only and c.kind == "decode":
                skip = "encoder-only architecture has no decode step"
            elif c.name == "long_500k" and not self.is_subquadratic:
                skip = (
                    "500k-context decode needs sub-quadratic attention; "
                    f"{self.name} is pure full-attention"
                )
            out.append(dataclasses.replace(c, skip=skip))
        return out

    def shape(self, name: str) -> ShapeSpec:
        for c in self.shapes():
            if c.name == name:
                return c
        raise KeyError(f"unknown shape {name!r} for {self.name}")
