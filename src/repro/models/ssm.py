"""Mamba-2 (state-space duality) blocks in pure JAX.

Implements the SSD chunked algorithm (Dao & Gu, 2024): intra-chunk
quadratic attention-like term + inter-chunk linear state recurrence, as a
``lax.scan`` over chunks carrying the (B, H, P, N) state.  Decode is the
O(1) single-token state update — the reason mamba2 runs the ``long_500k``
cell that full-attention architectures must skip.

The per-chunk einsum chain is also provided as a Pallas TPU kernel
(repro.kernels.ssd_scan) for the train/prefill hot path; this module is the
reference implementation and the decode path.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.config import ArchConfig
from repro.models.module import spec


def ssm_specs(cfg: ArchConfig):
    d = cfg.d_model
    di = cfg.d_inner_ssm
    H = cfg.ssm_heads
    N = cfg.ssm_state
    conv_dim = di + 2 * N  # x, B, C go through the causal conv
    return {
        "in_proj": spec((d, 2 * di + 2 * N + H), ("embed", "ssm_in")),
        "conv_w": spec((cfg.conv_width, conv_dim), (None, "ssm_conv"), scale=0.5),
        "conv_b": spec((conv_dim,), ("ssm_conv",), init="zeros"),
        "A_log": spec((H,), ("ssm_heads",), init="ones"),
        "D": spec((H,), ("ssm_heads",), init="ones"),
        "dt_bias": spec((H,), ("ssm_heads",), init="zeros"),
        "norm_w": spec((di,), ("ssm_inner",), init="ones"),
        "out_proj": spec((di, d), ("ssm_inner", "embed")),
    }


def _causal_conv(x, w, b, cache=None):
    """Depthwise causal conv along S.  x: (B,S,C); w: (W,C); b: (C,)."""
    W = w.shape[0]
    if cache is None:
        pad = jnp.zeros_like(x[:, : W - 1])
    else:
        pad = cache  # (B, W-1, C) previous inputs
    xp = jnp.concatenate([pad, x], axis=1)
    out = jnp.zeros_like(x)
    for i in range(W):
        out = out + xp[:, i : i + x.shape[1]] * w[i]
    new_cache = xp[:, -(W - 1) :] if W > 1 else xp[:, :0]
    return out + b, new_cache


def _segsum(log_a):
    """(..., L) -> (..., L, L) lower-triangular cumulative sums."""
    L = log_a.shape[-1]
    x = jnp.cumsum(log_a, axis=-1)
    # d[i, j] = sum_{k=j+1..i} log_a[k]  (0 on the diagonal)
    d = x[..., :, None] - x[..., None, :]
    mask = jnp.tril(jnp.ones((L, L), dtype=bool), k=0)
    return jnp.where(mask, d, -jnp.inf)


def ssd_chunked(x, dt, A, Bm, Cm, chunk: int, initial_state=None):
    """SSD forward.

    x: (B, S, H, P); dt: (B, S, H) positive; A: (H,) negative;
    Bm, Cm: (B, S, N) single-group SSM input/output projections.
    Returns (y, final_state) with state (B, H, P, N).
    """
    Bsz, S, H, P = x.shape
    N = Bm.shape[-1]
    L = chunk
    assert S % L == 0, f"seq {S} not divisible by chunk {L}"
    nc = S // L

    # discretize
    xb = (x * dt[..., None]).reshape(Bsz, nc, L, H, P)
    dA = (dt * A[None, None, :]).reshape(Bsz, nc, L, H)     # log decay, <=0
    Bc = Bm.reshape(Bsz, nc, L, N)
    Cc = Cm.reshape(Bsz, nc, L, N)

    # intra-chunk ("diagonal block"): attention-like with decay kernel
    seg = _segsum(jnp.moveaxis(dA, -1, -2))                 # (B,nc,H,L,L)
    decay_mat = jnp.exp(seg)
    scores = jnp.einsum("bcln,bcmn->bclm", Cc, Bc)          # (B,nc,L,L)
    y_diag = jnp.einsum(
        "bclm,bchlm,bcmhp->bclhp", scores, decay_mat, xb
    )

    # chunk-local states to pass forward
    cum = jnp.cumsum(dA, axis=2)                            # (B,nc,L,H)
    decay_states = jnp.exp(cum[:, :, -1:, :] - cum)         # (B,nc,L,H)
    states = jnp.einsum("bcln,bclh,bclhp->bchpn", Bc, decay_states, xb)
    chunk_decay = jnp.exp(cum[:, :, -1, :])                 # (B,nc,H)

    # inter-chunk recurrence
    if initial_state is None:
        initial_state = jnp.zeros((Bsz, H, P, N), dtype=x.dtype)

    def scan_fn(h, inp):
        st, dec = inp                                       # (B,H,P,N),(B,H)
        h_out = h                                           # state entering chunk
        h_next = h * dec[..., None, None] + st
        return h_next, h_out

    states_t = jnp.moveaxis(states, 1, 0)                   # (nc,B,H,P,N)
    decay_t = jnp.moveaxis(chunk_decay, 1, 0)               # (nc,B,H)
    final, h_in = jax.lax.scan(scan_fn, initial_state, (states_t, decay_t))
    h_in = jnp.moveaxis(h_in, 0, 1)                         # (B,nc,H,P,N)

    # contribution of the incoming state to each position
    state_decay = jnp.exp(cum)                              # (B,nc,L,H)
    y_off = jnp.einsum(
        "bcln,bchpn,bclh->bclhp", Cc, h_in, state_decay
    )
    y = (y_diag + y_off).reshape(Bsz, S, H, P)
    return y, final


def ssm_block(cfg: ArchConfig, params, x, cache=None, use_kernel=False):
    """Full mamba2 block.  cache = {'conv': (B,W-1,C), 'state': (B,H,P,N)}."""
    dt_ = x.dtype
    B, S, _ = x.shape
    di, H, P, N = cfg.d_inner_ssm, cfg.ssm_heads, cfg.ssm_head_dim, cfg.ssm_state
    proj = jnp.einsum("bsd,de->bse", x, params["in_proj"].astype(dt_))
    z, xc, Bm, Cm, dt = jnp.split(
        proj, [di, 2 * di, 2 * di + N, 2 * di + 2 * N], axis=-1
    )
    conv_in = jnp.concatenate([xc, Bm, Cm], axis=-1)
    conv_out, conv_cache = _causal_conv(
        conv_in, params["conv_w"].astype(dt_), params["conv_b"].astype(dt_),
        None if cache is None else cache["conv"],
    )
    conv_out = jax.nn.silu(conv_out)
    xc, Bm, Cm = jnp.split(conv_out, [di, di + N], axis=-1)
    xh = xc.reshape(B, S, H, P)
    A = -jnp.exp(params["A_log"].astype(jnp.float32))
    dt = jax.nn.softplus(dt.astype(jnp.float32) + params["dt_bias"])

    state0 = None if cache is None else cache["state"]
    if S == 1:
        # decode: exact single-token recurrence
        h = state0 if state0 is not None else jnp.zeros((B, H, P, N), dt_)
        dA = jnp.exp(dt[:, 0, :] * A[None, :])              # (B,H)
        dBx = jnp.einsum(
            "bn,bhp,bh->bhpn", Bm[:, 0], xh[:, 0], dt[:, 0]
        )
        h = h * dA[..., None, None].astype(dt_) + dBx.astype(dt_)
        y = jnp.einsum("bn,bhpn->bhp", Cm[:, 0], h)[:, None]  # (B,1,H,P)
        new_state = h
    elif use_kernel:
        from repro.kernels import ssd_ops

        y, new_state = ssd_ops.ssd(xh, dt.astype(dt_), A.astype(dt_), Bm, Cm,
                                   chunk=cfg.ssm_chunk)
    else:
        y, new_state = ssd_chunked(
            xh, dt.astype(dt_), A.astype(dt_), Bm, Cm, chunk=min(cfg.ssm_chunk, S),
            initial_state=state0,
        )
    y = y + params["D"].astype(dt_)[None, None, :, None] * xh
    y = y.reshape(B, S, di)
    from repro.models.layers import rms_norm

    y = rms_norm(y * jax.nn.silu(z), params["norm_w"])
    out = jnp.einsum("bse,ed->bsd", y, params["out_proj"].astype(dt_))
    new_cache = {"conv": conv_cache, "state": new_state} if cache is not None else None
    return out, new_cache


def init_ssm_cache(cfg: ArchConfig, batch: int, dtype):
    di, H, P, N = cfg.d_inner_ssm, cfg.ssm_heads, cfg.ssm_head_dim, cfg.ssm_state
    conv_dim = di + 2 * N
    return {
        "conv": jnp.zeros((batch, cfg.conv_width - 1, conv_dim), dtype),
        "state": jnp.zeros((batch, H, P, N), dtype),
    }
