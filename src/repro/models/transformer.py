"""Model assembly for every architecture family.

A model is a list of *scan groups*: each group is a block-spec pytree with
a leading ``layers`` axis and a body function, executed with ``lax.scan``
(+ optional remat) so the compiled HLO stays one-block-sized regardless of
depth.  Families map onto groups as:

  dense / audio / moe : 1 group, block = (attn|mla) + (mlp|moe)
  ssm                 : 1 group, block = mamba2 mixer (no MLP, per spec)
  hybrid              : (rec, rec, local-attn) superblocks + recurrent tail
  vlm                 : (4 self + 1 gated cross) superblocks

Three entry points: ``train_loss`` (next-token CE + router aux),
``prefill`` (build decode caches), ``decode_step`` (one token with cache).
Decode caches are stacked per group along the layer axis and scanned
together with the parameters.
"""

from __future__ import annotations

import dataclasses
import functools
from typing import Any, Callable

import jax
import jax.numpy as jnp

from repro.models.config import ArchConfig
from repro.models import layers as L
from repro.models import rglru as R
from repro.models import ssm as S
from repro.models.module import spec, is_spec
from repro.sharding.partitioning import constraint


# --------------------------------------------------------------- group defs
def _stack(specs_tree, n: int):
    """Prepend a (n,)+'layers' axis to every ParamSpec leaf."""
    return jax.tree_util.tree_map(
        lambda s: dataclasses.replace(
            s, shape=(n,) + s.shape, axes=("layers",) + s.axes
        ),
        specs_tree,
        is_leaf=is_spec,
    )


def _dense_block_specs(cfg: ArchConfig):
    return {
        "ln1": L.norm_spec(cfg),
        "attn": L.attention_specs(cfg),
        "ln2": L.norm_spec(cfg),
        "mlp": L.mlp_specs(cfg),
    }


def _moe_block_specs(cfg: ArchConfig):
    return {
        "ln1": L.norm_spec(cfg),
        "attn": L.mla_specs(cfg) if cfg.kv_lora else L.attention_specs(cfg),
        "ln2": L.norm_spec(cfg),
        "moe": L.moe_specs(cfg),
    }


def _ssm_block_specs(cfg: ArchConfig):
    return {"ln1": L.norm_spec(cfg), "ssm": S.ssm_specs(cfg)}


def _hybrid_super_specs(cfg: ArchConfig):
    one_mlp = lambda: L.mlp_specs(cfg)
    return {
        "rec1": {"ln1": L.norm_spec(cfg), "rec": R.rglru_specs(cfg),
                 "ln2": L.norm_spec(cfg), "mlp": one_mlp()},
        "rec2": {"ln1": L.norm_spec(cfg), "rec": R.rglru_specs(cfg),
                 "ln2": L.norm_spec(cfg), "mlp": one_mlp()},
        "attn": {"ln1": L.norm_spec(cfg), "attn": L.attention_specs(cfg),
                 "ln2": L.norm_spec(cfg), "mlp": one_mlp()},
    }


def _vlm_super_specs(cfg: ArchConfig):
    selfb = lambda: _dense_block_specs(cfg)
    return {
        "self": _stack(selfb(), cfg.cross_attn_every),
        "cross": {
            "ln1": L.norm_spec(cfg),
            "attn": L.attention_specs(cfg, cross=True),
            "ln2": L.norm_spec(cfg),
            "mlp": L.mlp_specs(cfg),
        },
    }


def groups_of(cfg: ArchConfig) -> list[tuple[str, int, Any]]:
    """[(group_name, repeats, block_spec_tree_unstacked)]"""
    if cfg.family in ("dense", "audio"):
        return [("dense", cfg.n_layers, _dense_block_specs(cfg))]
    if cfg.family == "moe":
        return [("moe", cfg.n_layers, _moe_block_specs(cfg))]
    if cfg.family == "ssm":
        return [("ssm", cfg.n_layers, _ssm_block_specs(cfg))]
    if cfg.family == "hybrid":
        k = cfg.rglru_pattern + 1                       # 2 rec + 1 attn
        supers, tail = divmod(cfg.n_layers, k)
        groups = [("hybrid", supers, _hybrid_super_specs(cfg))]
        for i in range(tail):
            groups.append(
                (f"hybrid_tail{i}", 1,
                 {"ln1": L.norm_spec(cfg), "rec": R.rglru_specs(cfg),
                  "ln2": L.norm_spec(cfg), "mlp": L.mlp_specs(cfg)})
            )
        return groups
    if cfg.family == "vlm":
        assert cfg.n_layers % (cfg.cross_attn_every + 1) == 0
        supers = cfg.n_layers // (cfg.cross_attn_every + 1)
        return [("vlm", supers, _vlm_super_specs(cfg))]
    raise ValueError(cfg.family)


def model_specs(cfg: ArchConfig):
    d, v = cfg.d_model, cfg.vocab_padded
    specs: dict[str, Any] = {}
    if cfg.frame_input or cfg.family == "audio":
        specs["frame_proj"] = spec((d, d), ("embed", "embed2"))
    specs["embed"] = spec((v, d), ("vocab", "embed"), scale=1.0)
    specs["groups"] = {
        name: _stack(tree, n) for name, n, tree in groups_of(cfg)
    }
    specs["final_norm"] = L.norm_spec(cfg)
    if not cfg.tie_embeddings:
        specs["head"] = spec((d, v), ("embed", "vocab"))
    return specs


# ------------------------------------------------------------- block bodies
def _residual_attn_mlp(cfg, p, x, pos, cache, mask_kind):
    h, cache = L.attention(cfg, p["attn"], L.norm(cfg, x, p["ln1"]), pos,
                           cache=cache, mask_kind=mask_kind)
    x = x + h
    x = x + L.mlp(p["mlp"], L.norm(cfg, x, p["ln2"]))
    return constraint(x, "batch", "seq", "embed"), cache


def _dense_body(cfg, p, x, pos, cache, mode):
    mask = "bidirectional" if cfg.encoder_only else "causal"
    return _residual_attn_mlp(cfg, p, x, pos, cache, mask) + (jnp.float32(0),)


def _moe_body(cfg, p, x, pos, cache, mode):
    xn = L.norm(cfg, x, p["ln1"])
    if cfg.kv_lora:
        h, cache = L.mla_attention(cfg, p["attn"], xn, pos, cache=cache)
    else:
        h, cache = L.attention(cfg, p["attn"], xn, pos, cache=cache)
    x = x + h
    y, aux = L.moe(cfg, p["moe"], L.norm(cfg, x, p["ln2"]))
    x = x + y
    return constraint(x, "batch", "seq", "embed"), cache, aux


def _ssm_body(cfg, p, x, pos, cache, mode):
    h, cache = S.ssm_block(cfg, p["ssm"], L.norm(cfg, x, p["ln1"]), cache=cache)
    return constraint(x + h, "batch", "seq", "embed"), cache, jnp.float32(0)


def _rec_sub(cfg, p, x, cache):
    h, cache = R.rglru_block(cfg, p["rec"], L.norm(cfg, x, p["ln1"]), cache=cache)
    x = x + h
    x = x + L.mlp(p["mlp"], L.norm(cfg, x, p["ln2"]))
    return x, cache


def _hybrid_body(cfg, p, x, pos, cache, mode):
    c = cache or {"rec1": None, "rec2": None, "attn": None}
    x, c1 = _rec_sub(cfg, p["rec1"], x, c["rec1"])
    x, c2 = _rec_sub(cfg, p["rec2"], x, c["rec2"])
    x, ca = _residual_attn_mlp(cfg, p["attn"], x, pos, c["attn"], "causal")
    new_c = {"rec1": c1, "rec2": c2, "attn": ca} if cache is not None else None
    return x, new_c, jnp.float32(0)


def _hybrid_tail_body(cfg, p, x, pos, cache, mode):
    x, c = _rec_sub(cfg, p, x, cache)
    return constraint(x, "batch", "seq", "embed"), c, jnp.float32(0)


def _vlm_body(cfg, p, x, pos, cache, mode, img=None):
    c = cache or {"self": None, "cross": None}

    def self_scan(carry, xs):
        xx = carry
        if cache is None:
            pp, cc = xs, None
        else:
            pp, cc = xs
        xx, cc2 = _residual_attn_mlp(cfg, pp, xx, pos, cc, "causal")
        return xx, cc2

    xs = p["self"] if cache is None else (p["self"], c["self"])
    x, new_self = jax.lax.scan(self_scan, x, xs)
    # gated cross-attention onto the (stub) image tokens; the image k/v is
    # computed at train/prefill and reused as a static cache during decode.
    xn = L.norm(cfg, x, p["cross"]["ln1"])
    h, kv = L.cross_attention(
        cfg, p["cross"]["attn"], xn,
        img=img, kv_cache=None if cache is None else c["cross"],
    )
    new_cross = kv if cache is not None else None
    x = x + h
    x = x + L.mlp(p["cross"]["mlp"], L.norm(cfg, x, p["cross"]["ln2"]))
    new_c = {"self": new_self, "cross": new_cross} if cache is not None else None
    return constraint(x, "batch", "seq", "embed"), new_c, jnp.float32(0)


_BODIES: dict[str, Callable] = {
    "dense": _dense_body,
    "moe": _moe_body,
    "ssm": _ssm_body,
    "hybrid": _hybrid_body,
    "vlm": _vlm_body,
}


def _body_for(name: str) -> Callable:
    if name.startswith("hybrid_tail"):
        return _hybrid_tail_body
    return _BODIES[name.split("_")[0] if name not in _BODIES else name]


# ------------------------------------------------------------ cache builders
def init_caches(cfg: ArchConfig, batch: int, max_len: int, dtype=None):
    """Stacked decode caches per group (layer axis leading)."""
    dt = jnp.dtype(dtype or cfg.dtype)

    def attn_cache():
        kvlen = min(max_len, cfg.window) if cfg.window else max_len
        return {
            "k": jnp.zeros((batch, kvlen, cfg.n_kv, cfg.d_head), dt),
            "v": jnp.zeros((batch, kvlen, cfg.n_kv, cfg.d_head), dt),
            "index": jnp.int32(0),
        }

    def mla_cache():
        return {
            "ckv": jnp.zeros((batch, max_len, cfg.kv_lora), dt),
            "krope": jnp.zeros((batch, max_len, cfg.rope_head_dim), dt),
            "index": jnp.int32(0),
        }

    def one(name):
        if name.startswith("dense") or name == "vlm_self":
            return attn_cache()
        if name == "moe":
            return mla_cache() if cfg.kv_lora else attn_cache()
        if name == "ssm":
            return S.init_ssm_cache(cfg, batch, dt)
        raise ValueError(name)

    caches = {}
    for gname, n, _tree in groups_of(cfg):
        if gname == "ssm":
            caches[gname] = _stack_tree(one("ssm"), n)
        elif gname in ("dense", "moe"):
            caches[gname] = _stack_tree(one(gname), n)
        elif gname == "hybrid":
            unit = {
                "rec1": R.init_rglru_cache(cfg, batch, dt),
                "rec2": R.init_rglru_cache(cfg, batch, dt),
                "attn": attn_cache(),
            }
            caches[gname] = _stack_tree(unit, n)
        elif gname.startswith("hybrid_tail"):
            caches[gname] = _stack_tree(R.init_rglru_cache(cfg, batch, dt), n)
        elif gname == "vlm":
            unit = {
                "self": _stack_tree(attn_cache(), cfg.cross_attn_every),
                "cross": {
                    "k": jnp.zeros(
                        (batch, cfg.frontend_tokens, cfg.n_kv, cfg.d_head), dt
                    ),
                    "v": jnp.zeros(
                        (batch, cfg.frontend_tokens, cfg.n_kv, cfg.d_head), dt
                    ),
                },
            }
            caches[gname] = _stack_tree(unit, n)
    return caches


def _stack_tree(tree, n):
    return jax.tree_util.tree_map(
        lambda x: jnp.broadcast_to(x[None], (n,) + x.shape), tree
    )


# ----------------------------------------------------------------- forward
def _run_groups(cfg, params, x, pos, caches, mode, img=None, remat=True):
    from repro.sharding.partitioning import (
        constrain_params_by_specs,
        gather_rule_set,
    )

    aux_total = jnp.float32(0)
    new_caches = {} if caches is not None else None
    gather_rs = gather_rule_set()
    for gname, n, _tree in groups_of(cfg):
        body = _body_for(gname)
        gp = params["groups"][gname]
        gc = None if caches is None else caches[gname]

        def scan_body(carry, xs, _tree=_tree):
            xx, aux = carry
            pp = xs[0]
            cc = xs[1] if gc is not None else None
            if gather_rs is not None:
                # weight-gathering: constrain the layer's weight slice to
                # TP-only sharding at use time (§Perf iteration 5)
                pp = constrain_params_by_specs(_tree, pp, gather_rs)
            kwargs = {"img": img} if gname == "vlm" else {}
            xx, cc2, a = body(cfg, pp, xx, pos, cc, mode, **kwargs)
            return (xx, aux + a), cc2

        if remat and mode == "train":
            scan_body = jax.checkpoint(
                scan_body, policy=jax.checkpoint_policies.nothing_saveable
            )
        xs = (gp,) if gc is None else (gp, gc)
        (x, aux_total), cs = jax.lax.scan(scan_body, (x, aux_total), xs)
        if new_caches is not None:
            new_caches[gname] = cs
    return x, aux_total, new_caches


def embed_inputs(cfg: ArchConfig, params, batch):
    dt = jnp.dtype(cfg.dtype)
    if cfg.frame_input or cfg.family == "audio":
        x = batch["frames"].astype(dt)
        x = jnp.einsum("bsd,de->bse", x, params["frame_proj"].astype(dt))
    else:
        x = params["embed"].astype(dt)[batch["tokens"]]
    return constraint(x, "batch", "seq", "embed")


def unembed(cfg: ArchConfig, params, x):
    dt = x.dtype
    w = (
        params["embed"].T if cfg.tie_embeddings else params["head"]
    ).astype(dt)
    logits = jnp.einsum("bsd,dv->bsv", x, w)
    if cfg.vocab_padded != cfg.vocab:  # mask padded vocab columns
        logits = jnp.where(
            jnp.arange(cfg.vocab_padded) < cfg.vocab, logits, -1e30
        )
    return constraint(logits, "batch", "seq", "vocab")


def forward_train(cfg: ArchConfig, params, batch, remat=True):
    x = embed_inputs(cfg, params, batch)
    B, Sq = x.shape[:2]
    pos = jnp.broadcast_to(jnp.arange(Sq)[None], (B, Sq))
    img = batch.get("image_embeds")
    if img is not None:
        img = img.astype(x.dtype)
    x, aux, _ = _run_groups(cfg, params, x, pos, None, "train", img, remat)
    x = L.norm(cfg, x, params["final_norm"])
    return unembed(cfg, params, x), aux


def train_loss(cfg: ArchConfig, params, batch, remat=True):
    logits, aux = forward_train(cfg, params, batch, remat)
    labels = batch["labels"]
    logp = jax.nn.log_softmax(logits.astype(jnp.float32), axis=-1)
    ll = jnp.take_along_axis(logp, labels[..., None], axis=-1)[..., 0]
    mask = (labels >= 0).astype(jnp.float32)
    loss = -(ll * mask).sum() / jnp.maximum(mask.sum(), 1.0)
    return loss + aux, {"ce": loss, "aux": aux}


def prefill(cfg: ArchConfig, params, batch, max_len: int):
    """Run the prompt, returning (logits_last, caches)."""
    x = embed_inputs(cfg, params, batch)
    B, Sq = x.shape[:2]
    pos = jnp.broadcast_to(jnp.arange(Sq)[None], (B, Sq))
    caches = init_caches(cfg, B, max_len)
    img = batch.get("image_embeds")
    if img is not None:
        img = img.astype(x.dtype)
    x, _aux, caches = _run_groups(cfg, params, x, pos, caches, "prefill", img,
                                  remat=False)
    x = L.norm(cfg, x, params["final_norm"])
    return unembed(cfg, params, x[:, -1:]), caches


def decode_step(cfg: ArchConfig, params, tokens, caches, index):
    """One decode step.  tokens: (B, 1); index: scalar position."""
    dt = jnp.dtype(cfg.dtype)
    x = params["embed"].astype(dt)[tokens]
    B = x.shape[0]
    pos = jnp.full((B, 1), index, dtype=jnp.int32)
    x, _aux, caches = _run_groups(cfg, params, x, pos, caches, "decode",
                                  remat=False)
    x = L.norm(cfg, x, params["final_norm"])
    return unembed(cfg, params, x), caches
