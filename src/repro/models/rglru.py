"""RG-LRU recurrent block (RecurrentGemma / Griffin) in pure JAX.

The Real-Gated Linear Recurrent Unit:

    r_t = sigmoid(x_t W_a + b_a)            (recurrence gate)
    i_t = sigmoid(x_t W_x + b_x)            (input gate)
    log a_t = -c * softplus(Lambda) * r_t   (c = 8)
    h_t = a_t * h_{t-1} + sqrt(1 - a_t^2) * (i_t * x_t)

Training/prefill uses ``jax.lax.associative_scan`` (log-depth on TPU);
decode is the O(1) recurrence — with the 1:2 local-attention pattern this
is why recurrentgemma runs the ``long_500k`` cell.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.config import ArchConfig
from repro.models.module import spec

C_FACTOR = 8.0


def rglru_specs(cfg: ArchConfig):
    d, w = cfg.d_model, cfg.lru_dim
    return {
        "in_x": spec((d, w), ("embed", "lru")),
        "in_gate": spec((d, w), ("embed", "lru")),
        "conv_w": spec((cfg.conv_width, w), (None, "lru"), scale=0.5),
        "conv_b": spec((w,), ("lru",), init="zeros"),
        "wa": spec((w, w), ("lru", "lru2"), scale=0.5),
        "ba": spec((w,), ("lru",), init="zeros"),
        "wx": spec((w, w), ("lru", "lru2"), scale=0.5),
        "bx": spec((w,), ("lru",), init="zeros"),
        "lam": spec((w,), ("lru",), init="ones"),  # Lambda (pre-softplus)
        "out": spec((w, d), ("lru", "embed")),
    }


def _rglru_gates(params, x, dt):
    r = jax.nn.sigmoid(
        jnp.einsum("bsw,wv->bsv", x, params["wa"].astype(dt)) + params["ba"].astype(dt)
    )
    i = jax.nn.sigmoid(
        jnp.einsum("bsw,wv->bsv", x, params["wx"].astype(dt)) + params["bx"].astype(dt)
    )
    log_a = (
        -C_FACTOR
        * jax.nn.softplus(params["lam"].astype(jnp.float32))[None, None, :]
        * r.astype(jnp.float32)
    )
    a = jnp.exp(log_a)
    gated_x = jnp.sqrt(jnp.maximum(1.0 - jnp.exp(2 * log_a), 1e-12)) * (
        i.astype(jnp.float32) * x.astype(jnp.float32)
    )
    return a, gated_x


def rglru_block(cfg: ArchConfig, params, x, cache=None):
    """x: (B,S,D).  cache = {'conv': (B,W-1,lru), 'state': (B,lru)}."""
    from repro.models.ssm import _causal_conv

    dt = x.dtype
    B, S, _ = x.shape
    xb = jnp.einsum("bsd,dw->bsw", x, params["in_x"].astype(dt))
    gate = jax.nn.gelu(jnp.einsum("bsd,dw->bsw", x, params["in_gate"].astype(dt)))
    xb, conv_cache = _causal_conv(
        xb, params["conv_w"].astype(dt), params["conv_b"].astype(dt),
        None if cache is None else cache["conv"],
    )
    a, gx = _rglru_gates(params, xb, dt)

    if S == 1 and cache is not None:
        h = cache["state"].astype(jnp.float32) * a[:, 0] + gx[:, 0]
        y = h[:, None, :]
        new_state = h
    else:
        h0 = None if cache is None else cache["state"]
        if h0 is not None:
            # fold the carried state in as a virtual step 0
            a0 = jnp.ones_like(a[:, :1])
            a = jnp.concatenate([a0, a], axis=1)
            gx = jnp.concatenate([h0.astype(jnp.float32)[:, None], gx], axis=1)

        def combine(c1, c2):
            a1, b1 = c1
            a2, b2 = c2
            return a1 * a2, b1 * a2 + b2

        aa, hh = jax.lax.associative_scan(combine, (a, gx), axis=1)
        y = hh if h0 is None else hh[:, 1:]
        new_state = y[:, -1]

    y = (y.astype(dt)) * gate
    out = jnp.einsum("bsw,wd->bsd", y, params["out"].astype(dt))
    new_cache = (
        {"conv": conv_cache, "state": new_state.astype(jnp.float32)}
        if cache is not None
        else None
    )
    return out, new_cache


def init_rglru_cache(cfg: ArchConfig, batch: int, dtype):
    return {
        "conv": jnp.zeros((batch, cfg.conv_width - 1, cfg.lru_dim), dtype),
        "state": jnp.zeros((batch, cfg.lru_dim), jnp.float32),
    }
