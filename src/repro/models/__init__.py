from repro.models.config import ArchConfig, ShapeSpec  # noqa: F401
