"""Core transformer building blocks, pure JAX.

All forward functions take params as pytrees of arrays (master fp32) and
compute in the config dtype (bf16 by default).  Attention supports causal,
local-window, bidirectional (encoder) and cross-attention variants, GQA
grouping, qk-norm and MLA (compressed-KV) attention; the MoE layer uses a
sort-based dropping dispatch whose expert axis shards over the ``model``
mesh axis (the expert all-to-all of the paper's All-to-All kernel).
"""

from __future__ import annotations

import dataclasses
import functools
import math
from typing import Any

import jax
import jax.numpy as jnp

from repro.models.config import ArchConfig
from repro.models.module import spec


def cdtype(cfg: ArchConfig):
    return jnp.dtype(cfg.dtype)


# ---------------------------------------------------------------- norms
def rms_norm(x, w, eps=1e-6):
    var = jnp.mean(jnp.square(x.astype(jnp.float32)), axis=-1, keepdims=True)
    y = x.astype(jnp.float32) * jax.lax.rsqrt(var + eps)
    return (y * w.astype(jnp.float32)).astype(x.dtype)


def nonparam_layer_norm(x, eps=1e-5):
    """OLMo-style non-parametric LayerNorm (no scale/bias)."""
    xf = x.astype(jnp.float32)
    mu = jnp.mean(xf, axis=-1, keepdims=True)
    var = jnp.var(xf, axis=-1, keepdims=True)
    return ((xf - mu) * jax.lax.rsqrt(var + eps)).astype(x.dtype)


def norm(cfg: ArchConfig, x, w):
    if cfg.nonparam_ln:
        return nonparam_layer_norm(x)
    return rms_norm(x, w)


def norm_spec(cfg: ArchConfig):
    # kept (and simply unused) for non-parametric LN so the layer pytree
    # structure is family-uniform
    return spec((cfg.d_model,), ("embed",), init="ones")


# ---------------------------------------------------------------- rotary
def rope_freqs(dim: int, theta: float):
    return 1.0 / (theta ** (jnp.arange(0, dim, 2, dtype=jnp.float32) / dim))


def apply_rope(x, positions, theta: float):
    """x: (..., S, H, D) with D even; positions: (..., S)."""
    d = x.shape[-1]
    freqs = rope_freqs(d, theta)                       # (D/2,)
    ang = positions[..., :, None].astype(jnp.float32) * freqs  # (..., S, D/2)
    cos = jnp.cos(ang)[..., :, None, :]
    sin = jnp.sin(ang)[..., :, None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


# ---------------------------------------------------------------- MLP
def mlp_specs(cfg: ArchConfig, d_ff: int | None = None):
    d_ff = d_ff or cfg.d_ff
    d = cfg.d_model
    return {
        "wi": spec((d, d_ff), ("embed", "ff")),
        "wg": spec((d, d_ff), ("embed", "ff")),
        "wo": spec((d_ff, d), ("ff", "embed")),
    }


def mlp(params, x):
    dt = x.dtype
    h = jnp.einsum("...d,df->...f", x, params["wi"].astype(dt))
    g = jnp.einsum("...d,df->...f", x, params["wg"].astype(dt))
    h = jax.nn.silu(g) * h
    return jnp.einsum("...f,fd->...d", h, params["wo"].astype(dt))


# ---------------------------------------------------------------- attention
def attention_specs(cfg: ArchConfig, cross: bool = False):
    d, h, kv, dh = cfg.d_model, cfg.n_heads, cfg.n_kv, cfg.d_head
    s = {
        "wq": spec((d, h, dh), ("embed", "heads", "head_dim")),
        "wk": spec((d, kv, dh), ("embed", "kv_heads", "head_dim")),
        "wv": spec((d, kv, dh), ("embed", "kv_heads", "head_dim")),
        "wo": spec((h, dh, d), ("heads", "head_dim", "embed")),
    }
    if cfg.qk_norm:
        s["q_norm"] = spec((dh,), ("head_dim",), init="ones")
        s["k_norm"] = spec((dh,), ("head_dim",), init="ones")
    if cross:
        s["gate"] = spec((1,), (None,), init="zeros")  # tanh-gated cross-attn
    return s


def chunked_sdpa(q, k, v, q_pos, k_pos, causal, window, chunk=4096,
                 use_kernel=False):
    """Exact softmax attention, blocked over QUERIES with static triangular
    key prefixes.

    Each query block attends over a statically-sliced key prefix
    [lo, hi) — for causal self-attention block i needs only keys
    < (i+1)*chunk, and a local window additionally bounds lo.  This (a)
    skips the upper-triangle work entirely (~2x causal FLOPs/traffic),
    (b) keeps peak memory at O(chunk * T') per block, and (c) avoids the
    per-key-chunk accumulator churn a k-scan formulation pays in HBM
    (see EXPERIMENTS.md §Perf iteration 2).

    q: (B,S,G,rep,dh); k/v: (B,T,G,dh); *_pos absolute positions with
    negative k_pos marking invalid (unwritten cache) slots.  The static
    triangular slicing applies when positions are the canonical aranges
    (train/prefill); decode (S=1) and cache/cross paths use the full range.

    On TPU the same contraction runs as the Pallas flash-attention kernel
    (repro.kernels.flash_attention); this is its jnp oracle and the CPU /
    dry-run path.
    """
    if use_kernel:
        from repro.kernels import flash_ops

        return flash_ops.flash_attention(q, k, v, q_pos, k_pos, causal, window)

    B, Sq, G, rep, dh = q.shape
    T = k.shape[1]
    dt = q.dtype
    scale = 1.0 / math.sqrt(dh)
    # static triangular slicing is valid only for aligned self-attention
    aligned = causal and Sq == T
    qb = min(chunk, Sq)
    nq = -(-Sq // qb)

    def block(qs, qp, lo, hi):
        ks, vs, kp = k[:, lo:hi], v[:, lo:hi], k_pos[:, lo:hi]
        logits = jnp.einsum("bsgrd,btgd->bsgrt", qs, ks).astype(jnp.float32)
        logits = logits * scale
        valid = kp[:, None, :] >= 0
        if causal:
            valid = valid & (kp[:, None, :] <= qp[:, :, None])
        if window:
            valid = valid & (kp[:, None, :] > qp[:, :, None] - window)
        logits = jnp.where(valid[:, :, None, None, :], logits, -1e30)
        m = logits.max(axis=-1, keepdims=True)
        p = jnp.exp(logits - m)
        l = p.sum(axis=-1, keepdims=True)
        out = jnp.einsum("bsgrt,btgd->bsgrd", (p / jnp.maximum(l, 1e-30)
                                               ).astype(dt), vs)
        return out

    if nq == 1:
        return block(q, q_pos, 0, T).astype(dt)
    outs = []
    for i in range(nq):
        s0, s1 = i * qb, min((i + 1) * qb, Sq)
        if aligned:
            hi = s1
            lo = max(0, s0 - window) if window else 0
        else:
            lo, hi = 0, T
        outs.append(block(q[:, s0:s1], q_pos[:, s0:s1], lo, hi))
    return jnp.concatenate(outs, axis=1).astype(dt)


def _ring_positions(T: int, idx, B: int):
    """Absolute position stored in each ring-cache slot after writes < idx.

    Slot j holds the largest p < idx with p % T == j (or -1 if unwritten).
    """
    j = jnp.arange(T)
    last = idx - 1 - ((idx - 1 - j) % T)
    pos = jnp.where(last >= 0, last, -1)
    return jnp.broadcast_to(pos[None], (B, T))


def _project_qkv(cfg, params, x, src, dt):
    q = jnp.einsum("bsd,dhk->bshk", x, params["wq"].astype(dt))
    k = jnp.einsum("btd,dgk->btgk", src, params["wk"].astype(dt))
    v = jnp.einsum("btd,dgk->btgk", src, params["wv"].astype(dt))
    if cfg.qk_norm:
        q = rms_norm(q, params["q_norm"])
        k = rms_norm(k, params["k_norm"])
    return q, k, v


def _finish(cfg, params, out, dt):
    B, S = out.shape[:2]
    out = out.reshape(B, S, cfg.n_heads, cfg.d_head)
    y = jnp.einsum("bshk,hkd->bsd", out, params["wo"].astype(dt))
    if "gate" in params:  # gated cross-attention (vision layers)
        y = jnp.tanh(params["gate"].astype(dt)) * y
    return y


def attention(
    cfg: ArchConfig,
    params,
    x,                    # (B, S, D)
    q_pos,                # (B, S) absolute positions
    cache=None,           # ring cache {'k','v','index'}; None = no cache
    mask_kind="causal",
    use_kernel=False,
):
    """Self-attention: train/prefill (S tokens) or decode (S==1, cache).

    Caches are RING buffers of length kvlen (= window for local attention,
    max_len otherwise): slot = position % kvlen.  Prefill fills the ring
    from the computed k/v tail; decode writes one slot and attends over the
    ring with positions reconstructed per slot.
    """
    dt = x.dtype
    B, S, _ = x.shape
    kvh, dh = cfg.n_kv, cfg.d_head
    rep = cfg.n_heads // kvh
    q, k, v = _project_qkv(cfg, params, x, x, dt)
    q = apply_rope(q, q_pos, cfg.rope_theta)
    k = apply_rope(k, q_pos, cfg.rope_theta)
    win = cfg.window if mask_kind == "causal" else 0
    causal = mask_kind == "causal"
    qg = q.reshape(B, S, kvh, rep, dh)

    if cache is None:
        out = chunked_sdpa(qg, k, v, q_pos, q_pos, causal, win,
                           use_kernel=use_kernel)
        return _finish(cfg, params, out, dt), None

    T = cache["k"].shape[1]
    idx = cache["index"]
    if S > 1:
        # prefill: attend in-context, then write the k/v tail into the ring
        out = chunked_sdpa(qg, k, v, q_pos, q_pos, causal, win,
                           use_kernel=use_kernel)
        tail = min(T, S)
        kt, vt = k[:, -tail:], v[:, -tail:]
        pt = q_pos[:, -tail:]                          # absolute positions
        slot = pt[0] % T                               # (tail,) same per batch
        ck = cache["k"].at[:, slot].set(kt)
        cv = cache["v"].at[:, slot].set(vt)
        new_cache = {"k": ck, "v": cv, "index": idx + S}
        return _finish(cfg, params, out, dt), new_cache

    # decode: write one slot, attend over the ring
    slot = idx % T
    ck = jax.lax.dynamic_update_slice_in_dim(cache["k"], k, slot, axis=1)
    cv = jax.lax.dynamic_update_slice_in_dim(cache["v"], v, slot, axis=1)
    k_pos = _ring_positions(T, idx + 1, B)
    out = chunked_sdpa(qg, ck, cv, q_pos, k_pos, causal, win,
                       use_kernel=use_kernel)
    new_cache = {"k": ck, "v": cv, "index": idx + 1}
    return _finish(cfg, params, out, dt), new_cache


def cross_attention(
    cfg: ArchConfig,
    params,
    x,                    # (B, S, D) text stream
    img,                  # (B, Timg, D) modality tokens, or None if cached
    kv_cache=None,        # {'k','v'} static cross cache
):
    """Gated cross-attention onto (stub) modality tokens.

    Returns (y, {'k','v'}) so serving computes the image k/v once at prefill
    and reuses it for every decode step.
    """
    dt = x.dtype
    B, S, _ = x.shape
    kvh, dh = cfg.n_kv, cfg.d_head
    rep = cfg.n_heads // kvh
    q = jnp.einsum("bsd,dhk->bshk", x, params["wq"].astype(dt))
    if cfg.qk_norm:
        q = rms_norm(q, params["q_norm"])
    if kv_cache is not None and img is None:
        k, v = kv_cache["k"], kv_cache["v"]
    else:
        k = jnp.einsum("btd,dgk->btgk", img, params["wk"].astype(dt))
        v = jnp.einsum("btd,dgk->btgk", img, params["wv"].astype(dt))
        if cfg.qk_norm:
            k = rms_norm(k, params["k_norm"])
    T = k.shape[1]
    qg = q.reshape(B, S, kvh, rep, dh)
    q_pos = jnp.zeros((B, S), jnp.int32)
    k_pos = jnp.zeros((B, T), jnp.int32)
    out = chunked_sdpa(qg, k, v, q_pos, k_pos, causal=False, window=0)
    return _finish(cfg, params, out, dt), {"k": k, "v": v}


# ---------------------------------------------------------------- MLA
def mla_specs(cfg: ArchConfig):
    d, h = cfg.d_model, cfg.n_heads
    dh, dr, dkv, dq = cfg.d_head, cfg.rope_head_dim, cfg.kv_lora, cfg.q_lora
    return {
        "wdq": spec((d, dq), ("embed", "q_lora")),
        "q_norm": spec((dq,), ("q_lora",), init="ones"),
        "wuq": spec((dq, h, dh + dr), ("q_lora", "heads", "head_dim")),
        "wdkv": spec((d, dkv), ("embed", "kv_lora")),
        "kv_norm": spec((dkv,), ("kv_lora",), init="ones"),
        "wuk": spec((dkv, h, dh), ("kv_lora", "heads", "head_dim")),
        "wuv": spec((dkv, h, dh), ("kv_lora", "heads", "head_dim")),
        "wkr": spec((d, dr), ("embed", "rope_dim")),
        "wo": spec((h, dh, d), ("heads", "head_dim", "embed")),
    }


def mla_attention(cfg: ArchConfig, params, x, q_pos, cache=None):
    """DeepSeek-V2 multi-head latent attention.

    The KV cache stores only the compressed latent c_kv (``kv_lora`` wide)
    plus the shared rope key — the architecture's whole point.  Decode uses
    the *absorbed-matrix* form (q contracted with W_uk, context expanded
    with W_uv after the softmax) so the latent is never re-expanded to
    per-head keys; train/prefill expand once and run the chunked
    online-softmax path.
    """
    dt = x.dtype
    B, S, _ = x.shape
    h, dh, dr = cfg.n_heads, cfg.d_head, cfg.rope_head_dim
    cq = rms_norm(jnp.einsum("bsd,de->bse", x, params["wdq"].astype(dt)),
                  params["q_norm"])
    q = jnp.einsum("bse,ehk->bshk", cq, params["wuq"].astype(dt))
    q_nope, q_rope = q[..., :dh], q[..., dh:]
    q_rope = apply_rope(q_rope, q_pos, cfg.rope_theta)

    ckv = rms_norm(jnp.einsum("bsd,de->bse", x, params["wdkv"].astype(dt)),
                   params["kv_norm"])
    k_rope_new = apply_rope(
        jnp.einsum("bsd,dr->bsr", x, params["wkr"].astype(dt))[:, :, None, :],
        q_pos, cfg.rope_theta,
    )[:, :, 0, :]                                          # (B,S,dr)

    if cache is not None and S == 1:
        # ---- absorbed-matrix decode ----
        idx = cache["index"]
        ckv_all = jax.lax.dynamic_update_slice_in_dim(cache["ckv"], ckv, idx, 1)
        kr_all = jax.lax.dynamic_update_slice_in_dim(
            cache["krope"], k_rope_new, idx, 1
        )
        new_cache = {"ckv": ckv_all, "krope": kr_all, "index": idx + 1}
        T = ckv_all.shape[1]
        q_lat = jnp.einsum("bshk,ehk->bshe", q_nope, params["wuk"].astype(dt))
        scale = 1.0 / math.sqrt(dh + dr)
        logits = (
            jnp.einsum("bshe,bte->bhst", q_lat, ckv_all)
            + jnp.einsum("bshr,btr->bhst", q_rope, kr_all)
        ).astype(jnp.float32) * scale
        k_pos = jnp.arange(T)[None, :]
        mask = k_pos <= q_pos[:, :1]                       # (B, T)
        logits = jnp.where(mask[:, None, None, :], logits, -1e30)
        probs = jax.nn.softmax(logits, axis=-1).astype(dt)
        ctx = jnp.einsum("bhst,bte->bshe", probs, ckv_all)
        out = jnp.einsum("bshe,ehk->bshk", ctx, params["wuv"].astype(dt))
        y = jnp.einsum("bshk,hkd->bsd", out, params["wo"].astype(dt))
        return y, new_cache

    # ---- train / prefill: expand once, chunked online softmax ----
    if cache is not None:
        idx = cache["index"]
        ckv_all = jax.lax.dynamic_update_slice_in_dim(cache["ckv"], ckv, idx, 1)
        kr_all = jax.lax.dynamic_update_slice_in_dim(
            cache["krope"], k_rope_new, idx, 1
        )
        new_cache = {"ckv": ckv_all, "krope": kr_all, "index": idx + S}
    else:
        new_cache = None
    k_nope = jnp.einsum("bse,ehk->bshk", ckv, params["wuk"].astype(dt))
    v = jnp.einsum("bse,ehk->bshk", ckv, params["wuv"].astype(dt))
    v = jnp.pad(v, ((0, 0), (0, 0), (0, 0), (0, dr)))      # pad v to dh+dr
    k_full = jnp.concatenate(
        [k_nope, jnp.broadcast_to(k_rope_new[:, :, None, :], (B, S, h, dr))],
        axis=-1,
    )
    q_full = jnp.concatenate([q_nope, q_rope], axis=-1)[:, :, :, None, :]
    out = chunked_sdpa(q_full, k_full, v, q_pos, q_pos, causal=True, window=0)
    out = out.reshape(B, S, h, dh + dr)[..., :dh]
    y = jnp.einsum("bshk,hkd->bsd", out, params["wo"].astype(dt))
    return y, new_cache


# ---------------------------------------------------------------- MoE
def moe_specs(cfg: ArchConfig):
    d, e, f = cfg.d_model, cfg.n_experts, cfg.d_ff_expert
    s = {
        "router": spec((d, e), ("embed", "experts"), scale=0.1),
        "wi": spec((e, d, f), ("experts", "embed", "ff")),
        "wg": spec((e, d, f), ("experts", "embed", "ff")),
        "wo": spec((e, f, d), ("experts", "ff", "embed")),
    }
    if cfg.n_shared:
        s["shared"] = mlp_specs(cfg, cfg.n_shared * cfg.d_ff_expert)
    return s


def moe(cfg: ArchConfig, params, x):
    """Token-choice top-k MoE, sort-based dispatch, hierarchical groups.

    Tokens are split into ``cfg.moe_groups`` groups aligned with the
    data-parallel batch sharding; routing, sorting and capacity dropping
    happen independently per group, so the token->buffer scatter never
    crosses shards.  The grouped buffer (G, E, C_g, D) is then resharded
    from group-sharded to expert-sharded for the expert matmuls — exactly
    one all-to-all each way over the fabric (the paper's All-to-All
    kernel), instead of the full-buffer all-reduce a global scatter would
    induce (see EXPERIMENTS.md §Perf iteration 1).

    Returns (y, aux_loss).  C_g = ceil(T_g * top_k / E * capacity_factor)
    per group; overflow dropped with weight renormalization.
    """
    from repro.sharding.partitioning import constraint

    dt = x.dtype
    B, S, D = x.shape
    E, K = cfg.n_experts, cfg.top_k
    T = B * S
    G = cfg.moe_groups if T % max(cfg.moe_groups, 1) == 0 else 1
    Tg = T // G
    xf = x.reshape(G, Tg, D)
    xf = constraint(xf, "moe_group", None, "embed")
    logits = jnp.einsum("gtd,de->gte", xf, params["router"].astype(dt))
    logits = logits.astype(jnp.float32)
    probs = jax.nn.softmax(logits, axis=-1)
    top_p, top_e = jax.lax.top_k(probs, K)                  # (G, Tg, K)
    top_p = top_p / jnp.maximum(top_p.sum(-1, keepdims=True), 1e-9)

    # load-balancing aux loss (Switch-style)
    density = jnp.mean(
        jax.nn.one_hot(top_e[..., 0], E, dtype=jnp.float32), axis=(0, 1)
    )
    density_prob = jnp.mean(probs, axis=(0, 1))
    aux = jnp.sum(density * density_prob) * E * cfg.router_aux_coef

    C = int(math.ceil(Tg * K / E * cfg.capacity_factor))
    flat_e = top_e.reshape(G, Tg * K)
    order = jnp.argsort(flat_e, axis=-1)                    # per-group sort
    sorted_e = jnp.take_along_axis(flat_e, order, axis=-1)
    ar = jnp.arange(Tg * K)[None]
    seg_start = jax.vmap(
        lambda se: jnp.searchsorted(se, jnp.arange(E), side="left")
    )(sorted_e)                                             # (G, E)
    pos = ar - jnp.take_along_axis(seg_start, sorted_e, axis=-1)
    keep = pos < C
    tok = order // K                                        # (G, Tg*K)
    slot = jnp.where(keep, sorted_e * C + pos, E * C)       # OOB drop row

    def scatter_group(xg, slotg, tokg):
        buf = jnp.zeros((E * C + 1, D), dtype=dt)
        return buf.at[slotg].set(xg[tokg], mode="drop")[: E * C]

    buf = jax.vmap(scatter_group)(xf, slot, tok)            # (G, E*C, D)
    buf = buf.reshape(G, E, C, D)
    buf = constraint(buf, "moe_group", None, None, "embed")
    # reshard group->expert: the expert-parallel all-to-all
    buf = constraint(buf, None, "experts", None, "embed")

    h = jnp.einsum("gecd,edf->gecf", buf, params["wi"].astype(dt))
    g = jnp.einsum("gecd,edf->gecf", buf, params["wg"].astype(dt))
    yexp = jnp.einsum("gecf,efd->gecd", jax.nn.silu(g) * h,
                      params["wo"].astype(dt))
    yexp = constraint(yexp, None, "experts", None, "embed")
    # reshard back expert->group: the return all-to-all
    yexp = constraint(yexp, "moe_group", None, None, "embed")

    yflat = yexp.reshape(G, E * C, D)
    w = jnp.take_along_axis(top_p.reshape(G, Tg * K), order, axis=-1)

    def combine_group(yg, slotg, tokg, keepg, wg):
        gathered = jnp.where(
            keepg[:, None], yg[jnp.minimum(slotg, E * C - 1)], 0.0
        )
        return jnp.zeros((Tg, D), dtype=dt).at[tokg].add(
            gathered * wg[:, None].astype(dt)
        )

    y = jax.vmap(combine_group)(yflat, slot, tok, keep, w)
    y = constraint(y, "moe_group", None, "embed")

    if cfg.n_shared:
        y = y + mlp(params["shared"], xf)
    return y.reshape(B, S, D), aux
