"""Minimal pure-JAX parameter/module system (no flax/optax in this stack).

A model is described by a pytree of :class:`ParamSpec` leaves.  Specs carry
shape, dtype, an initializer, and *logical axis names*; the sharding layer
(repro.sharding.partitioning) maps logical axes to mesh axes.  Three
materializations of one spec tree:

  * ``init(rng, specs)``            -> concrete parameter pytree
  * ``abstract(specs)``             -> jax.ShapeDtypeStruct pytree (dry-run)
  * ``tree_shardings(specs, rules, mesh)`` -> NamedSharding pytree

Stacked (scan-over-layers) parameters simply carry a leading "layers"
logical axis.
"""

from __future__ import annotations

import dataclasses
import math
from typing import Any, Callable

import jax
import jax.numpy as jnp
import numpy as np


@dataclasses.dataclass(frozen=True)
class ParamSpec:
    shape: tuple[int, ...]
    axes: tuple[str | None, ...]       # logical axis name per dim
    init: str = "normal"               # normal | zeros | ones | scaled
    scale: float = 1.0
    dtype: str = "float32"             # master weights fp32; compute casts

    def __post_init__(self):
        if len(self.shape) != len(self.axes):
            raise ValueError(f"shape {self.shape} vs axes {self.axes}")


def spec(shape, axes, init="normal", scale=1.0, dtype="float32") -> ParamSpec:
    return ParamSpec(tuple(int(s) for s in shape), tuple(axes), init, scale, dtype)


def is_spec(x) -> bool:
    return isinstance(x, ParamSpec)


def _init_leaf(key, s: ParamSpec):
    if s.init == "zeros":
        return jnp.zeros(s.shape, s.dtype)
    if s.init == "ones":
        return jnp.ones(s.shape, s.dtype)
    if s.init == "normal":
        fan_in = s.shape[0] if len(s.shape) > 1 else max(s.shape[-1], 1)
        std = s.scale / math.sqrt(fan_in)
        return (jax.random.normal(key, s.shape, jnp.float32) * std).astype(s.dtype)
    if s.init == "scaled":  # plain std = scale
        return (jax.random.normal(key, s.shape, jnp.float32) * s.scale).astype(s.dtype)
    raise ValueError(f"unknown init {s.init!r}")


def init(rng, specs):
    """Materialize a spec tree into parameters (deterministic per path)."""
    leaves, treedef = jax.tree_util.tree_flatten(specs, is_leaf=is_spec)
    keys = jax.random.split(rng, len(leaves))
    vals = [_init_leaf(k, s) for k, s in zip(keys, leaves)]
    return jax.tree_util.tree_unflatten(treedef, vals)


def abstract(specs):
    """ShapeDtypeStruct stand-ins — lower/compile without allocation."""
    return jax.tree_util.tree_map(
        lambda s: jax.ShapeDtypeStruct(s.shape, jnp.dtype(s.dtype)),
        specs,
        is_leaf=is_spec,
    )


def count_params(specs) -> int:
    leaves = jax.tree_util.tree_leaves(specs, is_leaf=is_spec)
    return int(sum(np.prod(s.shape) for s in leaves))


def tree_bytes(specs) -> int:
    leaves = jax.tree_util.tree_leaves(specs, is_leaf=is_spec)
    return int(
        sum(np.prod(s.shape) * jnp.dtype(s.dtype).itemsize for s in leaves)
    )


def map_with_specs(fn: Callable[[ParamSpec, Any], Any], specs, tree):
    """tree_map over (spec, value) pairs with specs as leaf guide."""
    return jax.tree_util.tree_map(fn, specs, tree, is_leaf=lambda x: is_spec(x))
