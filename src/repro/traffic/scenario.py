"""Declarative scenarios: pattern x placement x background x phases.

A :class:`ScenarioSpec` names *what runs where* — each app an ordered
list of registry phases on a placement (an explicit
:class:`~repro.core.allocation.Partition` or an allocation-strategy
name), plus optional background noise and a link-fault mask — and
:func:`build_workload` lowers it through the registry and
:func:`~repro.traffic.workload.compose_workload` into the single
machine-level :class:`~repro.traffic.workload.Workload` every consumer
(engine, sched bridge, collective sim, benchmarks) executes.

Seeds: ``ScenarioSpec.seed`` derives a per-app seed (``seed + app
index``) that is threaded only into *seeded* patterns and only when the
app does not fix its own — so two random-permutation apps in one
scenario draw different permutations by default, while unseeded kernels
stay bit-identical to their direct builders.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Mapping, Sequence

import numpy as np

from repro.core.allocation import Partition, allocate_partition
from repro.core.hyperx import HyperX
from repro.traffic.base import AppTraffic, build_phases, get_pattern
from repro.traffic.workload import Workload, background_noise, compose_workload


@dataclasses.dataclass(frozen=True)
class PhaseSpec:
    """One phase: a registered pattern name + builder params."""

    pattern: str
    params: Mapping[str, Any] = dataclasses.field(default_factory=dict)


@dataclasses.dataclass(frozen=True)
class AppSpec:
    """One application: ordered phases on a placement.

    ``phases`` accepts a pattern name, a :class:`PhaseSpec`, or a
    sequence of either (run in order, see
    :func:`~repro.traffic.base.concat_phases` for the window semantics).
    ``placement`` is an explicit Partition or an allocation-strategy
    name; strategy names are resolved against the scenario's topology
    with a per-strategy job counter, so two ``"row"`` apps land on
    consecutive base blocks.  ``ranks`` defaults to the partition size
    (or one base block n^2 for strategy names).
    """

    phases: Any  # str | PhaseSpec | Sequence[str | PhaseSpec]
    placement: Partition | str
    ranks: int | None = None
    window: int | None = None
    seed: int | None = None

    def phase_list(self) -> tuple[PhaseSpec, ...]:
        ph = self.phases
        if isinstance(ph, (str, PhaseSpec)):
            ph = (ph,)
        return tuple(
            PhaseSpec(p) if isinstance(p, str) else p for p in ph
        )


@dataclasses.dataclass(frozen=True)
class BackgroundSpec:
    """Background noise over the machine's free endpoints.

    ``endpoints`` overrides the default choice (everything no target app
    occupies).  The pattern must accept a ``packets`` parameter.
    """

    pattern: str = "random_permutation"
    packets: int = 1
    seed: int | None = None
    endpoints: np.ndarray | None = None


@dataclasses.dataclass(frozen=True)
class ScenarioSpec:
    """A full machine scenario, declaratively."""

    apps: Sequence[AppSpec]
    background: BackgroundSpec | None = None
    fabric_partitioning: str = "shared"
    warmup: int = 0
    link_ok: np.ndarray | None = None
    # optional repro.resil.epochs.FaultSchedule: time-varying fault
    # epochs lowered into the engine tables (ANDed with link_ok)
    fault_schedule: object | None = None
    seed: int = 0


def _resolve_placement(
    topo: HyperX,
    spec: AppSpec,
    strategy_counts: dict[str, int],
) -> Partition:
    if isinstance(spec.placement, Partition):
        return spec.placement
    job_id = strategy_counts.get(spec.placement, 0)
    strategy_counts[spec.placement] = job_id + 1
    return allocate_partition(spec.placement, topo, job_id, size=spec.ranks)


def build_app(spec: AppSpec, part: Partition, default_seed: int) -> AppTraffic:
    """Lower one AppSpec on its resolved partition to a step table."""
    k = spec.ranks if spec.ranks is not None else part.size
    seed = default_seed if spec.seed is None else spec.seed
    phases = [(p.pattern, p.params) for p in spec.phase_list()]
    return build_phases(phases, k, seed=seed, window=spec.window)


def build_workload(topo: HyperX, spec: ScenarioSpec) -> Workload:
    """Lower a ScenarioSpec to the one machine Workload it describes."""
    if not spec.apps:
        raise ValueError("scenario has no apps")
    strategy_counts: dict[str, int] = {}
    apps: list[tuple[AppTraffic, Partition]] = []
    for i, a in enumerate(spec.apps):
        part = _resolve_placement(topo, a, strategy_counts)
        apps.append((build_app(a, part, default_seed=spec.seed + i), part))

    backgrounds: list[tuple[AppTraffic, Partition]] = []
    if spec.background is not None:
        bg = spec.background
        get_pattern(bg.pattern)  # fail fast with the registered list
        if bg.endpoints is not None:
            free = np.asarray(bg.endpoints, dtype=np.int64)
        else:
            used = np.concatenate(
                [part.endpoints[: app.k] for app, part in apps]
            )
            free = np.setdiff1d(np.arange(topo.num_endpoints), used)
        if len(free) == 0:
            raise ValueError("no free endpoints left for background noise")
        bg_seed = bg.seed if bg.seed is not None else spec.seed + 99
        backgrounds.append(background_noise(
            topo, free, packets=bg.packets, seed=bg_seed, pattern=bg.pattern,
        ))

    return compose_workload(
        topo, apps, background=backgrounds,
        fabric_partitioning=spec.fabric_partitioning,
        warmup=spec.warmup, link_ok=spec.link_ok,
        fault_schedule=spec.fault_schedule,
    )
