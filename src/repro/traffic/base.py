"""Traffic-pattern contract + registry (the workload-side mirror of
:mod:`repro.route`).

A :class:`TrafficPattern` wraps one *builder*: a function producing the
step-table form the cycle simulator executes directly —

  * each rank walks an ordered list of steps; a step sends ``npkts``
    packets to each of ``deg`` destinations and (optionally) must receive
    ``recv_need`` packets tagged with the same step index before the step
    is complete;
  * a sliding ``window`` limits how many incomplete steps a rank may have
    outstanding (1 = fully synchronous, T = fully asynchronous);
  * destinations are either fixed rank ids or sampled uniformly from a
    rank range each time a packet is injected (uniform / switch-
    permutation traffic).

Patterns register by name (:func:`register_pattern`) and are resolved
through :func:`get_pattern` — unknown names raise with the registered
list, exactly like routing's ``get_policy``.  Every pattern builds a
plain :class:`AppTraffic`; nothing here touches the engine, so a new
pattern is a ~30-line plugin: write a builder, register it, and it is
reachable from the scenario layer, the sched bridge, the collective
simulator and the benchmark grids.

Phased applications (:func:`concat_phases`) concatenate several kernels
into one ordered step table — e.g. stencil exchange rounds followed by an
all-reduce, the canonical HPC iteration.  The phased table is a normal
``AppTraffic``; downstream it pads into the engine's power-of-two
``WorkloadTables`` shape buckets like any other app, so phased
pattern x strategy x seed grids still vmap as one compile + one device
call per bucket.
"""

from __future__ import annotations

import dataclasses
import math
from typing import Any, Callable, Mapping, Sequence

import numpy as np


# --------------------------------------------------------------------------
# Per-application step tables (rank-local)
# --------------------------------------------------------------------------
@dataclasses.dataclass
class AppTraffic:
    """Step-table traffic of one application over ranks 0..k-1."""

    name: str
    k: int
    sends_dst: np.ndarray  # (k, T, MAXD) destination rank, -1 pad
    npkts: np.ndarray      # (k, T, MAXD) packets per destination
    deg: np.ndarray        # (k, T) number of valid destinations
    recv_need: np.ndarray  # (k, T) packets that must arrive before step done
    window: int            # max outstanding incomplete steps
    sampled: np.ndarray | None = None  # (k, T, MAXD) bool: sample dst?
    lo: np.ndarray | None = None       # (k, T, MAXD) sample range lo
    hi: np.ndarray | None = None       # (k, T, MAXD) sample range hi (excl)

    @property
    def T(self) -> int:
        return self.sends_dst.shape[1]

    @property
    def maxd(self) -> int:
        return self.sends_dst.shape[2]

    @property
    def total_packets(self) -> int:
        # only valid destination slots count — padded slots carry -1
        return int(self.npkts[self.sends_dst >= 0].sum())

    def __post_init__(self):
        if self.sampled is None:
            self.sampled = np.zeros_like(self.sends_dst, dtype=bool)
            self.lo = np.zeros_like(self.sends_dst)
            self.hi = np.zeros_like(self.sends_dst)


def empty_tables(k: int, T: int, maxd: int):
    """Fresh (sends_dst, npkts, deg, recv_need) tables, all-pad."""
    return (
        np.full((k, T, maxd), -1, dtype=np.int64),
        np.zeros((k, T, maxd), dtype=np.int64),
        np.zeros((k, T), dtype=np.int64),
        np.zeros((k, T), dtype=np.int64),
    )


def grid_shape(k: int, ndim: int = 2) -> tuple[int, ...]:
    """Factor ``k`` into an ``ndim``-D near-square grid (powers of two
    balanced across dims; any odd factor lands in the last dim).

    2D keeps the historical (gy, gx) = (2^(b//2), k / gy) split so every
    pre-existing stencil grid is unchanged; 3D peels 2^(b//3) first.
    """
    if ndim < 2:
        raise ValueError(f"grid_shape needs ndim >= 2, got {ndim}")
    dims: list[int] = []
    rest = k
    for i in range(ndim - 1, 0, -1):
        g = 2 ** (int(math.log2(rest)) // (i + 1))
        dims.append(g)
        rest //= g
    dims.append(rest)
    if math.prod(dims) != k:
        raise ValueError(
            f"stencil needs k expressible as a {ndim}D power-of-two-ish "
            f"grid, got {k}"
        )
    return tuple(dims)


# --------------------------------------------------------------------------
# Phased composition
# --------------------------------------------------------------------------
def concat_phases(
    phases: Sequence[AppTraffic],
    window: int | None = None,
    name: str | None = None,
) -> AppTraffic:
    """Concatenate several kernels into one ordered phased step table.

    All phases must span the same rank count ``k``.  Step tables stack
    along the step axis (destination slots pad to the widest phase), so a
    rank finishes phase ``i``'s steps before walking phase ``i+1``'s —
    subject to the app's sliding window.

    ``window`` defaults to the **minimum** over the phases: the engine
    carries one window per rank, and the minimum is the only choice that
    preserves every phase's internal ordering (a synchronous all-reduce
    after an asynchronous stencil must not start before the exchange
    completes).  Pass an explicit ``window`` to trade strictness for
    overlap — e.g. ``window=2`` lets one step of the next phase overlap
    the tail of the previous one.
    """
    if not phases:
        raise ValueError("concat_phases needs at least one phase")
    k = phases[0].k
    if any(p.k != k for p in phases):
        raise ValueError(
            f"phases span different rank counts: {[p.k for p in phases]}"
        )
    if len(phases) == 1 and window is None and name is None:
        return phases[0]
    T = sum(p.T for p in phases)
    maxd = max(p.maxd for p in phases)
    dst, npk, deg, recv = empty_tables(k, T, maxd)
    sampled = np.zeros((k, T, maxd), dtype=bool)
    lo = np.zeros((k, T, maxd), dtype=np.int64)
    hi = np.zeros((k, T, maxd), dtype=np.int64)
    off = 0
    for p in phases:
        sl = slice(off, off + p.T)
        dst[:, sl, : p.maxd] = p.sends_dst
        npk[:, sl, : p.maxd] = p.npkts
        deg[:, sl] = p.deg
        recv[:, sl] = p.recv_need
        sampled[:, sl, : p.maxd] = p.sampled
        lo[:, sl, : p.maxd] = p.lo
        hi[:, sl, : p.maxd] = p.hi
        off += p.T
    w = min(p.window for p in phases) if window is None else int(window)
    return AppTraffic(
        name or "+".join(p.name for p in phases),
        k, dst, npk, deg, recv, w, sampled, lo, hi,
    )


# --------------------------------------------------------------------------
# Pattern contract + registry
# --------------------------------------------------------------------------
@dataclasses.dataclass(frozen=True)
class TrafficPattern:
    """One named, parameterized traffic builder.

    Attributes:
      name: registry key (the scenario layer's ``pattern=`` string).
      builder: ``builder(k, **params) -> AppTraffic`` over ranks 0..k-1.
      kind: coarse taxonomy — ``static`` (rate-style synthetic traffic),
        ``adversarial`` (permutation/offset stressors), ``collective``
        (communication kernels with recv synchronization), ``stencil``
        (nearest-neighbour exchanges).
      seeded: builder accepts a ``seed=`` kwarg; the scenario layer only
        threads its derived seeds into seeded patterns, so unseeded
        builders keep exact historical outputs.
    """

    name: str
    builder: Callable[..., AppTraffic]
    kind: str = "static"
    seeded: bool = False
    description: str = ""

    def build(
        self,
        k: int,
        seed: int | None = None,
        **params: Any,
    ) -> AppTraffic:
        """Build the pattern over ``k`` ranks.

        ``seed`` is injected only for seeded patterns, and only when the
        caller did not already fix ``seed`` in ``params``.
        """
        if self.seeded and seed is not None:
            params.setdefault("seed", int(seed))
        app = self.builder(k, **params)
        if app.k != k:
            raise ValueError(
                f"pattern {self.name!r} built {app.k} ranks for k={k}"
            )
        return app


_REGISTRY: dict[str, TrafficPattern] = {}


def register_pattern(pattern: TrafficPattern) -> TrafficPattern:
    """Add a pattern to the registry (returns it, decorator-style)."""
    if pattern.name in _REGISTRY:
        raise ValueError(f"traffic pattern {pattern.name!r} already registered")
    _REGISTRY[pattern.name] = pattern
    return pattern


def available_patterns(kind: str | None = None) -> tuple[str, ...]:
    """Registered pattern names, sorted; optionally filtered by ``kind``."""
    return tuple(sorted(
        name for name, p in _REGISTRY.items()
        if kind is None or p.kind == kind
    ))


def get_pattern(name: str) -> TrafficPattern:
    """Look a pattern up by name; unknown names list what IS registered."""
    try:
        return _REGISTRY[name]
    except KeyError:
        raise ValueError(
            f"unknown traffic pattern {name!r}; registered patterns: "
            f"{', '.join(available_patterns()) or '(none)'}"
        ) from None


def build_phases(
    phases: Sequence[tuple[str, Mapping[str, Any]] | str],
    k: int,
    seed: int | None = None,
    window: int | None = None,
) -> AppTraffic:
    """Resolve an ordered phase list through the registry and concatenate.

    Each phase is a pattern name or a ``(name, params)`` tuple; a single
    phase with no window override returns the pattern's table unchanged
    (bit-identical to calling the builder directly).
    """
    apps = []
    for ph in phases:
        name, params = (ph, {}) if isinstance(ph, str) else ph
        params = dict(params)
        use_seed = params.pop("seed", seed)  # explicit phase seed wins
        apps.append(get_pattern(name).build(k, seed=use_seed, **params))
    if len(apps) == 1 and window is None:
        return apps[0]
    return concat_phases(apps, window=window)
