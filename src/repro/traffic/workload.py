"""Machine-level workload composition (paper Sec. 6.1 + 6.3.3).

``compose_workload`` merges several applications (each placed on a
Partition) plus optional background noise into one machine-level spec with
rank -> endpoint maps and per-partition VC pools (fabric partitioning).
This is the low-level merge; the declarative front-end is
:mod:`repro.traffic.scenario`.
"""

from __future__ import annotations

import dataclasses
from typing import Sequence

import numpy as np

from repro.core.allocation import Partition
from repro.core.hyperx import HyperX
from repro.traffic.base import AppTraffic, get_pattern


@dataclasses.dataclass
class Workload:
    """A complete machine workload: merged step tables + placement maps.

    Global rank space concatenates all application ranks (targets first,
    background last).  Background ranks are *infinite* sources: they inject
    a fixed-rate stream and never complete; completion (makespan) is
    measured over target ranks only.
    """

    topo: HyperX
    R: int
    T: int
    maxd: int
    rank_ep: np.ndarray      # (R,) endpoint id per rank
    pool: np.ndarray         # (R,) VC pool per rank
    infinite: np.ndarray     # (R,) bool — background sources
    sends_dst: np.ndarray    # (R, T, MAXD) GLOBAL rank ids, -1 pad
    npkts: np.ndarray
    deg: np.ndarray
    recv_need: np.ndarray
    total_sends: np.ndarray  # (R, T)
    sampled: np.ndarray
    lo: np.ndarray           # GLOBAL rank space
    hi: np.ndarray
    window: np.ndarray       # (R,) per-rank window
    start: np.ndarray        # (R,) injection start time (warmup gating)
    num_pools: int
    names: list[str]
    # (S, q*n) bool, True = healthy directed link; None = all healthy.
    # See repro.route.faults for mask constructors and apply_faults().
    link_ok: np.ndarray | None = None
    # time-varying faults: a repro.resil.epochs.FaultSchedule (epoch
    # starts + per-epoch masks) lowered into the engine's epoch tables;
    # composes with link_ok (the engine ANDs both).  Kept duck-typed so
    # traffic does not import resil.
    fault_schedule: object | None = None

    @property
    def target_ranks(self) -> np.ndarray:
        return np.flatnonzero(~self.infinite)

    @property
    def target_packets(self) -> int:
        return int(self.npkts[~self.infinite].sum())


def compose_workload(
    topo: HyperX,
    apps: Sequence[tuple[AppTraffic, Partition]],
    background: Sequence[tuple[AppTraffic, Partition]] = (),
    fabric_partitioning: str = "shared",
    warmup: int = 0,
    link_ok: np.ndarray | None = None,
    fault_schedule: object | None = None,
) -> Workload:
    """Merge applications (+ background noise) into one machine workload.

    fabric_partitioning:
      * 'shared'    — every partition shares VC pool 0 (baseline, 4 VCs);
      * 'background'— targets pool 0, background pool 1 (Figs. 11-12);
      * 'per_app'   — one pool per application (full fabric partitioning).

    ``warmup``: target apps start injecting only at this time, letting the
    (infinite-rate) background reach steady state first; the simulator
    reports makespan relative to the warmup point.

    ``link_ok``: optional (S, q*n) link-fault mask (True = healthy); see
    :mod:`repro.route.faults`.  Travels with the workload into the
    engine's device tables, so fault scenarios batch like any other axis.

    ``fault_schedule``: optional time-varying fault epochs (a
    :class:`repro.resil.epochs.FaultSchedule`); ANDed with ``link_ok``
    when both are given.
    """
    all_jobs = list(apps) + list(background)
    n_bg = len(background)
    R = sum(app.k for app, _ in all_jobs)
    T = max(app.T for app, _ in all_jobs)
    maxd = max(app.maxd for app, _ in all_jobs)

    rank_ep = np.empty(R, dtype=np.int64)
    pool = np.zeros(R, dtype=np.int64)
    infinite = np.zeros(R, dtype=bool)
    window = np.ones(R, dtype=np.int64)
    start = np.zeros(R, dtype=np.int64)
    sends_dst = np.full((R, T, maxd), -1, dtype=np.int64)
    npkts = np.zeros((R, T, maxd), dtype=np.int64)
    deg = np.zeros((R, T), dtype=np.int64)
    recv_need = np.zeros((R, T), dtype=np.int64)
    sampled = np.zeros((R, T, maxd), dtype=bool)
    lo = np.zeros((R, T, maxd), dtype=np.int64)
    hi = np.zeros((R, T, maxd), dtype=np.int64)

    # endpoint disjointness guard: each endpoint hosts at most one rank
    used = np.concatenate([p.endpoints[: a.k] for a, p in all_jobs])
    if len(np.unique(used)) != len(used):
        uniq, cnt = np.unique(used, return_counts=True)
        raise ValueError(
            f"workload maps {int((cnt > 1).sum())} endpoints to multiple ranks "
            f"(e.g. {uniq[cnt > 1][:8].tolist()}); partitions must be disjoint"
        )

    off = 0
    names = []
    for j, (app, part) in enumerate(all_jobs):
        k, t, d = app.k, app.T, app.maxd
        if len(part.endpoints) < k:
            raise ValueError(
                f"partition has {len(part.endpoints)} endpoints < {k} ranks"
            )
        is_bg = j >= len(apps)
        sl = slice(off, off + k)
        rank_ep[sl] = part.endpoints[:k]
        infinite[sl] = is_bg
        window[sl] = app.window
        start[sl] = 0 if is_bg else warmup
        if fabric_partitioning == "shared":
            pool[sl] = 0
        elif fabric_partitioning == "background":
            pool[sl] = 1 if is_bg else 0
        elif fabric_partitioning == "per_app":
            pool[sl] = j
        else:
            raise ValueError(f"unknown fabric_partitioning {fabric_partitioning!r}")
        # shift destinations into the global rank space
        dstj = app.sends_dst.copy()
        dstj[dstj >= 0] += off
        sends_dst[sl, :t, :d] = dstj
        npkts[sl, :t, :d] = app.npkts
        deg[sl, :t] = app.deg
        recv_need[sl, :t] = app.recv_need
        sampled[sl, :t, :d] = app.sampled
        lo[sl, :t, :d] = app.lo + off
        hi[sl, :t, :d] = app.hi + off
        names.append(("bg:" if is_bg else "") + app.name)
        off += k

    total_sends = npkts.sum(axis=2)
    num_pools = int(pool.max()) + 1
    return Workload(
        topo=topo, R=R, T=T, maxd=maxd, rank_ep=rank_ep, pool=pool,
        infinite=infinite, sends_dst=sends_dst, npkts=npkts, deg=deg,
        recv_need=recv_need, total_sends=total_sends, sampled=sampled,
        lo=lo, hi=hi, window=window, start=start, num_pools=num_pools,
        names=names,
        link_ok=None if link_ok is None else np.asarray(link_ok, dtype=bool),
        fault_schedule=fault_schedule,
    )


def background_noise(
    topo: HyperX,
    free_endpoints: np.ndarray,
    packets: int = 1,
    seed: int = 1234,
    pattern: str = "random_permutation",
) -> tuple[AppTraffic, Partition]:
    """Background traffic of any registered pattern over free endpoints.

    The traffic is *infinite-rate* in the simulator (the ``infinite`` flag in
    the Workload makes the step table loop), so ``packets`` only shapes the
    table; 1 is enough.  ``pattern`` must accept a ``packets`` parameter
    (the rate-style patterns do).
    """
    k = len(free_endpoints)
    app = get_pattern(pattern).build(k, seed=seed, packets=max(1, packets))
    part = Partition(
        strategy="background",
        topo=topo,
        job_id=-1,
        size=k,
        endpoints=np.asarray(free_endpoints, dtype=np.int64),
        switches=np.unique(np.asarray(free_endpoints) // topo.concentration),
    )
    return app, part
