"""The shipped traffic patterns (paper Section 6.1 + HyperX adversaries).

Migrated bit-identically from the seed ``core/traffic.py`` (regression-
pinned in ``tests/test_traffic_patterns.py``): static patterns (Sec.
6.1.1) ``uniform``, ``random_permutation``, ``random_switch_permutation``;
application kernels (Sec. 6.1.2) ``all_to_all``, ``all_reduce``
(Rabenseifner), ``stencil_von_neumann`` / ``stencil_moore``,
``random_involution``; plus ``ring_allreduce``, migrated from
``fabric/collective_sim.py``'s former private builder.

New patterns (Multi-Plane HyperX, arXiv 2604.23519, stresses exactly this
mix of AI-collective and adversarial traffic):

  * ``transpose`` — matrix-transpose permutation over the rank grid, the
    classic bisection adversary (diagonal ranks idle);
  * ``shuffle``   — perfect-shuffle (bit-rotation) permutation, the FFT /
    butterfly exchange adversary;
  * ``tornado``   — half-machine offset in every grid dimension, the
    classic HyperX/torus adversary that defeats minimal routing;
  * ``incast``    — many-to-one convergence onto a few target ranks (the
    parameter-server / reduction-root hotspot);
  * ``recursive_doubling`` — full-vector butterfly all-reduce, the
    latency-optimal contrast to Rabenseifner's halving/doubling;
  * ``stencil_3d`` — 3D periodic von-Neumann stencil (6 neighbours) over
    a ``grid_shape(k, ndim=3)`` factorization.
"""

from __future__ import annotations

import math

import numpy as np

from repro.traffic.base import (
    AppTraffic,
    TrafficPattern,
    empty_tables as _empty,
    grid_shape,
    register_pattern,
)


def _grid_shape(k: int) -> tuple[int, int]:
    return grid_shape(k, ndim=2)  # type: ignore[return-value]


# ----------------------------------------------------------- static patterns
def uniform(k: int, packets: int = 64) -> AppTraffic:
    """Uniform random: every packet to a uniform destination in the app."""
    dst, npk, deg, recv = _empty(k, packets, 1)
    npk[:, :, 0] = 1
    deg[:, :] = 1
    sampled = np.ones((k, packets, 1), dtype=bool)
    lo = np.zeros((k, packets, 1), dtype=np.int64)
    hi = np.full((k, packets, 1), k, dtype=np.int64)
    dst[:, :, 0] = 0  # ignored when sampled
    return AppTraffic("uniform", k, dst, npk, deg, recv, packets, sampled, lo, hi)


def random_permutation(k: int, packets: int = 64, seed: int = 0) -> AppTraffic:
    """Each rank sends every packet to one fixed random unique destination."""
    rng = np.random.default_rng(seed)
    perm = rng.permutation(k)
    # avoid self-sends: re-draw derangement-ish (swap fixed points)
    fixed = np.flatnonzero(perm == np.arange(k))
    for i in fixed:
        j = (i + 1) % k
        perm[i], perm[j] = perm[j], perm[i]
    dst, npk, deg, recv = _empty(k, packets, 1)
    dst[:, :, 0] = perm[:, None]
    npk[:, :, 0] = 1
    deg[:, :] = 1
    return AppTraffic("random_permutation", k, dst, npk, deg, recv, packets)


def random_switch_permutation(
    k: int, group: int = 8, packets: int = 64, seed: int = 0
) -> AppTraffic:
    """Groups of ``group`` ranks send only to one other (permuted) group.

    Adversarial when the allocation maps rank groups onto single switches
    (locality-aware allocations + linear task mapping): all traffic of a
    switch targets exactly one other switch.
    """
    if k % group:
        raise ValueError(f"k={k} not a multiple of group={group}")
    g = k // group
    rng = np.random.default_rng(seed)
    gperm = rng.permutation(g)
    fixed = np.flatnonzero(gperm == np.arange(g))
    for i in fixed:
        j = (i + 1) % g
        gperm[i], gperm[j] = gperm[j], gperm[i]
    dst, npk, deg, recv = _empty(k, packets, 1)
    npk[:, :, 0] = 1
    deg[:, :] = 1
    sampled = np.ones((k, packets, 1), dtype=bool)
    my_group = np.arange(k) // group
    lo = (gperm[my_group] * group)[:, None, None] * np.ones(
        (1, packets, 1), dtype=np.int64
    )
    hi = lo + group
    return AppTraffic(
        "random_switch_permutation", k, dst, npk, deg, recv, packets, sampled, lo, hi
    )


# ------------------------------------------------------- application kernels
def all_to_all(k: int) -> AppTraffic:
    """MPI All-to-All: k-1 asynchronous steps; step i sends to (r+i+1) mod k."""
    T = k - 1
    dst, npk, deg, recv = _empty(k, T, 1)
    r = np.arange(k)[:, None]
    i = np.arange(T)[None, :]
    dst[:, :, 0] = (r + i + 1) % k
    npk[:, :, 0] = 1
    deg[:, :] = 1
    recv[:, :] = 1  # from (r - i - 1) mod k, same step index
    return AppTraffic("all_to_all", k, dst, npk, deg, recv, window=T)


def all_reduce(k: int, vector_packets: int = 64) -> AppTraffic:
    """Rabenseifner all-reduce: scatter-reduce + all-gather over a hypercube.

    ``vector_packets`` is the reduced vector size in packets; step i of the
    scatter phase exchanges vector/2^(i+1) packets with partner r XOR 2^i,
    the gather phase mirrors it.  Synchronous (window=1): a step cannot
    start before the previous exchange completed (the reduction needs the
    partner's data).
    """
    m = int(math.log2(k))
    if 2**m != k:
        raise ValueError(f"Rabenseifner all-reduce requires power-of-two k, got {k}")
    T = 2 * m
    dst, npk, deg, recv = _empty(k, T, 1)
    r = np.arange(k)
    sizes = []
    for i in range(m):  # scatter-reduce: halving
        sizes.append(max(1, vector_packets >> (i + 1)))
    for i in range(m):  # all-gather: doubling (mirror)
        sizes.append(max(1, vector_packets >> (m - i)))
    for t in range(T):
        i = t if t < m else (2 * m - 1 - t)
        partner = r ^ (1 << i)
        dst[:, t, 0] = partner
        npk[:, t, 0] = sizes[t]
        deg[:, t] = 1
        recv[:, t] = sizes[t]
    return AppTraffic("all_reduce", k, dst, npk, deg, recv, window=1)


def stencil(k: int, neighborhood: str = "von_neumann", rounds: int | None = None) -> AppTraffic:
    """2D periodic stencil; each round exchanges 1 packet with each neighbor."""
    gy, gx = _grid_shape(k)
    r = np.arange(k)
    y, x = r // gx, r % gx
    if neighborhood == "von_neumann":
        offs = [(-1, 0), (1, 0), (0, -1), (0, 1)]
    elif neighborhood == "moore":
        offs = [
            (-1, -1), (-1, 0), (-1, 1), (0, -1), (0, 1), (1, -1), (1, 0), (1, 1),
        ]
    else:
        raise ValueError(f"unknown neighborhood {neighborhood!r}")
    if rounds is None:
        rounds = max(1, 64 // len(offs))
    maxd = len(offs)
    dst, npk, deg, recv = _empty(k, rounds, maxd)
    for d, (dy, dx) in enumerate(offs):
        ny, nx = (y + dy) % gy, (x + dx) % gx
        dst[:, :, d] = (ny * gx + nx)[:, None]
        npk[:, :, d] = 1
    deg[:, :] = maxd
    recv[:, :] = maxd
    name = f"stencil_{neighborhood}"
    return AppTraffic(name, k, dst, npk, deg, recv, window=1)


def random_involution(k: int, packets: int = 63, seed: int = 0) -> AppTraffic:
    """Random perfect matching; paired ranks exchange ``packets`` packets."""
    if k % 2:
        raise ValueError("random involution requires even k")
    rng = np.random.default_rng(seed)
    order = rng.permutation(k)
    partner = np.empty(k, dtype=np.int64)
    partner[order[0::2]] = order[1::2]
    partner[order[1::2]] = order[0::2]
    dst, npk, deg, recv = _empty(k, packets, 1)
    dst[:, :, 0] = partner[:, None]
    npk[:, :, 0] = 1
    deg[:, :] = 1
    return AppTraffic("random_involution", k, dst, npk, deg, recv, window=packets)


def ring_allreduce(k: int, packets_per_step: int = 4) -> AppTraffic:
    """Ring reduce-scatter + all-gather: 2(k-1) steps of neighbour sends."""
    T = 2 * (k - 1)
    dst, npk, deg, recv = _empty(k, T, 1)
    r = np.arange(k)
    for t in range(T):
        dst[:, t, 0] = (r + 1) % k
        npk[:, t, 0] = packets_per_step
        deg[:, t] = 1
        recv[:, t] = packets_per_step
    return AppTraffic("ring_allreduce", k, dst, npk, deg, recv, window=1)


# ----------------------------------------------------- adversarial patterns
def transpose(k: int, packets: int = 64) -> AppTraffic:
    """Matrix-transpose permutation: rank (y, x) sends to rank (x, y).

    The destination grid is the source grid transposed (gx rows of gy),
    so the map is a bijection for any ``grid_shape`` factorization and an
    involution on square grids.  Diagonal ranks (y == x on square grids)
    would self-send and instead stay idle — the classic bisection-load
    adversary.
    """
    gy, gx = _grid_shape(k)
    r = np.arange(k)
    y, x = r // gx, r % gx
    target = x * gy + y  # (x, y) in the transposed gx-row-major grid
    send = target != r
    dst, npk, deg, recv = _empty(k, packets, 1)
    dst[send, :, 0] = target[send, None]
    npk[send, :, 0] = 1
    deg[send, :] = 1
    return AppTraffic("transpose", k, dst, npk, deg, recv, window=packets)


def shuffle(k: int, packets: int = 64) -> AppTraffic:
    """Perfect-shuffle permutation: destination = bit-rotate-left(rank).

    The FFT/butterfly exchange adversary; requires power-of-two k.  The
    all-zeros and all-ones ranks are fixed points and stay idle.
    """
    b = int(math.log2(k))
    if 2**b != k:
        raise ValueError(f"perfect shuffle requires power-of-two k, got {k}")
    r = np.arange(k)
    target = ((r << 1) | (r >> (b - 1))) & (k - 1)
    send = target != r
    dst, npk, deg, recv = _empty(k, packets, 1)
    dst[send, :, 0] = target[send, None]
    npk[send, :, 0] = 1
    deg[send, :] = 1
    return AppTraffic("shuffle", k, dst, npk, deg, recv, window=packets)


def tornado(k: int, packets: int = 64, offsets: tuple[int, ...] | None = None) -> AppTraffic:
    """Tornado: a half-grid offset in every rank-grid dimension.

    The classic HyperX/torus adversary — every rank in a row targets the
    same distant row/column offset, so minimal routing piles the whole
    load onto one port per dimension while adaptive/Valiant policies
    spread it.  ``offsets`` overrides the per-dimension shift (default
    ``g // 2`` per dimension).
    """
    gy, gx = _grid_shape(k)
    if offsets is None:
        offsets = (gy // 2, gx // 2)
    oy, ox = offsets
    if (oy % gy, ox % gx) == (0, 0):
        raise ValueError(f"tornado offsets {offsets} are a self-map on {gy}x{gx}")
    r = np.arange(k)
    y, x = r // gx, r % gx
    target = ((y + oy) % gy) * gx + (x + ox) % gx
    dst, npk, deg, recv = _empty(k, packets, 1)
    dst[:, :, 0] = target[:, None]
    npk[:, :, 0] = 1
    deg[:, :] = 1
    return AppTraffic("tornado", k, dst, npk, deg, recv, window=packets)


def incast(k: int, packets: int = 16, targets: int = 1) -> AppTraffic:
    """Many-to-one: every source rank streams to one of ``targets`` sinks.

    Source rank r (r >= targets) sends ``packets`` packets — one per step
    — to sink ``r % targets``; sinks send nothing and complete a step
    only once every source's packet for that step arrived.  The
    parameter-server / reduction-root hotspot: ejection bandwidth at the
    sinks, not bisection, is the bottleneck.
    """
    if not 0 < targets < k:
        raise ValueError(f"incast needs 0 < targets < k, got targets={targets}")
    dst, npk, deg, recv = _empty(k, packets, 1)
    r = np.arange(k)
    src = r >= targets
    dst[src, :, 0] = (r[src] % targets)[:, None]
    npk[src, :, 0] = 1
    deg[src, :] = 1
    fan_in = np.bincount(r[src] % targets, minlength=targets)
    recv[:targets, :] = fan_in[:, None]
    return AppTraffic("incast", k, dst, npk, deg, recv, window=packets)


def recursive_doubling(k: int, vector_packets: int = 16) -> AppTraffic:
    """Recursive-doubling all-reduce: log2(k) full-vector exchanges.

    Step i exchanges the *whole* vector with partner r XOR 2^i — half the
    steps of Rabenseifner's halving/doubling but log2(k)x the traffic;
    the latency-optimal variant small reductions actually use.
    Synchronous (window=1): each exchange needs the partner's reduced
    vector.
    """
    m = int(math.log2(k))
    if 2**m != k:
        raise ValueError(
            f"recursive-doubling all-reduce requires power-of-two k, got {k}"
        )
    dst, npk, deg, recv = _empty(k, m, 1)
    r = np.arange(k)
    for t in range(m):
        dst[:, t, 0] = r ^ (1 << t)
        npk[:, t, 0] = vector_packets
        deg[:, t] = 1
        recv[:, t] = vector_packets
    return AppTraffic("recursive_doubling", k, dst, npk, deg, recv, window=1)


def stencil_3d(k: int, rounds: int | None = None) -> AppTraffic:
    """3D periodic von-Neumann stencil: 6-neighbour exchange rounds.

    Ranks factor into a ``grid_shape(k, ndim=3)`` torus; every dimension
    must have at least 2 points (a size-1 dimension would make the +/-
    neighbours self-sends).
    """
    gz, gy, gx = grid_shape(k, ndim=3)
    if min(gz, gy, gx) < 2:
        raise ValueError(
            f"3D stencil needs every grid dim >= 2, got {gz}x{gy}x{gx} for k={k}"
        )
    r = np.arange(k)
    z, y, x = r // (gy * gx), (r // gx) % gy, r % gx
    offs = [(-1, 0, 0), (1, 0, 0), (0, -1, 0), (0, 1, 0), (0, 0, -1), (0, 0, 1)]
    if rounds is None:
        rounds = max(1, 64 // len(offs))
    maxd = len(offs)
    dst, npk, deg, recv = _empty(k, rounds, maxd)
    for d, (dz, dy, dx) in enumerate(offs):
        nz, ny, nx = (z + dz) % gz, (y + dy) % gy, (x + dx) % gx
        dst[:, :, d] = (nz * gy * gx + ny * gx + nx)[:, None]
        npk[:, :, d] = 1
    deg[:, :] = maxd
    recv[:, :] = maxd
    return AppTraffic("stencil_3d", k, dst, npk, deg, recv, window=1)


# --------------------------------------------------------------- registry
UNIFORM = register_pattern(TrafficPattern(
    "uniform", uniform, kind="static",
    description="every packet to a uniform-random destination",
))
RANDOM_PERMUTATION = register_pattern(TrafficPattern(
    "random_permutation", random_permutation, kind="static", seeded=True,
    description="fixed random fixed-point-free permutation",
))
RANDOM_SWITCH_PERMUTATION = register_pattern(TrafficPattern(
    "random_switch_permutation", random_switch_permutation,
    kind="adversarial", seeded=True,
    description="rank groups target one permuted group (switch adversary)",
))
ALL_TO_ALL = register_pattern(TrafficPattern(
    "all_to_all", all_to_all, kind="collective",
    description="MPI All-to-All, k-1 asynchronous shifted steps",
))
ALL_REDUCE = register_pattern(TrafficPattern(
    "all_reduce", all_reduce, kind="collective",
    description="Rabenseifner all-reduce (halving/doubling hypercube)",
))
STENCIL_VON_NEUMANN = register_pattern(TrafficPattern(
    "stencil_von_neumann",
    lambda k, rounds=None: stencil(k, "von_neumann", rounds),
    kind="stencil",
    description="2D periodic 4-neighbour exchange rounds",
))
STENCIL_MOORE = register_pattern(TrafficPattern(
    "stencil_moore",
    lambda k, rounds=None: stencil(k, "moore", rounds),
    kind="stencil",
    description="2D periodic 8-neighbour exchange rounds",
))
RANDOM_INVOLUTION = register_pattern(TrafficPattern(
    "random_involution", random_involution, kind="static", seeded=True,
    description="random perfect matching, paired ranks exchange",
))
RING_ALLREDUCE = register_pattern(TrafficPattern(
    "ring_allreduce", ring_allreduce, kind="collective",
    description="ring reduce-scatter + all-gather, 2(k-1) neighbour steps",
))
TRANSPOSE = register_pattern(TrafficPattern(
    "transpose", transpose, kind="adversarial",
    description="matrix-transpose permutation over the rank grid",
))
SHUFFLE = register_pattern(TrafficPattern(
    "shuffle", shuffle, kind="adversarial",
    description="perfect-shuffle (bit-rotation) permutation",
))
TORNADO = register_pattern(TrafficPattern(
    "tornado", tornado, kind="adversarial",
    description="half-grid offset per dimension (HyperX adversary)",
))
INCAST = register_pattern(TrafficPattern(
    "incast", incast, kind="adversarial",
    description="many-to-one convergence onto few sink ranks",
))
RECURSIVE_DOUBLING = register_pattern(TrafficPattern(
    "recursive_doubling", recursive_doubling, kind="collective",
    description="recursive-doubling all-reduce, log2(k) full exchanges",
))
STENCIL_3D = register_pattern(TrafficPattern(
    "stencil_3d", stencil_3d, kind="stencil",
    description="3D periodic 6-neighbour exchange rounds",
))


# Compatibility views of the registry (the seed module's public dicts).
KERNELS = {
    "all_to_all": all_to_all,
    "all_reduce": all_reduce,
    "stencil_von_neumann": lambda k: stencil(k, "von_neumann"),
    "stencil_moore": lambda k: stencil(k, "moore"),
    "random_involution": random_involution,
}

STATIC_PATTERNS = {
    "uniform": uniform,
    "random_permutation": random_permutation,
    "random_switch_permutation": None,  # needs group size; built in compose
}
