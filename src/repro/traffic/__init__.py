"""Pluggable traffic subsystem (the workload-side mirror of ``route/``).

Public surface:

  * :class:`TrafficPattern` + :func:`get_pattern` / :func:`register_pattern`
    / :func:`available_patterns` — the pattern registry (unknown names
    raise with the registered list);
  * :mod:`repro.traffic.patterns` — the shipped patterns: the paper's
    Sec. 6.1 set (migrated bit-identically from the seed builders),
    ``ring_allreduce`` (migrated from the collective simulator), and the
    adversarial/collective additions ``transpose``, ``shuffle``,
    ``tornado``, ``incast``, ``recursive_doubling``, ``stencil_3d``;
  * :class:`AppTraffic` / :func:`concat_phases` / :func:`build_phases` —
    step tables and phased (multi-kernel) composition;
  * :class:`Workload` / :func:`compose_workload` /
    :func:`background_noise` — machine-level merging;
  * :class:`ScenarioSpec` (+ :class:`AppSpec`, :class:`PhaseSpec`,
    :class:`BackgroundSpec`) and :func:`build_workload` — the declarative
    pattern x placement x background x phases layer every consumer
    (sched bridge, collective sim, benchmarks) constructs through.

Patterns build plain numpy step tables; the engine pads them into
power-of-two ``WorkloadTables`` shape buckets, so pattern x strategy x
seed grids vmap as one compile + one device call per bucket
(trace-counter-pinned in ``tests/test_traffic_patterns.py``).
"""

from repro.traffic.base import (
    AppTraffic,
    TrafficPattern,
    available_patterns,
    build_phases,
    concat_phases,
    empty_tables,
    get_pattern,
    grid_shape,
    register_pattern,
)
from repro.traffic import patterns
from repro.traffic.patterns import (
    all_reduce,
    all_to_all,
    incast,
    random_involution,
    random_permutation,
    random_switch_permutation,
    recursive_doubling,
    ring_allreduce,
    shuffle,
    stencil,
    stencil_3d,
    tornado,
    transpose,
    uniform,
)
from repro.traffic.workload import (
    Workload,
    background_noise,
    compose_workload,
)
from repro.traffic.scenario import (
    AppSpec,
    BackgroundSpec,
    PhaseSpec,
    ScenarioSpec,
    build_app,
    build_workload,
)

__all__ = [
    "AppSpec",
    "AppTraffic",
    "BackgroundSpec",
    "PhaseSpec",
    "ScenarioSpec",
    "TrafficPattern",
    "Workload",
    "all_reduce",
    "all_to_all",
    "available_patterns",
    "background_noise",
    "build_app",
    "build_phases",
    "build_workload",
    "compose_workload",
    "concat_phases",
    "empty_tables",
    "get_pattern",
    "grid_shape",
    "incast",
    "patterns",
    "random_involution",
    "random_permutation",
    "random_switch_permutation",
    "recursive_doubling",
    "register_pattern",
    "ring_allreduce",
    "shuffle",
    "stencil",
    "stencil_3d",
    "tornado",
    "transpose",
    "uniform",
]
