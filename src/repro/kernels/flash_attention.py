"""Blocked online-softmax (flash) attention — Pallas TPU kernel.

Grid: (batch, heads, q_blocks, kv_blocks); the kv dimension is innermost
and sequential ("arbitrary"), carrying the running max / denominator /
accumulator in VMEM scratch across kv blocks of one (b, h, iq) tile.

TPU adaptation notes (vs the CUDA flash-attention the literature targets):
  * block shapes are MXU-aligned (q, kv blocks multiples of 128 on the
    sequence axes; head_dim padded to 128 by the wrapper when needed);
  * no shared-memory banking / warp shuffles — the VMEM scratch + the
    sequential grid dimension express the same reduction;
  * causal + local-window masking is positional; fully-masked kv blocks
    are skipped with pl.when (block-sparse skip on the causal lower
    triangle), which roughly halves causal FLOPs.

GQA: the wrapper maps query head h to kv head h // (H / KV) in the
BlockSpec index map — no kv replication in HBM.
"""

from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

# jax < 0.5 names the TPU compiler-params dataclass TPUCompilerParams.
_CompilerParams = getattr(pltpu, "CompilerParams", None) or pltpu.TPUCompilerParams

NEG_INF = -1e30


def _kernel(q_ref, k_ref, v_ref, o_ref, m_ref, l_ref, acc_ref, *,
            scale, causal, window, bq, bk, nk):
    ik = pl.program_id(3)
    iq = pl.program_id(2)

    @pl.when(ik == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q_start = iq * bq
    k_start = ik * bk
    # block-level skip: causal => no kv block strictly above the diagonal;
    # window => no kv block entirely left of the window
    needed = True
    if causal:
        needed = k_start <= q_start + bq - 1
    if window:
        needed = needed & (k_start + bk - 1 > q_start - window)

    @pl.when(needed)
    def _body():
        q = q_ref[0, 0].astype(jnp.float32)          # (bq, d)
        k = k_ref[0, 0].astype(jnp.float32)          # (bk, d)
        v = v_ref[0, 0].astype(jnp.float32)
        logits = jax.lax.dot_general(
            q, k, (((1,), (1,)), ((), ())),
            preferred_element_type=jnp.float32,
        ) * scale                                     # (bq, bk)
        qpos = q_start + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 0)
        kpos = k_start + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 1)
        mask = jnp.ones((bq, bk), jnp.bool_)
        if causal:
            mask &= kpos <= qpos
        if window:
            mask &= kpos > qpos - window
        logits = jnp.where(mask, logits, NEG_INF)
        m_prev = m_ref[...]                          # (bq, 1)
        m_new = jnp.maximum(m_prev, logits.max(axis=1, keepdims=True))
        p = jnp.exp(logits - m_new)
        corr = jnp.exp(m_prev - m_new)
        l_ref[...] = l_ref[...] * corr + p.sum(axis=1, keepdims=True)
        acc_ref[...] = acc_ref[...] * corr + jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32,
        )
        m_ref[...] = m_new

    @pl.when(ik == nk - 1)
    def _finish():
        o_ref[0, 0] = (
            acc_ref[...] / jnp.maximum(l_ref[...], 1e-30)
        ).astype(o_ref.dtype)


@functools.partial(
    jax.jit,
    static_argnames=("causal", "window", "bq", "bk", "interpret"),
)
def flash_attention(
    q, k, v, causal=True, window=0, bq=128, bk=128, interpret=None
):
    """q: (B, H, S, D); k, v: (B, KV, T, D); returns (B, H, S, D).

    Self-attention with positions == arange (train/prefill).  S, T must be
    multiples of the block sizes (the ops wrapper pads).
    """
    B, H, S, D = q.shape
    KV, T = k.shape[1], k.shape[2]
    rep = H // KV
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    nq, nk = S // bq, T // bk
    scale = 1.0 / math.sqrt(D)

    kernel = functools.partial(
        _kernel, scale=scale, causal=causal, window=window,
        bq=bq, bk=bk, nk=nk,
    )
    grid = (B, H, nq, nk)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1, bq, D), lambda b, h, iq, ik: (b, h, iq, 0)),
            pl.BlockSpec((1, 1, bk, D), lambda b, h, iq, ik: (b, h // rep, ik, 0)),
            pl.BlockSpec((1, 1, bk, D), lambda b, h, iq, ik: (b, h // rep, ik, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, bq, D), lambda b, h, iq, ik: (b, h, iq, 0)),
        out_shape=jax.ShapeDtypeStruct((B, H, S, D), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((bq, 1), jnp.float32),
            pltpu.VMEM((bq, 1), jnp.float32),
            pltpu.VMEM((bq, D), jnp.float32),
        ],
        compiler_params=_CompilerParams(
            dimension_semantics=("parallel", "parallel", "parallel", "arbitrary"),
        ),
        interpret=interpret,
        name="flash_attention",
    )(q, k, v)
