"""jit wrapper adapting model layout to the flash-attention kernel."""

from __future__ import annotations

import jax.numpy as jnp

from repro.kernels.flash_attention import flash_attention as _kernel_call


def flash_attention(q, k, v, q_pos, k_pos, causal, window, bq=128, bk=128):
    """Model-layout entry point used by layers.chunked_sdpa(use_kernel=True).

    q: (B,S,G,rep,dh) grouped queries; k/v: (B,T,G,dh).  Assumes contiguous
    positions from 0 (train/prefill).  Pads S/T up to block multiples and
    dh to the 128-lane MXU width, then slices back.
    """
    B, S, G, rep, dh = q.shape
    T = k.shape[1]
    qh = q.transpose(0, 2, 3, 1, 4).reshape(B, G * rep, S, dh)
    kh = k.transpose(0, 2, 1, 3)                      # (B, G, T, dh)
    vh = v.transpose(0, 2, 1, 3)

    bq = min(bq, max(S, 8))
    bk = min(bk, max(T, 8))
    pS = (-S) % bq
    pT = (-T) % bk
    pD = (-dh) % 128 if dh > 128 else 0  # small-dh test shapes stay exact
    if pS or pD:
        qh = jnp.pad(qh, ((0, 0), (0, 0), (0, pS), (0, pD)))
    if pT or pD:
        kh = jnp.pad(kh, ((0, 0), (0, 0), (0, pT), (0, pD)))
        vh = jnp.pad(vh, ((0, 0), (0, 0), (0, pT), (0, pD)))
    # padded kv columns must never win the softmax: they are masked by the
    # causal test (kpos > any real qpos) when causal; otherwise mask via
    # window==0 and bidirectional needs explicit suppression -> use causal
    # semantics of the kernel by passing window/causal flags through.
    out = _kernel_call(qh, kh, vh, causal=causal, window=window, bq=bq, bk=bk)
    out = out[:, :, :S, :dh]
    return out.reshape(B, G, rep, S, dh).transpose(0, 3, 1, 2, 4)
