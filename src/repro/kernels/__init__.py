"""Pallas TPU kernels for the framework's compute hot spots.

  flash_attention — blocked online-softmax attention (train/prefill path)
  ssd_scan        — Mamba-2 SSD chunked scan (state-space duality)

Each kernel ships as <name>.py (pl.pallas_call + BlockSpec VMEM tiling),
ops.py-style jit wrappers (flash_ops / ssd_ops), and ref.py pure-jnp
oracles.  On non-TPU backends the kernels execute in interpret mode
(Python evaluation of the kernel body), which the test suite uses for
shape/dtype sweeps against the oracles.

The HyperX paper itself has no kernel-level contribution (its layer is
resource allocation); these kernels serve the framework's model stack per
the scope note in DESIGN.md.
"""
