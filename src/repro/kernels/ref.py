"""Pure-jnp oracles for the Pallas kernels (the correctness contract)."""

from __future__ import annotations

import math

import jax
import jax.numpy as jnp


def attention_ref(q, k, v, causal=True, window=0):
    """Direct softmax attention.  q: (B,H,S,D); k/v: (B,KV,T,D)."""
    B, H, S, D = q.shape
    KV, T = k.shape[1], k.shape[2]
    rep = H // KV
    kf = jnp.repeat(k, rep, axis=1).astype(jnp.float32)
    vf = jnp.repeat(v, rep, axis=1).astype(jnp.float32)
    logits = jnp.einsum("bhsd,bhtd->bhst", q.astype(jnp.float32), kf)
    logits = logits / math.sqrt(D)
    qpos = jnp.arange(S)[:, None]
    kpos = jnp.arange(T)[None, :]
    mask = jnp.ones((S, T), bool)
    if causal:
        mask &= kpos <= qpos
    if window:
        mask &= kpos > qpos - window
    logits = jnp.where(mask[None, None], logits, -1e30)
    p = jax.nn.softmax(logits, axis=-1)
    return jnp.einsum("bhst,bhtd->bhsd", p, vf).astype(q.dtype)


def ssd_ref(x, dt, A, Bm, Cm):
    """Sequential SSM recurrence — the exact semantics SSD must reproduce.

    x: (B,S,H,P); dt: (B,S,H); A: (H,); Bm/Cm: (B,S,N).
    h_t = exp(dt_t A) h_{t-1} + dt_t B_t x_t ;  y_t = C_t . h_t
    Returns (y: (B,S,H,P), state: (B,H,P,N)).
    """
    B, S, H, P = x.shape
    N = Bm.shape[-1]
    xf = x.astype(jnp.float32)
    dtf = dt.astype(jnp.float32)
    Af = A.astype(jnp.float32)

    def step(h, t):
        dA = jnp.exp(dtf[:, t] * Af[None, :])             # (B,H)
        dBx = jnp.einsum("bn,bhp,bh->bhpn", Bm[:, t].astype(jnp.float32),
                         xf[:, t], dtf[:, t])
        h = h * dA[..., None, None] + dBx
        y = jnp.einsum("bn,bhpn->bhp", Cm[:, t].astype(jnp.float32), h)
        return h, y

    h0 = jnp.zeros((B, H, P, N), jnp.float32)
    h, ys = jax.lax.scan(step, h0, jnp.arange(S))
    y = jnp.moveaxis(ys, 0, 1).astype(x.dtype)            # (B,S,H,P)
    return y, h
