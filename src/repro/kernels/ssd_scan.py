"""Mamba-2 SSD chunked scan — Pallas TPU kernel.

One program instance processes one (batch, head, chunk) tile:

    y_diag = (C B^T ∘ decay) · (dt x)          intra-chunk, MXU matmuls
    y_off  = (C h_in^T) ∘ exp(cum)             incoming-state contribution
    h_out  = h_in * exp(cum[-1]) + B^T · ((dt x) ∘ decay_states)

The chunk grid dimension is innermost and sequential; the (P, N) state
lives in VMEM scratch and carries across chunks — the TPU-native
re-expression of the CUDA kernel's inter-block state passing.  All
matmul operands are padded by the wrapper to MXU-aligned sizes
(chunk, P, N multiples of 128 where it matters).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

# jax < 0.5 names the TPU compiler-params dataclass TPUCompilerParams.
_CompilerParams = getattr(pltpu, "CompilerParams", None) or pltpu.TPUCompilerParams


def _kernel(x_ref, dt_ref, a_ref, b_ref, c_ref, y_ref, st_ref, state_ref, *,
            nc, L):
    ic = pl.program_id(2)

    @pl.when(ic == 0)
    def _init():
        state_ref[...] = jnp.zeros_like(state_ref)

    x = x_ref[0, 0].astype(jnp.float32)            # (L, P)
    dt = dt_ref[0, 0].astype(jnp.float32)          # (L,)
    A = a_ref[0].astype(jnp.float32)               # scalar
    Bm = b_ref[0].astype(jnp.float32)              # (L, N)
    Cm = c_ref[0].astype(jnp.float32)              # (L, N)

    dA = dt * A                                    # (L,) log-decay, <= 0
    cum = jnp.cumsum(dA)                           # (L,)
    seg = cum[:, None] - cum[None, :]              # (L, L)
    tri = jax.lax.broadcasted_iota(jnp.int32, (L, L), 0) >= \
        jax.lax.broadcasted_iota(jnp.int32, (L, L), 1)
    decay = jnp.where(tri, jnp.exp(seg), 0.0)      # (L, L)

    xd = x * dt[:, None]                           # (L, P) discretized
    scores = jax.lax.dot_general(
        Cm, Bm, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
    )                                              # (L, L)
    y = jax.lax.dot_general(
        scores * decay, xd, (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32,
    )                                              # (L, P)

    h_in = state_ref[...]                          # (N, P)
    y_off = jax.lax.dot_general(
        Cm, h_in, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32
    ) * jnp.exp(cum)[:, None]                      # (L, P)
    y_ref[0, 0] = (y + y_off).astype(y_ref.dtype)

    decay_states = jnp.exp(cum[-1] - cum)          # (L,)
    h_new = h_in * jnp.exp(cum[-1]) + jax.lax.dot_general(
        Bm, xd * decay_states[:, None], (((0,), (0,)), ((), ())),
        preferred_element_type=jnp.float32,
    )                                              # (N, P)
    state_ref[...] = h_new

    @pl.when(ic == nc - 1)
    def _emit_state():
        st_ref[0, 0] = h_new.astype(st_ref.dtype)


@functools.partial(jax.jit, static_argnames=("chunk", "interpret"))
def ssd_scan(x, dt, A, Bm, Cm, chunk=128, interpret=None):
    """SSD over one sequence.

    x: (B, S, H, P); dt: (B, S, H); A: (H,); Bm/Cm: (B, S, N).
    Returns (y: (B, S, H, P), state: (B, H, N, P)).  S % chunk == 0.
    """
    B, S, H, P = x.shape
    N = Bm.shape[-1]
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    L = chunk
    nc = S // L
    xt = x.transpose(0, 2, 1, 3)                   # (B, H, S, P)
    dtt = dt.transpose(0, 2, 1)                    # (B, H, S)

    kernel = functools.partial(_kernel, nc=nc, L=L)
    y, st = pl.pallas_call(
        kernel,
        grid=(B, H, nc),
        in_specs=[
            pl.BlockSpec((1, 1, L, P), lambda b, h, c: (b, h, c, 0)),
            pl.BlockSpec((1, 1, L), lambda b, h, c: (b, h, c)),
            pl.BlockSpec((1,), lambda b, h, c: (h,)),
            pl.BlockSpec((1, L, N), lambda b, h, c: (b, c, 0)),
            pl.BlockSpec((1, L, N), lambda b, h, c: (b, c, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, 1, L, P), lambda b, h, c: (b, h, c, 0)),
            pl.BlockSpec((1, 1, N, P), lambda b, h, c: (b, h, 0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((B, H, S, P), x.dtype),
            jax.ShapeDtypeStruct((B, H, N, P), jnp.float32),
        ],
        scratch_shapes=[pltpu.VMEM((N, P), jnp.float32)],
        compiler_params=_CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary"),
        ),
        interpret=interpret,
        name="ssd_scan",
    )(xt, dtt, A, Bm, Cm)
    return y.transpose(0, 2, 1, 3), st
