"""jit wrapper adapting the model's SSM layout to the SSD kernel."""

from __future__ import annotations

import jax.numpy as jnp

from repro.kernels.ssd_scan import ssd_scan


def ssd(x, dt, A, Bm, Cm, chunk=128):
    """models/ssm layout entry point.

    x: (B,S,H,P); dt: (B,S,H); A: (H,); Bm/Cm: (B,S,N).
    Returns (y: (B,S,H,P), state: (B,H,P,N)) matching ssm.ssd_chunked.
    """
    S = x.shape[1]
    L = min(chunk, S)
    pad = (-S) % L
    if pad:
        # zero dt on padded steps => decay 1, zero input: state unchanged
        x = jnp.pad(x, ((0, 0), (0, pad), (0, 0), (0, 0)))
        dt = jnp.pad(dt, ((0, 0), (0, pad), (0, 0)))
        Bm = jnp.pad(Bm, ((0, 0), (0, pad), (0, 0)))
        Cm = jnp.pad(Cm, ((0, 0), (0, pad), (0, 0)))
    y, st = ssd_scan(x, dt, A, Bm, Cm, chunk=L)
    if pad:
        y = y[:, :S]
    return y, st.transpose(0, 1, 3, 2)  # (B,H,N,P) -> (B,H,P,N)
