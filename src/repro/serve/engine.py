"""Batched serving engine: prefill + jitted greedy decode loop.

The engine owns jitted ``prefill`` and ``decode_step`` closures; requests
are served in fixed-size batches (padding short prompts left-aligned is
omitted — synthetic prompts are equal length, as in the dry-run shapes).
``decode_32k`` / ``long_500k`` cells lower exactly ``engine.decode_fn``.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.models.config import ArchConfig
from repro.models import transformer as M


class ServeEngine:
    def __init__(self, cfg: ArchConfig, params, max_len: int = 2048):
        if cfg.encoder_only:
            raise ValueError(f"{cfg.name} is encoder-only; no decode serving")
        self.cfg = cfg
        self.params = params
        self.max_len = max_len
        self.prefill_fn = jax.jit(
            functools.partial(M.prefill, cfg, max_len=max_len)
        )
        self.decode_fn = jax.jit(functools.partial(M.decode_step, cfg))

    def generate(self, batch: dict, steps: int, greedy: bool = True, seed: int = 0):
        """Generate ``steps`` tokens for a batch of equal-length prompts."""
        prompts = batch["tokens"]
        B, S = prompts.shape
        assert S + steps <= self.max_len
        logits, caches = self.prefill_fn(self.params, batch)
        key = jax.random.PRNGKey(seed)
        out = []
        tok = None
        for t in range(steps):
            if greedy:
                tok = jnp.argmax(logits[:, -1], axis=-1)[:, None].astype(jnp.int32)
            else:
                key, sub = jax.random.split(key)
                tok = jax.random.categorical(sub, logits[:, -1])[:, None].astype(
                    jnp.int32
                )
            out.append(tok)
            logits, caches = self.decode_fn(self.params, tok, caches, S + t)
        return jnp.concatenate(out, axis=1)
