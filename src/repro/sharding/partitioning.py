"""Logical-axis sharding: ParamSpec axes -> mesh PartitionSpecs.

Rule sets map logical axis names (what model code declares) onto mesh axis
names (what the launcher builds).  Two standard sets:

  * ``base``  — DP over (pod, data); TP over model (heads / ff / experts /
    vocab).  Parameters replicated across DP.
  * ``fsdp``  — additionally shards parameters and optimizer state over
    ``data`` along the embed dimension (ZeRO-3 style); XLA turns the
    gradient all-reduce into reduce-scatter + all-gather pairs.

Activation sharding constraints are applied through :func:`constraint`,
which consults a context-local mesh set by :func:`activation_mesh` — model
code stays mesh-agnostic and runs unchanged without any mesh (smoke tests).
"""

from __future__ import annotations

import contextlib
import threading

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.models.module import ParamSpec, is_spec

# logical axis -> mesh axis (None = replicated)
_BASE = {
    "batch": ("pod", "data"),
    "vocab": "model",
    "embed": None,
    "ff": "model",
    "heads": "model",
    "kv_heads": "model",
    "head_dim": None,
    "experts": "model",
    "moe_group": ("pod", "data"),
    "seq": None,
    "layers": None,
    "q_lora": None,
    "kv_lora": None,
    "rope_dim": None,
    "ssm_in": "model",
    "ssm_conv": "model",
    "ssm_inner": "model",
    "ssm_heads": "model",
    "lru": "model",
    "lru2": None,
    "cache_batch": ("pod", "data"),
    "cache_len": None,
    # sequence-parallel alternative for very long contexts
    "seq_sp": "model",
}

_FSDP = dict(_BASE)
_FSDP.update({"embed": "data"})

RULE_SETS = {"base": _BASE, "fsdp": _FSDP}


def logical_to_pspec(axes, rules, mesh_axes, shape=None, mesh_sizes=None) -> P:
    """Map logical axis names to a PartitionSpec on this mesh.

    When ``shape``/``mesh_sizes`` are given, a mesh axis is only assigned
    to a dimension it divides (e.g. kv_heads=8 stays replicated on a
    model=16 mesh instead of failing at lowering).
    """
    parts = []
    used = set()
    for i, ax in enumerate(axes):
        m = rules.get(ax) if ax is not None else None
        if m is None:
            parts.append(None)
            continue
        cand = m if isinstance(m, tuple) else (m,)
        sel = []
        prod = 1
        for c in cand:
            if c not in mesh_axes or c in used:
                continue
            if shape is not None and mesh_sizes is not None:
                if shape[i] % (prod * mesh_sizes[c]) != 0:
                    continue
            sel.append(c)
            prod *= mesh_sizes[c] if mesh_sizes else 1
        if not sel:
            parts.append(None)
        elif len(sel) == 1:
            parts.append(sel[0])
            used.add(sel[0])
        else:
            parts.append(tuple(sel))
            used.update(sel)
    return P(*parts)


def tree_shardings(specs, mesh: Mesh, rule_set: str = "base"):
    """NamedSharding pytree for a ParamSpec tree (divisibility-aware)."""
    rules = RULE_SETS[rule_set]
    mesh_axes = set(mesh.axis_names)
    sizes = dict(zip(mesh.axis_names, mesh.devices.shape))

    def one(s: ParamSpec):
        return NamedSharding(
            mesh, logical_to_pspec(s.axes, rules, mesh_axes, s.shape, sizes)
        )

    return jax.tree_util.tree_map(one, specs, is_leaf=is_spec)


# ----------------------------------------------------------- activations
_ctx = threading.local()
_gather = threading.local()


@contextlib.contextmanager
def weight_gather(rule_set: str = "base"):
    """Force per-layer weights to this rule set at USE time.

    With FSDP-stored parameters, constraining the layer's weight slice to
    the TP-only ('base') sharding inside the scan body makes GSPMD
    all-gather the (small) weights once per layer instead of all-reducing
    the (large) activation partial sums the data-sharded contraction would
    otherwise produce (EXPERIMENTS.md §Perf iteration 5)."""
    prev = getattr(_gather, "rs", None)
    _gather.rs = rule_set
    try:
        yield
    finally:
        _gather.rs = prev


def gather_rule_set():
    return getattr(_gather, "rs", None)


def constrain_params_by_specs(specs_tree, params_tree, rule_set: str):
    """Apply per-leaf logical-axis constraints to a parameter subtree."""
    state = getattr(_ctx, "state", None)
    if state is None:
        return params_tree
    mesh, _ = state
    rules = RULE_SETS[rule_set]
    mesh_axes = set(mesh.axis_names)
    sizes = dict(zip(mesh.axis_names, mesh.devices.shape))

    def one(s, v):
        pspec = logical_to_pspec(s.axes, rules, mesh_axes, v.shape, sizes)
        return jax.lax.with_sharding_constraint(v, NamedSharding(mesh, pspec))

    return jax.tree_util.tree_map(one, specs_tree, params_tree, is_leaf=is_spec)


@contextlib.contextmanager
def activation_mesh(mesh: Mesh | None, rule_set: str = "base"):
    """Enable activation sharding constraints inside model forwards."""
    prev = getattr(_ctx, "state", None)
    _ctx.state = (mesh, RULE_SETS[rule_set]) if mesh is not None else None
    try:
        yield
    finally:
        _ctx.state = prev


def constraint(x, *axes):
    """with_sharding_constraint by logical axes; no-op without a mesh."""
    state = getattr(_ctx, "state", None)
    if state is None:
        return x
    mesh, rules = state
    sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
    pspec = logical_to_pspec(
        axes, rules, set(mesh.axis_names), x.shape, sizes
    )
    return jax.lax.with_sharding_constraint(x, NamedSharding(mesh, pspec))
