from repro.sharding.partitioning import (  # noqa: F401
    RULE_SETS,
    activation_mesh,
    constraint,
    logical_to_pspec,
    tree_shardings,
)
