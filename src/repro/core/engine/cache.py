"""Persistent XLA compilation cache wiring (compile amortization).

Every engine configuration compiles its cycle loop once per process; for
grid sweeps driven from short-lived processes (benchmarks, CI smokes,
fleet workers) that first compile dominates wall time.  Pointing jax's
persistent compilation cache at a directory makes the *second process*
start from the serialized executable instead of recompiling:

    REPRO_COMPILE_CACHE=/path/to/cache python -m benchmarks.perf ...

or programmatically::

    from repro.core.engine import enable_persistent_cache
    enable_persistent_cache("/path/to/cache")

:class:`~repro.core.engine.runner.SimEngine` calls
:func:`enable_persistent_cache` (no arguments — environment-gated) at
construction, so any engine consumer opts in with the env var alone.
The thresholds are dropped to zero so even the small single-scenario
executables are cached: the engine's compiles are keyed on shape
buckets, so the cache stays small (one entry per bucket, not per
workload), and lane canonicalization (``SimEngine(canon=True)``) keeps
nearby grid sizes on the same entries.
"""

from __future__ import annotations

import os

import jax

ENV_VAR = "REPRO_COMPILE_CACHE"

_configured: str | None = None


def enable_persistent_cache(path: str | os.PathLike | None = None) -> str | None:
    """Point jax's persistent compilation cache at ``path`` (idempotent).

    ``path=None`` reads the ``REPRO_COMPILE_CACHE`` environment variable
    and silently no-ops when it is unset — the default-off contract every
    engine constructor relies on.  Returns the configured directory (or
    ``None`` when the cache stays off).  Re-pointing an already-configured
    process at a *different* directory raises: jax's cache config is
    process-global and executables already serialized to the old
    directory would silently stop being reused.
    """
    global _configured
    if path is None:
        path = os.environ.get(ENV_VAR) or None
    if path is None:
        return _configured
    path = str(path)
    if _configured is not None:
        if path != _configured:
            raise ValueError(
                f"persistent compile cache already configured at "
                f"{_configured!r}; refusing to re-point it at {path!r} "
                f"(jax cache config is process-global)"
            )
        return _configured
    jax.config.update("jax_compilation_cache_dir", path)
    # cache every executable, however small/fast the compile — the engine
    # keys on shape buckets, so entry count stays bounded by bucket count
    jax.config.update("jax_persistent_cache_min_entry_size_bytes", -1)
    jax.config.update("jax_persistent_cache_min_compile_time_secs", 0.0)
    _configured = path
    return _configured


def cache_dir() -> str | None:
    """The configured persistent-cache directory, or ``None`` when off."""
    return _configured
