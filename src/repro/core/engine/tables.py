"""Static compile-time structure of the simulator.

Everything here depends only on the *configuration* of a simulation — the
topology, routing mode, VC-pool count, deroute budget, and queue capacity —
never on the workload.  The tables are baked into the jit closure as trace
constants (they are genuinely constant across a sweep), while everything
per-workload lives in :mod:`repro.core.engine.workload_tables` and is passed
to the compiled step function as device *arguments*.

``build_static_tables`` is memoised on its full key, so every simulator /
engine construction for the same ``(topo, mode, P, m, cap, penalty)``
configuration shares one table set — and therefore one XLA compilation of
the step function.
"""

from __future__ import annotations

import functools
from typing import NamedTuple

import jax.numpy as jnp
import numpy as np

from repro.core.hyperx import HyperX

I32 = jnp.int32


class StaticTables(NamedTuple):
    """Topology / port / VC constant tables + static dimensions.

    Shapes (S switches, E endpoints, IN=OUT ports/switch, P pools, V VCs):
      coords          (S, q)     switch coordinates
      nbr             (S, q*n)   neighbour switch per network port
      in_port_at_nb   (S, q*n)   arrival port at that neighbour
      port_dim/val    (q*n,)     dimension / value addressed by each port
      h_pool, h_sw    (H,)       queue-head index decomposition (H == NQ)
      inj_base        (E,)       injection queue base index (pool 0, VC 0)
    """

    # dimensions (Python ints — static under jit)
    n: int
    q: int
    conc: int
    S: int
    E: int
    IN: int
    OUT: int
    P: int
    V: int
    NQ: int
    H: int
    CAP: int
    m: int            # deroute budget
    PEN: int          # deroute penalty on the cost scale
    use_min: bool
    # device constant tables
    coords: jnp.ndarray
    nbr: jnp.ndarray
    in_port_at_nb: jnp.ndarray
    port_dim: jnp.ndarray
    port_val: jnp.ndarray
    h_pool: jnp.ndarray
    h_sw: jnp.ndarray
    inj_base: jnp.ndarray


@functools.lru_cache(maxsize=None)
def build_static_tables(
    topo: HyperX,
    mode: str = "omniwar",
    num_pools: int = 1,
    max_deroutes: int | None = None,
    cap: int = 8,
    penalty_packets: int = 4,
) -> StaticTables:
    """Construct (and cache) the constant tables for one configuration."""
    if mode not in ("min", "omniwar"):
        raise ValueError(f"unknown routing mode {mode!r}")
    n, q, conc = topo.n, topo.q, topo.concentration
    S = topo.num_switches
    E = topo.num_endpoints
    IN = q * n + conc          # network input ports (dense dim*val) + injection
    OUT = q * n + conc         # network output ports + ejection per offset
    P = num_pools
    m = q if max_deroutes is None else max_deroutes
    V = q + m + 1              # hop-indexed VCs (deadlock freedom)
    NQ = S * IN * P * V
    H = NQ                     # one potential head per queue

    coords_np = topo.all_switch_coords()                       # (S, q)
    nbr = np.empty((S, q * n), dtype=np.int32)                 # dst switch
    in_port_at_nb = np.empty((S, q * n), dtype=np.int32)       # arrival port
    for d in range(q):
        for v in range(n):
            nc = coords_np.copy()
            nc[:, d] = v
            ids = np.zeros(S, dtype=np.int64)
            for d2 in range(q):
                ids = ids * n + nc[:, d2]
            nbr[:, d * n + v] = ids
            in_port_at_nb[:, d * n + v] = d * n + coords_np[:, d]

    h_idx = np.arange(H, dtype=np.int64)
    h_pool = jnp.asarray((h_idx // V) % P, dtype=I32)
    h_sw = jnp.asarray(h_idx // (V * P * IN), dtype=I32)

    # endpoint -> injection queue (pool of its rank added at runtime, VC 0)
    e_ids = np.arange(E)
    e_sw = e_ids // conc
    e_port = q * n + (e_ids % conc)
    inj_base = jnp.asarray(((e_sw * IN + e_port) * P) * V, dtype=I32)

    return StaticTables(
        n=n, q=q, conc=conc, S=S, E=E, IN=IN, OUT=OUT, P=P, V=V,
        NQ=NQ, H=H, CAP=cap, m=m,
        PEN=penalty_packets * 8,  # cost scale: occupancy*8 + jitter(3 bits)
        use_min=mode == "min",
        coords=jnp.asarray(coords_np, dtype=I32),
        nbr=jnp.asarray(nbr),
        in_port_at_nb=jnp.asarray(in_port_at_nb),
        port_dim=jnp.asarray(np.arange(q * n) // n, dtype=I32),
        port_val=jnp.asarray(np.arange(q * n) % n, dtype=I32),
        h_pool=h_pool,
        h_sw=h_sw,
        inj_base=inj_base,
    )
