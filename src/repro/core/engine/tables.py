"""Static compile-time structure of the simulator.

Everything here depends only on the *configuration* of a simulation — the
topology, routing policy, VC-pool count, deroute budget, and queue capacity
— never on the workload.  The tables are baked into the jit closure as
trace constants (they are genuinely constant across a sweep), while
everything per-workload lives in :mod:`repro.core.engine.workload_tables`
(including link-fault masks and Valiant intermediate pools) and is passed
to the compiled step function as device *arguments*.

The routing ``mode`` string resolves through the :mod:`repro.route`
registry: the policy declares its hop-indexed VC budget (which sizes the
queue space — deadlock freedom) and the static predicates the step kernel
specializes on.  Unknown modes raise with the registered policy names.

``build_static_tables`` is memoised on its full key, so every simulator /
engine construction for the same ``(topo, mode, P, m, cap, penalty)``
configuration shares one table set — and therefore one XLA compilation of
the step function.
"""

from __future__ import annotations

import functools
from typing import NamedTuple

import jax.numpy as jnp
import numpy as np

from repro.core.engine.packing import pack
from repro.core.hyperx import HyperX
from repro.route import get_policy, neighbor_tables, port_layout

I32 = jnp.int32


class StaticTables(NamedTuple):
    """Topology / port / VC constant tables + static dimensions.

    Shapes (S switches, E endpoints, IN=OUT ports/switch, P pools, V VCs):
      coords          (S, q)     switch coordinates
      nbr             (S, q*n)   neighbour switch per network port
      in_port_at_nb   (S, q*n)   arrival port at that neighbour
      port_dim/val    (q*n,)     dimension / value addressed by each port
      h_pool, h_sw    (H,)       queue-head index decomposition (H == NQ)
      inj_base        (E,)       injection queue base index (pool 0, VC 0)
      ep_sw           (E,)       switch hosting each endpoint
    """

    # dimensions (Python ints / strings — static under jit)
    n: int
    q: int
    conc: int
    S: int
    E: int
    IN: int
    OUT: int
    P: int
    V: int
    NQ: int
    H: int
    CAP: int
    m: int            # deroute budget
    PEN: int          # deroute penalty on the cost scale
    mode: str         # registered routing-policy name
    arb: str          # arbitration backend: "lax" scatter-min | "pallas"
    kernel: str       # route+arbitrate block: "lax" | "pallas" megakernel
    # device constant tables
    coords: jnp.ndarray
    nbr: jnp.ndarray
    in_port_at_nb: jnp.ndarray
    port_dim: jnp.ndarray
    port_val: jnp.ndarray
    h_pool: jnp.ndarray
    h_sw: jnp.ndarray
    inj_base: jnp.ndarray
    ep_sw: jnp.ndarray


@functools.lru_cache(maxsize=None)
def build_static_tables(
    topo: HyperX,
    mode: str = "omniwar",
    num_pools: int = 1,
    max_deroutes: int | None = None,
    cap: int = 8,
    penalty_packets: int = 4,
    arb: str = "lax",
    pack_tables: bool = True,
    kernel: str = "lax",
) -> StaticTables:
    """Construct (and cache) the constant tables for one configuration.

    ``arb`` selects the arbitration backend the step kernel is built with
    ("lax" scatter-min reference or the "pallas" per-switch kernel — bit
    identical, regression-pinned).  ``kernel`` selects the route+arbitrate
    block implementation: "lax" keeps the reference jnp path; "pallas"
    swaps in the fused per-switch megakernel (candidate masks, cost,
    argmin and both arbitration rounds in one ``pallas_call`` — bit
    identical, regression-pinned; subsumes ``arb`` for those rounds).
    ``pack_tables`` packs the small-range
    lookup tables to int8/int16 with topology-derived bounds (the step
    kernel widens to int32 at each gather); ``False`` keeps the int32
    reference layout for the packing parity tests.
    """
    policy = get_policy(mode)  # raises with registered names when unknown
    n, q, conc = topo.n, topo.q, topo.concentration
    S = topo.num_switches
    E = topo.num_endpoints
    IN = q * n + conc          # network input ports (dense dim*val) + injection
    OUT = q * n + conc         # network output ports + ejection per offset
    P = num_pools
    m = policy.default_deroutes(q) if max_deroutes is None else max_deroutes
    V = policy.vc_budget(q, m)  # hop-indexed VCs (deadlock freedom)
    NQ = S * IN * P * V
    H = NQ                     # one potential head per queue

    coords_np = topo.all_switch_coords()                       # (S, q)
    nbr, in_port_at_nb = neighbor_tables(coords_np, n, q)
    port_dim, port_val = port_layout(n, q)

    h_idx = np.arange(H, dtype=np.int64)
    h_pool_np = (h_idx // V) % P
    h_sw_np = h_idx // (V * P * IN)

    # endpoint -> injection queue (pool of its rank added at runtime, VC 0)
    e_ids = np.arange(E)
    e_sw = e_ids // conc
    e_port = q * n + (e_ids % conc)
    inj_base_np = ((e_sw * IN + e_port) * P) * V

    if pack_tables:
        # bounds are topology-derived (never data-derived): same config =>
        # same dtypes => one jit cache entry, regardless of workload values
        def lower(a, bound):
            return jnp.asarray(pack(a, bound))
    else:
        def lower(a, bound):
            return jnp.asarray(a, dtype=I32)

    return StaticTables(
        n=n, q=q, conc=conc, S=S, E=E, IN=IN, OUT=OUT, P=P, V=V,
        NQ=NQ, H=H, CAP=cap, m=m,
        PEN=penalty_packets * 8,  # cost scale: occupancy*8 + jitter(3 bits)
        mode=mode, arb=arb, kernel=kernel,
        coords=lower(coords_np, n - 1),
        nbr=lower(nbr, S - 1),
        in_port_at_nb=lower(in_port_at_nb, IN - 1),
        port_dim=lower(port_dim, q - 1),
        port_val=lower(port_val, n - 1),
        h_pool=lower(h_pool_np, P - 1),
        h_sw=lower(h_sw_np, S - 1),
        inj_base=lower(inj_base_np, NQ - 1),
        ep_sw=lower(e_sw, S - 1),
    )
