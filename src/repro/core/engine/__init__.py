"""Pytree-parameterized simulator engine (static structure vs workload data).

Public surface:

  * :class:`SimEngine` / :func:`get_engine` — compile-once, run-many
    execution with ``run`` / ``run_batch`` / ``run_seeds``;
  * :class:`WorkloadTables` / :func:`make_workload_tables` — per-workload
    device data as a padded pytree of jit arguments;
  * :func:`build_static_tables` — memoised topology/port/VC constants;
  * :class:`SimState`, :class:`SimResult` — simulation state & summary.

The legacy entry points ``build_simulator`` / ``simulate`` in
:mod:`repro.core.simulator` are thin facades over this package.
"""

from repro.core.engine.runner import (
    PACKET_FLITS,
    SimEngine,
    SimResult,
    get_engine,
)
from repro.core.engine.step import SimState, all_done, build_step, init_state
from repro.core.engine.tables import StaticTables, build_static_tables
from repro.core.engine.workload_tables import (
    PreparedWorkload,
    WorkloadTables,
    make_workload_tables,
    shape_bucket,
    stack_tables,
)

__all__ = [
    "PACKET_FLITS",
    "PreparedWorkload",
    "SimEngine",
    "SimResult",
    "SimState",
    "StaticTables",
    "WorkloadTables",
    "all_done",
    "build_static_tables",
    "build_step",
    "get_engine",
    "init_state",
    "make_workload_tables",
    "shape_bucket",
    "stack_tables",
]
