"""Pytree-parameterized simulator engine (static structure vs workload data).

Public surface:

  * :class:`SimEngine` / :func:`get_engine` — compile-once, run-many
    execution with ``run`` / ``run_batch`` / ``run_seeds`` and the
    device-sharded ``run_grid`` (lane axis over shard_map / pmap / vmap);
  * :class:`WorkloadTables` / :func:`make_workload_tables` — per-workload
    device data as a padded pytree of jit arguments (packed to
    int8/int16 by bucket-derived bounds; see :mod:`.packing`);
  * :func:`build_static_tables` — memoised topology/port/VC constants;
  * :mod:`.arb` — switch-arbitration backends (lax scatter-min
    reference and the bit-exact per-switch Pallas kernel);
  * :class:`SimState`, :class:`SimResult` — simulation state & summary.

The legacy entry points ``build_simulator`` / ``simulate`` in
:mod:`repro.core.simulator` are thin facades over this package.
"""

from repro.core.engine.arb import arbitrate_lax, make_arbiter
from repro.core.engine.cache import cache_dir, enable_persistent_cache
from repro.core.engine.packing import pack, pack_dtype
from repro.core.engine.route_kernel import make_fused_router
from repro.core.engine.runner import (
    PACKET_FLITS,
    SimEngine,
    SimResult,
    default_lane_backend,
    get_engine,
)
from repro.core.engine.step import SimState, all_done, build_step, init_state
from repro.core.engine.tables import StaticTables, build_static_tables
from repro.core.engine.workload_tables import (
    PreparedWorkload,
    WorkloadTables,
    make_workload_tables,
    shape_bucket,
    stack_tables,
)

__all__ = [
    "PACKET_FLITS",
    "PreparedWorkload",
    "SimEngine",
    "SimResult",
    "SimState",
    "StaticTables",
    "WorkloadTables",
    "all_done",
    "arbitrate_lax",
    "build_static_tables",
    "build_step",
    "cache_dir",
    "default_lane_backend",
    "enable_persistent_cache",
    "get_engine",
    "init_state",
    "make_arbiter",
    "make_fused_router",
    "make_workload_tables",
    "pack",
    "pack_dtype",
    "shape_bucket",
    "stack_tables",
]
