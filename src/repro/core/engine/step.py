"""The cycle kernel: one packet-time of the whole machine.

``build_step`` closes over the configuration's :class:`StaticTables` (trace
constants) and returns a pure function ``step(state, wt)`` operating on the
``(SimState, WorkloadTables)`` pair.  Because every workload-dependent array
arrives through ``wt`` — a pytree argument, not a closure constant — the
compiled step is shared by all workloads whose tables land in the same shape
bucket, and the surrounding while-loop can be ``jax.vmap``-ed over stacked
tables.

Routing is policy-driven: the ``mode`` string in the static tables resolves
through the :mod:`repro.route` registry, and the policy's static predicates
(candidate-set shape, Valiant intermediates, UGAL injection) specialize the
kernel at trace time.  Per-workload fault masks (``wt.link_ok``) exclude
dead links from every candidate set; minimal-only policies escalate to
budget-bounded deroutes when all minimal ports of a switch are dead, which
keeps worst-case hops inside the policy's declared hop-indexed VC budget
(deadlock freedom under faults).  With an all-healthy mask, ``min`` and
``omniwar`` are bit-identical to the seed simulator (regression-pinned).

The physics is unchanged from the seed simulator (see DESIGN.md §6 for the
CAMINOS fidelity deviations): packet-time granularity, input-queued FIFOs
with hop-indexed VCs per pool, table-driven routing with an occupancy +
deroute-penalty cost, two-round random separable allocation with a 2x
internal speedup token bucket, and the step/dependency engine that walks
the Workload step tables.
"""

from __future__ import annotations

from typing import Callable, NamedTuple

import jax
import jax.numpy as jnp

from repro.core.engine.arb import make_arbiter
from repro.core.engine.route_kernel import make_fused_router
from repro.core.engine.tables import StaticTables
from repro.core.engine.workload_tables import WorkloadTables
from repro.obs.probes import TelemetrySpec, TelemetryState
from repro.route import get_policy

I32 = jnp.int32
U32 = jnp.uint32


class SimState(NamedTuple):
    t: jnp.ndarray            # () int32 — current packet-time
    key: jnp.ndarray          # PRNG key
    # queue field arrays, flat (NQ * CAP,)
    f_dst: jnp.ndarray        # destination endpoint id
    f_der: jnp.ndarray        # deroutes left
    f_hop: jnp.ndarray        # hops taken
    f_rank: jnp.ndarray       # source rank
    f_step: jnp.ndarray       # source step index
    f_birth: jnp.ndarray      # injection time
    f_imd: jnp.ndarray        # Valiant intermediate switch (S = none);
                              # shape (1,) for policies without intermediates
    qhead: jnp.ndarray        # (NQ,) ring head
    qlen: jnp.ndarray         # (NQ,) occupancy
    busy: jnp.ndarray         # (S*OUT,) output-buffer tokens (2x speedup)
    # per-rank step engine
    cur_step: jnp.ndarray     # (R,)
    dst_i: jnp.ndarray        # (R,)
    pkt_i: jnp.ndarray        # (R,)
    completed: jnp.ndarray    # (R,) first incomplete step pointer
    sent: jnp.ndarray         # ((R+1)*T,) delivered sends per (rank, step)
    got: jnp.ndarray          # ((R+1)*T,) received packets per (rank, step)
    # metrics
    lat_sum: jnp.ndarray      # () float32 sum of target packet latencies
    n_delivered: jnp.ndarray  # () target packets delivered
    n_injected: jnp.ndarray   # () packets injected (all sources)
    hop_sum: jnp.ndarray      # () network hops of delivered target packets
    hop_max: jnp.ndarray      # () max hops over ALL ejected packets (VC bound)
    # resilience counters (fault epochs; all cheap extra accumulation)
    esc_count: jnp.ndarray    # () escalation-granted moves (re-escalated pkts)
    epoch_delivered: jnp.ndarray  # (NE,) target deliveries per fault epoch
    epoch_injected: jnp.ndarray   # (NE,) injections per fault epoch


def init_state(st: StaticTables, wt: WorkloadTables, seed) -> SimState:
    """Fresh simulation state for one workload (R/T taken from ``wt``)."""
    R, T = wt.R, wt.T
    use_imd = get_policy(st.mode).uses_intermediate

    def z(n):
        return jnp.zeros(n, dtype=I32)

    return SimState(
        t=jnp.int32(0), key=jax.random.PRNGKey(seed),
        f_dst=z(st.NQ * st.CAP), f_der=z(st.NQ * st.CAP),
        f_hop=z(st.NQ * st.CAP), f_rank=z(st.NQ * st.CAP),
        f_step=z(st.NQ * st.CAP), f_birth=z(st.NQ * st.CAP),
        f_imd=z(st.NQ * st.CAP) if use_imd else z(1),
        qhead=z(st.NQ), qlen=z(st.NQ), busy=z(st.S * st.OUT),
        cur_step=z(R), dst_i=z(R), pkt_i=z(R), completed=z(R),
        sent=z((R + 1) * T), got=z((R + 1) * T),
        lat_sum=jnp.float32(0.0),
        n_delivered=jnp.int32(0), n_injected=jnp.int32(0),
        hop_sum=jnp.int32(0), hop_max=jnp.int32(0),
        esc_count=jnp.int32(0),
        epoch_delivered=z(wt.NE), epoch_injected=z(wt.NE),
    )


def all_done(wt: WorkloadTables, state: SimState) -> jnp.ndarray:
    """All finite (target) ranks have completed their real steps."""
    return jnp.all(jnp.where(wt.finite, state.completed >= wt.n_steps, True))


def build_step(
    st: StaticTables,
    telemetry: TelemetrySpec | None = None,
) -> Callable[[SimState, WorkloadTables], SimState]:
    """Return the cycle kernel for one static configuration.

    With ``telemetry=None`` (the default) the kernel is byte-for-byte the
    pre-telemetry step: ``step(state, wt) -> state``.  With a
    :class:`~repro.obs.probes.TelemetrySpec` the kernel operates on a
    ``(SimState, TelemetryState)`` carry and additionally accumulates the
    spec's windowed probes from the cycle's internal signals — grant
    counts per output port, queue-occupancy samples, deroute/escalation
    grants, and delivery latencies.  The probe updates are pure extra
    scatters appended after the physics; the simulated trajectory is
    bit-identical either way (pinned in ``tests/test_obs.py``).
    """
    S, E, IN, OUT = st.S, st.E, st.IN, st.OUT
    P, V, NQ, H, CAP = st.P, st.V, st.NQ, st.H, st.CAP
    q, n, conc, m, PEN = st.q, st.n, st.conc, st.m, st.PEN
    policy = get_policy(st.mode)
    use_imd = policy.uses_intermediate
    coords, nbr, in_port_at_nb = st.coords, st.nbr, st.in_port_at_nb
    port_dim, port_val = st.port_dim, st.port_val
    h_pool, ep_sw = st.h_pool, st.ep_sw
    # tables may be packed to int8/int16 (bounds in tables.py); every value
    # that enters index arithmetic is widened to int32 exactly once — head
    # constants here (trace-time, folded), workload tables at their gather
    h_sw = st.h_sw.astype(I32)
    inj_base = st.inj_base.astype(I32)
    # per-round arbitration primitive: "lax" scatter-min or "pallas"
    # per-switch kernel (bit-exact — see repro.core.engine.arb)
    arbitrate = make_arbiter(st.S, st.OUT, st.H, st.arb)
    # fused route+arbitrate megakernel: kernel="pallas" replaces the whole
    # candidate/cost/argmin/two-round block with one per-switch pallas_call
    # (bit-exact — see repro.core.engine.route_kernel); the arb backend is
    # subsumed, since both rounds live inside the fused kernel
    fused_route = make_fused_router(st) if st.kernel == "pallas" else None
    BIGCOST = jnp.int32(1 << 28)
    OOB = jnp.int32(NQ * CAP + 5)  # safely out of bounds => dropped scatters
    NOMID = jnp.int32(S)           # f_imd sentinel: no (remaining) intermediate
    spec = telemetry

    def step(carry, wt: WorkloadTables):
        if spec is None:
            state: SimState = carry
        else:
            state, tel = carry
        R, T = wt.R, wt.T
        MAXD = wt.D
        t = state.t
        # fault epochs: select the mask (and its derived pool/reserve data)
        # active at cycle t.  NE is a *shape*, so this branch resolves at
        # trace time: the NE == 1 constant slice is the static-fault path,
        # bit-identical to the pre-epoch kernel (trace-counter-pinned);
        # NE > 1 pays exactly one gather on the epoch index per cycle.
        NE = wt.NE
        if NE == 1:
            ei = jnp.int32(0)
            link_ok_t = wt.link_ok[0]
            mid_pool_t = wt.mid_pool[0]
            n_mid_t = wt.n_mid[0]
            n_dead_t = wt.n_dead[0]
        else:
            ei = (jnp.sum(t >= wt.epoch_start.astype(I32)) - 1).astype(I32)
            link_ok_t = wt.link_ok[ei]
            mid_pool_t = wt.mid_pool[ei]
            n_mid_t = wt.n_mid[ei]
            n_dead_t = wt.n_dead[ei]
        key = jax.random.fold_in(state.key, t)
        # policies without intermediates split 3 keys exactly like the seed
        # engine, preserving bit-identical min/omniwar trajectories
        if use_imd:
            k_arb, k_jit, k_smp, k_mid = jax.random.split(key, 4)
        else:
            k_arb, k_jit, k_smp = jax.random.split(key, 3)

        qlen, qhead = state.qlen, state.qhead
        # per-(switch, in-port) total occupancy (packets over all pools+VCs):
        # the adaptive-routing congestion signal (CAMINOS counts phits in the
        # whole input buffer; penalty/range ratio ~1/8 is preserved).
        port_occ = qlen.reshape(S * IN, P * V).sum(axis=1)

        # ---------------- heads --------------------------------------------
        exists = qlen > 0                                   # (H,)
        slot = jnp.arange(H, dtype=I32) * CAP + qhead
        dst = state.f_dst[slot]
        der = state.f_der[slot]
        hop = state.f_hop[slot]
        dsw = dst // conc
        dof = dst % conc

        cur = h_sw
        at_dst = cur == dsw

        # Valiant phase 1 routes toward the packet's intermediate switch;
        # reaching it (or the final destination early) flips to phase 2.
        if use_imd:
            imd = state.f_imd[slot]
            in_phase1 = (imd < S) & (imd != cur) & ~at_dst
            route_dsw = jnp.where(in_phase1, imd, dsw)
        else:
            route_dsw = dsw

        # shared pre-kernel signals: the RNG draws must come off the host
        # key stream identically on both kernel paths (bit-exactness)
        busy_dec = jnp.maximum(state.busy - 1, 0)           # link served 1 pkt
        vcn = jnp.minimum(hop + 1, V - 1)                   # (H,) next VC
        jitter = jax.random.randint(k_jit, (H, q * n), 0, 8, dtype=I32)
        arb_key = jax.random.bits(k_arb, (H,), dtype=U32) >> 17  # 15 bits
        packed = (arb_key << 17) | jnp.arange(H, dtype=U32)

        def route_arbitrate_lax():
            # ---------- routing: candidate network ports (lax path) --------
            ccur = coords[cur]                              # (H, q)
            cdst = coords[route_dsw]                        # (H, q)
            pv = port_val[None, :]                          # (1, q*n)
            cur_d = ccur[:, port_dim]                       # (H, q*n)
            dst_d = cdst[:, port_dim]
            unaligned = cur_d != dst_d                      # (H, q*n)
            not_self = pv != cur_d
            is_min = (pv == dst_d) & unaligned
            healthy = link_ok_t[cur]                        # (H, q*n) faults
            nb = nbr[cur].astype(I32)                       # (H, q*n)
            ipnb = in_port_at_nb[cur].astype(I32)           # (H, q*n)
            qi_down = ((nb * IN + ipnb) * P + h_pool[:, None]) * V + vcn[:, None]
            room = qlen[qi_down] < CAP                      # own queue has space
            occ = port_occ[nb * IN + ipnb]                  # congestion signal
            avail_net = busy_dec[
                cur[:, None] * OUT + jnp.arange(q * n)[None, :]
            ] < 2
            if policy.adaptive_deroutes:
                # Omni-WAR: deroutes in any unaligned dimension while budget
                # lasts; dead links drop out of the candidate set.  Under
                # faults, voluntary deroutes must keep a *reserve* (one unit
                # per dead cable) so the budget can't be spent before a
                # forced escape is needed — a packet stranded at a dead
                # minimal link with der == 0 would wait forever.  The cap at
                # m - 1 keeps one voluntary deroute alive at any fault count
                # (a full-budget reserve would silently collapse omniwar
                # into min-with-escalation machine-wide); the escalation
                # term covers forced escapes below the reserve, exactly
                # like the minimal-only policies.
                reserve = jnp.minimum(n_dead_t, max(m - 1, 0))
                base = unaligned & not_self & healthy
                escalate = (
                    ~(is_min & healthy).any(axis=1, keepdims=True)
                    & base & (der[:, None] > 0)
                )
                legal = (
                    (base & (is_min | (der[:, None] > reserve)) | escalate)
                    & room & avail_net
                )
            else:
                # minimal-only (min / val / ugal): when every minimal port of
                # this switch is dead, escalate to budget-bounded deroutes so
                # packets can round the fault (hops stay inside the VC budget)
                is_min_h = is_min & healthy
                escalate = (
                    ~is_min_h.any(axis=1, keepdims=True)
                    & unaligned & not_self & healthy & (der[:, None] > 0)
                )
                legal = (is_min_h | escalate) & room & avail_net
            cost = occ * 8 + PEN * (~is_min) + jitter
            cost = jnp.where(legal, cost, BIGCOST)
            best = jnp.argmin(cost, axis=1).astype(I32)     # (H,)
            best_cost = jnp.take_along_axis(cost, best[:, None], 1)[:, 0]
            has_port = best_cost < BIGCOST
            best_min = jnp.take_along_axis(is_min, best[:, None], 1)[:, 0]

            out_port = jnp.where(at_dst, q * n + dof, best)
            requesting = exists & (at_dst | has_port)
            requesting = requesting & (busy_dec[cur * OUT + out_port] < 2)
            # NOTE: scatter/gather OOB markers must be POSITIVE out-of-range —
            # negative indices wrap NumPy-style in jnp .at[] even with
            # mode='drop'.
            OOB_OUT = jnp.int32(S * OUT + 1)
            req_out = jnp.where(requesting, cur * OUT + out_port, OOB_OUT)

            # --------- iterative random arbitration (2x internal speedup) --
            # Round 1: every head requests its best port; one random winner
            # per output.  Round 2 (separable-allocator iteration + the
            # paper's 2x crossbar speedup): losers re-route to their best
            # port that still has output tokens, enabling a second grant per
            # cycle per output.  The `busy` token bucket keeps sustained
            # link rate at 1 pkt/time.  Each round runs through the
            # configured arbiter backend (lax scatter-min or the per-switch
            # Pallas kernel — bit-exact).
            won1, g1 = arbitrate(req_out, packed)

            qi_best1 = jnp.take_along_axis(qi_down, best[:, None], 1)[:, 0]
            arr1 = jnp.zeros(NQ, dtype=I32).at[
                jnp.where(won1 & ~at_dst, qi_best1, NQ + 1)
            ].add(1, mode="drop")
            tokens = (2 - busy_dec) - g1                    # remaining slots

            loser = requesting & ~won1
            # re-route: best legal port with tokens left and downstream room
            # (accounting for the round-1 arrival into the same queue)
            tok_net = tokens[cur[:, None] * OUT + jnp.arange(q * n)[None, :]] > 0
            room_2 = qlen[qi_down] + arr1[qi_down] < CAP
            cost2 = jnp.where(legal & tok_net & room_2, cost, BIGCOST)
            best2 = jnp.argmin(cost2, axis=1).astype(I32)
            has2 = jnp.take_along_axis(cost2, best2[:, None], 1)[:, 0] < BIGCOST
            ej_ok = at_dst & (tokens[cur * OUT + q * n + dof] > 0)
            out2 = jnp.where(at_dst, q * n + dof, best2)
            req2 = loser & jnp.where(at_dst, ej_ok, has2)
            req_out2 = jnp.where(req2, cur * OUT + out2, OOB_OUT)
            won2, g2 = arbitrate(req_out2, packed)
            won = won1 | won2

            # final chosen queue / minimality per winner
            qi_best = jnp.where(
                won2,
                jnp.take_along_axis(
                    qi_down, jnp.minimum(best2, q * n - 1)[:, None], 1
                )[:, 0],
                qi_best1,
            )
            bmin = jnp.where(
                won2,
                jnp.take_along_axis(
                    is_min, jnp.minimum(best2, q * n - 1)[:, None], 1
                )[:, 0],
                best_min,
            )
            # per-winner escalation flag + round-1 arrival count into the
            # winner's queue (the only arr1 value downstream code needs)
            chosen = jnp.minimum(jnp.where(won2, best2, best), q * n - 1)
            esc_chosen = jnp.take_along_axis(escalate, chosen[:, None], 1)[:, 0]
            arr1_tgt = arr1[qi_best]
            return won, won2, qi_best, bmin, esc_chosen, arr1_tgt, g1, g2

        if fused_route is not None:
            # ---------- fused route+arbitrate megakernel (one pallas_call,
            # gridded per switch; candidate masks, cost, argmin and both
            # arbitration rounds stay VMEM-resident — bit-exact) ----------
            (won, won2, qi_best, best_min, esc_chosen, arr1_tgt, g1, g2) = (
                fused_route(
                    exists, at_dst, dof, der, vcn, route_dsw, link_ok_t,
                    n_dead_t, qlen, port_occ, busy_dec, jitter, packed,
                )
            )
        else:
            (won, won2, qi_best, best_min, esc_chosen, arr1_tgt, g1, g2) = (
                route_arbitrate_lax()
            )

        # output token update: +1 per grant (burst absorbed by 2x speedup)
        busy = busy_dec + g1 + g2

        # ---------------- dequeue winners ----------------------------------
        qhead = jnp.where(won, (qhead + 1) % CAP, qhead)
        dlen = jnp.zeros(NQ, dtype=I32).at[jnp.arange(H)].add(-won.astype(I32))

        # ---------------- deliveries (ejection winners) --------------------
        eject = won & at_dst
        rank = state.f_rank[slot]
        pstep = state.f_step[slot]
        src_finite = wt.finite[rank]
        # sender-side accounting row (infinite sources -> trash row R)
        send_row = jnp.where(src_finite, rank, R)
        OOB_RT = jnp.int32((R + 1) * T + 1)
        sent = state.sent.at[
            jnp.where(eject, send_row * T + pstep, OOB_RT)
        ].add(1, mode="drop")
        drank = wt.ep_rank[dst].astype(I32)
        drank_ok = (drank >= 0) & wt.finite[jnp.maximum(drank, 0)]
        recv_row = jnp.where(drank_ok, drank, R)
        got = state.got.at[
            jnp.where(eject, recv_row * T + pstep, OOB_RT)
        ].add(1, mode="drop")
        tgt_del = eject & src_finite
        lat_pkt = (t - state.f_birth[slot]).astype(jnp.float32)
        lat_add = jnp.sum(jnp.where(tgt_del, lat_pkt, 0.0))
        lat_sum = state.lat_sum + lat_add
        hop_sum = state.hop_sum + jnp.sum(jnp.where(tgt_del, hop, 0))
        n_delivered = state.n_delivered + jnp.sum(tgt_del)
        # every ejection bounds the VC invariant, background included
        hop_max = jnp.maximum(
            state.hop_max, jnp.max(jnp.where(eject, hop, 0))
        )

        # ---------------- network moves (enqueue downstream) ---------------
        net = won & ~at_dst
        # re-escalation accounting: moves granted through the forced
        # fault-escape candidate set (the port the winner took was only
        # legal because every minimal port was dead / reserve was spent)
        esc_count = state.esc_count + jnp.sum(net & esc_chosen)
        tgt_qi = qi_best
        # ring tail = head_pre + len_pre, invariant under same-cycle dequeue;
        # a round-2 arrival lands one slot behind the round-1 arrival.
        tgt_slot = (
            state.qhead[tgt_qi] + qlen[tgt_qi]
            + jnp.where(won2, arr1_tgt, 0)
        ) % CAP
        tgt_flat = jnp.where(net, tgt_qi * CAP + tgt_slot, OOB)
        f_dst = state.f_dst.at[tgt_flat].set(dst, mode="drop")
        f_der = state.f_der.at[tgt_flat].set(der - (~best_min), mode="drop")
        f_hop = state.f_hop.at[tgt_flat].set(hop + 1, mode="drop")
        f_rank = state.f_rank.at[tgt_flat].set(rank, mode="drop")
        f_step = state.f_step.at[tgt_flat].set(pstep, mode="drop")
        f_birth = state.f_birth.at[tgt_flat].set(state.f_birth[slot], mode="drop")
        if use_imd:
            # a packet leaving its intermediate switch enters phase 2
            f_imd = state.f_imd.at[tgt_flat].set(
                jnp.where(imd == cur, NOMID, imd), mode="drop"
            )
        else:
            f_imd = state.f_imd
        dlen = dlen.at[jnp.where(net, tgt_qi, NQ + 1)].add(1, mode="drop")

        # ---------------- step-engine: completion pointers ------------------
        # a rank is done after its *real* n_steps (padded steps never walked)
        completed = state.completed
        for _ in range(4):
            pidx = jnp.arange(R, dtype=I32) * T + jnp.minimum(completed, T - 1)
            comp = (completed >= wt.n_steps) | (
                (sent[pidx] >= wt.total_sends[pidx])
                & (got[pidx] >= wt.recv_need[pidx])
            )
            completed = completed + (
                wt.finite & (completed < wt.n_steps) & comp
            )

        # skip empty (padded) steps
        cs = state.cur_step
        cs_deg = wt.deg[jnp.arange(R), jnp.minimum(cs, T - 1)]
        cs = cs + (wt.finite & (cs < wt.n_steps) & (cs_deg == 0))

        # ---------------- injection ----------------------------------------
        r_of_e = wt.ep_rank.astype(I32)                     # (E,)
        r_safe = jnp.maximum(r_of_e, 0)
        e_fin = wt.finite[r_safe]
        e_cs = jnp.where(e_fin, cs[r_safe], 0)
        e_di = jnp.where(e_fin, state.dst_i[r_safe], 0)
        e_pk = jnp.where(e_fin, state.pkt_i[r_safe], 0)
        flat_td = jnp.minimum(e_cs, T - 1) * MAXD + e_di
        e_deg = wt.deg[r_safe, jnp.minimum(e_cs, T - 1)]
        e_np = wt.npkts[r_safe, flat_td]
        e_ns = wt.n_steps[r_safe]
        in_window = e_cs < jnp.minimum(e_ns, completed[r_safe] + wt.window[r_safe])
        has_work = jnp.where(
            e_fin, (e_cs < e_ns) & (e_di < e_deg) & in_window, True
        )
        has_work = has_work & (t >= wt.start_t[r_safe])
        inj_qi = inj_base + wt.pool[r_safe].astype(I32) * V
        has_room = qlen[inj_qi] + dlen[inj_qi] < CAP  # dlen: arrivals this cycle
        do_inj = (r_of_e >= 0) & has_work & has_room

        d_fixed = wt.sends_dst[r_safe, flat_td].astype(I32)
        rspan = jnp.maximum(wt.smp_hi[r_safe, flat_td] - wt.smp_lo[r_safe, flat_td], 1)
        rnd = jax.random.bits(k_smp, (E,), dtype=U32)
        d_smp = wt.smp_lo[r_safe, flat_td] + (rnd % rspan.astype(U32)).astype(I32)
        d_rank = jnp.where(wt.sampled[r_safe, flat_td], d_smp, d_fixed)
        d_rank = jnp.clip(d_rank, 0, R - 1)
        d_ep = wt.rank_ep[d_rank].astype(I32)

        inj_flat = jnp.where(
            do_inj, inj_qi * CAP + (state.qhead[inj_qi] + qlen[inj_qi]) % CAP,
            OOB,
        )
        f_dst = f_dst.at[inj_flat].set(d_ep, mode="drop")
        f_der = f_der.at[inj_flat].set(jnp.int32(m), mode="drop")
        f_hop = f_hop.at[inj_flat].set(0, mode="drop")
        f_rank = f_rank.at[inj_flat].set(r_safe, mode="drop")
        f_step = f_step.at[inj_flat].set(jnp.where(e_fin, e_cs, 0), mode="drop")
        f_birth = f_birth.at[inj_flat].set(t, mode="drop")
        if use_imd:
            # Valiant intermediate: one uniform draw per packet from the
            # healthy pool carried in the workload tables (mid_pool/n_mid
            # are device data — seeds and fault grids vmap, no retracing)
            rmid = jax.random.bits(k_mid, (E,), dtype=U32)
            span = jnp.maximum(n_mid_t, 1).astype(U32)
            mid = mid_pool_t[(rmid % span).astype(I32)].astype(I32)
            if policy.adaptive_injection:
                # UGAL-L: best minimal port vs best port toward the
                # sampled intermediate, weighted by path length, using
                # the same port_occ congestion signal as in-network cost
                csrc = coords[ep_sw]                        # (E, q)
                cde = coords[d_ep // conc]
                cme = coords[mid]
                src_d = csrc[:, port_dim]                   # (E, q*n)
                unal_d = src_d != cde[:, port_dim]
                unal_m = src_d != cme[:, port_dim]
                min_d = (port_val[None, :] == cde[:, port_dim]) & unal_d
                min_m = (port_val[None, :] == cme[:, port_dim]) & unal_m
                occ_e = port_occ[
                    nbr[ep_sw].astype(I32) * IN + in_port_at_nb[ep_sw]
                ]
                ok_e = link_ok_t[ep_sw]
                # a dead/empty candidate set prices as BIGOCC, small enough
                # that BIGOCC * h_val stays inside int32 for any q
                BIGOCC = jnp.int32(1 << 24)
                occ_min = jnp.min(
                    jnp.where(min_d & ok_e, occ_e, BIGOCC), axis=1
                )
                occ_val = jnp.min(
                    jnp.where(min_m & ok_e, occ_e, BIGOCC), axis=1
                )
                h_min = jnp.sum(csrc != cde, axis=1)
                h_val = (
                    jnp.sum(csrc != cme, axis=1)
                    + jnp.sum(cme != cde, axis=1)
                )
                take_val = occ_val * h_val < occ_min * h_min
                mid = jnp.where(take_val, mid, NOMID)
            f_imd = f_imd.at[inj_flat].set(mid, mode="drop")
        dlen = dlen.at[jnp.where(do_inj, inj_qi, NQ + 1)].add(1, mode="drop")
        n_injected = state.n_injected + jnp.sum(do_inj)

        # cursor advance for finite injecting ranks
        adv = do_inj & e_fin
        pk2 = jnp.where(adv, e_pk + 1, e_pk)
        move_d = adv & (pk2 >= e_np)
        di2 = jnp.where(move_d, e_di + 1, e_di)
        pk2 = jnp.where(move_d, 0, pk2)
        move_s = move_d & (di2 >= e_deg)
        cs2 = jnp.where(move_s, e_cs + 1, e_cs)
        di2 = jnp.where(move_s, 0, di2)
        # scatter back to rank arrays (each finite rank has exactly 1 endpoint)
        upd = jnp.where((r_of_e >= 0) & e_fin, r_of_e, R + 5)
        cur_step = cs.at[upd].set(cs2, mode="drop")
        dst_i = state.dst_i.at[upd].set(di2, mode="drop")
        pkt_i = state.pkt_i.at[upd].set(pk2, mode="drop")

        # per-epoch delivered / injected counters (epoch 0 on the static path)
        epoch_delivered = state.epoch_delivered.at[ei].add(jnp.sum(tgt_del))
        epoch_injected = state.epoch_injected.at[ei].add(jnp.sum(do_inj))

        new_state = SimState(
            t=t + 1, key=state.key,
            f_dst=f_dst, f_der=f_der, f_hop=f_hop, f_rank=f_rank,
            f_step=f_step, f_birth=f_birth, f_imd=f_imd,
            qhead=qhead, qlen=qlen + dlen, busy=busy,
            cur_step=cur_step, dst_i=dst_i, pkt_i=pkt_i, completed=completed,
            sent=sent, got=got,
            lat_sum=lat_sum, n_delivered=n_delivered, n_injected=n_injected,
            hop_sum=hop_sum, hop_max=hop_max,
            esc_count=esc_count,
            epoch_delivered=epoch_delivered, epoch_injected=epoch_injected,
        )
        if spec is None:
            return new_state

        # ------------- telemetry probes (enabled engines only) -------------
        # Pure extra accumulation from this cycle's internal signals; none
        # of it feeds back into the physics above.  Window index clamps so
        # cycles past n_windows * window accumulate into the last window.
        wi = jnp.minimum(t // spec.window, spec.n_windows - 1)
        net_move = net
        # fault-epoch probes: a flip is a cycle whose active epoch differs
        # from the previous cycle's; dead_links samples the directed dead
        # count of the active mask each cycle
        if NE == 1:
            flip = jnp.int32(0)
        else:
            ei_prev = (jnp.sum(
                jnp.maximum(t - 1, 0) >= wt.epoch_start.astype(I32)
            ) - 1).astype(I32)
            flip = ((t > 0) & (ei != ei_prev)).astype(I32)
        dead_now = jnp.sum(~link_ok_t)
        # per-pool occupancy histogram: one sample of every queue per cycle
        occ_hist = jnp.zeros(P * (CAP + 1), dtype=I32).at[
            h_pool.astype(I32) * (CAP + 1) + qlen
        ].add(1)
        # log2 ejection-latency bin per delivered target packet
        lat_bin = jnp.clip(
            jnp.floor(jnp.log2(jnp.maximum(lat_pkt, 1.0))).astype(I32),
            0, spec.lat_bins - 1,
        )
        tel = TelemetryState(
            link_util=tel.link_util.at[wi].add((g1 + g2).reshape(S, OUT)),
            vc_occ=tel.vc_occ.at[wi].add(occ_hist),
            deroutes=tel.deroutes.at[wi].add(
                jnp.sum(net_move & ~best_min)
            ),
            escalations=tel.escalations.at[wi].add(
                jnp.sum(net_move & esc_chosen)
            ),
            inflight=tel.inflight.at[wi].add(jnp.sum(qlen)),
            cycles=tel.cycles.at[wi].add(1),
            injected=tel.injected.at[wi].add(jnp.sum(do_inj)),
            delivered=tel.delivered.at[wi].add(jnp.sum(tgt_del)),
            lat_sum=tel.lat_sum.at[wi].add(lat_add),
            lat_hist=tel.lat_hist.at[
                jnp.where(tgt_del, lat_bin, spec.lat_bins + 1)
            ].add(1, mode="drop"),
            epoch_flips=tel.epoch_flips.at[wi].add(flip),
            dead_links=tel.dead_links.at[wi].add(dead_now),
        )
        return new_state, tel

    return step
