"""Per-workload device data, as a pytree of jit *arguments*.

The seed simulator baked every workload array into the jit closure, so each
scenario — even with identical shapes — produced a fresh trace.  Here all
per-workload state lives in a :class:`WorkloadTables` NamedTuple (a pytree),
padded to shape *buckets*, and is handed to the compiled step function as a
device argument.  Two consequences:

  * scenarios whose tables land in the same bucket share one compilation
    (the jit cache keys on shapes, not values);
  * same-bucket tables can be ``jnp.stack``-ed along a leading axis and the
    whole while-loop ``jax.vmap``-ed, so an entire strategy x seed sweep is
    one device call.

Padding is semantics-preserving:

  * extra *steps* (T -> T_b) are never walked: the per-rank ``n_steps``
    field keeps the real step count, and the completion / window / injection
    logic compares against it instead of the padded table width;
  * extra *ranks* (R -> R_b) are flagged ``infinite`` (ignored by the
    completion predicate) and mapped to no endpoint (so they never inject);
  * extra *destination slots* (MAXD -> D_b) sit beyond ``deg`` and are never
    dereferenced by the send cursor;
  * extra *fault epochs* (NE -> NE_b) repeat the last real mask at start
    cycle INT32_MAX, so the epoch index never selects them.
"""

from __future__ import annotations

import dataclasses

from typing import NamedTuple, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.engine.packing import pack
from repro.core.traffic import Workload
from repro.route import faults
from repro.route.topology import self_port_mask

I32 = jnp.int32


class WorkloadTables(NamedTuple):
    """All per-workload arrays the step function consumes (R, T, D padded).

    Every leaf is a jnp array so the tuple is a pytree: it can be passed as
    a jit argument, stacked with ``stack_tables`` and vmapped.  The fault
    mask and Valiant intermediate pool have topology-static shapes, so a
    fault-scenario grid batches exactly like a strategy or seed axis.
    """

    rank_ep: jnp.ndarray      # (R,)   endpoint id per rank (pad: 0)
    ep_rank: jnp.ndarray      # (E,)   rank per endpoint, -1 = none
    pool: jnp.ndarray         # (R,)   VC pool per rank
    finite: jnp.ndarray       # (R,)   bool; pad ranks are ~finite
    window: jnp.ndarray       # (R,)   outstanding-step window
    start_t: jnp.ndarray      # (R,)   injection start time (warmup gating)
    n_steps: jnp.ndarray      # (R,)   real step count (<= padded T)
    sends_dst: jnp.ndarray    # (R, T*D) destination rank ids
    npkts: jnp.ndarray        # (R, T*D) packets per destination
    deg: jnp.ndarray          # (R, T) valid destinations per step
    recv_need: jnp.ndarray    # (R*T,) packets needed to complete a step
    total_sends: jnp.ndarray  # (R*T,) packets sent when a step is done
    sampled: jnp.ndarray      # (R, T*D) bool: sample destination?
    smp_lo: jnp.ndarray       # (R, T*D) sample range lo
    smp_hi: jnp.ndarray       # (R, T*D) sample range hi (exclusive)
    # fault epochs: NE >= 1 time-varying mask epochs (NE = 1 is a static
    # mask; padded epochs repeat the last mask and never start)
    link_ok: jnp.ndarray      # (NE, S, q*n) bool: healthy directed links
    mid_pool: jnp.ndarray     # (NE, S) healthy Valiant intermediates (cyclic)
    n_mid: jnp.ndarray        # (NE,) count of distinct healthy intermediates
    n_dead: jnp.ndarray       # (NE,) dead cables — sizes the deroute reserve
                              #     adaptive policies keep for fault escapes
    epoch_start: jnp.ndarray  # (NE,) int32 cycle each epoch begins; [0] == 0,
                              #     pad entries are INT32_MAX (never reached)

    @property
    def R(self) -> int:
        return self.rank_ep.shape[-1]

    @property
    def T(self) -> int:
        return self.deg.shape[-1]

    @property
    def D(self) -> int:
        return self.sends_dst.shape[-1] // self.deg.shape[-1]

    @property
    def NE(self) -> int:
        return self.epoch_start.shape[-1]

    @property
    def shape_bucket(self) -> tuple[int, int, int, int]:
        return (self.R, self.T, self.D, self.NE)


@dataclasses.dataclass(frozen=True)
class PreparedWorkload:
    """A workload lowered to device tables + the host-side metadata that
    the engine needs to interpret raw simulation outputs."""

    tables: WorkloadTables
    warmup: int        # makespan is reported relative to this time
    num_pools: int     # must match the engine's static pool count
    R: int             # real (unpadded) rank count
    T: int             # real (unpadded) step count
    NE: int = 1        # real (unpadded) fault-epoch count


def _pow2_bucket(x: int, floor: int = 1) -> int:
    b = max(floor, 1)
    while b < x:
        b *= 2
    return b


def shape_bucket(R: int, T: int, maxd: int) -> tuple[int, int, int]:
    """Pad (R, T, D) up to power-of-two buckets so near-miss shapes share
    one compilation (e.g. all-to-all T=63 and all-reduce T=64 -> T_b=64)."""
    return _pow2_bucket(R, 8), _pow2_bucket(T, 4), _pow2_bucket(maxd, 1)


def make_workload_tables(
    wl: Workload,
    bucket: bool = True,
    pack_tables: bool = True,
) -> PreparedWorkload:
    """Lower a :class:`Workload` into padded device tables.

    ``pack_tables`` (default) stores every small-range table in the
    narrowest dtype its **bucket-derived** bound admits (rank ids bound by
    R_b, endpoint ids by E, step counts by T_b, ...), so dtypes are a
    function of the shape bucket alone — packed tables stack and share
    compilations exactly like the int32 reference layout, and the step
    kernel widens at each gather, keeping results bit-identical
    (hypothesis-pinned).  ``pack_tables=False`` produces the int32
    reference used by the parity tests.
    """
    R, T, D = wl.R, wl.T, wl.maxd
    R_b, T_b, D_b = shape_bucket(R, T, D) if bucket else (R, T, D)
    E = wl.topo.num_endpoints

    def pad_r(a: np.ndarray, fill=0):
        if R_b == R:
            return a
        out = np.full((R_b,) + a.shape[1:], fill, dtype=a.dtype)
        out[:R] = a
        return out

    def pad_rtd(a: np.ndarray, fill=0):
        out = np.full((R_b, T_b, D_b), fill, dtype=a.dtype)
        out[:R, :T, :D] = a
        return out

    def pad_rt(a: np.ndarray, fill=0):
        out = np.full((R_b, T_b), fill, dtype=a.dtype)
        out[:R, :T] = a
        return out

    ep_rank = np.full(E, -1, dtype=np.int64)
    ep_rank[wl.rank_ep] = np.arange(R)

    n_steps = np.full(R_b, 0, dtype=np.int64)
    n_steps[:R] = T

    # pad ranks: infinite (ignored by completion) + no endpoint (never inject)
    infinite = pad_r(wl.infinite, fill=True)

    # fault mask + Valiant intermediate pool: topology-static shapes, so
    # fault scenarios share the shape bucket of their healthy counterparts.
    # A fault *schedule* stacks NE mask epochs along a leading axis; the
    # epoch count pads to a power of two (part of the shape bucket), with
    # pad epochs repeating the last mask at a start cycle no simulation
    # reaches.  NE = 1 (no schedule) keeps the static path.
    base_ok = wl.link_ok if wl.link_ok is not None else faults.no_faults(wl.topo)
    base_ok = np.asarray(base_ok, dtype=bool)
    sched = getattr(wl, "fault_schedule", None)
    if sched is None:
        epoch_start = np.zeros(1, dtype=np.int64)
        link_ok = base_ok[None]
    else:
        epoch_start = np.asarray(sched.epoch_start, dtype=np.int64)
        link_ok = np.asarray(sched.link_ok, dtype=bool) & base_ok[None]
    NE = len(epoch_start)
    NE_b = _pow2_bucket(NE, 1) if bucket else NE
    if NE_b > NE:
        _NEVER = np.iinfo(np.int32).max
        epoch_start = np.concatenate([
            epoch_start, np.full(NE_b - NE, _NEVER, dtype=np.int64)
        ])
        link_ok = np.concatenate([
            link_ok, np.repeat(link_ok[-1:], NE_b - NE, axis=0)
        ])
    valid_ports = self_port_mask(
        wl.topo.all_switch_coords(), wl.topo.n, wl.topo.q
    )
    mid_pool = np.empty((NE_b, wl.topo.num_switches), dtype=np.int32)
    n_mid = np.empty(NE_b, dtype=np.int64)
    n_dead = np.empty(NE_b, dtype=np.int64)
    for e in range(NE_b):
        mid_pool[e], n_mid[e] = faults.intermediate_pool(wl.topo, link_ok[e])
        dead_dirs = int((valid_ports & ~link_ok[e]).sum())
        n_dead[e] = (dead_dirs + 1) // 2  # cables (directed pairs, ceil)

    if pack_tables:
        # bucket-derived bounds only (R_b/T_b/D_b/E/S) — two same-bucket
        # workloads always pack to identical dtypes, so packed tables
        # stack and share compilations exactly like the int32 layout
        def lower(a, bound):
            return jnp.asarray(pack(a, bound))
    else:
        def lower(a, bound):
            return jnp.asarray(a, dtype=I32)

    # the window only acts through min(n_steps, completed + window) with
    # n_steps <= T_b, so clamping to T_b is semantics-free and gives the
    # field a bucket-derived bound (applied to both layouts for parity)
    window = np.minimum(pad_r(wl.window, fill=1), T_b)

    tables = WorkloadTables(
        rank_ep=lower(pad_r(wl.rank_ep), E - 1),
        ep_rank=lower(ep_rank, R_b),
        pool=lower(pad_r(wl.pool), max(wl.num_pools - 1, 0)),
        finite=jnp.asarray(~infinite),
        window=lower(window, T_b),
        start_t=jnp.asarray(pad_r(wl.start), dtype=I32),
        n_steps=lower(n_steps, T_b),
        sends_dst=lower(
            pad_rtd(wl.sends_dst, fill=-1).reshape(R_b, T_b * D_b), R_b
        ),
        npkts=jnp.asarray(pad_rtd(wl.npkts).reshape(R_b, T_b * D_b), dtype=I32),
        deg=lower(pad_rt(wl.deg), D_b),
        recv_need=jnp.asarray(pad_rt(wl.recv_need).reshape(R_b * T_b), dtype=I32),
        total_sends=jnp.asarray(
            pad_rt(wl.total_sends).reshape(R_b * T_b), dtype=I32
        ),
        sampled=jnp.asarray(pad_rtd(wl.sampled.astype(bool)).reshape(R_b, T_b * D_b)),
        smp_lo=lower(pad_rtd(wl.lo).reshape(R_b, T_b * D_b), R_b),
        smp_hi=lower(pad_rtd(wl.hi).reshape(R_b, T_b * D_b), R_b),
        link_ok=jnp.asarray(link_ok),
        mid_pool=lower(mid_pool, wl.topo.num_switches - 1),
        n_mid=jnp.asarray(n_mid, dtype=I32),
        n_dead=jnp.asarray(n_dead, dtype=I32),
        epoch_start=jnp.asarray(epoch_start, dtype=I32),
    )
    return PreparedWorkload(
        tables=tables, warmup=int(wl.start.max()), num_pools=wl.num_pools,
        R=R, T=T, NE=NE,
    )


def stack_tables(tables: Sequence[WorkloadTables]) -> WorkloadTables:
    """Stack same-bucket tables along a new leading batch axis (for vmap)."""
    buckets = {t.shape_bucket for t in tables}
    if len(buckets) != 1:
        raise ValueError(
            f"cannot stack workload tables from different shape buckets: "
            f"{sorted(buckets)}"
        )
    return jax.tree_util.tree_map(lambda *xs: jnp.stack(xs), *tables)
