"""SimEngine: compile-once, run-many execution of the cycle simulator.

The engine splits a simulation into

  * static structure (:mod:`tables`) — baked into one jitted step/while-loop
    per configuration, shared by every workload;
  * per-workload device data (:mod:`workload_tables`) — passed as pytree
    arguments, so the jit cache keys only on shape buckets.

``run`` executes one scenario; ``run_batch`` stacks same-bucket tables and
``jax.vmap``-s the entire ``lax.while_loop``, so a whole strategy x seed
sweep is **one compilation and one device call** (per shape bucket).
``run_seeds`` fans one scenario across many seeds without replicating its
tables.  ``run_grid`` flattens a workload x seed cross product into a
*lane* axis and shards it across every local device (``jax.shard_map``
over a 1-D mesh, ``jax.pmap`` fallback, the nested-vmap path on a single
device) — lanes are embarrassingly parallel, so an N-device host runs an
N-times-wider grid at the same wall-clock per bucket.

Engines are memoised by :func:`get_engine`; ``trace_count`` /
``device_calls`` expose how many XLA traces and dispatches actually
happened (the benchmark suite and the trace-counter tests assert on them).
"""

from __future__ import annotations

import contextlib
import dataclasses
import functools
from typing import Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.engine.cache import enable_persistent_cache
from repro.core.engine.step import SimState, all_done, build_step, init_state
from repro.core.engine.tables import build_static_tables
from repro.core.engine.workload_tables import (
    PreparedWorkload,
    WorkloadTables,
    make_workload_tables,
    stack_tables,
)
from repro.core.hyperx import HyperX
from repro.core.traffic import Workload
from repro.obs import probes as obs_probes
from repro.obs import trace as obs_trace
from repro.obs.probes import Telemetry, TelemetrySpec, init_telemetry
from repro.route import get_policy

PACKET_FLITS = 16  # paper Table 2: packet size 16 flits


def default_lane_backend(ndev: int | None = None) -> str:
    """The lane dispatcher :meth:`SimEngine.run_grid` will use on this host.

    Resolved at engine construction (and by the run manifest), not lazily
    at the first grid call: ``"vmap"`` on a single device, else
    ``"shard_map"`` when the jax build exports it, else ``"pmap"``.
    """
    if ndev is None:
        ndev = jax.local_device_count()
    if ndev == 1:
        return "vmap"
    try:
        try:
            jax.shard_map  # type: ignore[attr-defined]
        except AttributeError:
            from jax.experimental.shard_map import shard_map  # noqa: F401
        return "shard_map"
    except Exception:  # pragma: no cover - depends on jax build
        return "pmap"


def _index_outs(outs, idx):
    """Index every leaf of a core-output pytree along the leading axis.

    Outputs are a tuple of arrays plus, for telemetry-enabled engines, a
    trailing :class:`TelemetryState` — tree indexing keeps both shapes
    uniform across the vmap/shard_map batching layouts.
    """
    return jax.tree_util.tree_map(lambda x: x[idx], outs)


@dataclasses.dataclass(frozen=True)
class SimResult:
    makespan: int             # packet-times until all target ranks completed
    makespan_cycles: int      # flit-cycles (x packet size)
    delivered: int            # target packets delivered
    injected: int             # packets injected (targets + background)
    avg_latency: float        # packet-times, target packets
    avg_hops: float           # network hops per delivered target packet
    completed: bool           # all target ranks finished within horizon
    max_hops: int = 0         # max hops over all ejected packets — must stay
                              # below the policy's VC budget (deadlock bound)
    # resilience accounting (defaults keep pre-epoch pickles comparable)
    reescalated: int = 0      # moves granted via forced fault-escape deroutes
    stranded: int = 0         # packets still queued in-network at the horizon
    ejected: int = 0          # packets ejected anywhere (injected - stranded)
    epoch_delivered: tuple = ()   # (NE,) target deliveries per fault epoch
    epoch_injected: tuple = ()    # (NE,) injections per fault epoch
    # windowed in-sim time series (engines built with a TelemetrySpec
    # only); excluded from equality so telemetry-on results still compare
    # against telemetry-off results on the simulated fields
    telemetry: Telemetry | None = dataclasses.field(
        default=None, compare=False, repr=False,
    )


class SimEngine:
    """Pytree-parameterized simulator for one static configuration.

    One engine == one ``(topo, mode, num_pools, max_deroutes, cap,
    penalty)`` tuple; ``mode`` resolves through the :mod:`repro.route`
    policy registry (``available_policies()`` lists valid names).  All
    workloads run through the same jitted core; re-tracing happens only
    when a workload's shape *bucket* is new — fault masks and Valiant
    intermediate pools are per-workload device data, so routing x
    strategy x fault grids batch like any other scenario axis.
    """

    def __init__(
        self,
        topo: HyperX,
        mode: str = "omniwar",
        num_pools: int = 1,
        max_deroutes: int | None = None,
        cap: int = 8,
        penalty_packets: int = 4,
        bucket: bool = True,
        arb: str = "lax",
        pack: bool = True,
        telemetry: TelemetrySpec | None = None,
        kernel: str = "lax",
        chunk: int = 1,
        canon: bool = False,
    ):
        if chunk < 1:
            raise ValueError(f"chunk must be >= 1, got {chunk}")
        self.topo = topo
        self.mode = mode
        self.policy = get_policy(mode)  # registry: unknown modes raise here
        self.num_pools = num_pools
        self.bucket = bucket
        self.pack = pack
        self.telemetry = telemetry
        self.kernel = kernel
        self.chunk = chunk
        self.canon = canon
        # opt-in persistent XLA compile cache (REPRO_COMPILE_CACHE env or an
        # earlier enable_persistent_cache() call); no-op when unconfigured
        enable_persistent_cache()
        self.static = build_static_tables(
            topo, mode=mode, num_pools=num_pools, max_deroutes=max_deroutes,
            cap=cap, penalty_packets=penalty_packets, arb=arb,
            pack_tables=pack, kernel=kernel,
        )
        self._step = build_step(self.static, telemetry=telemetry)
        self.trace_count = 0   # XLA traces of the core (any batching)
        self.device_calls = 0  # jitted dispatches issued
        self.bucket_hits = 0   # dispatches whose compile key was seen before
        self.bucket_misses = 0  # dispatches that opened a new compile key
        self._seen_keys: set = set()

        if chunk == 1:
            # cycle-granular reference loop: `all_done` checked every cycle
            loop = jax.lax.while_loop
        else:
            def loop(cond, body, init):
                # while-of-scan chunks: the `all_done` reduction runs every
                # `chunk` cycles and XLA fuses across cycles within a chunk
                # (scan/while carries are buffer-donated by XLA, so the
                # chunk adds no copies).  Result-exact for any K: `cond` is
                # monotone (horizon and completion only latch one way), so
                # freezing the carry on the first inactive cycle makes the
                # in-chunk tail a no-op and records the exact completion
                # cycle — the fixed point is the while_loop's, bit for bit.
                def cstep(carry, _):
                    active = cond(carry)
                    new = body(carry)
                    return jax.tree_util.tree_map(
                        lambda old, upd: jnp.where(active, upd, old),
                        carry, new,
                    ), None

                def chunk_body(carry):
                    carry, _ = jax.lax.scan(cstep, carry, None, length=chunk)
                    return carry

                return jax.lax.while_loop(cond, chunk_body, init)

        if telemetry is None:
            def core(wt: WorkloadTables, seed, horizon):
                # Python side effect: runs once per trace, never per call.
                self.trace_count += 1

                def cond(state: SimState):
                    return (state.t < horizon) & ~all_done(wt, state)

                def body(state: SimState):
                    return self._step(state, wt)

                final = loop(cond, body, init_state(self.static, wt, seed))
                return (
                    final.t, all_done(wt, final), final.n_delivered,
                    final.n_injected, final.lat_sum, final.hop_sum,
                    final.hop_max, final.esc_count, jnp.sum(final.qlen),
                    final.epoch_delivered, final.epoch_injected,
                )
        else:
            st = self.static

            def core(wt: WorkloadTables, seed, horizon):
                self.trace_count += 1

                def cond(carry):
                    state, _ = carry
                    return (state.t < horizon) & ~all_done(wt, state)

                def body(carry):
                    return self._step(carry, wt)

                init = (
                    init_state(st, wt, seed),
                    init_telemetry(telemetry, st.S, st.OUT, st.P, st.CAP),
                )
                final, tel = loop(cond, body, init)
                return (
                    final.t, all_done(wt, final), final.n_delivered,
                    final.n_injected, final.lat_sum, final.hop_sum,
                    final.hop_max, final.esc_count, jnp.sum(final.qlen),
                    final.epoch_delivered, final.epoch_injected, tel,
                )

        self._core = core
        self._run1 = jax.jit(core)
        self._runN = jax.jit(jax.vmap(core, in_axes=(0, 0, None)))
        self._runS = jax.jit(jax.vmap(core, in_axes=(None, 0, None)))
        # (workloads x seeds) cross product: tables batch on the outer axis
        # only, seeds broadcast on the inner — no per-seed table replication
        self._runNS = jax.jit(jax.vmap(
            jax.vmap(core, in_axes=(None, 0, None)),
            in_axes=(0, None, None),
        ))
        self._lane_runner = None       # built lazily (multi-device only)
        # resolved at construction on every host shape (the run manifest
        # records it); _make_lane_runner can still downgrade shard_map ->
        # pmap if the mesh build fails at dispatch time
        self.lane_backend = default_lane_backend()

    # ------------------------------------------------------------- prepare
    def prepare(self, wl: Workload | PreparedWorkload) -> PreparedWorkload:
        """Lower a Workload to padded device tables (idempotent)."""
        if isinstance(wl, PreparedWorkload):
            prep = wl
        else:
            if wl.topo != self.topo:
                raise ValueError(
                    f"workload was composed on {wl.topo} but engine was "
                    f"built for {self.topo}"
                )
            prep = make_workload_tables(
                wl, bucket=self.bucket, pack_tables=self.pack
            )
        if prep.num_pools != self.num_pools:
            raise ValueError(
                f"workload uses {prep.num_pools} VC pools but engine was "
                f"built with num_pools={self.num_pools}"
            )
        return prep

    # --------------------------------------------- shape canonicalization
    def _canon_pad(self, count: int) -> int:
        """Canonical batch-axis length: next power of two (``canon`` only).

        Workload tables already pow2-pad their own dims (R/T/D/NE — see
        :func:`~repro.core.engine.workload_tables.shape_bucket`); the one
        remaining compile-key degree of freedom is how many lanes are
        stacked per dispatch.  Padding that count to a power of two makes
        nearby grid sizes (5 vs 7 workloads, 3 vs 4 seeds) share one
        compiled executable; padded lanes repeat existing ones and their
        results are discarded.
        """
        if not self.canon or count <= 1:
            return count
        return 1 << (count - 1).bit_length()

    def _pad_idxs(self, idxs: list) -> list:
        """Round-robin-extend ``idxs`` to its canonical length."""
        tgt = self._canon_pad(len(idxs))
        return idxs + [idxs[k % len(idxs)] for k in range(tgt - len(idxs))]

    def _note_bucket(self, fn: str, bucket, dims: tuple) -> None:
        """Account one dispatch against the compile-key it lands on.

        ``(fn, shape bucket, batch dims)`` mirrors the jit cache key of
        the dispatched callable — a *miss* is a dispatch that opens a new
        key (first trace+compile), a *hit* reuses one.  The hit rate is
        the compile-amortization figure of merit ``benchmarks/perf.py``
        records in ``BENCH_*.json``.
        """
        key = (fn, bucket, dims)
        if key in self._seen_keys:
            self.bucket_hits += 1
        else:
            self.bucket_misses += 1
            self._seen_keys.add(key)

    def bucket_stats(self) -> dict:
        """Compile-key hit/miss counters for this engine's dispatches."""
        total = self.bucket_hits + self.bucket_misses
        return {
            "hits": self.bucket_hits,
            "misses": self.bucket_misses,
            "hit_rate": (self.bucket_hits / total) if total else 0.0,
        }

    # ------------------------------------------------------------ running
    def run(
        self,
        wl: Workload | PreparedWorkload,
        seed: int = 0,
        horizon: int = 60_000,
    ) -> SimResult:
        prep = self.prepare(wl)
        self.device_calls += 1
        self._note_bucket("run1", prep.tables.shape_bucket, ())
        with self._dispatch_span("run", lanes=1):
            out = self._run1(prep.tables, jnp.int32(seed), jnp.int32(horizon))
        return self._to_result(out, prep)

    def run_batch(
        self,
        workloads: Sequence[Workload | PreparedWorkload],
        seeds: Sequence[int] | None = None,
        horizon: int = 60_000,
    ) -> list[SimResult]:
        """Run many scenarios as (one device call per shape bucket).

        ``seeds`` has one entry per workload (default: all 0).  Workloads
        are grouped by shape bucket internally; results come back in input
        order.  The jit cache keys on the stacked shapes — which include
        the batch dimension — so repeated sweeps of the same grid size
        (e.g. one batch per kernel over a fixed strategy set) share one
        compilation.
        """
        preps = [self.prepare(w) for w in workloads]
        if seeds is None:
            seeds = [0] * len(preps)
        if len(seeds) != len(preps):
            raise ValueError(
                f"{len(seeds)} seeds for {len(preps)} workloads"
            )
        groups: dict[tuple[int, int, int, int], list[int]] = {}
        for i, p in enumerate(preps):
            groups.setdefault(p.tables.shape_bucket, []).append(i)
        results: list[SimResult | None] = [None] * len(preps)
        for idxs in groups.values():
            # canon: pad the stacked axis to a power of two (padded lanes
            # repeat real ones; their rows are simply never read back)
            idxs_p = self._pad_idxs(idxs)
            stacked = stack_tables([preps[i].tables for i in idxs_p])
            seed_arr = jnp.asarray(
                [int(seeds[i]) for i in idxs_p], dtype=jnp.int32
            )
            self.device_calls += 1
            self._note_bucket("runN", preps[idxs[0]].tables.shape_bucket,
                              (len(idxs_p),))
            with self._dispatch_span("run_batch", lanes=len(idxs_p)):
                outs = self._runN(stacked, seed_arr, jnp.int32(horizon))
            for j, i in enumerate(idxs):
                results[i] = self._to_result(_index_outs(outs, j), preps[i])
        return results  # type: ignore[return-value]

    def run_batch_seeds(
        self,
        workloads: Sequence[Workload | PreparedWorkload],
        seeds: Sequence[int],
        horizon: int = 60_000,
    ) -> list[list[SimResult]]:
        """Cross product: every workload x every seed, one device call per
        shape bucket.  Tables batch only on the workload axis (seeds
        broadcast), so nothing is replicated per seed.  Returns
        ``results[workload][seed]`` in input order.
        """
        preps = [self.prepare(w) for w in workloads]
        seeds_p = self._pad_idxs([int(s) for s in seeds])
        seed_arr = jnp.asarray(seeds_p, dtype=jnp.int32)
        groups: dict[tuple[int, int, int, int], list[int]] = {}
        for i, p in enumerate(preps):
            groups.setdefault(p.tables.shape_bucket, []).append(i)
        results: list[list[SimResult] | None] = [None] * len(preps)
        for idxs in groups.values():
            idxs_p = self._pad_idxs(idxs)
            stacked = stack_tables([preps[i].tables for i in idxs_p])
            self.device_calls += 1
            self._note_bucket("runNS", preps[idxs[0]].tables.shape_bucket,
                              (len(idxs_p), len(seeds_p)))
            with self._dispatch_span("run_batch_seeds",
                                     lanes=len(idxs_p) * len(seeds_p)):
                outs = self._runNS(stacked, seed_arr, jnp.int32(horizon))
            for j, i in enumerate(idxs):
                results[i] = [
                    self._to_result(_index_outs(outs, (j, k)), preps[i])
                    for k in range(len(seeds))
                ]
        return results  # type: ignore[return-value]

    # ------------------------------------------------- device-sharded lanes
    def _make_lane_runner(self):
        """Build the multi-device lane dispatcher (shard_map, else pmap).

        Lanes — flattened (workload, seed) pairs with stacked tables — are
        embarrassingly parallel, so the dispatcher just splits the lane
        axis across devices and vmaps within each shard.  Tracing still
        happens once per shape bucket (SPMD), which the trace-counter
        tests pin.
        """
        ndev = jax.local_device_count()
        try:
            try:  # jax >= 0.6 exports shard_map at top level
                shard_map = jax.shard_map  # type: ignore[attr-defined]
            except AttributeError:
                from jax.experimental.shard_map import shard_map
            from jax.sharding import Mesh, PartitionSpec as P

            mesh = Mesh(np.asarray(jax.devices()), ("lanes",))
            fn = jax.jit(shard_map(
                jax.vmap(self._core, in_axes=(0, 0, None)),
                mesh=mesh,
                in_specs=(P("lanes"), P("lanes"), None),
                out_specs=P("lanes"),
                check_rep=False,
            ))
            self.lane_backend = "shard_map"

            def dispatch(stacked, seed_arr, horizon):
                return fn(stacked, seed_arr, horizon)

        except Exception:  # pragma: no cover - depends on jax build
            pfn = jax.pmap(
                jax.vmap(self._core, in_axes=(0, 0, None)),
                in_axes=(0, 0, None),
            )
            self.lane_backend = "pmap"

            def dispatch(stacked, seed_arr, horizon):
                L = seed_arr.shape[0]
                per = L // ndev
                split = jax.tree_util.tree_map(
                    lambda x: x.reshape((ndev, per) + x.shape[1:]), stacked
                )
                outs = pfn(split, seed_arr.reshape(ndev, per), horizon)
                return jax.tree_util.tree_map(
                    lambda o: o.reshape((L,) + o.shape[2:]), outs
                )

        return dispatch

    def run_grid(
        self,
        workloads: Sequence[Workload | PreparedWorkload],
        seeds: Sequence[int] | None = None,
        horizon: int = 60_000,
    ) -> list[list[SimResult]]:
        """Run the workload x seed cross product sharded across devices.

        The grid is flattened into a *lane* axis (one lane per
        (workload, seed) pair, grouped by shape bucket) and dispatched

          * via ``jax.shard_map`` over a 1-D device mesh when the host has
            more than one device (``jax.pmap`` when shard_map is
            unavailable) — lanes are padded round-robin to a multiple of
            the device count so uneven grids still compile once per
            (bucket, lane-count) and every device receives equal work;
          * via the existing nested-vmap path (``run_batch_seeds``'s
            dispatch — seeds broadcast, tables never replicated) on a
            single device.

        Results are bitwise identical to ``run_batch_seeds`` on every
        backend (lane flattening only re-associates the batch axes) and
        come back as ``results[workload][seed]`` in input order.
        ``self.lane_backend`` records which dispatcher ran.
        """
        preps = [self.prepare(w) for w in workloads]
        seeds = [0] if seeds is None else list(seeds)
        ndev = jax.local_device_count()
        groups: dict[tuple[int, int, int, int], list[int]] = {}
        for i, p in enumerate(preps):
            groups.setdefault(p.tables.shape_bucket, []).append(i)
        results: list[list[SimResult] | None] = [None] * len(preps)
        if ndev == 1:
            # single device: the nested-vmap cross product is already the
            # fastest layout (no table replication across the seed axis)
            seeds_p = self._pad_idxs([int(s) for s in seeds])
            seed_arr = jnp.asarray(seeds_p, dtype=jnp.int32)
            for idxs in groups.values():
                idxs_p = self._pad_idxs(idxs)
                stacked = stack_tables([preps[i].tables for i in idxs_p])
                self.device_calls += 1
                self._note_bucket(
                    "runNS", preps[idxs[0]].tables.shape_bucket,
                    (len(idxs_p), len(seeds_p)),
                )
                with self._dispatch_span("run_grid",
                                         lanes=len(idxs_p) * len(seeds_p)):
                    outs = self._runNS(stacked, seed_arr, jnp.int32(horizon))
                for j, i in enumerate(idxs):
                    results[i] = [
                        self._to_result(_index_outs(outs, (j, k)), preps[i])
                        for k in range(len(seeds))
                    ]
            return results  # type: ignore[return-value]

        if self._lane_runner is None:
            self._lane_runner = self._make_lane_runner()
        for idxs in groups.values():
            lanes = [(i, k) for i in idxs for k in range(len(seeds))]
            # canon first (pow2 lane count), then to a device-count
            # multiple so every shard is full
            tgt = self._canon_pad(len(lanes))
            tgt += (-tgt) % ndev
            pad = tgt - len(lanes)
            # round-robin padding: repeat existing lanes so every device
            # shard is full; padded lanes are computed and discarded
            lanes_p = lanes + [lanes[k % len(lanes)] for k in range(pad)]
            stacked = stack_tables([preps[i].tables for i, _ in lanes_p])
            seed_arr = jnp.asarray([int(seeds[k]) for _, k in lanes_p],
                                   dtype=jnp.int32)
            self.device_calls += 1
            self._note_bucket("lanes", preps[idxs[0]].tables.shape_bucket,
                              (len(lanes_p),))
            with self._dispatch_span("run_grid", lanes=len(lanes_p)):
                outs = self._lane_runner(stacked, seed_arr, jnp.int32(horizon))
            for lane, (i, k) in enumerate(lanes):
                if results[i] is None:
                    results[i] = [None] * len(seeds)  # type: ignore[list-item]
                results[i][k] = self._to_result(
                    _index_outs(outs, lane), preps[i]
                )
        return results  # type: ignore[return-value]

    def run_seeds(
        self,
        wl: Workload | PreparedWorkload,
        seeds: Sequence[int],
        horizon: int = 60_000,
    ) -> list[SimResult]:
        """One scenario, many seeds — tables are not replicated on device."""
        prep = self.prepare(wl)
        seeds_p = self._pad_idxs([int(s) for s in seeds])
        seed_arr = jnp.asarray(seeds_p, dtype=jnp.int32)
        self.device_calls += 1
        self._note_bucket("runS", prep.tables.shape_bucket, (len(seeds_p),))
        with self._dispatch_span("run_seeds", lanes=len(seeds_p)):
            outs = self._runS(prep.tables, seed_arr, jnp.int32(horizon))
        return [
            self._to_result(_index_outs(outs, j), prep)
            for j in range(len(seeds))
        ]

    def run_debug(
        self,
        wl: Workload | PreparedWorkload,
        seed: int = 0,
        steps: int = 512,
        stride: int = 16,
    ):
        """Scan ``steps`` cycles; return per-stride (delivered, injected, qsum)."""
        prep = self.prepare(wl)
        wt = prep.tables

        def body(state, _):
            s2 = self._step(state, wt)
            return s2, (s2.n_delivered, s2.n_injected, s2.qlen.sum())

        state = init_state(self.static, wt, seed)
        final, (d, i, qs) = jax.lax.scan(body, state, None, length=steps)
        return (
            final,
            np.asarray(d)[::stride],
            np.asarray(i)[::stride],
            np.asarray(qs)[::stride],
        )

    # ------------------------------------------------------------ private
    @contextlib.contextmanager
    def _dispatch_span(self, api: str, lanes: int):
        """Span one device dispatch (and flag fresh compiles) when a
        tracer is active; a bare yield — no timing, no allocation — when
        tracing is off."""
        tracer = obs_trace.active()
        if tracer is None:
            yield
            return
        traces0 = self.trace_count
        with tracer.span("engine.dispatch", api=api, mode=self.mode,
                         lanes=lanes, backend=self.lane_backend):
            yield
        if self.trace_count > traces0:
            tracer.event("engine.compile", api=api, mode=self.mode,
                         traces=self.trace_count - traces0)

    def _to_result(self, out, prep: PreparedWorkload) -> SimResult:
        tel = None
        if self.telemetry is not None:
            out, tel_state = out[:11], out[11]
            tel = obs_probes.to_host(tel_state, self.telemetry, self.static)
        (t, done, ndel, ninj, lat, hops, hmax, esc, qsum, edel, einj) = (
            np.asarray(x) for x in out
        )
        ndel = int(ndel)
        return SimResult(
            makespan=int(t) - prep.warmup,
            makespan_cycles=(int(t) - prep.warmup) * PACKET_FLITS,
            delivered=ndel,
            injected=int(ninj),
            avg_latency=float(lat) / max(ndel, 1),
            avg_hops=float(hops) / max(ndel, 1),
            completed=bool(done),
            max_hops=int(hmax),
            reescalated=int(esc),
            stranded=int(qsum),
            ejected=int(ninj) - int(qsum),
            # pad epochs never start, so their counters are exact zeros;
            # trim to the real epoch count for the host view
            epoch_delivered=tuple(int(x) for x in edel[: prep.NE]),
            epoch_injected=tuple(int(x) for x in einj[: prep.NE]),
            telemetry=tel,
        )


@functools.lru_cache(maxsize=None)
def _engine_for(topo, mode, num_pools, max_deroutes, cap, penalty_packets,
                bucket, arb, pack, telemetry, kernel, chunk, canon):
    return SimEngine(
        topo, mode=mode, num_pools=num_pools, max_deroutes=max_deroutes,
        cap=cap, penalty_packets=penalty_packets, bucket=bucket, arb=arb,
        pack=pack, telemetry=telemetry, kernel=kernel, chunk=chunk,
        canon=canon,
    )


def get_engine(
    topo: HyperX,
    mode: str = "omniwar",
    num_pools: int = 1,
    max_deroutes: int | None = None,
    cap: int = 8,
    penalty_packets: int = 4,
    bucket: bool = True,
    arb: str = "lax",
    pack: bool = True,
    telemetry: TelemetrySpec | None = None,
    kernel: str = "lax",
    chunk: int = 1,
    canon: bool = False,
) -> SimEngine:
    """Memoised engine lookup: one engine (and one compile) per config.

    Arguments are normalised into one positional cache key, so calls that
    spell defaults explicitly share the engine with calls that omit them.
    ``arb`` selects the switch-arbitration backend ("lax" | "pallas", bit
    identical); ``kernel`` selects the route+arbitrate implementation
    ("lax" | "pallas" fused megakernel, bit identical); ``chunk`` is the
    early-exit granularity of the cycle loop (K cycles per ``all_done``
    check — result-exact for any K, K=1 is the cycle-granular reference);
    ``canon`` pow2-pads batch-axis lengths so nearby grid sizes share
    compiles; ``pack`` controls int8/int16 table packing (default on —
    ``False`` is the int32 reference layout for parity tests).
    ``telemetry`` (a hashable :class:`~repro.obs.probes.TelemetrySpec`)
    is part of the key: enabling probes builds a separate engine, leaving
    every default-keyed consumer on the untouched kernel.
    """
    return _engine_for(
        topo, mode, num_pools, max_deroutes, cap, penalty_packets, bucket,
        arb, pack, telemetry, kernel, chunk, canon,
    )
