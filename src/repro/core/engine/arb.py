"""Switch arbitration backends: lax scatter-min reference and Pallas kernel.

One arbitration round resolves, for every queue head in the machine, the
request it posted against one output port of its own switch: per output,
the head carrying the smallest packed key (15 random bits << 17 | global
head index — unique, so ties are impossible) wins.  The step kernel runs
two such rounds per cycle (separable allocation with the paper's 2x
internal speedup); this module provides the round primitive

    arbitrate(req_out, packed) -> (won, gcount)

with ``req_out`` the *global* output index ``switch * OUT + port`` (any
value >= S*OUT means "not requesting"), ``won`` the per-head grant mask
and ``gcount`` the per-output grant count (the drain/token update).

Two implementations, selected by ``StaticTables.arb``:

  * ``"lax"`` — the reference: one scatter-min over the flat (S*OUT,)
    grant table, exactly the seed engine's code path;
  * ``"pallas"`` — a ``pallas_call`` with one program instance per
    switch.  Arbitration is switch-local (a head can only request its own
    switch's outputs, and heads are switch-major in queue order), so each
    instance loads its (IN*P*V,) slice of requests/keys, builds the
    (heads, OUT) request matrix in registers/VMEM and takes a masked min
    per output — no scatter at all.  Integer min over unique keys is
    platform-independent, so the kernel is **bit-exact** against the lax
    reference (regression-pinned in ``tests/test_arb.py``, interpret
    mode on CPU CI; compiled on TPU where ``interpret=None`` resolves to
    False).

Both backends vmap (pallas_call has a batching rule that prepends grid
dimensions), so lane-batched grids run unchanged under either.
"""

from __future__ import annotations

import functools
from typing import Callable

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl

I32 = jnp.int32
U32 = jnp.uint32
_INVALID = np.uint32(0xFFFFFFFF)


def arbitrate_lax(req_out, packed, S: int, OUT: int):
    """Reference round: scatter-min grant table over all S*OUT outputs."""
    valid = req_out < S * OUT
    req_safe = jnp.minimum(req_out, S * OUT - 1)
    grant = jnp.full(S * OUT, jnp.uint32(_INVALID))
    grant = grant.at[req_out].min(packed, mode="drop")
    won = valid & (grant[req_safe] == packed)
    gcount = jnp.zeros(S * OUT, dtype=I32).at[
        jnp.where(won, req_out, S * OUT + 1)
    ].add(1, mode="drop")
    return won, gcount


def _arb_kernel(local_ref, key_ref, won_ref, gcnt_ref, *, OUT: int):
    """One switch: masked min per output over this switch's queue heads."""
    lp = local_ref[0]                        # (HS,) local port, -1 = none
    key = key_ref[0]                         # (HS,) packed uint32, unique
    HS = lp.shape[0]
    oid = jax.lax.broadcasted_iota(jnp.int32, (HS, OUT), 1)
    req = lp[:, None] == oid                 # (HS, OUT) request matrix
    vals = jnp.where(req, key[:, None], _INVALID)
    grant = vals.min(axis=0)                 # (OUT,) winning key per output
    won = req & (key[:, None] == grant[None, :])
    won_ref[0] = won.any(axis=1).astype(I32)
    gcnt_ref[0] = won.sum(axis=0).astype(I32)


def make_arbiter(
    S: int, OUT: int, H: int, arb: str, interpret: bool | None = None
) -> Callable:
    """Build the round primitive for one static configuration.

    ``H`` must be switch-major divisible (H == S * heads_per_switch, the
    engine's queue layout).  ``interpret=None`` resolves per-backend:
    interpret off TPU (CPU CI), compiled on TPU.
    """
    if arb == "lax":
        def arbiter(req_out, packed):
            return arbitrate_lax(req_out, packed, S, OUT)
        return arbiter
    if arb != "pallas":
        raise ValueError(f"unknown arbitration backend {arb!r} "
                         "(expected 'lax' or 'pallas')")
    if H % S:
        raise ValueError(f"H={H} not divisible by S={S}")
    HS = H // S
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    sw = jnp.asarray(np.arange(H) // HS, dtype=I32)  # switch of each head
    call = pl.pallas_call(
        functools.partial(_arb_kernel, OUT=OUT),
        grid=(S,),
        in_specs=[pl.BlockSpec((1, HS), lambda s: (s, 0)),
                  pl.BlockSpec((1, HS), lambda s: (s, 0))],
        out_specs=[pl.BlockSpec((1, HS), lambda s: (s, 0)),
                   pl.BlockSpec((1, OUT), lambda s: (s, 0))],
        out_shape=[jax.ShapeDtypeStruct((S, HS), jnp.int32),
                   jax.ShapeDtypeStruct((S, OUT), jnp.int32)],
        interpret=interpret,
        name="switch_arbitration",
    )

    def arbiter(req_out, packed):
        # local port within the head's own switch; -1 never matches an output
        local = jnp.where(
            req_out < S * OUT, req_out - sw * OUT, -1
        ).astype(I32)
        won2d, g2d = call(local.reshape(S, HS), packed.reshape(S, HS))
        return won2d.reshape(H).astype(bool), g2d.reshape(S * OUT)

    return arbiter
