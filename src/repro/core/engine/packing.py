"""Small-range integer table packing (int8/int16) with bound-derived dtypes.

The engine's lookup tables hold small-range values — coordinates (< n),
ports (< q*n + conc), switch ids (< S), rank ids (< R_b) — yet the seed
engine stored everything as int32.  Packing them to the narrowest dtype
that provably fits halves (or quarters) the memory traffic of the gather-
heavy step kernel and of every host->device table transfer.

Two rules keep the packing *semantics-free* and *bucket-stable*:

  * the dtype is chosen from a **bound** derived from the topology or the
    shape bucket — never from the data values — so two workloads landing
    in the same shape bucket always carry identical dtypes (the jit cache
    keys on dtypes; value-dependent packing would silently fragment
    compilation buckets and break ``stack_tables``);
  * the step kernel widens to int32 at a **single point per table — the
    gather that reads it** — so all arithmetic (port indices, scatter
    targets, cost terms) stays int32 exactly as before.  Packed and
    unpacked tables are therefore bit-identical in every ``SimResult``
    (hypothesis-pinned in ``tests/test_packing.py``).

``pack_dtype`` also covers the ``-1`` sentinels (destination "none", rank
"none"): every signed dtype that fits ``bound`` fits ``-1``.
"""

from __future__ import annotations

import numpy as np

# inclusive maximum magnitude representable per packed dtype
I8_MAX = np.iinfo(np.int8).max    # 127
I16_MAX = np.iinfo(np.int16).max  # 32767


def pack_dtype(bound: int) -> np.dtype:
    """Narrowest signed dtype holding every value in ``[-bound-1, bound]``.

    ``bound`` is the largest value the table can possibly contain, derived
    from topology / bucket dimensions (NOT from the data).  The extra -1
    of headroom on the negative side covers the engine's sentinels.  Falls
    back to int32 above the int16 range — the overflow guard for
    large-``k`` machines (``S`` or ``R_b`` beyond 32767).
    """
    if bound < 0:
        raise ValueError(f"pack bound must be non-negative, got {bound}")
    if bound <= I8_MAX:
        return np.dtype(np.int8)
    if bound <= I16_MAX:
        return np.dtype(np.int16)
    return np.dtype(np.int32)


def pack(arr: np.ndarray, bound: int) -> np.ndarray:
    """Cast ``arr`` to the bound-derived dtype (checked in debug builds)."""
    dt = pack_dtype(bound)
    a = np.asarray(arr)
    if a.size and (a.max(initial=0) > bound or a.min(initial=0) < -bound - 1):
        raise OverflowError(
            f"table value range [{a.min()}, {a.max()}] exceeds declared "
            f"pack bound {bound}"
        )
    return a.astype(dt)
