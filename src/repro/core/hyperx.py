"""HyperX (Hamming graph) topology model.

A symmetric qD HyperX organizes n**q switches on a q-dimensional grid of side
n; two switches are linked iff their addresses differ in exactly one
coordinate (Hamming distance 1).  Each switch hosts ``concentration``
endpoints (a well-balanced HyperX uses concentration == n), giving
n**(q+1) endpoints total for the well-balanced case.

Endpoints are addressed as (switch coordinates..., local offset c); linear
endpoint ids enumerate offsets fastest, i.e. for 2D:

    endpoint_id = (s_y * n + s_x) * concentration + c

All distance / link math follows Section 2 of the paper:
  * diameter = q
  * average switch distance (self-pairs included) = q - q/n
  * total switch-to-switch links = q * (n - 1) * n**q / 2
"""

from __future__ import annotations

import dataclasses
import itertools
from typing import Iterator, Sequence, Tuple

import numpy as np

Coord = Tuple[int, ...]


@dataclasses.dataclass(frozen=True)
class HyperX:
    """A symmetric qD HyperX of side ``n`` with ``concentration`` endpoints/switch."""

    n: int
    q: int = 2
    concentration: int | None = None  # defaults to n (well-balanced)

    def __post_init__(self):
        if self.n < 2:
            raise ValueError(f"HyperX side must be >= 2, got n={self.n}")
        if self.q < 1:
            raise ValueError(f"HyperX dimension must be >= 1, got q={self.q}")
        if self.concentration is None:
            object.__setattr__(self, "concentration", self.n)

    # ------------------------------------------------------------------ sizes
    @property
    def num_switches(self) -> int:
        return self.n**self.q

    @property
    def num_endpoints(self) -> int:
        return self.num_switches * self.concentration

    @property
    def num_links(self) -> int:
        """Switch-to-switch bidirectional links (cables)."""
        return self.q * (self.n - 1) * self.num_switches // 2

    @property
    def diameter(self) -> int:
        return self.q

    @property
    def switch_radix(self) -> int:
        """Ports per switch: network ports + endpoint (local) ports."""
        return self.q * (self.n - 1) + self.concentration

    def average_switch_distance(self, include_self: bool = True) -> float:
        """Average Hamming distance over ordered switch pairs.

        With self-pairs included (the paper's convention) this is q - q/n.
        """
        if include_self:
            return self.q - self.q / self.n
        # Excluding self pairs: E[d] * N^2 / (N^2 - N)
        ns = self.num_switches
        return (self.q - self.q / self.n) * ns / (ns - 1)

    def wires_per_endpoint(self) -> float:
        """Raw cost: network cables per endpoint computer (-> q/2 from below)."""
        return self.q * (self.n - 1) / (2 * self.concentration)

    # ------------------------------------------------------- coordinate logic
    def switch_coords(self, s: int) -> Coord:
        """Decompose linear switch id into q coordinates, slowest dim first.

        For q=2 the result is (s_y, s_x) with s = s_y*n + s_x.
        """
        if not 0 <= s < self.num_switches:
            raise ValueError(f"switch id {s} out of range for {self}")
        coords = []
        for _ in range(self.q):
            coords.append(s % self.n)
            s //= self.n
        return tuple(reversed(coords))

    def switch_id(self, coords: Sequence[int]) -> int:
        if len(coords) != self.q:
            raise ValueError(f"expected {self.q} coordinates, got {coords}")
        s = 0
        for c in coords:
            if not 0 <= c < self.n:
                raise ValueError(f"coordinate {c} out of range [0,{self.n})")
            s = s * self.n + c
        return s

    def endpoint_id(self, coords: Sequence[int], c: int) -> int:
        if not 0 <= c < self.concentration:
            raise ValueError(f"endpoint offset {c} out of range")
        return self.switch_id(coords) * self.concentration + c

    def endpoint_switch(self, e: int) -> int:
        return e // self.concentration

    def endpoint_offset(self, e: int) -> int:
        return e % self.concentration

    # --------------------------------------------------------------- distance
    def distance(self, a: int, b: int) -> int:
        """Hamming (graph) distance between two switch ids."""
        ca, cb = self.switch_coords(a), self.switch_coords(b)
        return sum(x != y for x, y in zip(ca, cb))

    def endpoint_distance(self, e1: int, e2: int) -> int:
        return self.distance(self.endpoint_switch(e1), self.endpoint_switch(e2))

    def all_switch_coords(self) -> np.ndarray:
        """(num_switches, q) int array of coordinates, slowest dim first."""
        grids = np.meshgrid(
            *[np.arange(self.n)] * self.q, indexing="ij"
        )
        return np.stack([g.ravel() for g in grids], axis=-1)

    def distance_matrix(self) -> np.ndarray:
        """(S, S) Hamming distance matrix over switches (vectorized)."""
        coords = self.all_switch_coords()  # (S, q)
        return (coords[:, None, :] != coords[None, :, :]).sum(axis=-1)

    # ------------------------------------------------------------------ links
    def links(self) -> Iterator[Tuple[int, int]]:
        """Yield each undirected switch link once as (low_id, high_id)."""
        for s in range(self.num_switches):
            coords = self.switch_coords(s)
            for dim in range(self.q):
                for v in range(coords[dim] + 1, self.n):
                    other = list(coords)
                    other[dim] = v
                    yield (s, self.switch_id(other))

    def link_array(self) -> np.ndarray:
        """(L, 2) array of undirected links."""
        return np.array(list(self.links()), dtype=np.int64)

    def neighbors(self, s: int) -> list[int]:
        coords = self.switch_coords(s)
        out = []
        for dim in range(self.q):
            for v in range(self.n):
                if v != coords[dim]:
                    other = list(coords)
                    other[dim] = v
                    out.append(self.switch_id(other))
        return out

    def link_index(self) -> dict[Tuple[int, int], int]:
        """Map each *directed* (src, dst) switch pair at distance 1 to a dense id.

        Directed links: 2 * num_links entries.  Used by routing/link-load code.
        """
        idx = {}
        for a, b in self.links():
            idx[(a, b)] = len(idx)
            idx[(b, a)] = len(idx)
        return idx

    # ------------------------------------------------------------- directions
    def unaligned_dims(self, src: int, dst: int) -> list[int]:
        cs, cd = self.switch_coords(src), self.switch_coords(dst)
        return [i for i in range(self.q) if cs[i] != cd[i]]

    def move(self, s: int, dim: int, value: int) -> int:
        coords = list(self.switch_coords(s))
        coords[dim] = value
        return self.switch_id(coords)

    def minimal_paths(self, src: int, dst: int) -> list[list[int]]:
        """All minimal switch paths src -> dst (each a list of switch ids)."""
        dims = self.unaligned_dims(src, dst)
        cd = self.switch_coords(dst)
        paths = []
        for order in itertools.permutations(dims):
            cur, path = src, [src]
            for dim in order:
                cur = self.move(cur, dim, cd[dim])
                path.append(cur)
            paths.append(path)
        # dedupe (permutations of equal dims can't collide here, but be safe)
        uniq = []
        seen = set()
        for p in paths:
            t = tuple(p)
            if t not in seen:
                seen.add(t)
                uniq.append(p)
        return uniq

    def __repr__(self) -> str:  # keep dataclass repr short in logs
        return f"HyperX(n={self.n}, q={self.q}, c={self.concentration})"
