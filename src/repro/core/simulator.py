"""Cycle-driven HyperX network simulator, vectorized in JAX.

The paper evaluates allocation strategies with CAMINOS, an event-driven
flit-level simulator.  An event queue is hostile to JAX; the TPU-native
re-think used here is a fully vectorized, cycle-driven simulator operating
at *packet-time* granularity:

  * one simulator step = the service time of one packet on one link
    (16 flit-cycles in the paper's configuration).  Every directed link and
    every ejection port moves at most one packet per step, which makes link
    bandwidth exact at packet granularity; phit-level interleaving inside a
    packet is abstracted away.
  * switches are input-queued: one FIFO per (input port, VC pool, hop-VC).
    Hop-indexed virtual channels (a packet that has taken h hops occupies
    VC h; with Omni-WAR's hop limit q+m this needs q+m+1 VCs) make the
    buffer dependency graph acyclic => deadlock freedom, mirroring the
    escape VCs real HyperX routers use.
  * VC *pools* implement the paper's fabric partitioning (Sec. 6.3.3): each
    pool has private FIFOs per input port, so traffic in other pools cannot
    HoL-block it, but all pools share physical link bandwidth.
  * routing is MIN or Omni-WAR: moves only in unaligned dimensions; the
    minimal hop of a dimension is preferred over deroutes through an
    occupancy cost with a deroute penalty (paper: P = 64 phits = 4 packets);
    at most m = q deroutes per packet.
  * output arbitration is random among requesting queue heads (paper
    Table 2: "Allocator: Random"); internal speedup is modeled by letting
    different VC queues of one input port win different outputs in the same
    cycle.
  * injection: each endpoint owns an injection queue and may inject one
    packet per step (1 packet/packet-time == 1 phit/cycle, the paper's
    maximum injection rate).
  * the step/dependency engine executes Workload step tables (traffic.py):
    windows, multi-destination steps, receive counts, infinite background
    sources.

Everything is fixed-shape and jit-compiled; a whole simulation is one
``lax.while_loop``.  See DESIGN.md §6 for the fidelity deviations from
CAMINOS and their rationale.
"""

from __future__ import annotations

import dataclasses
import functools
from typing import NamedTuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.hyperx import HyperX
from repro.core.traffic import Workload

I32 = jnp.int32
U32 = jnp.uint32


class SimState(NamedTuple):
    t: jnp.ndarray            # () int32 — current packet-time
    key: jnp.ndarray          # PRNG key
    # queue field arrays, flat (NQ * CAP,)
    f_dst: jnp.ndarray        # destination endpoint id
    f_der: jnp.ndarray        # deroutes left
    f_hop: jnp.ndarray        # hops taken
    f_rank: jnp.ndarray       # source rank
    f_step: jnp.ndarray       # source step index
    f_birth: jnp.ndarray      # injection time
    qhead: jnp.ndarray        # (NQ,) ring head
    qlen: jnp.ndarray         # (NQ,) occupancy
    busy: jnp.ndarray         # (S*OUT,) output-buffer tokens (2x speedup)
    # per-rank step engine
    cur_step: jnp.ndarray     # (R,)
    dst_i: jnp.ndarray        # (R,)
    pkt_i: jnp.ndarray        # (R,)
    completed: jnp.ndarray    # (R,) first incomplete step pointer
    sent: jnp.ndarray         # ((R+1)*T,) delivered sends per (rank, step)
    got: jnp.ndarray          # ((R+1)*T,) received packets per (rank, step)
    # metrics
    lat_sum: jnp.ndarray      # () float32 sum of target packet latencies
    n_delivered: jnp.ndarray  # () target packets delivered
    n_injected: jnp.ndarray   # () packets injected (all sources)
    hop_sum: jnp.ndarray      # () network hops of delivered target packets


@dataclasses.dataclass(frozen=True)
class SimResult:
    makespan: int             # packet-times until all target ranks completed
    makespan_cycles: int      # flit-cycles (x packet size)
    delivered: int            # target packets delivered
    injected: int             # packets injected (targets + background)
    avg_latency: float        # packet-times, target packets
    avg_hops: float           # network hops per delivered target packet
    completed: bool           # all target ranks finished within horizon


PACKET_FLITS = 16  # paper Table 2: packet size 16 flits


def build_simulator(
    topo: HyperX,
    wl: Workload,
    mode: str = "omniwar",
    max_deroutes: int | None = None,
    cap: int = 8,
    penalty_packets: int = 4,
    horizon: int = 60_000,
):
    """Compile a simulator for one workload shape; returns run(seed)->SimResult.

    The returned callable re-traces only when the *shapes* of the workload
    change, so sweeps over strategies (same k, same pattern) share one
    compilation.
    """
    n, q, conc = topo.n, topo.q, topo.concentration
    S = topo.num_switches
    E = topo.num_endpoints
    IN = q * n + conc          # network input ports (dense dim*val) + injection
    OUT = q * n + conc         # network output ports + ejection per offset
    P = wl.num_pools
    m = q if max_deroutes is None else max_deroutes
    V = q + m + 1              # hop-indexed VCs (deadlock freedom)
    NQ = S * IN * P * V
    H = NQ                     # one potential head per queue
    R, T, MAXD = wl.R, wl.T, wl.maxd
    CAP = cap
    PEN = penalty_packets * 8  # cost scale: occupancy*8 + jitter(3 bits)
    BIGCOST = jnp.int32(1 << 28)
    use_min = mode == "min"
    if mode not in ("min", "omniwar"):
        raise ValueError(f"unknown routing mode {mode!r}")

    # ---------------- static topology tables (jnp constants) ---------------
    coords_np = topo.all_switch_coords()                       # (S, q)
    nbr = np.empty((S, q * n), dtype=np.int32)                 # dst switch
    in_port_at_nb = np.empty((S, q * n), dtype=np.int32)       # arrival port
    for d in range(q):
        for v in range(n):
            nc = coords_np.copy()
            nc[:, d] = v
            ids = np.zeros(S, dtype=np.int64)
            for d2 in range(q):
                ids = ids * n + nc[:, d2]
            nbr[:, d * n + v] = ids
            in_port_at_nb[:, d * n + v] = d * n + coords_np[:, d]
    coords = jnp.asarray(coords_np, dtype=I32)                 # (S, q)
    nbr = jnp.asarray(nbr)
    in_port_at_nb = jnp.asarray(in_port_at_nb)
    port_dim = jnp.asarray(np.arange(q * n) // n, dtype=I32)   # (q*n,)
    port_val = jnp.asarray(np.arange(q * n) % n, dtype=I32)

    # ---------------- head index decomposition (constants) -----------------
    h_idx = np.arange(H, dtype=np.int64)
    h_vc = jnp.asarray(h_idx % V, dtype=I32)
    h_pool = jnp.asarray((h_idx // V) % P, dtype=I32)
    h_port = jnp.asarray((h_idx // (V * P)) % IN, dtype=I32)
    h_sw = jnp.asarray(h_idx // (V * P * IN), dtype=I32)

    # ---------------- workload tables --------------------------------------
    rank_ep = jnp.asarray(wl.rank_ep, dtype=I32)               # (R,)
    ep_rank = np.full(E, -1, dtype=np.int32)
    ep_rank[wl.rank_ep] = np.arange(R)
    ep_rank = jnp.asarray(ep_rank)
    pool_of_rank = jnp.asarray(wl.pool, dtype=I32)
    finite = jnp.asarray(~wl.infinite)
    window = jnp.asarray(wl.window, dtype=I32)
    start_t = jnp.asarray(wl.start, dtype=I32)
    warmup = int(wl.start.max())
    sends_dst = jnp.asarray(wl.sends_dst.reshape(R, T * MAXD), dtype=I32)
    npkts = jnp.asarray(wl.npkts.reshape(R, T * MAXD), dtype=I32)
    deg = jnp.asarray(wl.deg, dtype=I32)                       # (R, T)
    recv_need = jnp.asarray(wl.recv_need.reshape(R * T), dtype=I32)
    total_sends = jnp.asarray(wl.total_sends.reshape(R * T), dtype=I32)
    sampled = jnp.asarray(wl.sampled.reshape(R, T * MAXD))
    smp_lo = jnp.asarray(wl.lo.reshape(R, T * MAXD), dtype=I32)
    smp_hi = jnp.asarray(wl.hi.reshape(R, T * MAXD), dtype=I32)

    # endpoint -> injection queue (pool of its rank, VC 0)
    e_ids = np.arange(E)
    e_sw = e_ids // conc
    e_port = q * n + (e_ids % conc)
    inj_qi_np = ((e_sw * IN + e_port) * P) * V  # + pool*V later (pool varies)
    inj_base = jnp.asarray(inj_qi_np, dtype=I32)

    OOB = jnp.int32(NQ * CAP + 5)  # safely out of bounds => dropped scatters

    def step(state: SimState) -> SimState:
        t = state.t
        key = jax.random.fold_in(state.key, t)
        k_arb, k_jit, k_smp = jax.random.split(key, 3)

        qlen, qhead = state.qlen, state.qhead
        # per-(switch, in-port) total occupancy (packets over all pools+VCs):
        # the adaptive-routing congestion signal (CAMINOS counts phits in the
        # whole input buffer; penalty/range ratio ~1/8 is preserved).
        port_occ = qlen.reshape(S * IN, P * V).sum(axis=1)

        # ---------------- heads --------------------------------------------
        exists = qlen > 0                                   # (H,)
        slot = jnp.arange(H, dtype=I32) * CAP + qhead
        dst = state.f_dst[slot]
        der = state.f_der[slot]
        hop = state.f_hop[slot]
        dsw = dst // conc
        dof = dst % conc

        cur = h_sw
        at_dst = cur == dsw

        # ---------------- routing: candidate network ports -----------------
        ccur = coords[cur]                                  # (H, q)
        cdst = coords[dsw]                                  # (H, q)
        pv = port_val[None, :]                              # (1, q*n)
        cur_d = ccur[:, port_dim]                           # (H, q*n)
        dst_d = cdst[:, port_dim]
        unaligned = cur_d != dst_d                          # (H, q*n)
        not_self = pv != cur_d
        is_min = (pv == dst_d) & unaligned
        nb = nbr[cur]                                       # (H, q*n)
        ipnb = in_port_at_nb[cur]                           # (H, q*n)
        vc_next = jnp.minimum(hop + 1, V - 1)[:, None]      # (H, 1)
        qi_down = ((nb * IN + ipnb) * P + h_pool[:, None]) * V + vc_next
        room = qlen[qi_down] < CAP                          # own queue has space
        occ = port_occ[nb * IN + ipnb]                      # congestion signal
        busy = jnp.maximum(state.busy - 1, 0)               # link served 1 pkt
        avail_net = busy[cur[:, None] * OUT + jnp.arange(q * n)[None, :]] < 2
        if use_min:
            legal = is_min & room & avail_net
        else:
            legal = (
                unaligned & not_self & (is_min | (der[:, None] > 0))
                & room & avail_net
            )
        jitter = jax.random.randint(k_jit, (H, q * n), 0, 8, dtype=I32)
        cost = occ * 8 + PEN * (~is_min) + jitter
        cost = jnp.where(legal, cost, BIGCOST)
        best = jnp.argmin(cost, axis=1).astype(I32)         # (H,)
        best_cost = jnp.take_along_axis(cost, best[:, None], 1)[:, 0]
        has_port = best_cost < BIGCOST
        best_min = jnp.take_along_axis(is_min, best[:, None], 1)[:, 0]

        out_port = jnp.where(at_dst, q * n + dof, best)
        requesting = exists & (at_dst | has_port)
        requesting = requesting & (busy[cur * OUT + out_port] < 2)
        # NOTE: scatter/gather OOB markers must be POSITIVE out-of-range —
        # negative indices wrap NumPy-style in jnp .at[] even with mode='drop'.
        OOB_OUT = jnp.int32(S * OUT + 1)
        req_out = jnp.where(requesting, cur * OUT + out_port, OOB_OUT)
        req_out_safe = jnp.minimum(req_out, S * OUT - 1)

        # ------------- iterative random arbitration (2x internal speedup) --
        # Round 1: every head requests its best port; one random winner per
        # output.  Round 2 (separable-allocator iteration + the paper's 2x
        # crossbar speedup): losers re-route to their best port that still
        # has output tokens, enabling a second grant per cycle per output.
        # The `busy` token bucket keeps sustained link rate at 1 pkt/time.
        arb_key = jax.random.bits(k_arb, (H,), dtype=U32) >> 17  # 15 bits
        packed = (arb_key << 17) | jnp.arange(H, dtype=U32)
        INVALID = jnp.uint32(0xFFFFFFFF)
        grant1 = jnp.full(S * OUT, INVALID)
        grant1 = grant1.at[req_out].min(packed, mode="drop")
        won1 = requesting & (grant1[req_out_safe] == packed)

        qi_best1 = jnp.take_along_axis(qi_down, best[:, None], 1)[:, 0]
        arr1 = jnp.zeros(NQ, dtype=I32).at[
            jnp.where(won1 & ~at_dst, qi_best1, NQ + 1)
        ].add(1, mode="drop")
        g1 = jnp.zeros(S * OUT, dtype=I32).at[
            jnp.where(won1, req_out, OOB_OUT)
        ].add(1, mode="drop")
        tokens = (2 - busy) - g1                            # remaining slots

        loser = requesting & ~won1
        # re-route: best legal port with tokens left and downstream room
        # (accounting for the round-1 arrival into the same queue)
        tok_net = tokens[cur[:, None] * OUT + jnp.arange(q * n)[None, :]] > 0
        room_2 = qlen[qi_down] + arr1[qi_down] < CAP
        cost2 = jnp.where(legal & tok_net & room_2, cost, BIGCOST)
        best2 = jnp.argmin(cost2, axis=1).astype(I32)
        has2 = jnp.take_along_axis(cost2, best2[:, None], 1)[:, 0] < BIGCOST
        ej_ok = at_dst & (tokens[cur * OUT + q * n + dof] > 0)
        out2 = jnp.where(at_dst, q * n + dof, best2)
        req2 = loser & jnp.where(at_dst, ej_ok, has2)
        req_out2 = jnp.where(req2, cur * OUT + out2, OOB_OUT)
        req_out2_safe = jnp.minimum(req_out2, S * OUT - 1)
        grant2 = jnp.full(S * OUT, INVALID)
        grant2 = grant2.at[req_out2].min(packed, mode="drop")
        won2 = req2 & (grant2[req_out2_safe] == packed)
        won = won1 | won2

        # final chosen queue / minimality per winner
        qi_best = jnp.where(
            won2,
            jnp.take_along_axis(qi_down, jnp.minimum(best2, q * n - 1)[:, None], 1)[:, 0],
            qi_best1,
        )
        best_min = jnp.where(
            won2,
            jnp.take_along_axis(is_min, jnp.minimum(best2, q * n - 1)[:, None], 1)[:, 0],
            best_min,
        )

        # output token update: +1 per grant (burst absorbed by 2x speedup)
        gcount = g1.at[jnp.where(won2, req_out2, OOB_OUT)].add(1, mode="drop")
        busy = busy + gcount

        # ---------------- dequeue winners ----------------------------------
        qhead = jnp.where(won, (qhead + 1) % CAP, qhead)
        dlen = jnp.zeros(NQ, dtype=I32).at[jnp.arange(H)].add(-won.astype(I32))

        # ---------------- deliveries (ejection winners) --------------------
        eject = won & at_dst
        rank = state.f_rank[slot]
        pstep = state.f_step[slot]
        src_finite = finite[rank]
        # sender-side accounting row (infinite sources -> trash row R)
        send_row = jnp.where(src_finite, rank, R)
        OOB_RT = jnp.int32((R + 1) * T + 1)
        sent = state.sent.at[
            jnp.where(eject, send_row * T + pstep, OOB_RT)
        ].add(1, mode="drop")
        drank = ep_rank[dst]
        drank_ok = (drank >= 0) & finite[jnp.maximum(drank, 0)]
        recv_row = jnp.where(drank_ok, drank, R)
        got = state.got.at[
            jnp.where(eject, recv_row * T + pstep, OOB_RT)
        ].add(1, mode="drop")
        tgt_del = eject & src_finite
        lat_sum = state.lat_sum + jnp.sum(
            jnp.where(tgt_del, (t - state.f_birth[slot]).astype(jnp.float32), 0.0)
        )
        hop_sum = state.hop_sum + jnp.sum(jnp.where(tgt_del, hop, 0))
        n_delivered = state.n_delivered + jnp.sum(tgt_del)

        # ---------------- network moves (enqueue downstream) ---------------
        net = won & ~at_dst
        tgt_qi = qi_best
        # ring tail = head_pre + len_pre, invariant under same-cycle dequeue;
        # a round-2 arrival lands one slot behind the round-1 arrival.
        tgt_slot = (
            state.qhead[tgt_qi] + qlen[tgt_qi]
            + jnp.where(won2, arr1[tgt_qi], 0)
        ) % CAP
        tgt_flat = jnp.where(net, tgt_qi * CAP + tgt_slot, OOB)
        f_dst = state.f_dst.at[tgt_flat].set(dst, mode="drop")
        f_der = state.f_der.at[tgt_flat].set(der - (~best_min), mode="drop")
        f_hop = state.f_hop.at[tgt_flat].set(hop + 1, mode="drop")
        f_rank = state.f_rank.at[tgt_flat].set(rank, mode="drop")
        f_step = state.f_step.at[tgt_flat].set(pstep, mode="drop")
        f_birth = state.f_birth.at[tgt_flat].set(state.f_birth[slot], mode="drop")
        dlen = dlen.at[jnp.where(net, tgt_qi, NQ + 1)].add(1, mode="drop")

        # ---------------- step-engine: completion pointers ------------------
        completed = state.completed
        for _ in range(4):
            pidx = jnp.arange(R, dtype=I32) * T + jnp.minimum(completed, T - 1)
            comp = (completed >= T) | (
                (sent[pidx] >= total_sends[pidx]) & (got[pidx] >= recv_need[pidx])
            )
            completed = completed + (finite & (completed < T) & comp)

        # skip empty (padded) steps
        cs = state.cur_step
        cs_deg = deg[jnp.arange(R), jnp.minimum(cs, T - 1)]
        cs = cs + (finite & (cs < T) & (cs_deg == 0))

        # ---------------- injection ----------------------------------------
        r_of_e = ep_rank                                    # (E,)
        r_safe = jnp.maximum(r_of_e, 0)
        e_fin = finite[r_safe]
        e_cs = jnp.where(e_fin, cs[r_safe], 0)
        e_di = jnp.where(e_fin, state.dst_i[r_safe], 0)
        e_pk = jnp.where(e_fin, state.pkt_i[r_safe], 0)
        flat_td = jnp.minimum(e_cs, T - 1) * MAXD + e_di
        e_deg = deg[r_safe, jnp.minimum(e_cs, T - 1)]
        e_np = npkts[r_safe, flat_td]
        in_window = e_cs < jnp.minimum(
            jnp.asarray(T, I32), completed[r_safe] + window[r_safe]
        )
        has_work = jnp.where(e_fin, (e_cs < T) & (e_di < e_deg) & in_window, True)
        has_work = has_work & (t >= start_t[r_safe])
        inj_qi = inj_base + pool_of_rank[r_safe] * V
        has_room = qlen[inj_qi] + dlen[inj_qi] < CAP  # dlen: arrivals this cycle
        do_inj = (r_of_e >= 0) & has_work & has_room

        d_fixed = sends_dst[r_safe, flat_td]
        rspan = jnp.maximum(smp_hi[r_safe, flat_td] - smp_lo[r_safe, flat_td], 1)
        rnd = jax.random.bits(k_smp, (E,), dtype=U32)
        d_smp = smp_lo[r_safe, flat_td] + (rnd % rspan.astype(U32)).astype(I32)
        d_rank = jnp.where(sampled[r_safe, flat_td], d_smp, d_fixed)
        d_rank = jnp.clip(d_rank, 0, R - 1)
        d_ep = rank_ep[d_rank]

        inj_flat = jnp.where(
            do_inj, inj_qi * CAP + (state.qhead[inj_qi] + qlen[inj_qi]) % CAP,
            OOB,
        )
        f_dst = f_dst.at[inj_flat].set(d_ep, mode="drop")
        f_der = f_der.at[inj_flat].set(jnp.int32(m), mode="drop")
        f_hop = f_hop.at[inj_flat].set(0, mode="drop")
        f_rank = f_rank.at[inj_flat].set(r_safe, mode="drop")
        f_step = f_step.at[inj_flat].set(jnp.where(e_fin, e_cs, 0), mode="drop")
        f_birth = f_birth.at[inj_flat].set(t, mode="drop")
        dlen = dlen.at[jnp.where(do_inj, inj_qi, NQ + 1)].add(1, mode="drop")
        n_injected = state.n_injected + jnp.sum(do_inj)

        # cursor advance for finite injecting ranks
        adv = do_inj & e_fin
        pk2 = jnp.where(adv, e_pk + 1, e_pk)
        move_d = adv & (pk2 >= e_np)
        di2 = jnp.where(move_d, e_di + 1, e_di)
        pk2 = jnp.where(move_d, 0, pk2)
        move_s = move_d & (di2 >= e_deg)
        cs2 = jnp.where(move_s, e_cs + 1, e_cs)
        di2 = jnp.where(move_s, 0, di2)
        # scatter back to rank arrays (each finite rank has exactly 1 endpoint)
        upd = jnp.where((r_of_e >= 0) & e_fin, r_of_e, R + 5)
        cur_step = cs.at[upd].set(cs2, mode="drop")
        dst_i = state.dst_i.at[upd].set(di2, mode="drop")
        pkt_i = state.pkt_i.at[upd].set(pk2, mode="drop")

        return SimState(
            t=t + 1, key=state.key,
            f_dst=f_dst, f_der=f_der, f_hop=f_hop, f_rank=f_rank,
            f_step=f_step, f_birth=f_birth,
            qhead=qhead, qlen=qlen + dlen, busy=busy,
            cur_step=cur_step, dst_i=dst_i, pkt_i=pkt_i, completed=completed,
            sent=sent, got=got,
            lat_sum=lat_sum, n_delivered=n_delivered, n_injected=n_injected,
            hop_sum=hop_sum,
        )

    def all_done(state: SimState) -> jnp.ndarray:
        return jnp.all(jnp.where(finite, state.completed >= T, True))

    def cond(state: SimState) -> jnp.ndarray:
        return (state.t < horizon) & ~all_done(state)

    @jax.jit
    def run(seed: jnp.ndarray) -> tuple:
        z = functools.partial(jnp.zeros, dtype=I32)
        state = SimState(
            t=jnp.int32(0), key=jax.random.PRNGKey(seed),
            f_dst=z(NQ * CAP), f_der=z(NQ * CAP), f_hop=z(NQ * CAP),
            f_rank=z(NQ * CAP), f_step=z(NQ * CAP), f_birth=z(NQ * CAP),
            qhead=z(NQ), qlen=z(NQ), busy=z(S * OUT),
            cur_step=z(R), dst_i=z(R), pkt_i=z(R), completed=z(R),
            sent=z((R + 1) * T), got=z((R + 1) * T),
            lat_sum=jnp.float32(0.0),
            n_delivered=jnp.int32(0), n_injected=jnp.int32(0),
            hop_sum=jnp.int32(0),
        )
        final = jax.lax.while_loop(cond, step, state)
        return (
            final.t, all_done(final), final.n_delivered, final.n_injected,
            final.lat_sum, final.hop_sum,
        )

    def run_debug(seed: int = 0, steps: int = 512, stride: int = 16):
        """Scan ``steps`` cycles; return per-stride (delivered, injected, qsum)."""

        def body(state, _):
            s2 = step(state)
            return s2, (s2.n_delivered, s2.n_injected, s2.qlen.sum())

        z = functools.partial(jnp.zeros, dtype=I32)
        state = SimState(
            t=jnp.int32(0), key=jax.random.PRNGKey(seed),
            f_dst=z(NQ * CAP), f_der=z(NQ * CAP), f_hop=z(NQ * CAP),
            f_rank=z(NQ * CAP), f_step=z(NQ * CAP), f_birth=z(NQ * CAP),
            qhead=z(NQ), qlen=z(NQ), busy=z(S * OUT),
            cur_step=z(R), dst_i=z(R), pkt_i=z(R), completed=z(R),
            sent=z((R + 1) * T), got=z((R + 1) * T),
            lat_sum=jnp.float32(0.0),
            n_delivered=jnp.int32(0), n_injected=jnp.int32(0),
            hop_sum=jnp.int32(0),
        )
        final, (d, i, qs) = jax.lax.scan(body, state, None, length=steps)
        return final, np.asarray(d)[::stride], np.asarray(i)[::stride], np.asarray(qs)[::stride]

    def run_result(seed: int = 0) -> SimResult:
        t, done, ndel, ninj, lat, hops = (
            np.asarray(x) for x in run(jnp.int32(seed))
        )
        ndel = int(ndel)
        return SimResult(
            makespan=int(t) - warmup,
            makespan_cycles=(int(t) - warmup) * PACKET_FLITS,
            delivered=ndel,
            injected=int(ninj),
            avg_latency=float(lat) / max(ndel, 1),
            avg_hops=float(hops) / max(ndel, 1),
            completed=bool(done),
        )

    run_result.debug = run_debug
    return run_result


def simulate(
    topo: HyperX,
    wl: Workload,
    mode: str = "omniwar",
    seed: int = 0,
    **kw,
) -> SimResult:
    """One-shot convenience wrapper around build_simulator."""
    return build_simulator(topo, wl, mode=mode, **kw)(seed)
