"""Cycle-driven HyperX network simulator — backward-compatible facade.

The simulator core lives in :mod:`repro.core.engine`: static topology
structure is compiled once per configuration (:mod:`engine.tables`), every
per-workload array travels as a pytree jit argument
(:mod:`engine.workload_tables`), the cycle kernel is
:func:`engine.step.build_step`, and :class:`engine.SimEngine` offers
``run`` / ``run_batch`` / ``run_seeds`` with vmapped whole-simulation
batching.  See the engine package docstrings and DESIGN.md §6 for the
physics and its fidelity deviations from CAMINOS.

This module keeps the original seed API alive:

  * ``build_simulator(topo, wl, ...) -> run(seed) -> SimResult`` — now a
    thin wrapper that *genuinely* shares one compilation across workloads
    of the same shape bucket (the seed version re-traced per workload
    because tables were closure constants);
  * ``simulate(topo, wl, ...)`` — one-shot convenience;
  * re-exports of ``SimState``, ``SimResult``, ``PACKET_FLITS``.
"""

from __future__ import annotations

from repro.core.engine import (  # noqa: F401  (re-exports are the API)
    PACKET_FLITS,
    SimEngine,
    SimResult,
    SimState,
    get_engine,
)
from repro.core.hyperx import HyperX
from repro.core.traffic import Workload


def build_simulator(
    topo: HyperX,
    wl: Workload,
    mode: str = "omniwar",
    max_deroutes: int | None = None,
    cap: int = 8,
    penalty_packets: int = 4,
    horizon: int = 60_000,
):
    """Prepare a simulator for one workload; returns run(seed)->SimResult.

    The underlying engine is memoised per configuration and re-traces only
    when the workload's shape *bucket* is new, so sweeps over strategies
    (same kernel, same job size) share one compilation and one engine.
    """
    engine = get_engine(
        topo, mode=mode, num_pools=wl.num_pools, max_deroutes=max_deroutes,
        cap=cap, penalty_packets=penalty_packets,
    )
    prep = engine.prepare(wl)

    def run_result(seed: int = 0) -> SimResult:
        return engine.run(prep, seed=seed, horizon=horizon)

    def run_debug(seed: int = 0, steps: int = 512, stride: int = 16):
        return engine.run_debug(prep, seed=seed, steps=steps, stride=stride)

    run_result.debug = run_debug
    run_result.engine = engine
    run_result.prepared = prep
    return run_result


def simulate(
    topo: HyperX,
    wl: Workload,
    mode: str = "omniwar",
    seed: int = 0,
    **kw,
) -> SimResult:
    """One-shot convenience wrapper around build_simulator."""
    return build_simulator(topo, wl, mode=mode, **kw)(seed)
