"""Topological properties of HyperX partitions (paper Section 5).

Implements, for an arbitrary set of allocated endpoints:

  * average / maximum intra-partition distance (paper Eq. 2, self-pairs
    included by convention),
  * convexity / weak convexity (Definition 2),
  * switch locality (Definition 3),
  * convex hull links (Definition 4),
  * partition bandwidth PB (Eq. 3), including the per-dimension refinement
    the paper applies to the Rectangular tessellation.

Everything is vectorized numpy over the (at most n**q) switches involved.
"""

from __future__ import annotations

import dataclasses
from typing import Iterable

import numpy as np

from repro.core.allocation import Partition
from repro.core.hyperx import HyperX


# --------------------------------------------------------------------------
# Distances
# --------------------------------------------------------------------------
def endpoint_distance_stats(topo: HyperX, endpoints: np.ndarray) -> tuple[float, int]:
    """(average, maximum) distance over ordered endpoint pairs incl. self.

    This is Eq. (2): D_P = (1/|P|^2) * sum_{e1,e2} d(e1,e2).  (The paper
    writes 1/|P| but normalizes by the pair count in all derived values;
    we use the pair count so Row gives exactly 1 - 1/n.)
    """
    endpoints = np.asarray(endpoints)
    switches = endpoints // topo.concentration
    coords = np.stack([np.array(topo.switch_coords(int(s))) for s in np.unique(switches)])
    uniq, counts = np.unique(switches, return_counts=True)
    # pairwise switch distances weighted by endpoint multiplicity
    dmat = (coords[:, None, :] != coords[None, :, :]).sum(-1)
    w = counts.astype(np.float64)
    total = (w[:, None] * w[None, :] * dmat).sum()
    avg = total / (len(endpoints) ** 2)
    dmax = int(dmat.max()) if len(uniq) > 1 else 0
    return float(avg), dmax


def per_dimension_distance(topo: HyperX, endpoints: np.ndarray) -> np.ndarray:
    """(q,) average hop count per dimension over ordered endpoint pairs."""
    endpoints = np.asarray(endpoints)
    switches = endpoints // topo.concentration
    uniq, counts = np.unique(switches, return_counts=True)
    coords = np.stack([np.array(topo.switch_coords(int(s))) for s in uniq])
    w = counts.astype(np.float64)
    out = np.zeros(topo.q)
    for d in range(topo.q):
        diff = (coords[:, None, d] != coords[None, :, d]).astype(np.float64)
        out[d] = (w[:, None] * w[None, :] * diff).sum() / (len(endpoints) ** 2)
    return out


# --------------------------------------------------------------------------
# Convexity
# --------------------------------------------------------------------------
def interval_vertices(topo: HyperX, u: int, v: int) -> list[int]:
    """All switches on some minimal path u -> v (the Hamming 'interval')."""
    cu, cv = topo.switch_coords(u), topo.switch_coords(v)
    verts = [()]
    for a, b in zip(cu, cv):
        choices = (a,) if a == b else (a, b)
        verts = [t + (c,) for t in verts for c in choices]
    return [topo.switch_id(t) for t in verts]


def is_convex(topo: HyperX, switches: Iterable[int]) -> bool:
    """True iff every minimal path between members stays inside the set."""
    sset = set(int(s) for s in switches)
    slist = sorted(sset)
    for i, u in enumerate(slist):
        for v in slist[i + 1 :]:
            if not all(w in sset for w in interval_vertices(topo, u, v)):
                return False
    return True


def is_weakly_convex(topo: HyperX, switches: Iterable[int]) -> bool:
    """True iff at least one minimal path between members stays inside."""
    sset = set(int(s) for s in switches)
    slist = sorted(sset)
    for i, u in enumerate(slist):
        for v in slist[i + 1 :]:
            if not _reachable_minimally(topo, u, v, sset):
                return False
    return True


def _reachable_minimally(topo: HyperX, u: int, v: int, allowed: set[int]) -> bool:
    """BFS from u to v using only minimal-path moves inside ``allowed``."""
    target = topo.switch_coords(v)
    frontier = {u}
    dist = topo.distance(u, v)
    for _ in range(dist):
        nxt = set()
        for s in frontier:
            cs = topo.switch_coords(s)
            for dim in range(topo.q):
                if cs[dim] != target[dim]:
                    cand = topo.move(s, dim, target[dim])
                    if cand in allowed:
                        nxt.add(cand)
        if not nxt:
            return False
        frontier = nxt
    return v in frontier


def convexity_class(topo: HyperX, switches: Iterable[int]) -> str:
    if is_convex(topo, switches):
        return "convex"
    if is_weakly_convex(topo, switches):
        return "weakly-convex"
    return "non-convex"


def has_switch_locality(topo: HyperX, endpoints: np.ndarray) -> bool:
    """Definition 3: every touched switch contributes ALL its endpoints."""
    endpoints = np.asarray(endpoints)
    switches = endpoints // topo.concentration
    uniq, counts = np.unique(switches, return_counts=True)
    return bool((counts == topo.concentration).all())


# --------------------------------------------------------------------------
# Convex hull and partition bandwidth
# --------------------------------------------------------------------------
def convex_hull_links(topo: HyperX, switches: Iterable[int]) -> np.ndarray:
    """(L, 2) undirected links on some shortest path between members (Def. 4)."""
    slist = np.array(sorted(set(int(s) for s in switches)), dtype=np.int64)
    if len(slist) == 0:
        return np.zeros((0, 2), dtype=np.int64)
    links = topo.link_array()  # (L, 2)
    dmat = topo.distance_matrix()  # (S, S)
    a, b = links[:, 0], links[:, 1]
    # link (a,b) on a shortest u->v path iff d(u,a)+1+d(b,v) == d(u,v)
    # (checked in both directions since links are undirected)
    du_a = dmat[np.ix_(slist, a)]  # (P, L)
    du_b = dmat[np.ix_(slist, b)]
    duv = dmat[np.ix_(slist, slist)]  # (P, P)
    on_path = np.zeros(len(links), dtype=bool)
    # forward direction u -> a -> b -> v
    fwd = du_a[:, None, :] + 1 + du_b[None, :, :] == duv[:, :, None].transpose(1, 0, 2)
    bwd = du_b[:, None, :] + 1 + du_a[None, :, :] == duv[:, :, None].transpose(1, 0, 2)
    on_path = (fwd | bwd).any(axis=(0, 1))
    return links[on_path]


def link_dimension(topo: HyperX, links: np.ndarray) -> np.ndarray:
    """(L,) which dimension each link belongs to."""
    dims = np.empty(len(links), dtype=np.int64)
    for i, (a, b) in enumerate(links):
        ca, cb = topo.switch_coords(int(a)), topo.switch_coords(int(b))
        dims[i] = next(d for d in range(topo.q) if ca[d] != cb[d])
    return dims


@dataclasses.dataclass(frozen=True)
class PartitionProperties:
    """Bundle of everything Table 1 reports, for one concrete partition."""

    strategy: str
    avg_distance: float
    max_distance: int
    convexity: str
    switch_locality: bool
    hull_links: int
    partition_bandwidth: float  # per-dimension refined (phits/cycle/endpoint)
    partition_bandwidth_bound: float  # aggregate upper bound, Eq. (3)


def partition_bandwidth(
    topo: HyperX, endpoints: np.ndarray, per_dimension: bool = True
) -> tuple[float, float]:
    """(refined PB, aggregate Eq.3 bound) for a set of endpoints.

    Aggregate bound: PB <= 2L / (|P| * D_P).  The refined value applies the
    same bound per dimension (links of that dimension vs hops in that
    dimension) and takes the minimum, catching anisotropic partitions such
    as the Rectangular tessellation where the short dimension saturates
    first (paper Sec. 5.3).
    """
    endpoints = np.asarray(endpoints)
    switches = np.unique(endpoints // topo.concentration)
    hull = convex_hull_links(topo, switches)
    avg, _ = endpoint_distance_stats(topo, endpoints)
    if avg == 0:
        return float("inf"), float("inf")
    bound = 2.0 * len(hull) / (len(endpoints) * avg)
    if not per_dimension:
        return bound, bound
    dims = link_dimension(topo, hull)
    dim_dist = per_dimension_distance(topo, endpoints)
    vals = []
    for d in range(topo.q):
        if dim_dist[d] > 0:
            l_d = int((dims == d).sum())
            vals.append(2.0 * l_d / (len(endpoints) * dim_dist[d]))
    refined = min(vals) if vals else float("inf")
    return float(min(refined, bound)), float(bound)


def analyze_partition(topo: HyperX, part: Partition) -> PartitionProperties:
    avg, dmax = endpoint_distance_stats(topo, part.endpoints)
    pb, pb_bound = partition_bandwidth(topo, part.endpoints)
    hull = convex_hull_links(topo, part.switches)
    return PartitionProperties(
        strategy=part.strategy,
        avg_distance=avg,
        max_distance=dmax,
        convexity=convexity_class(topo, part.switches),
        switch_locality=has_switch_locality(topo, part.endpoints),
        hull_links=len(hull),
        partition_bandwidth=pb,
        partition_bandwidth_bound=pb_bound,
    )


# --------------------------------------------------------------------------
# Dilation of an application embedding (Definition 1)
# --------------------------------------------------------------------------
def dilation(
    topo: HyperX,
    app_edges: np.ndarray,
    rank_to_endpoint: np.ndarray,
) -> tuple[float, int]:
    """(average, maximum) dilation of application edges under an embedding.

    ``app_edges``: (E, 2) rank pairs; ``rank_to_endpoint``: (R,) endpoint ids.
    """
    app_edges = np.asarray(app_edges)
    if len(app_edges) == 0:
        return 0.0, 0
    e1 = rank_to_endpoint[app_edges[:, 0]] // topo.concentration
    e2 = rank_to_endpoint[app_edges[:, 1]] // topo.concentration
    coords = topo.all_switch_coords()
    d = (coords[e1] != coords[e2]).sum(-1)
    return float(d.mean()), int(d.max())
