"""Compatibility shim — the traffic subsystem lives in :mod:`repro.traffic`.

The workload side grew into a registry-driven subsystem mirroring
``repro/route/`` (see DESIGN.md §Traffic):

  * :mod:`repro.traffic.base`     — ``AppTraffic`` step tables, the
    ``TrafficPattern`` registry, phased composition;
  * :mod:`repro.traffic.patterns` — the shipped patterns (the paper's
    Sec. 6.1 set plus the adversarial/collective additions);
  * :mod:`repro.traffic.workload` — ``Workload`` / ``compose_workload``
    / ``background_noise`` machine-level merging;
  * :mod:`repro.traffic.scenario` — declarative ``ScenarioSpec`` layer.

Every pre-existing name keeps importing from here unchanged; new code
should import from :mod:`repro.traffic` directly.
"""

from repro.traffic.base import (  # noqa: F401
    AppTraffic,
    TrafficPattern,
    available_patterns,
    build_phases,
    concat_phases,
    get_pattern,
    register_pattern,
)
from repro.traffic.base import empty_tables as _empty  # noqa: F401
from repro.traffic.base import grid_shape as _grid_shape  # noqa: F401
from repro.traffic.patterns import (  # noqa: F401
    KERNELS,
    STATIC_PATTERNS,
    all_reduce,
    all_to_all,
    incast,
    random_involution,
    random_permutation,
    random_switch_permutation,
    recursive_doubling,
    ring_allreduce,
    shuffle,
    stencil,
    stencil_3d,
    tornado,
    transpose,
    uniform,
)
from repro.traffic.workload import (  # noqa: F401
    Workload,
    background_noise,
    compose_workload,
)
