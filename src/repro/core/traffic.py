"""Workload generation (paper Section 6.1).

Static traffic patterns and application communication kernels, expressed in a
single *step-table* form that the cycle-level simulator executes directly:

  * each rank walks an ordered list of steps; a step sends ``npkts`` packets
    to each of ``deg`` destinations and (optionally) must receive
    ``recv_need`` packets tagged with the same step index before the step is
    complete;
  * a sliding ``window`` limits how many incomplete steps a rank may have
    outstanding (1 = fully synchronous, T = fully asynchronous);
  * destinations are either fixed rank ids or sampled uniformly from a rank
    range each time a packet is injected (uniform / switch-permutation
    traffic).

Implemented static patterns (Sec. 6.1.1): uniform, random permutation,
random switch permutation.  Application kernels (Sec. 6.1.2): All-to-All,
Rabenseifner All-Reduce, von Neumann / Moore stencils, Random Involution.

``compose_workload`` merges several applications (each placed on a
Partition) plus optional background noise into one machine-level spec with
rank -> endpoint maps and per-partition VC pools (fabric partitioning,
Sec. 6.3.3).
"""

from __future__ import annotations

import dataclasses
import math
from typing import Sequence

import numpy as np

from repro.core.allocation import Partition
from repro.core.hyperx import HyperX


# --------------------------------------------------------------------------
# Per-application step tables (rank-local)
# --------------------------------------------------------------------------
@dataclasses.dataclass
class AppTraffic:
    """Step-table traffic of one application over ranks 0..k-1."""

    name: str
    k: int
    sends_dst: np.ndarray  # (k, T, MAXD) destination rank, -1 pad
    npkts: np.ndarray      # (k, T, MAXD) packets per destination
    deg: np.ndarray        # (k, T) number of valid destinations
    recv_need: np.ndarray  # (k, T) packets that must arrive before step done
    window: int            # max outstanding incomplete steps
    sampled: np.ndarray | None = None  # (k, T, MAXD) bool: sample dst?
    lo: np.ndarray | None = None       # (k, T, MAXD) sample range lo
    hi: np.ndarray | None = None       # (k, T, MAXD) sample range hi (excl)

    @property
    def T(self) -> int:
        return self.sends_dst.shape[1]

    @property
    def maxd(self) -> int:
        return self.sends_dst.shape[2]

    @property
    def total_packets(self) -> int:
        return int(self.npkts[self.sends_dst >= -1].sum())

    def __post_init__(self):
        if self.sampled is None:
            self.sampled = np.zeros_like(self.sends_dst, dtype=bool)
            self.lo = np.zeros_like(self.sends_dst)
            self.hi = np.zeros_like(self.sends_dst)


def _empty(k: int, T: int, maxd: int):
    return (
        np.full((k, T, maxd), -1, dtype=np.int64),
        np.zeros((k, T, maxd), dtype=np.int64),
        np.zeros((k, T), dtype=np.int64),
        np.zeros((k, T), dtype=np.int64),
    )


# ----------------------------------------------------------- static patterns
def uniform(k: int, packets: int = 64) -> AppTraffic:
    """Uniform random: every packet to a uniform destination in the app."""
    dst, npk, deg, recv = _empty(k, packets, 1)
    npk[:, :, 0] = 1
    deg[:, :] = 1
    sampled = np.ones((k, packets, 1), dtype=bool)
    lo = np.zeros((k, packets, 1), dtype=np.int64)
    hi = np.full((k, packets, 1), k, dtype=np.int64)
    dst[:, :, 0] = 0  # ignored when sampled
    return AppTraffic("uniform", k, dst, npk, deg, recv, packets, sampled, lo, hi)


def random_permutation(k: int, packets: int = 64, seed: int = 0) -> AppTraffic:
    """Each rank sends every packet to one fixed random unique destination."""
    rng = np.random.default_rng(seed)
    perm = rng.permutation(k)
    # avoid self-sends: re-draw derangement-ish (swap fixed points)
    fixed = np.flatnonzero(perm == np.arange(k))
    for i in fixed:
        j = (i + 1) % k
        perm[i], perm[j] = perm[j], perm[i]
    dst, npk, deg, recv = _empty(k, packets, 1)
    dst[:, :, 0] = perm[:, None]
    npk[:, :, 0] = 1
    deg[:, :] = 1
    return AppTraffic("random_permutation", k, dst, npk, deg, recv, packets)


def random_switch_permutation(
    k: int, group: int, packets: int = 64, seed: int = 0
) -> AppTraffic:
    """Groups of ``group`` ranks send only to one other (permuted) group.

    Adversarial when the allocation maps rank groups onto single switches
    (locality-aware allocations + linear task mapping): all traffic of a
    switch targets exactly one other switch.
    """
    if k % group:
        raise ValueError(f"k={k} not a multiple of group={group}")
    g = k // group
    rng = np.random.default_rng(seed)
    gperm = rng.permutation(g)
    fixed = np.flatnonzero(gperm == np.arange(g))
    for i in fixed:
        j = (i + 1) % g
        gperm[i], gperm[j] = gperm[j], gperm[i]
    dst, npk, deg, recv = _empty(k, packets, 1)
    npk[:, :, 0] = 1
    deg[:, :] = 1
    sampled = np.ones((k, packets, 1), dtype=bool)
    my_group = np.arange(k) // group
    lo = (gperm[my_group] * group)[:, None, None] * np.ones(
        (1, packets, 1), dtype=np.int64
    )
    hi = lo + group
    return AppTraffic(
        "random_switch_permutation", k, dst, npk, deg, recv, packets, sampled, lo, hi
    )


# ------------------------------------------------------- application kernels
def all_to_all(k: int) -> AppTraffic:
    """MPI All-to-All: k-1 asynchronous steps; step i sends to (r+i+1) mod k."""
    T = k - 1
    dst, npk, deg, recv = _empty(k, T, 1)
    r = np.arange(k)[:, None]
    i = np.arange(T)[None, :]
    dst[:, :, 0] = (r + i + 1) % k
    npk[:, :, 0] = 1
    deg[:, :] = 1
    recv[:, :] = 1  # from (r - i - 1) mod k, same step index
    return AppTraffic("all_to_all", k, dst, npk, deg, recv, window=T)


def all_reduce(k: int, vector_packets: int = 64) -> AppTraffic:
    """Rabenseifner all-reduce: scatter-reduce + all-gather over a hypercube.

    ``vector_packets`` is the reduced vector size in packets; step i of the
    scatter phase exchanges vector/2^(i+1) packets with partner r XOR 2^i,
    the gather phase mirrors it.  Synchronous (window=1): a step cannot
    start before the previous exchange completed (the reduction needs the
    partner's data).
    """
    m = int(math.log2(k))
    if 2**m != k:
        raise ValueError(f"Rabenseifner all-reduce requires power-of-two k, got {k}")
    T = 2 * m
    dst, npk, deg, recv = _empty(k, T, 1)
    r = np.arange(k)
    sizes = []
    for i in range(m):  # scatter-reduce: halving
        sizes.append(max(1, vector_packets >> (i + 1)))
    for i in range(m):  # all-gather: doubling (mirror)
        sizes.append(max(1, vector_packets >> (m - i)))
    for t in range(T):
        i = t if t < m else (2 * m - 1 - t)
        partner = r ^ (1 << i)
        dst[:, t, 0] = partner
        npk[:, t, 0] = sizes[t]
        deg[:, t] = 1
        recv[:, t] = sizes[t]
    return AppTraffic("all_reduce", k, dst, npk, deg, recv, window=1)


def _grid_shape(k: int) -> tuple[int, int]:
    gy = 2 ** (int(math.log2(k)) // 2)
    gx = k // gy
    if gy * gx != k:
        raise ValueError(f"stencil needs k expressible as a 2^a x 2^b grid, got {k}")
    return gy, gx


def stencil(k: int, neighborhood: str = "von_neumann", rounds: int | None = None) -> AppTraffic:
    """2D periodic stencil; each round exchanges 1 packet with each neighbor."""
    gy, gx = _grid_shape(k)
    r = np.arange(k)
    y, x = r // gx, r % gx
    if neighborhood == "von_neumann":
        offs = [(-1, 0), (1, 0), (0, -1), (0, 1)]
    elif neighborhood == "moore":
        offs = [
            (-1, -1), (-1, 0), (-1, 1), (0, -1), (0, 1), (1, -1), (1, 0), (1, 1),
        ]
    else:
        raise ValueError(f"unknown neighborhood {neighborhood!r}")
    if rounds is None:
        rounds = max(1, 64 // len(offs))
    maxd = len(offs)
    dst, npk, deg, recv = _empty(k, rounds, maxd)
    for d, (dy, dx) in enumerate(offs):
        ny, nx = (y + dy) % gy, (x + dx) % gx
        dst[:, :, d] = (ny * gx + nx)[:, None]
        npk[:, :, d] = 1
    deg[:, :] = maxd
    recv[:, :] = maxd
    name = f"stencil_{neighborhood}"
    return AppTraffic(name, k, dst, npk, deg, recv, window=1)


def random_involution(k: int, packets: int = 63, seed: int = 0) -> AppTraffic:
    """Random perfect matching; paired ranks exchange ``packets`` packets."""
    if k % 2:
        raise ValueError("random involution requires even k")
    rng = np.random.default_rng(seed)
    order = rng.permutation(k)
    partner = np.empty(k, dtype=np.int64)
    partner[order[0::2]] = order[1::2]
    partner[order[1::2]] = order[0::2]
    dst, npk, deg, recv = _empty(k, packets, 1)
    dst[:, :, 0] = partner[:, None]
    npk[:, :, 0] = 1
    deg[:, :] = 1
    return AppTraffic("random_involution", k, dst, npk, deg, recv, window=packets)


KERNELS = {
    "all_to_all": all_to_all,
    "all_reduce": all_reduce,
    "stencil_von_neumann": lambda k: stencil(k, "von_neumann"),
    "stencil_moore": lambda k: stencil(k, "moore"),
    "random_involution": random_involution,
}

STATIC_PATTERNS = {
    "uniform": uniform,
    "random_permutation": random_permutation,
    "random_switch_permutation": None,  # needs group size; built in compose
}


# --------------------------------------------------------------------------
# Machine-level composition
# --------------------------------------------------------------------------
@dataclasses.dataclass
class Workload:
    """A complete machine workload: merged step tables + placement maps.

    Global rank space concatenates all application ranks (targets first,
    background last).  Background ranks are *infinite* sources: they inject
    a fixed-rate stream and never complete; completion (makespan) is
    measured over target ranks only.
    """

    topo: HyperX
    R: int
    T: int
    maxd: int
    rank_ep: np.ndarray      # (R,) endpoint id per rank
    pool: np.ndarray         # (R,) VC pool per rank
    infinite: np.ndarray     # (R,) bool — background sources
    sends_dst: np.ndarray    # (R, T, MAXD) GLOBAL rank ids, -1 pad
    npkts: np.ndarray
    deg: np.ndarray
    recv_need: np.ndarray
    total_sends: np.ndarray  # (R, T)
    sampled: np.ndarray
    lo: np.ndarray           # GLOBAL rank space
    hi: np.ndarray
    window: np.ndarray       # (R,) per-rank window
    start: np.ndarray        # (R,) injection start time (warmup gating)
    num_pools: int
    names: list[str]
    # (S, q*n) bool, True = healthy directed link; None = all healthy.
    # See repro.route.faults for mask constructors and apply_faults().
    link_ok: np.ndarray | None = None

    @property
    def target_ranks(self) -> np.ndarray:
        return np.flatnonzero(~self.infinite)

    @property
    def target_packets(self) -> int:
        return int(self.npkts[~self.infinite].sum())


def compose_workload(
    topo: HyperX,
    apps: Sequence[tuple[AppTraffic, Partition]],
    background: Sequence[tuple[AppTraffic, Partition]] = (),
    fabric_partitioning: str = "shared",
    warmup: int = 0,
    link_ok: np.ndarray | None = None,
) -> Workload:
    """Merge applications (+ background noise) into one machine workload.

    fabric_partitioning:
      * 'shared'    — every partition shares VC pool 0 (baseline, 4 VCs);
      * 'background'— targets pool 0, background pool 1 (Figs. 11-12);
      * 'per_app'   — one pool per application (full fabric partitioning).

    ``warmup``: target apps start injecting only at this time, letting the
    (infinite-rate) background reach steady state first; the simulator
    reports makespan relative to the warmup point.

    ``link_ok``: optional (S, q*n) link-fault mask (True = healthy); see
    :mod:`repro.route.faults`.  Travels with the workload into the
    engine's device tables, so fault scenarios batch like any other axis.
    """
    all_jobs = list(apps) + list(background)
    n_bg = len(background)
    R = sum(app.k for app, _ in all_jobs)
    T = max(app.T for app, _ in all_jobs)
    maxd = max(app.maxd for app, _ in all_jobs)

    rank_ep = np.empty(R, dtype=np.int64)
    pool = np.zeros(R, dtype=np.int64)
    infinite = np.zeros(R, dtype=bool)
    window = np.ones(R, dtype=np.int64)
    start = np.zeros(R, dtype=np.int64)
    sends_dst = np.full((R, T, maxd), -1, dtype=np.int64)
    npkts = np.zeros((R, T, maxd), dtype=np.int64)
    deg = np.zeros((R, T), dtype=np.int64)
    recv_need = np.zeros((R, T), dtype=np.int64)
    sampled = np.zeros((R, T, maxd), dtype=bool)
    lo = np.zeros((R, T, maxd), dtype=np.int64)
    hi = np.zeros((R, T, maxd), dtype=np.int64)

    # endpoint disjointness guard: each endpoint hosts at most one rank
    used = np.concatenate([p.endpoints[: a.k] for a, p in all_jobs])
    if len(np.unique(used)) != len(used):
        uniq, cnt = np.unique(used, return_counts=True)
        raise ValueError(
            f"workload maps {int((cnt > 1).sum())} endpoints to multiple ranks "
            f"(e.g. {uniq[cnt > 1][:8].tolist()}); partitions must be disjoint"
        )

    off = 0
    names = []
    for j, (app, part) in enumerate(all_jobs):
        k, t, d = app.k, app.T, app.maxd
        if len(part.endpoints) < k:
            raise ValueError(
                f"partition has {len(part.endpoints)} endpoints < {k} ranks"
            )
        is_bg = j >= len(apps)
        sl = slice(off, off + k)
        rank_ep[sl] = part.endpoints[:k]
        infinite[sl] = is_bg
        window[sl] = app.window
        start[sl] = 0 if is_bg else warmup
        if fabric_partitioning == "shared":
            pool[sl] = 0
        elif fabric_partitioning == "background":
            pool[sl] = 1 if is_bg else 0
        elif fabric_partitioning == "per_app":
            pool[sl] = j
        else:
            raise ValueError(f"unknown fabric_partitioning {fabric_partitioning!r}")
        # shift destinations into the global rank space
        dstj = app.sends_dst.copy()
        dstj[dstj >= 0] += off
        sends_dst[sl, :t, :d] = dstj
        npkts[sl, :t, :d] = app.npkts
        deg[sl, :t] = app.deg
        recv_need[sl, :t] = app.recv_need
        sampled[sl, :t, :d] = app.sampled
        lo[sl, :t, :d] = app.lo + off
        hi[sl, :t, :d] = app.hi + off
        names.append(("bg:" if is_bg else "") + app.name)
        off += k

    total_sends = npkts.sum(axis=2)
    num_pools = int(pool.max()) + 1
    return Workload(
        topo=topo, R=R, T=T, maxd=maxd, rank_ep=rank_ep, pool=pool,
        infinite=infinite, sends_dst=sends_dst, npkts=npkts, deg=deg,
        recv_need=recv_need, total_sends=total_sends, sampled=sampled,
        lo=lo, hi=hi, window=window, start=start, num_pools=num_pools,
        names=names,
        link_ok=None if link_ok is None else np.asarray(link_ok, dtype=bool),
    )


def background_noise(
    topo: HyperX,
    free_endpoints: np.ndarray,
    packets: int = 1,
    seed: int = 1234,
) -> tuple[AppTraffic, Partition]:
    """Random-permutation background over all currently free endpoints.

    The traffic is *infinite-rate* in the simulator (the ``infinite`` flag in
    the Workload makes the step table loop), so ``packets`` only shapes the
    table; 1 is enough.
    """
    k = len(free_endpoints)
    app = random_permutation(k, packets=max(1, packets), seed=seed)
    part = Partition(
        strategy="background",
        topo=topo,
        job_id=-1,
        size=k,
        endpoints=np.asarray(free_endpoints, dtype=np.int64),
        switches=np.unique(np.asarray(free_endpoints) // topo.concentration),
    )
    return app, part
