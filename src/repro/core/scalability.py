"""Topology scalability math (paper Section 2.3 / Figure 4).

Endpoint counts supported by each topology family as a function of switch
radix, for the well-balanced / canonical configurations the paper compares:

  * 2D / 3D HyperX (concentration n, q(n-1) network ports)
  * 2- / 3-level Fat-trees
  * canonical balanced Dragonfly (a = 2h, p = h), with optional trunking t
"""

from __future__ import annotations

import math


def hyperx_side_for_radix(radix: int, q: int) -> int:
    """Largest well-balanced side n with n + q(n-1) <= radix."""
    # n + q(n-1) <= r  ->  n <= (r + q) / (q + 1)
    return max(2, (radix + q) // (q + 1))


def hyperx_endpoints(radix: int, q: int) -> int:
    n = hyperx_side_for_radix(radix, q)
    return n ** (q + 1)


def hyperx_cables_per_endpoint(radix: int, q: int) -> float:
    n = hyperx_side_for_radix(radix, q)
    return q * (n - 1) / (2 * n)


def fat_tree_endpoints(radix: int, levels: int) -> int:
    """Full bisection k-ary fat-tree: r^levels / 2^(levels-1)."""
    return radix**levels // (2 ** (levels - 1))


def dragonfly_h_for_radix(radix: int) -> int:
    """Balanced Dragonfly (p = h, a = 2h): radix = p + (a-1) + h = 4h - 1."""
    return max(1, (radix + 1) // 4)


def dragonfly_endpoints(radix: int, trunking: int = 1) -> int:
    """Endpoints of a balanced Dragonfly; trunking t divides global links."""
    h = dragonfly_h_for_radix(radix)
    a, p = 2 * h, h
    groups = (a * h) // trunking + 1
    return groups * a * p


def dragonfly_cables_per_endpoint(radix: int, trunking: int = 1) -> float:
    h = dragonfly_h_for_radix(radix)
    a, p = 2 * h, h
    groups = (a * h) // trunking + 1
    local = groups * a * (a - 1) / 2
    global_ = groups * a * h / 2
    return (local + global_) / (groups * a * p)


def scalability_table(radices=(16, 24, 32, 48, 64, 96, 128)) -> list[dict]:
    """One row per radix with endpoint counts per topology (Figure 4)."""
    rows = []
    for r in radices:
        rows.append(
            {
                "radix": r,
                "hyperx_2d": hyperx_endpoints(r, 2),
                "hyperx_3d": hyperx_endpoints(r, 3),
                "fat_tree_2l": fat_tree_endpoints(r, 2),
                "fat_tree_3l": fat_tree_endpoints(r, 3),
                "dragonfly": dragonfly_endpoints(r),
                "dragonfly_t4": dragonfly_endpoints(r, trunking=4),
            }
        )
    return rows


def paper_examples() -> dict:
    """The concrete scalability claims from Section 2.3, for validation."""
    return {
        # radix 64: 2-level fat tree 2048 endpoints vs 22x22 HyperX 10648
        "ft2_r64": fat_tree_endpoints(64, 2),
        "hx2_r64_side": hyperx_side_for_radix(64, 2),
        "hx2_r64": hyperx_endpoints(64, 2),
        # radix 128: ft 8192 vs 43x43 HyperX 79507
        "ft2_r128": fat_tree_endpoints(128, 2),
        "hx2_r128_side": hyperx_side_for_radix(128, 2),
        "hx2_r128": hyperx_endpoints(128, 2),
        # 3D HyperX 16x16x16 with radix-64 switches: 4096 switches, 65536 endpoints
        "hx3_r64_side": hyperx_side_for_radix(64, 3),
        "hx3_r64": hyperx_endpoints(64, 3),
    }
