"""Routing models for HyperX (paper Section 2.2).

Two layers:

  * Path-set enumeration for MIN (all minimal paths, split evenly) and an
    idealized Valiant-within-set non-minimal scheme, feeding the analytical
    link-load / throughput model in ``analytical.py``.
  * Candidate-port logic shared with the cycle-level simulator: from a
    (current switch, destination switch) pair, the set of legal Omni-WAR
    output ports (minimal hop per unaligned dimension plus deroutes while
    the non-minimal hop budget m lasts; m = q by default).

Omni-WAR reference: McDonald et al., SC'19.  The same route set underlies
DAL (Ahn et al., SC'09).
"""

from __future__ import annotations

import numpy as np

from repro.core.hyperx import HyperX


# --------------------------------------------------------------------------
# Directed-link indexing shared by analytical model and simulator
# --------------------------------------------------------------------------
class LinkSpace:
    """Dense ids for directed switch-to-switch links of a HyperX.

    A directed link is (src_switch, dim, target_coord) with
    target_coord != src_coord[dim].  Dense id layout:

        link_id = (src * q + dim) * n + target_coord

    ids where target_coord == src_coord[dim] are *invalid* (self loops) and
    never used; keeping the dense layout makes id computation branch-free
    inside jit.  Total id space = S * q * n.
    """

    def __init__(self, topo: HyperX):
        from repro.route.topology import dst_switch_table, self_port_mask

        self.topo = topo
        self.n, self.q = topo.n, topo.q
        self.num_ids = topo.num_switches * topo.q * topo.n
        coords = topo.all_switch_coords()  # (S, q)
        self.switch_coords = coords
        # dst switch id for every (src, dim, val) — broadcast construction,
        # parity with the seed's nested loops pinned by tests/test_route.py
        self.dst_switch = dst_switch_table(coords, topo.n, topo.q)
        self.valid = self_port_mask(coords, topo.n, topo.q).reshape(
            topo.num_switches, topo.q, topo.n
        )

    def link_id(self, src: np.ndarray, dim: np.ndarray, val: np.ndarray) -> np.ndarray:
        return (np.asarray(src) * self.q + np.asarray(dim)) * self.n + np.asarray(val)

    def decode(self, link_id: np.ndarray):
        val = link_id % self.n
        dim = (link_id // self.n) % self.q
        src = link_id // (self.n * self.q)
        return src, dim, val


# --------------------------------------------------------------------------
# Analytical link loads
# --------------------------------------------------------------------------
def minimal_link_loads(topo: HyperX, traffic: np.ndarray) -> np.ndarray:
    """Per-directed-link load under MIN routing with even path splitting.

    ``traffic``: (S, S) switch-level rate matrix (phits/cycle aggregated over
    the endpoints of each switch).  Returns a dense (S*q*n,) load vector in
    LinkSpace ids.  Minimal paths correct one unaligned dimension per hop in
    any order; with even splitting over dimension orders, the flow crossing
    dimension d between u and v is carried on the single link fixing d, from
    a switch whose other unaligned coords are a mix of u's and v's.  For
    q=2 this is exact and cheap; implemented for general q by enumerating
    dimension orders (q! small: q <= 4 in practice).
    """
    import itertools

    ls = LinkSpace(topo)
    load = np.zeros(ls.num_ids)
    S = topo.num_switches
    coords = ls.switch_coords
    nz = np.argwhere(traffic > 0)
    for u, v in nz:
        rate = traffic[u, v]
        if u == v:
            continue
        dims = [d for d in range(topo.q) if coords[u, d] != coords[v, d]]
        orders = list(itertools.permutations(dims))
        share = rate / len(orders)
        for order in orders:
            cur = u
            for d in order:
                lid = ls.link_id(cur, d, coords[v, d])
                load[lid] += share
                cur = ls.dst_switch[cur, d, coords[v, d]]
    return load


def saturation_throughput(topo: HyperX, traffic: np.ndarray) -> float:
    """Max per-unit scaling factor before some link exceeds 1 phit/cycle.

    ``traffic`` is normalized so each endpoint injects 1 phit/cycle; the
    result is therefore the accepted rate per endpoint at saturation -- the
    quantity the paper's PB metric bounds.
    """
    load = minimal_link_loads(topo, traffic)
    peak = load.max()
    return float("inf") if peak == 0 else 1.0 / float(peak)


def uniform_partition_traffic(topo: HyperX, endpoints: np.ndarray) -> np.ndarray:
    """(S, S) switch rate matrix for uniform traffic inside a partition.

    Each endpoint injects 1 phit/cycle to uniformly random members of the
    partition (self included, the paper's convention).
    """
    S = topo.num_switches
    endpoints = np.asarray(endpoints)
    switches = endpoints // topo.concentration
    uniq, counts = np.unique(switches, return_counts=True)
    m = len(endpoints)
    t = np.zeros((S, S))
    # endpoint at switch i sends count_j / m of its rate to switch j
    for i, ci in zip(uniq, counts):
        for j, cj in zip(uniq, counts):
            t[i, j] += ci * cj / m
    return t


def empirical_partition_bandwidth(topo: HyperX, endpoints: np.ndarray) -> float:
    """Saturation throughput of uniform-in-partition traffic under MIN.

    This is the *measured* counterpart of the PB metric: for the symmetric
    partitions the paper analyzes, it matches Eq. (3) exactly.
    """
    t = uniform_partition_traffic(topo, endpoints)
    return saturation_throughput(topo, t)


# --------------------------------------------------------------------------
# Omni-WAR candidate ports (shared with the simulator)
# --------------------------------------------------------------------------
def candidate_ports(
    ls: LinkSpace,
    cur: np.ndarray,
    dst: np.ndarray,
    deroutes_left: np.ndarray,
    mode: str = "omniwar",
):
    """Vectorized legal output ports for packets at ``cur`` heading to ``dst``.

    Returns (link_ids, is_minimal, valid) with shape (N, q*n): for each
    packet, every (dim, val) port; ``valid`` marks ports that are legal under
    the routing mode:

      * a port is considered only in *unaligned* dimensions (Omni-WAR rule);
      * the minimal port of an unaligned dimension is val == dst[dim];
      * deroute ports (val != cur[dim], dst[dim]) are legal while the packet
        has non-minimal budget left; under ``mode == 'min'`` never.
    """
    n, q = ls.n, ls.q
    cur = np.asarray(cur)
    dst = np.asarray(dst)
    N = cur.shape[0]
    cur_c = ls.switch_coords[cur]  # (N, q)
    dst_c = ls.switch_coords[dst]
    dims = np.arange(q)[None, :, None]  # (1, q, 1)
    vals = np.arange(n)[None, None, :]  # (1, 1, n)
    unaligned = (cur_c != dst_c)[:, :, None]  # (N, q, 1)
    is_min = (vals == dst_c[:, :, None]) & unaligned
    not_self = vals != cur_c[:, :, None]
    if mode == "min":
        valid = is_min
    else:
        can_deroute = (deroutes_left > 0)[:, None, None]
        valid = unaligned & not_self & (is_min | can_deroute)
    lid = (cur[:, None, None] * q + dims) * n + vals
    return (
        lid.reshape(N, q * n),
        is_min.reshape(N, q * n),
        valid.reshape(N, q * n),
    )
