"""Resource allocation functions for HyperX networks (paper Section 4).

Each allocation function maps the logical coordinates of a job's rank onto
physical topology coordinates:

    f(p, r_y, r_x) = (s_y, s_x, c)

where ``p`` is the partition identifier, ``r = n*r_y + r_x`` is the linear
rank inside the partition, ``(s_y, s_x)`` the physical switch and ``c`` the
endpoint offset within the switch.  On an n x n HyperX with concentration n,
the machine supports exactly n disjoint partitions of n**2 endpoints each.

Implemented strategies (names follow the paper):

  linear:     row, diagonal, full_spread
  tiled:      rectangular, l_shape
  stochastic: random_endpoint, random_switch

Jobs larger than n**2 take the union of consecutive base blocks (paper
Section 6.2: "a partition consists on the union of consecutive blocks").

All ``map_block`` implementations are vectorized over numpy int arrays so the
simulator, the property analysis and the fabric placement layer can evaluate
them for thousands of ranks at once.
"""

from __future__ import annotations

import dataclasses
from typing import Callable, Dict, Sequence, Tuple

import numpy as np

from repro.core.hyperx import HyperX

Triplet = Tuple[np.ndarray, np.ndarray, np.ndarray]


# --------------------------------------------------------------------------
# Strategy definitions
# --------------------------------------------------------------------------
@dataclasses.dataclass(frozen=True)
class AllocationStrategy:
    """A named allocation function plus its static properties (paper Table 1)."""

    name: str
    kind: str  # 'linear' | 'tiling' | 'random'
    locality_aware: bool
    convexity: str  # 'convex' | 'weakly-convex' | 'non-convex'
    # map_block(p, r_y, r_x, n, rng) -> (s_y, s_x, c); vectorized over arrays.
    map_block: Callable[[np.ndarray, np.ndarray, np.ndarray, int, np.random.Generator], Triplet]
    needs_rng: bool = False

    def __call__(self, p, r_y, r_x, n, rng=None):
        p = np.asarray(p, dtype=np.int64)
        r_y = np.asarray(r_y, dtype=np.int64)
        r_x = np.asarray(r_x, dtype=np.int64)
        if self.needs_rng and rng is None:
            rng = np.random.default_rng(0)
        return self.map_block(p, r_y, r_x, n, rng)


def _row(p, r_y, r_x, n, rng):
    # row(p, r_y, r_x) = (p, r_y, r_x): all endpoints in row p.
    return p % n, r_y % n, r_x % n


def _full_spread(p, r_y, r_x, n, rng):
    # full_spread(p, r_y, r_x) = (r_y, r_x, p): one endpoint on EVERY switch.
    return r_y % n, r_x % n, p % n


def _diagonal(p, r_y, r_x, n, rng):
    # diagonal(p, r_y, r_x) = (r_y, (r_y + p) mod n, r_x): one switch per
    # row/column -- maximal distance, maximal partition bandwidth among
    # locality-aware strategies.
    return r_y % n, (r_y + p) % n, r_x % n


def _rectangular(p, r_y, r_x, n, rng):
    # Paper formula (Sec. 4.2):
    #   (rem(r_y,2) + n/2*rem(p,2), quo(r_y,2) + 2*quo(p,2), r_x)
    # As printed this yields OVERLAPPING rectangles (p=0 covers rows {0,1} x
    # cols {0..3}, p=2 covers rows {0,1} x cols {2..5}), contradicting the
    # paper's own claim of n non-overlapping partitions.  Swapping the two
    # offset terms gives the intended disjoint sqrt(n/2) x sqrt(2n) tiling
    # (2 rows x 4 cols for n=8); erratum recorded in DESIGN.md.
    if n % 2:
        raise ValueError("rectangular tessellation requires even n")
    s_y = (r_y % 2) + 2 * (p // 2)
    s_x = (r_y // 2) + (n // 2) * (p % 2)
    return s_y % n, s_x % n, r_x % n


def _l_shape(p, r_y, r_x, n, rng):
    # Piecewise: a vertical ray anchored at (p, p) plus a horizontal ray.
    #   (p + r_y, p, r_x)                       for r_y <  n//2
    #   (p, p + r_y - n//2 + 1, r_x)            otherwise
    # Modular arithmetic applies to switch coordinates.
    half = n // 2
    vert = r_y < half
    s_y = np.where(vert, (p + r_y) % n, p % n)
    s_x = np.where(vert, p % n, (p + r_y - half + 1) % n)
    return s_y, s_x, r_x % n


def _perm_from_rng(rng: np.random.Generator, size: int) -> np.ndarray:
    return rng.permutation(size)


def _random_endpoint(p, r_y, r_x, n, rng):
    # pi is a random permutation of the n**3 endpoint triplets; the linear
    # rank index maps straight into the permuted space.
    pi = _perm_from_rng(rng, n**3)
    lin = (p * n * n + r_y * n + r_x) % (n**3)
    tgt = pi[lin]
    c = tgt % n
    s_x = (tgt // n) % n
    s_y = tgt // (n * n)
    return s_y, s_x, c


def _random_switch(p, r_y, r_x, n, rng):
    # sigma is a random permutation of the n**2 switches; r_y selects the
    # switch, r_x the endpoint offset -> switch locality preserved.
    sigma = _perm_from_rng(rng, n * n)
    lin = (p * n + r_y) % (n * n)
    tgt = sigma[lin]
    return tgt // n, tgt % n, r_x % n


ALLOCATIONS: Dict[str, AllocationStrategy] = {
    s.name: s
    for s in [
        AllocationStrategy("row", "linear", True, "convex", _row),
        AllocationStrategy("diagonal", "linear", True, "non-convex", _diagonal),
        AllocationStrategy("full_spread", "linear", False, "convex", _full_spread),
        AllocationStrategy("rectangular", "tiling", True, "convex", _rectangular),
        AllocationStrategy("l_shape", "tiling", True, "weakly-convex", _l_shape),
        AllocationStrategy(
            "random_endpoint", "random", False, "non-convex", _random_endpoint, True
        ),
        AllocationStrategy(
            "random_switch", "random", True, "non-convex", _random_switch, True
        ),
    ]
}


def get_strategy(name: str) -> AllocationStrategy:
    try:
        return ALLOCATIONS[name]
    except KeyError:
        raise KeyError(
            f"unknown allocation strategy {name!r}; available: {sorted(ALLOCATIONS)}"
        ) from None


# --------------------------------------------------------------------------
# Partition construction
# --------------------------------------------------------------------------
@dataclasses.dataclass(frozen=True)
class Partition:
    """A concrete set of endpoints allocated to one job."""

    strategy: str
    topo: HyperX
    job_id: int
    size: int  # endpoints
    endpoints: np.ndarray  # (size,) linear endpoint ids, rank order
    switches: np.ndarray  # sorted unique switch ids touched

    @property
    def rank_to_endpoint(self) -> np.ndarray:
        return self.endpoints

    def endpoint_to_rank(self) -> Dict[int, int]:
        return {int(e): r for r, e in enumerate(self.endpoints)}


def allocate_partition(
    strategy: str | AllocationStrategy,
    topo: HyperX,
    job_id: int,
    size: int | None = None,
    seed: int = 0,
) -> Partition:
    """Allocate ``size`` endpoints (default n**2) for job ``job_id``.

    Jobs of k*n**2 endpoints take base blocks p = job_id*k .. job_id*k + k-1
    (consecutive blocks, paper Section 6.2).  Sizes that are not multiples of
    n**2 take a prefix of the final block.  The random permutations are keyed
    by ``seed`` only (machine-wide), so different jobs on one machine draw
    from the same permutation and stay disjoint.
    """
    strat = get_strategy(strategy) if isinstance(strategy, str) else strategy
    n = topo.n
    block = n * n
    if size is None:
        size = block
    if size <= 0 or size > topo.num_endpoints:
        raise ValueError(f"partition size {size} out of range")
    k = -(-size // block)  # blocks needed (ceil)
    first_block = job_id * k
    ranks = np.arange(size, dtype=np.int64)
    blk = first_block + ranks // block  # base partition id per rank
    r_in = ranks % block
    r_y = r_in // n
    r_x = r_in % n
    rng = np.random.default_rng(seed) if strat.needs_rng else None
    s_y, s_x, c = strat(blk, r_y, r_x, n, rng)
    endpoints = (s_y * n + s_x) * topo.concentration + c
    switches = np.unique(s_y * n + s_x)
    return Partition(
        strategy=strat.name,
        topo=topo,
        job_id=job_id,
        size=size,
        endpoints=endpoints.astype(np.int64),
        switches=switches.astype(np.int64),
    )


def allocate_blocks(
    strategy: str | AllocationStrategy,
    topo: HyperX,
    block_ids: Sequence[int] | np.ndarray,
    job_id: int = 0,
    size: int | None = None,
    seed: int = 0,
) -> Partition:
    """Allocate a partition over an *arbitrary* list of base blocks.

    Generalizes :func:`allocate_partition` (which always takes consecutive
    blocks) to the fragmented-machine case: the online scheduler hands the
    block slots it found free, in rank order.  Rank ``r`` lands in block
    ``block_ids[r // n**2]``; ``size`` (default: all of them) may take a
    prefix of the final block.  All strategies keep distinct block ids in
    ``[0, n)`` pairwise disjoint, so any subset of slots yields a valid
    partition.
    """
    strat = get_strategy(strategy) if isinstance(strategy, str) else strategy
    n = topo.n
    block = n * n
    block_ids = np.asarray(block_ids, dtype=np.int64)
    if block_ids.ndim != 1 or len(block_ids) == 0:
        raise ValueError(f"need a non-empty 1D block list, got {block_ids!r}")
    if len(np.unique(block_ids)) != len(block_ids):
        raise ValueError(f"duplicate block ids in {block_ids.tolist()}")
    if (block_ids < 0).any() or (block_ids >= n).any():
        raise ValueError(f"block ids {block_ids.tolist()} out of range [0, {n})")
    if size is None:
        size = len(block_ids) * block
    if not 0 < size <= len(block_ids) * block:
        raise ValueError(
            f"size {size} does not fit {len(block_ids)} blocks of {block}"
        )
    ranks = np.arange(size, dtype=np.int64)
    blk = block_ids[ranks // block]
    r_in = ranks % block
    rng = np.random.default_rng(seed) if strat.needs_rng else None
    s_y, s_x, c = strat(blk, r_in // n, r_in % n, n, rng)
    endpoints = (s_y * n + s_x) * topo.concentration + c
    return Partition(
        strategy=strat.name,
        topo=topo,
        job_id=job_id,
        size=size,
        endpoints=endpoints.astype(np.int64),
        switches=np.unique(s_y * n + s_x).astype(np.int64),
    )


def scavenge_partition(
    free_mask: np.ndarray, topo: HyperX, job_id: int, size: int
) -> Partition:
    """The first ``size`` free endpoints as a structureless partition.

    Shared last-resort placement used by every allocator's ``scavenge``;
    the caller does its own record-keeping (free-mask update, job table).
    """
    free = np.flatnonzero(free_mask)
    if len(free) < size:
        raise RuntimeError(f"no {size} free endpoints to scavenge")
    eps = free[:size].astype(np.int64)
    return Partition(
        strategy="scavenge", topo=topo, job_id=job_id, size=size,
        endpoints=eps, switches=np.unique(eps // topo.concentration),
    )


def machine_partitions(
    strategy: str | AllocationStrategy,
    topo: HyperX,
    num_jobs: int,
    job_size: int | None = None,
    seed: int = 0,
) -> list[Partition]:
    """All ``num_jobs`` disjoint partitions on one machine instance."""
    return [
        allocate_partition(strategy, topo, j, job_size, seed) for j in range(num_jobs)
    ]


def endpoint_owner(partitions: list[Partition], num_endpoints: int) -> np.ndarray:
    """(num_endpoints,) array: partition index owning each endpoint, -1 if free.

    Raises if two partitions claim the same endpoint (allocation bug).
    """
    owner = np.full(num_endpoints, -1, dtype=np.int64)
    for i, part in enumerate(partitions):
        if (owner[part.endpoints] != -1).any():
            clash = part.endpoints[owner[part.endpoints] != -1]
            raise ValueError(
                f"partition overlap: job {i} ({part.strategy}) claims endpoints "
                f"{clash[:8].tolist()} already owned"
            )
        owner[part.endpoints] = i
    return owner


# --------------------------------------------------------------------------
# Incremental job allocator (SLURM-like resource manager facade)
# --------------------------------------------------------------------------
class JobAllocator:
    """Incremental resource manager over one HyperX machine.

    Tracks free endpoints; serves jobs by trying the requested strategy's
    next free base block(s).  This is the layer the training launcher and the
    elastic runtime talk to.
    """

    def __init__(self, topo: HyperX, strategy: str = "diagonal", seed: int = 0):
        self.topo = topo
        self.strategy = get_strategy(strategy)
        self.seed = seed
        self.free = np.ones(topo.num_endpoints, dtype=bool)
        self.failed = np.zeros(topo.num_endpoints, dtype=bool)
        self.jobs: Dict[int, Partition] = {}
        self._next_job = 0

    def capacity(self) -> int:
        return int(self.free.sum())

    def allocate(self, size: int | None = None, strategy: str | None = None) -> Partition:
        strat = get_strategy(strategy) if strategy else self.strategy
        n = self.topo.n
        block = n * n
        size = size or block
        k = -(-size // block)
        max_jobs = self.topo.num_endpoints // (k * block)
        for slot in range(max_jobs):
            part = allocate_partition(strat, self.topo, slot, size, self.seed)
            if self.free[part.endpoints].all():
                part = dataclasses.replace(part, job_id=self._next_job)
                self.free[part.endpoints] = False
                self.jobs[self._next_job] = part
                self._next_job += 1
                return part
        raise RuntimeError(
            f"no free {strat.name} partition of size {size} "
            f"(free endpoints: {self.capacity()})"
        )

    def scavenge(self, size: int) -> Partition:
        """Last-resort placement: the first ``size`` free endpoints, with no
        allocation structure at all.  The elastic runtime falls back to this
        when every strategy (including the stochastic ones) fails on the
        fragmented fleet."""
        part = scavenge_partition(self.free, self.topo, self._next_job, size)
        self.free[part.endpoints] = False
        self.jobs[part.job_id] = part
        self._next_job += 1
        return part

    def release(self, job_id: int) -> None:
        part = self.jobs.pop(job_id)
        # failed endpoints stay out of the pool until repaired
        self.free[part.endpoints] = ~self.failed[part.endpoints]

    def fail_endpoints(self, endpoints: np.ndarray) -> list[int]:
        """Mark endpoints as failed (not free); return affected job ids."""
        endpoints = np.asarray(endpoints, dtype=np.int64)
        affected = []
        for jid, part in self.jobs.items():
            if np.intersect1d(part.endpoints, endpoints).size:
                affected.append(jid)
        self.failed[endpoints] = True
        self.free[endpoints] = False
        return affected

    def repair_endpoints(self, endpoints: np.ndarray) -> None:
        """Return repaired endpoints to the free pool (maintenance done)."""
        endpoints = np.asarray(endpoints, dtype=np.int64)
        self.failed[endpoints] = False
        owned = np.zeros_like(self.free)
        for part in self.jobs.values():
            owned[part.endpoints] = True
        self.free[endpoints] = ~owned[endpoints]
