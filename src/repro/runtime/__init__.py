from repro.runtime.fault_tolerance import FleetRuntime, StragglerMonitor  # noqa: F401
