"""Fleet runtime: fault tolerance, elastic rescale, straggler mitigation.

The allocation functions of the paper are the *repair policy*: on endpoint
failure the runtime asks the JobAllocator for a replacement partition over
the surviving endpoints, re-places the mesh (fabric.placement), and resumes
from the last committed checkpoint.  When no full-size partition survives,
the job shrinks elastically to the largest mesh that still fits (halving
the ``data`` axis), re-lowering the step and resharding the restored state.

Hardware failure itself is simulated (we have one CPU); everything above
the failure *signal* — detection bookkeeping, reallocation, checkpoint
restore, mesh rebuild, straggler statistics — is the real production code
path and is exercised by tests/test_runtime.py.
"""

from __future__ import annotations

import dataclasses
import time

import numpy as np

from repro.core.allocation import JobAllocator
from repro.core.hyperx import HyperX
from repro.fabric.placement import HyperXPlacement, default_fleet, place_job


# ----------------------------------------------------------- stragglers
class StragglerMonitor:
    """Per-step wall-time statistics with outlier flagging.

    On a real fleet the per-host step times come from the coordination
    service; here the train loop feeds (host, seconds) samples.  A host is
    a straggler when its step time exceeds ``threshold`` x the rolling
    median; persistent stragglers (>= ``evict_after`` flags) are proposed
    for eviction, which the FleetRuntime treats like a failure (the
    standard large-fleet mitigation).
    """

    def __init__(self, threshold: float = 1.8, window: int = 32,
                 evict_after: int = 3):
        self.threshold = threshold
        self.window = window
        self.evict_after = evict_after
        self.samples: dict[int, list[float]] = {}
        self.flags: dict[int, int] = {}

    def record(self, host: int, seconds: float) -> bool:
        s = self.samples.setdefault(host, [])
        s.append(seconds)
        del s[: -self.window]
        med = float(np.median([x[-1] for x in self.samples.values()]))
        is_straggler = seconds > self.threshold * med and len(self.samples) > 1
        if is_straggler:
            self.flags[host] = self.flags.get(host, 0) + 1
        else:
            self.flags[host] = 0
        return is_straggler

    def evictions(self) -> list[int]:
        return [h for h, c in self.flags.items() if c >= self.evict_after]


# ------------------------------------------------------------- runtime
@dataclasses.dataclass
class JobState:
    placement: HyperXPlacement
    mesh_shape: tuple
    generation: int = 0     # bumped on every repair/rescale (re-lower key)


class FleetRuntime:
    """Owns the fleet allocator and one job's placement lifecycle."""

    def __init__(
        self,
        mesh_shape: tuple[int, ...],
        axis_names: tuple[str, ...],
        strategy: str = "diagonal",
        topo: HyperX | None = None,
        allocator=None,
    ):
        """``allocator`` may inject any JobAllocator-compatible resource
        manager (e.g. the online scheduler's ``repro.sched.BlockLedger``) so
        the fleet and a job stream share one machine-state ledger; default
        is a private JobAllocator over ``topo``."""
        size = int(np.prod(mesh_shape))
        if allocator is not None and topo is not None and allocator.topo != topo:
            raise ValueError(
                f"allocator manages {allocator.topo}, runtime asked for {topo}"
            )
        self.topo = allocator.topo if allocator is not None else (
            topo or default_fleet(size)
        )
        self.allocator = allocator or JobAllocator(self.topo, strategy=strategy)
        self.axis_names = tuple(axis_names)
        self.strategy = strategy
        self._owned: set[int] = set()  # jobs THIS runtime allocated; a shared
        # allocator may also hold other tenants' jobs, which we never touch
        part = self.allocator.allocate(size=size)
        self._owned.add(part.job_id)
        placement = self._placement_from(part, mesh_shape)
        self.job = JobState(placement=placement, mesh_shape=tuple(mesh_shape))
        self.events: list[dict] = []

    def _placement_from(self, part, mesh_shape) -> HyperXPlacement:
        return HyperXPlacement.from_partition(
            part, mesh_shape, self.axis_names
        )

    # -------------------------------------------------------- failures
    def fail(self, endpoints) -> dict:
        """Report failed endpoints; repair or shrink.  Returns the event."""
        endpoints = np.atleast_1d(np.asarray(endpoints))
        affected = self.allocator.fail_endpoints(endpoints)
        touched = np.intersect1d(self.job.placement.endpoints, endpoints).size
        event = {
            "time": time.time(),
            "failed": endpoints.tolist(),
            "job_affected": bool(touched),
            "action": "none",
        }
        if touched:
            event["action"] = self._repair()
        self.events.append(event)
        return event

    def _release_current(self):
        for jid in list(self._owned):
            if jid in self.allocator.jobs:
                self.allocator.release(jid)
            self._owned.discard(jid)

    def _try_allocate(self, size: int):
        """Primary strategy, then stochastic fallbacks over the fragmented
        fleet (the random allocations exist exactly for this: any free
        switch/endpoint set works)."""
        try:
            return self.allocator.allocate(size=size), self.strategy
        except RuntimeError:
            pass
        for seed in range(16):
            for strat in ("random_switch", "random_endpoint"):
                try:
                    old_seed = self.allocator.seed
                    self.allocator.seed = 1000 + seed
                    try:
                        return self.allocator.allocate(size=size, strategy=strat), strat
                    finally:
                        self.allocator.seed = old_seed
                except RuntimeError:
                    continue
        # last resort: any free endpoints at all (arbitrary placement)
        return self.allocator.scavenge(size), "scavenge"

    def _repair(self) -> str:
        """Try same-size reallocation; elastically halve ``data`` if needed."""
        size = int(np.prod(self.job.mesh_shape))
        self._release_current()
        shape = list(self.job.mesh_shape)
        while True:
            try:
                part, strat = self._try_allocate(int(np.prod(shape)))
                self._owned.add(part.job_id)
                self.job = JobState(
                    placement=self._placement_from(part, tuple(shape)),
                    mesh_shape=tuple(shape),
                    generation=self.job.generation + 1,
                )
                tag = (
                    "reallocated"
                    if int(np.prod(shape)) == size
                    else f"rescaled_to_{tuple(shape)}"
                )
                return tag if strat == self.strategy else f"{tag}:{strat}"
            except RuntimeError:
                # shrink the data axis (first axis by convention)
                if shape[0] == 1:
                    raise RuntimeError(
                        "fleet cannot host the job at any size"
                    ) from None
                shape[0] //= 2

    # --------------------------------------------------------- queries
    @property
    def placement(self) -> HyperXPlacement:
        return self.job.placement

    def healthy_devices(self) -> int:
        return int(np.prod(self.job.mesh_shape))
