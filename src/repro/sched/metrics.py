"""Metrics layer: per-job records and per-stream aggregates.

Wait/turnaround are classic scheduler metrics; ``realized_pb`` and
``switch_local`` apply the paper's Section 5 partition properties to the
partitions *actually placed* on the fragmented machine — a Diagonal job
backfilled onto scattered blocks does not get the textbook Diagonal PB,
and this layer is where that gap becomes measurable.
"""

from __future__ import annotations

import dataclasses
from typing import List

import numpy as np


@dataclasses.dataclass
class JobRecord:
    """Lifecycle + realized-placement metrics of one job."""

    job_id: int
    arrival: float
    blocks: int
    service: float
    kernel: str
    start: float | None = None
    finish: float | None = None
    wait: float | None = None        # first start - arrival
    scattered: bool = False          # placed on non-contiguous slots
    migrations: int = 0              # failure-driven re-placements
    requeues: int = 0                # failure evictions back to the queue
    retries: int = 0                 # eviction count (drives the backoff)
    degraded: bool = False           # shrunk below its requested blocks
    failed: bool = False             # gave up after max_retries evictions
    realized_pb: float | None = None
    pb_bound: float | None = None
    switch_local: bool | None = None

    @property
    def turnaround(self) -> float | None:
        return None if self.finish is None else self.finish - self.arrival

    @property
    def slowdown(self) -> float | None:
        t = self.turnaround
        return None if t is None else t / max(self.service, 1e-9)


@dataclasses.dataclass
class StreamResult:
    """Everything one (strategy, policy, stream) scheduling run produced."""

    strategy: str
    policy: str
    records: List[JobRecord]
    snapshots: list  # list[Snapshot] — kept loose to avoid a cycle
    span: float                    # first arrival .. last completion
    utilization: float             # requested endpoint-seconds / (E * span)
    gross_utilization: float       # slot-held endpoint-seconds / (E * span)
    frag_mean: float               # time-weighted mean fragmentation
    frag_max: float
    mean_queue: float              # time-weighted mean queue length

    def finished(self) -> List[JobRecord]:
        return [r for r in self.records if r.finish is not None]

    def summary(self) -> dict:
        """One flat row (the benchmark CSV contract)."""
        waits = [r.wait for r in self.records if r.wait is not None]
        slow = [r.slowdown for r in self.finished()]
        pbs = [r.realized_pb for r in self.records
               if r.realized_pb is not None and np.isfinite(r.realized_pb)]
        loc = [r.switch_local for r in self.records if r.switch_local is not None]
        placed = [r for r in self.records if r.start is not None]
        return {
            "strategy": self.strategy,
            "policy": self.policy,
            "jobs": len(self.records),
            "placed": len(placed),
            "finished": len(self.finished()),
            "span": round(self.span, 2),
            "utilization": round(self.utilization, 4),
            "gross_utilization": round(self.gross_utilization, 4),
            "mean_wait": round(float(np.mean(waits)), 3) if waits else 0.0,
            "p95_wait": round(float(np.percentile(waits, 95)), 3) if waits else 0.0,
            "max_wait": round(float(np.max(waits)), 3) if waits else 0.0,
            "mean_slowdown": round(float(np.mean(slow)), 3) if slow else 0.0,
            "frag_mean": round(self.frag_mean, 4),
            "frag_max": round(self.frag_max, 4),
            "mean_queue": round(self.mean_queue, 3),
            "scattered_frac": round(
                float(np.mean([r.scattered for r in placed])), 4
            ) if placed else 0.0,
            "migrations": sum(r.migrations for r in self.records),
            "requeues": sum(r.requeues for r in self.records),
            "degraded": sum(r.degraded for r in self.records),
            "failed": sum(r.failed for r in self.records),
            "realized_pb_mean": round(float(np.mean(pbs)), 4) if pbs else -1.0,
            "realized_pb_min": round(float(np.min(pbs)), 4) if pbs else -1.0,
            "locality_frac": round(float(np.mean(loc)), 4) if loc else -1.0,
            "snapshots": len(self.snapshots),
        }
