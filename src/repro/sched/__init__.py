"""Online cluster scheduler: the paper's allocation strategies under churn.

The paper evaluates its seven allocation functions as *static* partitions
of a fully-packed machine; real HPC/AI fleets face a continuous stream of
job arrivals and departures that fragments the machine.  This subsystem
turns the allocation functions into dynamic placement policies:

  * :mod:`jobs`      — synthetic (Poisson / heavy-tailed) arrival
    generators and deterministic trace replay, jobs sized in base blocks;
  * :mod:`ledger`    — the machine-state ledger: free/occupied block
    slots and endpoints, strategy-aware first-fit/best-fit placement on a
    fragmented machine, failure/repair bookkeeping;
  * :mod:`scheduler` — the event loop: FCFS + EASY backfilling, failure
    re-placement, co-resident snapshots at scheduling events;
  * :mod:`metrics`   — per-strategy utilization, wait, fragmentation and
    realized partition-bandwidth / switch-locality of placed partitions;
  * :mod:`bridge`    — evaluates co-resident snapshots through the
    batched :class:`~repro.core.engine.SimEngine`, so a whole strategy x
    seed x snapshot grid stays one compile + one device call per shape
    bucket.
"""

from repro.sched.bridge import (
    evaluate_snapshots,
    evaluate_snapshots_by_routing,
    snapshot_workload,
)
from repro.sched.jobs import (
    Job,
    heavy_tailed_stream,
    load_trace,
    poisson_stream,
    save_trace,
)
from repro.sched.ledger import BlockLedger
from repro.sched.metrics import JobRecord, StreamResult
from repro.sched.scheduler import FailureEvent, OnlineScheduler, Snapshot

__all__ = [
    "BlockLedger",
    "FailureEvent",
    "Job",
    "JobRecord",
    "OnlineScheduler",
    "Snapshot",
    "StreamResult",
    "evaluate_snapshots",
    "evaluate_snapshots_by_routing",
    "heavy_tailed_stream",
    "load_trace",
    "poisson_stream",
    "save_trace",
    "snapshot_workload",
]
