"""Machine-state ledger: block slots + endpoints on a fragmented machine.

The paper's allocation functions tessellate a well-balanced n x n HyperX
into exactly ``n`` disjoint *base blocks* (partition ids p in [0, n)), so
the natural scheduling granularity is the block slot.  The ledger keeps
**endpoint-level occupancy as ground truth** (a bool per endpoint, exactly
like :class:`~repro.core.allocation.JobAllocator`), and derives per-strategy
slot views from it: block slot ``p`` of strategy ``S`` is free iff every
endpoint that ``S`` maps into block ``p`` is free and healthy.  Because the
views are derived, jobs placed under *different* strategies can safely
coexist on one machine (their block frames differ, but endpoint-level
disjointness is what is enforced and conserved).

Placement policies over block sets:

  * ``first_fit`` — lowest contiguous run of free slots that fits;
  * ``best_fit``  — smallest contiguous run that fits (ties: lowest);
  * both fall back to the lowest k free slots ("scatter") when no
    contiguous run fits and ``allow_scatter`` is set — the paper's
    consecutive-blocks convention is preferred but not required, and the
    realized-PB metrics quantify what scattering costs.

The API is a superset of :class:`JobAllocator`'s surface (``allocate`` /
``release`` / ``fail_endpoints`` / ``repair_endpoints`` / ``capacity`` plus
``free``/``failed``/``jobs``/``seed``), so the ledger drops into
:class:`repro.runtime.FleetRuntime` as the fleet allocator and the repair
path goes through :meth:`replace_job`.
"""

from __future__ import annotations

import dataclasses
from typing import Dict

import numpy as np

from repro.core.allocation import (
    AllocationStrategy,
    Partition,
    allocate_blocks,
    get_strategy,
    scavenge_partition,
)
from repro.core.hyperx import HyperX


@dataclasses.dataclass(frozen=True)
class PlacedJob:
    """Ledger record of one placed job."""

    partition: Partition
    slots: tuple[int, ...]       # block slots occupied, rank order
    slot_endpoints: np.ndarray   # ALL endpoints of those slots (>= size)
    contiguous: bool


def _runs(free: np.ndarray) -> list[tuple[int, int]]:
    """Maximal runs of True as (start, length), in index order."""
    out = []
    start = None
    for i, f in enumerate(free):
        if f and start is None:
            start = i
        elif not f and start is not None:
            out.append((start, i - start))
            start = None
    if start is not None:
        out.append((start, len(free) - start))
    return out


class BlockLedger:
    """Free/occupied block and endpoint tracking for one HyperX machine."""

    def __init__(
        self,
        topo: HyperX,
        strategy: str | AllocationStrategy = "diagonal",
        seed: int = 0,
        policy: str = "first_fit",
        allow_scatter: bool = True,
    ):
        if topo.concentration != topo.n:
            raise ValueError(
                f"block ledger needs a well-balanced machine "
                f"(concentration == n), got {topo}"
            )
        if policy not in ("first_fit", "best_fit"):
            raise ValueError(f"unknown placement policy {policy!r}")
        self.topo = topo
        self.strategy = get_strategy(strategy) if isinstance(strategy, str) else strategy
        self.seed = seed
        self.policy = policy
        self.allow_scatter = allow_scatter
        self.block = topo.n * topo.n
        self.num_slots = topo.n
        self.free = np.ones(topo.num_endpoints, dtype=bool)
        self.failed = np.zeros(topo.num_endpoints, dtype=bool)
        self.jobs: Dict[int, PlacedJob] = {}
        self._next_job = 0
        self._slot_eps: dict[tuple[str, int, int], np.ndarray] = {}

    # ------------------------------------------------------------ slot views
    def slot_endpoints(self, slot: int, strategy=None) -> np.ndarray:
        """All n**2 endpoints that ``strategy`` maps into block ``slot``.

        The cache is keyed by the *current* seed as well: FleetRuntime's
        stochastic fallback mutates ``allocator.seed`` between placements,
        and a view cached under another seed would disagree with what
        :func:`allocate_blocks` actually allocates."""
        strat = self._strat(strategy)
        key = (strat.name, self.seed, int(slot))
        eps = self._slot_eps.get(key)
        if eps is None:
            part = allocate_blocks(strat, self.topo, [int(slot)], seed=self.seed)
            eps = np.sort(part.endpoints)
            self._slot_eps[key] = eps
        return eps

    def free_slots(self, strategy=None) -> np.ndarray:
        """(n,) bool: slot fully free AND fully healthy under ``strategy``."""
        ok = np.empty(self.num_slots, dtype=bool)
        for p in range(self.num_slots):
            eps = self.slot_endpoints(p, strategy)
            ok[p] = bool(self.free[eps].all())
        return ok

    def capacity(self) -> int:
        return int(self.free.sum())

    def fragmentation(self, strategy=None) -> float:
        """1 - largest_free_run / free_slots (0 = contiguous, -> 1 = shredded).

        Measured in the block frame of ``strategy`` (default: the ledger's):
        a machine whose free slots cannot host a multi-block job contiguously
        forces either queueing or scattered placement.
        """
        free = self.free_slots(strategy)
        total = int(free.sum())
        if total == 0:
            return 0.0
        largest = max((ln for _, ln in _runs(free)), default=0)
        return 1.0 - largest / total

    # ------------------------------------------------------------- placement
    def find_slots(self, k: int, strategy=None) -> tuple[list[int], bool] | None:
        """Pick ``k`` free slots by policy; (slots, contiguous) or None."""
        if k <= 0:
            raise ValueError(f"need a positive block count, got {k}")
        free = self.free_slots(strategy)
        runs = [(s, ln) for s, ln in _runs(free) if ln >= k]
        if runs:
            if self.policy == "best_fit":
                start, _ = min(runs, key=lambda r: (r[1], r[0]))
            else:
                start, _ = runs[0]
            return list(range(start, start + k)), True
        if self.allow_scatter:
            idx = np.flatnonzero(free)
            if len(idx) >= k:
                return idx[:k].tolist(), False
        return None

    def place(
        self,
        blocks: int,
        size: int | None = None,
        strategy=None,
        job_id: int | None = None,
    ) -> Partition:
        """Place a job of ``blocks`` base blocks; raises RuntimeError if it
        does not fit.  ``size`` (endpoints, default blocks*n**2) may take a
        prefix of the final block; the whole slot is still held (internal
        fragmentation, exactly like node-granular HPC schedulers)."""
        strat = self._strat(strategy)
        found = self.find_slots(blocks, strat)
        if found is None:
            raise RuntimeError(
                f"no {blocks} free {strat.name} block(s) "
                f"(free endpoints: {self.capacity()}, "
                f"fragmentation: {self.fragmentation(strat):.2f})"
            )
        slots, contiguous = found
        jid = self._next_job if job_id is None else job_id
        if jid in self.jobs:
            raise ValueError(f"job id {jid} is already placed")
        part = allocate_blocks(
            strat, self.topo, slots, job_id=jid, size=size, seed=self.seed
        )
        slot_eps = np.concatenate([self.slot_endpoints(p, strat) for p in slots])
        assert self.free[slot_eps].all(), "ledger invariant: slots were free"
        self.free[slot_eps] = False
        self.jobs[jid] = PlacedJob(
            partition=part, slots=tuple(slots),
            slot_endpoints=slot_eps, contiguous=contiguous,
        )
        # keep auto ids clear of explicit ones (shared-ledger tenants)
        self._next_job = max(self._next_job, jid + 1)
        return part

    def allocate(self, size: int | None = None, strategy=None) -> Partition:
        """JobAllocator-compatible entry: size in endpoints, blocks = ceil."""
        size = size or self.block
        return self.place(-(-size // self.block), size=size, strategy=strategy)

    def scavenge(self, size: int) -> Partition:
        """Last-resort placement on arbitrary free endpoints (no block
        structure) — the FleetRuntime fallback contract.  Recorded with an
        empty slot list; the held endpoints are exactly the partition's."""
        part = scavenge_partition(self.free, self.topo, self._next_job, size)
        self.free[part.endpoints] = False
        self.jobs[part.job_id] = PlacedJob(
            partition=part, slots=(), slot_endpoints=part.endpoints,
            contiguous=False,
        )
        self._next_job += 1
        return part

    def release(self, job_id: int) -> None:
        job = self.jobs.pop(job_id)
        # failed endpoints stay out of the pool until repaired
        self.free[job.slot_endpoints] = ~self.failed[job.slot_endpoints]

    # ------------------------------------------------------ failure / repair
    def fail_endpoints(self, endpoints) -> list[int]:
        """Mark endpoints failed; return ids of jobs whose slots they hit."""
        endpoints = np.atleast_1d(np.asarray(endpoints, dtype=np.int64))
        affected = [
            jid for jid, job in self.jobs.items()
            if np.intersect1d(job.slot_endpoints, endpoints).size
        ]
        self.failed[endpoints] = True
        self.free[endpoints] = False
        return affected

    def repair_endpoints(self, endpoints) -> None:
        """Return repaired endpoints to the pool (unless currently held)."""
        endpoints = np.atleast_1d(np.asarray(endpoints, dtype=np.int64))
        self.failed[endpoints] = False
        held = np.zeros_like(self.free)
        for job in self.jobs.values():
            held[job.slot_endpoints] = True
        self.free[endpoints] = ~held[endpoints]

    def replace_job(self, job_id: int, strategy=None) -> Partition:
        """Re-place a job after failures hit its slots (the repair path).

        Releases the old slots and places the same block count on the
        surviving machine — same contract as FleetRuntime's repair: the
        caller restores application state from checkpoint onto the new
        partition.  Raises RuntimeError (with the job *unplaced* and its
        old slots released) when the survivors cannot host it.
        """
        old = self.jobs[job_id]
        self.release(job_id)
        return self.place(
            len(old.slots), size=old.partition.size,
            strategy=strategy, job_id=job_id,
        )

    # ------------------------------------------------------------ invariants
    def owner_map(self) -> np.ndarray:
        """(E,) job id holding each endpoint, -1 free/failed.  Raises on
        overlap (the disjointness invariant the tests pin)."""
        owner = np.full(self.topo.num_endpoints, -1, dtype=np.int64)
        for jid, job in self.jobs.items():
            if (owner[job.slot_endpoints] != -1).any():
                raise ValueError(f"ledger overlap at job {jid}")
            owner[job.slot_endpoints] = jid
        return owner

    def check_conservation(self) -> None:
        """free, held and failed-unheld endpoints must tile the machine."""
        owner = self.owner_map()  # raises on overlap
        held = owner != -1
        if (self.free & held).any():
            raise AssertionError("endpoint both free and held")
        if (self.free & self.failed).any():
            raise AssertionError("endpoint both free and failed")
        accounted = self.free | held | self.failed
        if not accounted.all():
            raise AssertionError(
                f"{int((~accounted).sum())} endpoints leaked from the ledger"
            )

    def _strat(self, strategy) -> AllocationStrategy:
        if strategy is None:
            return self.strategy
        return get_strategy(strategy) if isinstance(strategy, str) else strategy

    def __repr__(self) -> str:
        return (
            f"BlockLedger({self.topo}, {self.strategy.name}, "
            f"free={self.capacity()}/{self.topo.num_endpoints}, "
            f"jobs={len(self.jobs)})"
        )
