"""Job-stream layer: synthetic arrival generators + deterministic replay.

Jobs are sized in *base blocks* (one block = n**2 endpoints, the unit the
paper's allocation functions tessellate the machine into).  Streams are
plain lists of :class:`Job`, so any generator output can be saved to a CSV
trace and replayed bit-identically — the scheduler itself is deterministic
given a stream, which makes per-strategy comparisons exact (every strategy
sees the same arrivals).

Two synthetic generators cover the standard workload models the HPC
scheduling literature uses (cf. AccaSim's workload generators):

  * :func:`poisson_stream` — exponential interarrival and service times
    (M/M/c-like churn, light tail);
  * :func:`heavy_tailed_stream` — exponential arrivals with bounded-Pareto
    service times (a few very long jobs dominate machine occupancy, the
    empirically observed HPC regime).
"""

from __future__ import annotations

import csv
import dataclasses
from typing import Iterable, Sequence

import numpy as np

#: kernels whose step tables are valid for any block-multiple job size on
#: the paper machines (power-of-two rank counts).
STREAM_KERNELS = ("all_to_all", "all_reduce", "stencil_von_neumann")


@dataclasses.dataclass(frozen=True)
class Job:
    """One job of the stream, sized in base blocks of the machine."""

    job_id: int
    arrival: float   # scheduler time units
    blocks: int      # base blocks requested (1 block = n**2 endpoints)
    service: float   # runtime once started (walltime, known at submit)
    kernel: str = "all_to_all"  # communication kernel for interference eval


def _draw_blocks(rng: np.random.Generator, block_weights) -> int:
    sizes = np.array([b for b, _ in block_weights], dtype=np.int64)
    w = np.array([p for _, p in block_weights], dtype=np.float64)
    return int(rng.choice(sizes, p=w / w.sum()))


def _make_stream(
    num_jobs: int,
    rate: float,
    service_draw,
    block_weights,
    kernels: Sequence[str],
    seed: int,
) -> list[Job]:
    rng = np.random.default_rng(seed)
    t = 0.0
    jobs = []
    for j in range(num_jobs):
        t += float(rng.exponential(1.0 / rate))
        jobs.append(
            Job(
                job_id=j,
                arrival=round(t, 6),
                blocks=_draw_blocks(rng, block_weights),
                service=round(max(float(service_draw(rng)), 1e-3), 6),
                kernel=str(rng.choice(np.asarray(kernels, dtype=object))),
            )
        )
    return jobs


def poisson_stream(
    num_jobs: int,
    rate: float = 0.5,
    mean_service: float = 8.0,
    block_weights: Sequence[tuple[int, float]] = ((1, 0.5), (2, 0.3), (4, 0.2)),
    kernels: Sequence[str] = STREAM_KERNELS,
    seed: int = 0,
) -> list[Job]:
    """Poisson arrivals (``rate`` jobs/time-unit), exponential service.

    Offered load on an n-slot machine is roughly
    ``rate * mean_service * E[blocks] / n``; pick ``rate`` near saturation
    to exercise queueing and fragmentation.
    """
    return _make_stream(
        num_jobs, rate, lambda rng: rng.exponential(mean_service),
        block_weights, kernels, seed,
    )


def heavy_tailed_stream(
    num_jobs: int,
    rate: float = 0.5,
    service_scale: float = 3.0,
    pareto_shape: float = 1.5,
    service_cap: float = 200.0,
    block_weights: Sequence[tuple[int, float]] = ((1, 0.5), (2, 0.3), (4, 0.2)),
    kernels: Sequence[str] = STREAM_KERNELS,
    seed: int = 0,
) -> list[Job]:
    """Poisson arrivals with bounded-Pareto service times (heavy tail)."""

    def draw(rng: np.random.Generator) -> float:
        return min(service_scale * (1.0 + rng.pareto(pareto_shape)), service_cap)

    return _make_stream(num_jobs, rate, draw, block_weights, kernels, seed)


# ------------------------------------------------------------- trace replay
_FIELDS = ("job_id", "arrival", "blocks", "service", "kernel")


def save_trace(jobs: Iterable[Job], path: str) -> None:
    """Write a stream as a CSV trace (the deterministic-replay format)."""
    with open(path, "w", newline="") as f:
        w = csv.writer(f)
        w.writerow(_FIELDS)
        for j in jobs:
            w.writerow([j.job_id, j.arrival, j.blocks, j.service, j.kernel])


def load_trace(path: str) -> list[Job]:
    """Read a CSV trace back into a stream, sorted by arrival time."""
    jobs = []
    with open(path, newline="") as f:
        r = csv.DictReader(f)
        missing = set(_FIELDS) - set(r.fieldnames or ())
        if missing:
            raise ValueError(f"trace {path} missing columns {sorted(missing)}")
        for row in r:
            jobs.append(
                Job(
                    job_id=int(row["job_id"]),
                    arrival=float(row["arrival"]),
                    blocks=int(row["blocks"]),
                    service=float(row["service"]),
                    kernel=row["kernel"],
                )
            )
    return sorted(jobs, key=lambda j: (j.arrival, j.job_id))
