"""Interference bridge: co-resident snapshots through the batched SimEngine.

A :class:`~repro.sched.scheduler.Snapshot` freezes the set of jobs sharing
the machine at one scheduling event.  This module lowers snapshots through
the declarative scenario layer (:mod:`repro.traffic.scenario`) to
:class:`~repro.traffic.workload.Workload`s (each job runs its registry
kernel on its *actually placed* partition) and executes the whole
strategy x snapshot x seed grid through ``SimEngine.run_batch_seeds`` — the
engine groups workloads by shape bucket internally, so the entire grid
costs **one compilation and one device call per shape bucket** regardless
of how many strategies, snapshots, or seeds it spans (the trace-counter
test pins this).

Fault-aware routing closes the loop with the scheduler's failure churn: a
snapshot records the endpoints the ledger had marked failed, and
``churn_faults=True`` lowers them to link-fault masks
(:func:`repro.route.faults.faults_from_endpoints` — failure domains are
co-packaged, so a dead node takes an adjacent cable with it).  Masks ride
in the workload tables, so fault scenarios batch like any other axis.
:func:`evaluate_snapshots_by_routing` runs the same snapshot grid once per
registered routing policy (one engine — one compile set — per policy).
"""

from __future__ import annotations

from typing import Mapping, Sequence

import numpy as np

from repro.core.engine import SimResult, get_engine
from repro.core.engine.workload_tables import shape_bucket
from repro.core.hyperx import HyperX
from repro.obs import trace as obs_trace
from repro.route import apply_faults, faults_from_endpoints
from repro.sched.scheduler import Snapshot
from repro.traffic import AppSpec, ScenarioSpec, build_workload, get_pattern
from repro.traffic.workload import Workload


def snapshot_workload(
    topo: HyperX,
    snap: Snapshot,
    fabric_partitioning: str = "shared",
    churn_faults: bool = False,
) -> Workload:
    """Lower one snapshot: every co-resident job's kernel on its partition.

    Job kernels resolve through the traffic-pattern registry, so any
    registered pattern name (including phased ``"a+b"`` compositions) is
    a valid job kernel.  ``churn_faults`` additionally lowers the
    snapshot's failed endpoints (the scheduler's churn, frozen at
    snapshot time) into a link-fault mask the routing policies must
    steer around.
    """
    apps = []
    for job_id, kernel, part in snap.jobs:
        phases = kernel.split("+")
        for name in phases:
            try:
                get_pattern(name)
            except ValueError as e:
                raise KeyError(f"job {job_id}: {e}") from None
        apps.append(AppSpec(phases=tuple(phases), placement=part))
    wl = build_workload(topo, ScenarioSpec(
        apps=tuple(apps), fabric_partitioning=fabric_partitioning,
    ))
    if churn_faults and snap.failed_endpoints:
        wl = apply_faults(
            wl, faults_from_endpoints(topo, snap.failed_endpoints)
        )
    return wl


def pick_snapshots(
    snapshots: Sequence[Snapshot],
    max_snapshots: int,
    min_jobs: int = 2,
) -> list[Snapshot]:
    """Evenly sample up to ``max_snapshots`` snapshots with >= min_jobs."""
    eligible = [s for s in snapshots if s.num_jobs >= min_jobs]
    if len(eligible) <= max_snapshots:
        return eligible
    idx = np.linspace(0, len(eligible) - 1, max_snapshots).round().astype(int)
    return [eligible[i] for i in sorted(set(idx.tolist()))]


def evaluate_snapshots(
    topo: HyperX,
    snapshots_by_key: Mapping[str, Sequence[Snapshot]],
    seeds: Sequence[int] = (0,),
    horizon: int = 60_000,
    mode: str = "omniwar",
    fabric_partitioning: str = "shared",
    churn_faults: bool = False,
) -> tuple[list[dict], dict]:
    """Evaluate snapshot grids for many strategies in batched device calls.

    ``snapshots_by_key`` maps a label (typically the strategy name) to its
    snapshots.  ALL workloads across all keys go through one engine and one
    ``run_batch_seeds`` call, so same-shape-bucket scenarios of different
    strategies share both the compilation and the dispatch.

    Returns (rows, stats): one row per (key, snapshot, seed) with the
    SimResult metrics plus co-residency context; ``stats`` holds the
    ``engine`` plus the ``traces`` / ``device_calls`` this evaluation
    *added* (deltas — engines are memoised per config and may already
    carry counts from earlier sweeps).
    """
    keys, snaps, workloads = [], [], []
    for key, group in snapshots_by_key.items():
        for snap in group:
            wl = snapshot_workload(
                topo, snap, fabric_partitioning, churn_faults=churn_faults
            )
            keys.append(key)
            snaps.append(snap)
            workloads.append(wl)
    if not workloads:
        return [], {"engine": None, "traces": 0, "device_calls": 0}
    num_pools = {wl.num_pools for wl in workloads}
    if len(num_pools) != 1:
        raise ValueError(
            f"snapshots lower to mixed VC pool counts {sorted(num_pools)}; "
            "evaluate per fabric_partitioning mode"
        )
    engine = get_engine(topo, mode=mode, num_pools=num_pools.pop())
    traces0, calls0 = engine.trace_count, engine.device_calls
    # device-sharded lanes: on a multi-device host the snapshot x seed grid
    # splits across devices; on one device this is the nested-vmap call
    with obs_trace.span("bridge.evaluate_snapshots", mode=mode,
                        workloads=len(workloads), seeds=len(seeds)):
        per_wl = engine.run_grid(workloads, seeds=seeds, horizon=horizon)
    rows = []
    for key, snap, wl, per_seed in zip(keys, snaps, workloads, per_wl):
        bucket = shape_bucket(wl.R, wl.T, wl.maxd)
        for seed, res in zip(seeds, per_seed):
            assert isinstance(res, SimResult)
            rows.append({
                "key": key,
                "routing": mode,
                "time": round(snap.time, 3),
                "co_jobs": snap.num_jobs,
                "failed_eps": len(snap.failed_endpoints) if churn_faults else 0,
                "ranks": wl.R,
                "bucket": "x".join(map(str, bucket)),
                "seed": int(seed),
                "makespan": res.makespan if res.completed else -1,
                "avg_latency": round(res.avg_latency, 3),
                "avg_hops": round(res.avg_hops, 4),
                "completed": res.completed,
            })
    return rows, {
        "engine": engine,
        "traces": engine.trace_count - traces0,
        "device_calls": engine.device_calls - calls0,
    }


def evaluate_snapshots_by_routing(
    topo: HyperX,
    snapshots_by_key: Mapping[str, Sequence[Snapshot]],
    modes: Sequence[str] = ("min", "omniwar", "val", "ugal"),
    seeds: Sequence[int] = (0,),
    horizon: int = 60_000,
    fabric_partitioning: str = "shared",
    churn_faults: bool = True,
) -> tuple[list[dict], dict]:
    """The snapshot interference grid, once per routing policy.

    Each policy is its own engine (its VC budget changes the queue
    space), so the cost is one compile set per mode — within a mode the
    whole strategy x snapshot x seed grid still batches per shape
    bucket.  ``churn_faults`` (default on) sources link faults from each
    snapshot's recorded failure churn, making this the
    routing x strategy x fault grid of DESIGN.md §Routing.

    Returns (rows, stats_by_mode): rows carry a ``routing`` column;
    ``stats_by_mode[mode]`` is the per-mode stats dict of
    :func:`evaluate_snapshots`.
    """
    rows: list[dict] = []
    stats_by_mode: dict[str, dict] = {}
    for mode in modes:
        mode_rows, stats = evaluate_snapshots(
            topo, snapshots_by_key, seeds=seeds, horizon=horizon,
            mode=mode, fabric_partitioning=fabric_partitioning,
            churn_faults=churn_faults,
        )
        rows.extend(mode_rows)
        stats_by_mode[mode] = stats
    return rows, stats_by_mode
