"""Interference bridge: co-resident snapshots through the batched SimEngine.

A :class:`~repro.sched.scheduler.Snapshot` freezes the set of jobs sharing
the machine at one scheduling event.  This module lowers snapshots to
:class:`~repro.core.traffic.Workload`s (each job runs its communication
kernel on its *actually placed* partition) and executes the whole
strategy x snapshot x seed grid through ``SimEngine.run_batch_seeds`` — the
engine groups workloads by shape bucket internally, so the entire grid
costs **one compilation and one device call per shape bucket** regardless
of how many strategies, snapshots, or seeds it spans (the trace-counter
test pins this).
"""

from __future__ import annotations

from typing import Mapping, Sequence

import numpy as np

from repro.core import traffic as tr
from repro.core.engine import SimResult, get_engine
from repro.core.engine.workload_tables import shape_bucket
from repro.core.hyperx import HyperX
from repro.core.traffic import Workload
from repro.sched.scheduler import Snapshot

_KERNELS = dict(tr.KERNELS)
_KERNELS["uniform"] = tr.uniform
_KERNELS["random_permutation"] = tr.random_permutation


def snapshot_workload(
    topo: HyperX,
    snap: Snapshot,
    fabric_partitioning: str = "shared",
) -> Workload:
    """Lower one snapshot: every co-resident job's kernel on its partition."""
    apps = []
    for job_id, kernel, part in snap.jobs:
        try:
            builder = _KERNELS[kernel]
        except KeyError:
            raise KeyError(
                f"job {job_id}: unknown kernel {kernel!r}; "
                f"available: {sorted(_KERNELS)}"
            ) from None
        apps.append((builder(part.size), part))
    return tr.compose_workload(
        topo, apps, fabric_partitioning=fabric_partitioning
    )


def pick_snapshots(
    snapshots: Sequence[Snapshot],
    max_snapshots: int,
    min_jobs: int = 2,
) -> list[Snapshot]:
    """Evenly sample up to ``max_snapshots`` snapshots with >= min_jobs."""
    eligible = [s for s in snapshots if s.num_jobs >= min_jobs]
    if len(eligible) <= max_snapshots:
        return eligible
    idx = np.linspace(0, len(eligible) - 1, max_snapshots).round().astype(int)
    return [eligible[i] for i in sorted(set(idx.tolist()))]


def evaluate_snapshots(
    topo: HyperX,
    snapshots_by_key: Mapping[str, Sequence[Snapshot]],
    seeds: Sequence[int] = (0,),
    horizon: int = 60_000,
    mode: str = "omniwar",
    fabric_partitioning: str = "shared",
) -> tuple[list[dict], dict]:
    """Evaluate snapshot grids for many strategies in batched device calls.

    ``snapshots_by_key`` maps a label (typically the strategy name) to its
    snapshots.  ALL workloads across all keys go through one engine and one
    ``run_batch_seeds`` call, so same-shape-bucket scenarios of different
    strategies share both the compilation and the dispatch.

    Returns (rows, stats): one row per (key, snapshot, seed) with the
    SimResult metrics plus co-residency context; ``stats`` holds the
    ``engine`` plus the ``traces`` / ``device_calls`` this evaluation
    *added* (deltas — engines are memoised per config and may already
    carry counts from earlier sweeps).
    """
    keys, snaps, workloads = [], [], []
    for key, group in snapshots_by_key.items():
        for snap in group:
            wl = snapshot_workload(topo, snap, fabric_partitioning)
            keys.append(key)
            snaps.append(snap)
            workloads.append(wl)
    if not workloads:
        return [], {"engine": None, "traces": 0, "device_calls": 0}
    num_pools = {wl.num_pools for wl in workloads}
    if len(num_pools) != 1:
        raise ValueError(
            f"snapshots lower to mixed VC pool counts {sorted(num_pools)}; "
            "evaluate per fabric_partitioning mode"
        )
    engine = get_engine(topo, mode=mode, num_pools=num_pools.pop())
    traces0, calls0 = engine.trace_count, engine.device_calls
    per_wl = engine.run_batch_seeds(workloads, seeds=seeds, horizon=horizon)
    rows = []
    for key, snap, wl, per_seed in zip(keys, snaps, workloads, per_wl):
        bucket = shape_bucket(wl.R, wl.T, wl.maxd)
        for seed, res in zip(seeds, per_seed):
            assert isinstance(res, SimResult)
            rows.append({
                "key": key,
                "time": round(snap.time, 3),
                "co_jobs": snap.num_jobs,
                "ranks": wl.R,
                "bucket": "x".join(map(str, bucket)),
                "seed": int(seed),
                "makespan": res.makespan if res.completed else -1,
                "avg_latency": round(res.avg_latency, 3),
                "avg_hops": round(res.avg_hops, 4),
                "completed": res.completed,
            })
    return rows, {
        "engine": engine,
        "traces": engine.trace_count - traces0,
        "device_calls": engine.device_calls - calls0,
    }
