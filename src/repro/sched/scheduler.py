"""Online scheduler event loop: FCFS + EASY backfilling over the ledger.

Discrete-event simulation of a job stream against one
:class:`~repro.sched.ledger.BlockLedger`.  Event kinds, in same-time
processing order: departures free slots first, repairs return endpoints,
failures take them, arrivals join the queue; after each timestamp the
scheduling pass runs.

Scheduling is FCFS with count-based EASY backfilling: when the queue head
does not fit, its *shadow time* (earliest time enough block slots will be
free, from the known finish times of running jobs) reserves capacity, and a
later job may jump ahead only if it fits now and either finishes before the
shadow time or leaves enough slots for the head's reservation.  Service
times are known at submission (user-supplied walltime), the standard EASY
assumption.

Failures route through the ledger's repair path: a job whose slots are hit
is re-placed on the surviving machine (a migration — same contract as
``FleetRuntime``'s checkpoint-restore repair) and, when the survivors
cannot host it, evicted back to the queue head with its remaining service
time (a requeue).

At every successful placement the scheduler snapshots the co-resident job
set; :mod:`repro.sched.bridge` turns those snapshots into batched SimEngine
evaluations.

When a :mod:`repro.obs.trace` tracer is active, the event loop emits
structured ``sched.*`` events (arrive / start / backfill flag / depart /
fail / migrate / requeue / repair), fragmentation gauges at every
scheduling pass, and a final per-stream summary — the fleet report
generator aggregates these into the fragmentation/churn tables.  With no
tracer configured the loop pays a single global check per event.
"""

from __future__ import annotations

import dataclasses
import heapq
from typing import Sequence

import numpy as np

from repro.core.allocation import Partition
from repro.core.hyperx import HyperX
from repro.core.properties import has_switch_locality, partition_bandwidth
from repro.obs import trace as obs_trace
from repro.sched.jobs import Job
from repro.sched.ledger import BlockLedger
from repro.sched.metrics import JobRecord, StreamResult

_ORDER = {"depart": 0, "repair": 1, "fail": 2, "arrive": 3}


@dataclasses.dataclass(frozen=True)
class FailureEvent:
    """Endpoints fail at ``time``; optionally repaired at ``repair_at``."""

    time: float
    endpoints: tuple[int, ...]
    repair_at: float | None = None


@dataclasses.dataclass(frozen=True)
class Snapshot:
    """Co-resident jobs at one scheduling event (placement time)."""

    time: float
    trigger: int  # job id whose placement produced this snapshot
    jobs: tuple[tuple[int, str, Partition], ...]  # (job_id, kernel, partition)
    # endpoints marked failed in the ledger when the snapshot was taken —
    # the bridge lowers these to link-fault masks for fault-aware routing
    failed_endpoints: tuple[int, ...] = ()

    @property
    def num_jobs(self) -> int:
        return len(self.jobs)


class OnlineScheduler:
    """One strategy x policy scheduling run over a job stream."""

    def __init__(
        self,
        topo: HyperX,
        strategy: str = "diagonal",
        policy: str = "first_fit",
        backfill: bool = True,
        allow_scatter: bool = True,
        seed: int = 0,
        analyze: bool = True,
    ):
        self.topo = topo
        self.ledger = BlockLedger(
            topo, strategy=strategy, seed=seed,
            policy=policy, allow_scatter=allow_scatter,
        )
        self.backfill = backfill
        self.analyze = analyze

    # --------------------------------------------------------------- driver
    def run_stream(
        self,
        jobs: Sequence[Job],
        failures: Sequence[FailureEvent] = (),
        check_invariants: bool = False,
    ) -> StreamResult:
        ledger = self.ledger
        too_big = [j.job_id for j in jobs if j.blocks > ledger.num_slots]
        if too_big:
            raise ValueError(
                f"jobs {too_big[:4]} request more than the machine's "
                f"{ledger.num_slots} base blocks"
            )
        records = {j.job_id: JobRecord(
            job_id=j.job_id, arrival=j.arrival, blocks=j.blocks,
            service=j.service, kernel=j.kernel,
        ) for j in jobs}

        heap: list[tuple] = []
        seq = 0
        for j in sorted(jobs, key=lambda x: (x.arrival, x.job_id)):
            heapq.heappush(heap, (j.arrival, _ORDER["arrive"], seq, "arrive", j))
            seq += 1
        for f in failures:
            heapq.heappush(heap, (f.time, _ORDER["fail"], seq, "fail", f))
            seq += 1
            if f.repair_at is not None:
                heapq.heappush(
                    heap, (f.repair_at, _ORDER["repair"], seq, "repair", f)
                )
                seq += 1

        stream = f"{ledger.strategy.name}/{ledger.policy}"

        queue: list[Job] = []
        running: dict[int, dict] = {}  # jid -> {job, finish}
        gens: dict[int, int] = {}      # jid -> placement generation
        snapshots: list[Snapshot] = []
        # time integrals
        last_t = 0.0
        busy = 0.0        # requested endpoint-seconds
        gross = 0.0       # slot-held endpoint-seconds
        frag_int = 0.0
        frag_max = 0.0
        queue_int = 0.0
        E = self.topo.num_endpoints

        def advance(now: float):
            nonlocal last_t, busy, gross, frag_int, frag_max, queue_int
            dt = now - last_t
            if dt > 0:
                req = sum(ledger.jobs[j].partition.size for j in running)
                held = sum(len(ledger.jobs[j].slot_endpoints) for j in running)
                frag = ledger.fragmentation()
                busy += req * dt
                gross += held * dt
                frag_int += frag * dt
                frag_max = max(frag_max, frag)
                queue_int += len(queue) * dt
                last_t = now

        def analyze_placement(jid: int):
            """Record the job's CURRENT placement quality (last placement
            wins: a migration onto scattered blocks must show up)."""
            rec = records[jid]
            placed = ledger.jobs[jid]
            rec.scattered = rec.scattered or not placed.contiguous
            if self.analyze:
                eps = placed.partition.endpoints
                pb, bound = partition_bandwidth(self.topo, eps)
                rec.realized_pb = pb
                rec.pb_bound = bound
                rec.switch_local = has_switch_locality(self.topo, eps)

        def take_snapshot(now: float, trigger: int):
            snapshots.append(Snapshot(
                time=now, trigger=trigger,
                jobs=tuple(
                    (jid, running[jid]["job"].kernel, ledger.jobs[jid].partition)
                    for jid in sorted(running)
                ),
                failed_endpoints=tuple(
                    int(e) for e in np.flatnonzero(ledger.failed)
                ),
            ))

        def start(job: Job, now: float, backfilled: bool = False) -> bool:
            try:
                ledger.place(job.blocks, job_id=job.job_id)
            except RuntimeError:
                return False
            rec = records[job.job_id]
            if rec.start is None:
                rec.start = now
                rec.wait = now - rec.arrival
            obs_trace.event(
                "sched.start", stream=stream, job=job.job_id, t_sim=now,
                blocks=job.blocks, wait=round(now - rec.arrival, 4),
                backfilled=backfilled,
                scattered=not ledger.jobs[job.job_id].contiguous,
            )
            nonlocal seq
            gen = gens.get(job.job_id, 0) + 1
            gens[job.job_id] = gen
            running[job.job_id] = {"job": job, "finish": now + job.service}
            heapq.heappush(
                heap,
                (now + job.service, _ORDER["depart"], seq, "depart",
                 (job.job_id, gen)),
            )
            seq += 1
            analyze_placement(job.job_id)
            take_snapshot(now, job.job_id)
            return True

        def shadow_for(head: Job, now: float) -> tuple[float, int]:
            """Count-based reservation: (shadow time, slots freed by then)."""
            free_now = int(ledger.free_slots().sum())
            if free_now >= head.blocks:
                return now, 0  # blocked by fragmentation only, not capacity
            freed = 0
            for jid in sorted(running, key=lambda j: running[j]["finish"]):
                freed += len(ledger.jobs[jid].slots)
                if free_now + freed >= head.blocks:
                    return running[jid]["finish"], freed
            return float("inf"), freed

        def schedule(now: float):
            while queue:
                if start(queue[0], now):
                    queue.pop(0)
                    continue
                if not self.backfill or len(queue) == 1:
                    break
                head = queue[0]
                shadow, freed_by_shadow = shadow_for(head, now)
                for cand in list(queue[1:]):
                    if ledger.find_slots(cand.blocks) is None:
                        continue
                    free_now = int(ledger.free_slots().sum())
                    fits_reservation = (
                        now + cand.service <= shadow + 1e-9
                        or free_now - cand.blocks + freed_by_shadow >= head.blocks
                    )
                    if fits_reservation and start(cand, now, backfilled=True):
                        queue.remove(cand)
                break

        while heap:
            now = heap[0][0]
            while heap and heap[0][0] == now:
                _, _, _, kind, payload = heapq.heappop(heap)
                advance(now)
                if kind == "arrive":
                    queue.append(payload)
                    obs_trace.event("sched.arrive", stream=stream,
                                    job=payload.job_id, t_sim=now,
                                    blocks=payload.blocks)
                elif kind == "depart":
                    jid, gen = payload
                    if jid not in running or gens.get(jid) != gen:
                        continue  # stale event (job was requeued)
                    del running[jid]
                    ledger.release(jid)
                    records[jid].finish = now
                    obs_trace.event("sched.depart", stream=stream, job=jid,
                                    t_sim=now)
                elif kind == "fail":
                    affected = ledger.fail_endpoints(np.asarray(payload.endpoints))
                    obs_trace.event("sched.fail", stream=stream, t_sim=now,
                                    endpoints=len(payload.endpoints),
                                    affected_jobs=len(affected))
                    for jid in affected:
                        if jid not in running:
                            continue
                        rec = records[jid]
                        try:
                            ledger.replace_job(jid)
                            rec.migrations += 1
                            # a migration IS a placement: refresh the
                            # realized metrics and snapshot the machine
                            analyze_placement(jid)
                            take_snapshot(now, jid)
                            obs_trace.event("sched.migrate", stream=stream,
                                            job=jid, t_sim=now)
                        except RuntimeError:
                            # evicted: back to the queue head with the
                            # remaining service time
                            info = running.pop(jid)
                            gens[jid] += 1  # invalidate the depart event
                            remaining = info["finish"] - now
                            rec.requeues += 1
                            queue.insert(0, dataclasses.replace(
                                info["job"], service=remaining,
                            ))
                            obs_trace.event("sched.requeue", stream=stream,
                                            job=jid, t_sim=now)
                elif kind == "repair":
                    ledger.repair_endpoints(np.asarray(payload.endpoints))
                    obs_trace.event("sched.repair", stream=stream, t_sim=now,
                                    endpoints=len(payload.endpoints))
            schedule(now)
            if obs_trace.active() is not None:
                obs_trace.gauge("sched.frag", round(ledger.fragmentation(), 6),
                                stream=stream, t_sim=now,
                                running=len(running), queued=len(queue))
            if check_invariants:
                ledger.check_conservation()

        span = max(last_t, 1e-9)
        obs_trace.event(
            "sched.summary", stream=stream, jobs=len(jobs),
            snapshots=len(snapshots), span=round(span, 4),
            utilization=round(busy / (E * span), 6),
            frag_mean=round(frag_int / span, 6),
            frag_max=round(frag_max, 6),
            mean_queue=round(queue_int / span, 6),
        )
        return StreamResult(
            strategy=ledger.strategy.name,
            policy=ledger.policy,
            records=[records[j.job_id] for j in
                     sorted(jobs, key=lambda x: (x.arrival, x.job_id))],
            snapshots=snapshots,
            span=span,
            utilization=busy / (E * span),
            gross_utilization=gross / (E * span),
            frag_mean=frag_int / span,
            frag_max=frag_max,
            mean_queue=queue_int / span,
        )
