"""Online scheduler event loop: FCFS + EASY backfilling over the ledger.

Discrete-event simulation of a job stream against one
:class:`~repro.sched.ledger.BlockLedger`.  Event kinds, in same-time
processing order: departures free slots first, repairs return endpoints,
straggler reports are scored, failures take endpoints, arrivals join the
queue; after each timestamp the scheduling pass runs.

Scheduling is FCFS with count-based EASY backfilling: when the queue head
does not fit, its *shadow time* (earliest time enough block slots will be
free, from the known finish times of running jobs) reserves capacity, and a
later job may jump ahead only if it fits now and either finishes before the
shadow time or leaves enough slots for the head's reservation.  Service
times are known at submission (user-supplied walltime), the standard EASY
assumption.

Failures route through the ledger's repair path: a job whose slots are hit
is re-placed on the surviving machine (a migration — same contract as
``FleetRuntime``'s checkpoint-restore repair) and, when the survivors
cannot host it, optionally *shrunk to fit* (halving its block count until
it places, marked degraded) before being evicted back to the queue with
its remaining service time (a requeue).  Robustness knobs — all
behavior-preserving at their defaults:

  * ``mttr``      — failures without an explicit ``repair_at`` draw an
    exponential repair delay (mean ``mttr``) instead of staying down
    forever;
  * ``backoff_base`` — requeued jobs re-arrive after an exponential
    backoff (``base * 2**(retries-1)``) instead of jumping to the queue
    head;
  * ``max_retries`` — a job evicted more than this many times is marked
    failed and abandoned (``sched.giveup``);
  * ``shrink_to_fit`` — the graceful-degradation placement fallback above.

Straggler reports (``stragglers=[(time, host, seconds)]``) feed a
:class:`~repro.runtime.fault_tolerance.StragglerMonitor`; hosts it evicts
are treated as endpoint failures through the same migrate/requeue path
(``sched.evict``).

Crash safety: ``checkpoint_dir`` snapshots the entire stream state
(ledger + heap + queue + records + RNG) through the checkpoint substrate
every ``checkpoint_every`` processed timestamps; ``resume=True`` picks up
the latest committed snapshot and replays to a bit-identical final
``StreamResult`` (pinned by a kill-and-resume test).  ``crash_at`` kills
the process hard at the first event time past the given instant — the
test hook for that pin.

At every successful placement the scheduler snapshots the co-resident job
set; :mod:`repro.sched.bridge` turns those snapshots into batched SimEngine
evaluations.

When a :mod:`repro.obs.trace` tracer is active, the event loop emits
structured ``sched.*`` events (arrive / start / backfill flag / depart /
fail / migrate / requeue / repair / straggle / evict / degrade / giveup /
resume / checkpoint), fragmentation gauges at every scheduling pass,
periodic ``sched.heartbeat`` liveness beacons (every ``heartbeat_every``
ticks — the fleet watcher's stall rule keys off their gaps), and a final
per-stream summary — the fleet report generator aggregates these into
the fragmentation/churn tables.  With no tracer configured the loop pays
a single global check per event.
"""

from __future__ import annotations

import dataclasses
import heapq
import os
import pickle
from typing import Sequence

import numpy as np

from repro.core.allocation import Partition
from repro.core.hyperx import HyperX
from repro.core.properties import has_switch_locality, partition_bandwidth
from repro.obs import trace as obs_trace
from repro.runtime.fault_tolerance import StragglerMonitor
from repro.sched.jobs import Job
from repro.sched.ledger import BlockLedger
from repro.sched.metrics import JobRecord, StreamResult

# relative order of the pre-existing kinds (depart < repair < fail <
# arrive) is load-bearing: changing it would reorder same-time event
# processing and shift every pinned stream metric
_ORDER = {"depart": 0, "repair": 1, "straggle": 2, "fail": 3, "arrive": 4}


@dataclasses.dataclass(frozen=True)
class FailureEvent:
    """Endpoints fail at ``time``; optionally repaired at ``repair_at``."""

    time: float
    endpoints: tuple[int, ...]
    repair_at: float | None = None


@dataclasses.dataclass(frozen=True)
class Snapshot:
    """Co-resident jobs at one scheduling event (placement time)."""

    time: float
    trigger: int  # job id whose placement produced this snapshot
    jobs: tuple[tuple[int, str, Partition], ...]  # (job_id, kernel, partition)
    # endpoints marked failed in the ledger when the snapshot was taken —
    # the bridge lowers these to link-fault masks for fault-aware routing
    failed_endpoints: tuple[int, ...] = ()

    @property
    def num_jobs(self) -> int:
        return len(self.jobs)


@dataclasses.dataclass
class _StreamState:
    """Every mutable piece of one ``run_stream`` pass, in one picklable bag.

    The crash-safe checkpoint is ``pickle.dumps((ledger, state))`` — heap
    entries never compare payloads (the monotone ``seq`` breaks ties), all
    payloads are frozen dataclasses or plain tuples, and the RNG /
    straggler monitor ride along, so a resumed stream replays the exact
    trajectory of an uninterrupted one.
    """

    records: dict  # jid -> JobRecord
    heap: list = dataclasses.field(default_factory=list)
    seq: int = 0
    queue: list = dataclasses.field(default_factory=list)   # of Job
    running: dict = dataclasses.field(default_factory=dict)  # jid -> info
    gens: dict = dataclasses.field(default_factory=dict)     # jid -> gen
    snapshots: list = dataclasses.field(default_factory=list)
    retries: dict = dataclasses.field(default_factory=dict)  # jid -> evictions
    evicted: set = dataclasses.field(default_factory=set)    # straggler hosts
    # time integrals
    last_t: float = 0.0
    busy: float = 0.0        # requested endpoint-seconds
    gross: float = 0.0       # slot-held endpoint-seconds
    frag_int: float = 0.0
    frag_max: float = 0.0
    queue_int: float = 0.0
    ticks: int = 0           # processed timestamps (the checkpoint step)
    rng: np.random.Generator | None = None
    monitor: StragglerMonitor | None = None


class OnlineScheduler:
    """One strategy x policy scheduling run over a job stream."""

    def __init__(
        self,
        topo: HyperX,
        strategy: str = "diagonal",
        policy: str = "first_fit",
        backfill: bool = True,
        allow_scatter: bool = True,
        seed: int = 0,
        analyze: bool = True,
        mttr: float | None = None,
        backoff_base: float = 0.0,
        max_retries: int | None = None,
        shrink_to_fit: bool = False,
    ):
        self.topo = topo
        self.ledger = BlockLedger(
            topo, strategy=strategy, seed=seed,
            policy=policy, allow_scatter=allow_scatter,
        )
        self.backfill = backfill
        self.analyze = analyze
        self.seed = seed
        if mttr is not None and mttr <= 0:
            raise ValueError(f"mttr must be positive, got {mttr}")
        if backoff_base < 0:
            raise ValueError(f"backoff_base must be >= 0, got {backoff_base}")
        self.mttr = mttr
        self.backoff_base = backoff_base
        self.max_retries = max_retries
        self.shrink_to_fit = shrink_to_fit

    # --------------------------------------------------------------- driver
    def run_stream(
        self,
        jobs: Sequence[Job],
        failures: Sequence[FailureEvent] = (),
        check_invariants: bool = False,
        stragglers: Sequence[tuple[float, int, float]] = (),
        straggler_monitor: StragglerMonitor | None = None,
        checkpoint_dir: str | None = None,
        checkpoint_every: int = 16,
        resume: bool = False,
        crash_at: float | None = None,
        heartbeat_every: int = 16,
    ) -> StreamResult:
        ledger = self.ledger
        too_big = [j.job_id for j in jobs if j.blocks > ledger.num_slots]
        if too_big:
            raise ValueError(
                f"jobs {too_big[:4]} request more than the machine's "
                f"{ledger.num_slots} base blocks"
            )
        stream = f"{ledger.strategy.name}/{ledger.policy}"

        ckpt = None
        if checkpoint_dir is not None:
            from repro.checkpoint import Checkpointer

            ckpt = Checkpointer(str(checkpoint_dir))

        st: _StreamState | None = None
        if resume and ckpt is not None and ckpt.latest_step() is not None:
            blob, _extra = ckpt.restore({"pickle": None})
            ledger, st = pickle.loads(
                np.asarray(blob["pickle"], dtype=np.uint8).tobytes()
            )
            self.ledger = ledger
            obs_trace.event("sched.resume", stream=stream, step=st.ticks,
                            t_sim=st.last_t, queued=len(st.queue),
                            running=len(st.running))
        if st is None:
            st = _StreamState(records={j.job_id: JobRecord(
                job_id=j.job_id, arrival=j.arrival, blocks=j.blocks,
                service=j.service, kernel=j.kernel,
            ) for j in jobs})
            st.rng = np.random.default_rng(self.seed)
            if straggler_monitor is not None:
                st.monitor = straggler_monitor
            elif stragglers:
                st.monitor = StragglerMonitor()
            for j in sorted(jobs, key=lambda x: (x.arrival, x.job_id)):
                heapq.heappush(
                    st.heap, (j.arrival, _ORDER["arrive"], st.seq, "arrive", j)
                )
                st.seq += 1
            for f in failures:
                heapq.heappush(st.heap, (f.time, _ORDER["fail"], st.seq,
                                         "fail", f))
                st.seq += 1
                if f.repair_at is not None:
                    heapq.heappush(
                        st.heap, (f.repair_at, _ORDER["repair"], st.seq,
                                  "repair", f)
                    )
                    st.seq += 1
            for t, host, seconds in stragglers:
                heapq.heappush(
                    st.heap,
                    (float(t), _ORDER["straggle"], st.seq, "straggle",
                     (int(host), float(seconds))),
                )
                st.seq += 1

        E = self.topo.num_endpoints

        def advance(now: float):
            dt = now - st.last_t
            if dt > 0:
                req = sum(ledger.jobs[j].partition.size for j in st.running)
                held = sum(
                    len(ledger.jobs[j].slot_endpoints) for j in st.running
                )
                frag = ledger.fragmentation()
                st.busy += req * dt
                st.gross += held * dt
                st.frag_int += frag * dt
                st.frag_max = max(st.frag_max, frag)
                st.queue_int += len(st.queue) * dt
                st.last_t = now

        def analyze_placement(jid: int):
            """Record the job's CURRENT placement quality (last placement
            wins: a migration onto scattered blocks must show up)."""
            rec = st.records[jid]
            placed = ledger.jobs[jid]
            rec.scattered = rec.scattered or not placed.contiguous
            if self.analyze:
                eps = placed.partition.endpoints
                pb, bound = partition_bandwidth(self.topo, eps)
                rec.realized_pb = pb
                rec.pb_bound = bound
                rec.switch_local = has_switch_locality(self.topo, eps)

        def take_snapshot(now: float, trigger: int):
            st.snapshots.append(Snapshot(
                time=now, trigger=trigger,
                jobs=tuple(
                    (jid, st.running[jid]["job"].kernel,
                     ledger.jobs[jid].partition)
                    for jid in sorted(st.running)
                ),
                failed_endpoints=tuple(
                    int(e) for e in np.flatnonzero(ledger.failed)
                ),
            ))

        def start(job: Job, now: float, backfilled: bool = False) -> bool:
            try:
                ledger.place(job.blocks, job_id=job.job_id)
            except RuntimeError:
                return False
            rec = st.records[job.job_id]
            if rec.start is None:
                rec.start = now
                rec.wait = now - rec.arrival
            obs_trace.event(
                "sched.start", stream=stream, job=job.job_id, t_sim=now,
                blocks=job.blocks, wait=round(now - rec.arrival, 4),
                backfilled=backfilled,
                scattered=not ledger.jobs[job.job_id].contiguous,
            )
            gen = st.gens.get(job.job_id, 0) + 1
            st.gens[job.job_id] = gen
            st.running[job.job_id] = {"job": job, "finish": now + job.service}
            heapq.heappush(
                st.heap,
                (now + job.service, _ORDER["depart"], st.seq, "depart",
                 (job.job_id, gen)),
            )
            st.seq += 1
            analyze_placement(job.job_id)
            take_snapshot(now, job.job_id)
            return True

        def shadow_for(head: Job, now: float) -> tuple[float, int]:
            """Count-based reservation: (shadow time, slots freed by then)."""
            free_now = int(ledger.free_slots().sum())
            if free_now >= head.blocks:
                return now, 0  # blocked by fragmentation only, not capacity
            freed = 0
            for jid in sorted(st.running,
                              key=lambda j: st.running[j]["finish"]):
                freed += len(ledger.jobs[jid].slots)
                if free_now + freed >= head.blocks:
                    return st.running[jid]["finish"], freed
            return float("inf"), freed

        def schedule(now: float):
            queue = st.queue
            while queue:
                if start(queue[0], now):
                    queue.pop(0)
                    continue
                if not self.backfill or len(queue) == 1:
                    break
                head = queue[0]
                shadow, freed_by_shadow = shadow_for(head, now)
                for cand in list(queue[1:]):
                    if ledger.find_slots(cand.blocks) is None:
                        continue
                    free_now = int(ledger.free_slots().sum())
                    fits_reservation = (
                        now + cand.service <= shadow + 1e-9
                        or free_now - cand.blocks + freed_by_shadow >= head.blocks
                    )
                    if fits_reservation and start(cand, now, backfilled=True):
                        queue.remove(cand)
                break

        def try_shrink(jid: int, now: float) -> bool:
            """Graceful degradation: halve the block count until it places."""
            job = st.running[jid]["job"]
            b = job.blocks // 2
            while b >= 1:
                try:
                    ledger.place(b, job_id=jid)
                except RuntimeError:
                    b //= 2
                    continue
                rec = st.records[jid]
                rec.degraded = True
                analyze_placement(jid)
                take_snapshot(now, jid)
                obs_trace.event("sched.degrade", stream=stream, job=jid,
                                t_sim=now, blocks=b, requested=job.blocks)
                return True
            return False

        def requeue_or_giveup(jid: int, now: float):
            """Evict a running job; requeue with backoff, or abandon it."""
            info = st.running.pop(jid)
            st.gens[jid] += 1  # invalidate the depart event
            remaining = info["finish"] - now
            rec = st.records[jid]
            st.retries[jid] = st.retries.get(jid, 0) + 1
            tries = st.retries[jid]
            rec.retries = tries
            if self.max_retries is not None and tries > self.max_retries:
                rec.failed = True
                obs_trace.event("sched.giveup", stream=stream, job=jid,
                                t_sim=now, retries=tries)
                return
            rec.requeues += 1
            job2 = dataclasses.replace(info["job"], service=remaining)
            if self.backoff_base > 0:
                delay = self.backoff_base * (2 ** (tries - 1))
                heapq.heappush(
                    st.heap,
                    (now + delay, _ORDER["arrive"], st.seq, "arrive", job2),
                )
                st.seq += 1
                obs_trace.event("sched.requeue", stream=stream, job=jid,
                                t_sim=now, backoff=round(delay, 4))
            else:
                # legacy behavior: straight back to the queue head
                st.queue.insert(0, job2)
                obs_trace.event("sched.requeue", stream=stream, job=jid,
                                t_sim=now)

        def handle_failed_jobs(now: float, affected: list[int]):
            """Migrate / shrink / requeue every running job that lost slots."""
            for jid in affected:
                if jid not in st.running:
                    continue
                rec = st.records[jid]
                try:
                    ledger.replace_job(jid)
                    rec.migrations += 1
                    # a migration IS a placement: refresh the realized
                    # metrics and snapshot the machine
                    analyze_placement(jid)
                    take_snapshot(now, jid)
                    obs_trace.event("sched.migrate", stream=stream,
                                    job=jid, t_sim=now)
                    continue
                except RuntimeError:
                    pass  # job is released and unplaced
                if self.shrink_to_fit and try_shrink(jid, now):
                    continue
                requeue_or_giveup(jid, now)

        def push_repair(now: float, endpoints: tuple[int, ...]):
            """MTTR repair timer for a failure with no scripted repair."""
            delay = max(float(st.rng.exponential(self.mttr)), 1e-9)
            heapq.heappush(
                st.heap,
                (now + delay, _ORDER["repair"], st.seq, "repair",
                 FailureEvent(time=now, endpoints=tuple(endpoints),
                              repair_at=now + delay)),
            )
            st.seq += 1

        def save_checkpoint():
            buf = np.frombuffer(pickle.dumps((ledger, st)), dtype=np.uint8)
            ckpt.save(st.ticks, {"pickle": buf},
                      extra={"t_sim": st.last_t, "stream": stream})
            obs_trace.event("sched.checkpoint", stream=stream, step=st.ticks,
                            t_sim=st.last_t, bytes=int(buf.size))

        while st.heap:
            now = st.heap[0][0]
            if crash_at is not None and now >= crash_at:
                os._exit(137)  # hard kill: no atexit, no flush (test hook)
            while st.heap and st.heap[0][0] == now:
                _, _, _, kind, payload = heapq.heappop(st.heap)
                advance(now)
                if kind == "arrive":
                    st.queue.append(payload)
                    obs_trace.event("sched.arrive", stream=stream,
                                    job=payload.job_id, t_sim=now,
                                    blocks=payload.blocks)
                elif kind == "depart":
                    jid, gen = payload
                    if jid not in st.running or st.gens.get(jid) != gen:
                        continue  # stale event (job was requeued)
                    del st.running[jid]
                    ledger.release(jid)
                    st.records[jid].finish = now
                    obs_trace.event("sched.depart", stream=stream, job=jid,
                                    t_sim=now)
                elif kind == "fail":
                    affected = ledger.fail_endpoints(
                        np.asarray(payload.endpoints)
                    )
                    obs_trace.event("sched.fail", stream=stream, t_sim=now,
                                    endpoints=len(payload.endpoints),
                                    affected_jobs=len(affected))
                    if self.mttr is not None and payload.repair_at is None:
                        push_repair(now, payload.endpoints)
                    handle_failed_jobs(now, affected)
                elif kind == "straggle":
                    host, seconds = payload
                    if st.monitor is None:
                        st.monitor = StragglerMonitor()
                    flagged = st.monitor.record(host, seconds)
                    obs_trace.event("sched.straggle", stream=stream,
                                    t_sim=now, host=host,
                                    seconds=round(seconds, 4),
                                    flagged=flagged)
                    for h in st.monitor.evictions():
                        if h in st.evicted:
                            continue
                        st.evicted.add(h)
                        affected = ledger.fail_endpoints(np.asarray([h]))
                        obs_trace.event("sched.evict", stream=stream,
                                        t_sim=now, host=h,
                                        affected_jobs=len(affected))
                        if self.mttr is not None:
                            push_repair(now, (int(h),))
                        handle_failed_jobs(now, affected)
                elif kind == "repair":
                    ledger.repair_endpoints(np.asarray(payload.endpoints))
                    obs_trace.event("sched.repair", stream=stream, t_sim=now,
                                    endpoints=len(payload.endpoints))
            schedule(now)
            if obs_trace.active() is not None:
                obs_trace.gauge("sched.frag", round(ledger.fragmentation(), 6),
                                stream=stream, t_sim=now,
                                running=len(st.running),
                                queued=len(st.queue))
                # liveness beacon for the fleet watcher's stall rule: a
                # wedged stream stops heartbeating, a healthy one emits
                # every ``heartbeat_every`` ticks
                if st.ticks % max(heartbeat_every, 1) == 0:
                    obs_trace.event("sched.heartbeat", stream=stream,
                                    t_sim=now, tick=st.ticks,
                                    queued=len(st.queue),
                                    running=len(st.running))
            if check_invariants:
                ledger.check_conservation()
            st.ticks += 1
            if ckpt is not None and st.ticks % max(checkpoint_every, 1) == 0:
                save_checkpoint()

        span = max(st.last_t, 1e-9)
        obs_trace.event(
            "sched.summary", stream=stream, jobs=len(st.records),
            snapshots=len(st.snapshots), span=round(span, 4),
            utilization=round(st.busy / (E * span), 6),
            frag_mean=round(st.frag_int / span, 6),
            frag_max=round(st.frag_max, 6),
            mean_queue=round(st.queue_int / span, 6),
        )
        return StreamResult(
            strategy=ledger.strategy.name,
            policy=ledger.policy,
            records=sorted(st.records.values(),
                           key=lambda r: (r.arrival, r.job_id)),
            snapshots=st.snapshots,
            span=span,
            utilization=st.busy / (E * span),
            gross_utilization=st.gross / (E * span),
            frag_mean=st.frag_int / span,
            frag_max=st.frag_max,
            mean_queue=st.queue_int / span,
        )
