"""Deterministic synthetic data pipeline.

Produces a structured, learnable token stream (a noisy order-k Markov
process over the vocabulary, derived from a stateless per-position hash) so
training loss decreases measurably.  Properties the runtime relies on:

  * **stateless addressing** — batch ``i`` is a pure function of
    ``(seed, i)``; the checkpointable pipeline state is just the step
    index, so restart/elastic-rescale resumes exactly;
  * **host sharding** — each data-parallel host materializes only its slice
    (``host_slice``); in the single-process dry-run/tests the global batch
    is formed and device_put with the batch NamedSharding.
"""

from __future__ import annotations

import dataclasses

import jax
import numpy as np

from repro.models.config import ArchConfig


def _hash64(x: np.ndarray) -> np.ndarray:
    x = (x ^ (x >> 30)) * np.uint64(0xBF58476D1CE4E5B9)
    x = (x ^ (x >> 27)) * np.uint64(0x94D049BB133111EB)
    return x ^ (x >> 31)


@dataclasses.dataclass
class SyntheticLM:
    cfg: ArchConfig
    seed: int = 0
    step: int = 0                      # checkpointable position

    def batch_at(self, step: int, batch: int, seq: int, lo: int = 0,
                 hi: int | None = None) -> dict:
        """Batch rows [lo, hi) of global batch ``step`` (host sharding)."""
        hi = batch if hi is None else hi
        v = self.cfg.vocab
        rows = np.arange(lo, hi, dtype=np.uint64)
        base = (
            np.uint64(self.seed) * np.uint64(0x9E3779B97F4A7C15)
            + np.uint64(step) * np.uint64(1 << 32)
        )
        # learnable affine chain: tok[t+1] = (a*tok[t] + b) % v with prob
        # ~0.8 (the cross-batch-stable structure the model can learn), a
        # fresh hashed token otherwise.
        a, b = 3, 7
        n = hi - lo
        tok = np.empty((n, seq + 1), dtype=np.int64)
        tok[:, 0] = _hash64(base + rows * np.uint64(65537)) % np.uint64(v)
        noise = _hash64(
            base ^ (rows[:, None] + np.arange(seq + 1, dtype=np.uint64)[None, :]
                    * np.uint64(101))
        )
        is_noise = (noise % np.uint64(5)) == 0
        noise_tok = (noise % np.uint64(v)).astype(np.int64)
        for t in range(1, seq + 1):
            chain = (a * tok[:, t - 1] + b) % v
            tok[:, t] = np.where(is_noise[:, t], noise_tok[:, t], chain)
        tok = tok.astype(np.int32)
        out = {}
        if self.cfg.frame_input:
            emb = (tok[:, :seq, None] % 97).astype(np.float32) / 48.0 - 1.0
            out["frames"] = np.broadcast_to(
                emb, (hi - lo, seq, self.cfg.d_model)
            ).copy()
            out["labels"] = tok[:, :seq] % self.cfg.vocab
        else:
            out["tokens"] = tok[:, :seq]
            out["labels"] = tok[:, 1:]
        if self.cfg.family == "vlm":
            img = _hash64(base + rows[:, None] * np.uint64(31))[
                :, :, None
            ]  # (B,1,1)
            t = np.arange(self.cfg.frontend_tokens)[None, :, None]
            d = np.arange(self.cfg.d_model)[None, None, :]
            out["image_embeds"] = (
                np.sin((img % np.uint64(1024)).astype(np.float32) / 100 + t * 0.1 + d * 0.01)
            ).astype(np.float32)
        return out

    def next_batch(self, batch: int, seq: int) -> dict:
        b = self.batch_at(self.step, batch, seq)
        self.step += 1
        return b

    def state_dict(self) -> dict:
        return {"seed": self.seed, "step": self.step}

    def load_state_dict(self, d: dict):
        self.seed, self.step = int(d["seed"]), int(d["step"])


def make_batch_specs(cfg: ArchConfig, batch: int, seq: int, kind: str = "train"):
    """ShapeDtypeStruct stand-ins for every model input (dry-run contract).

    train: {tokens,(frames),(image_embeds),labels}; prefill: prompt inputs;
    decode: one-token inputs + the stacked decode caches + position index.
    """
    import jax.numpy as jnp

    f32 = jnp.dtype("float32")
    i32 = jnp.dtype("int32")
    sd = jax.ShapeDtypeStruct
    out = {}
    if kind in ("train", "prefill"):
        if cfg.frame_input:
            out["frames"] = sd((batch, seq, cfg.d_model), f32)
        else:
            out["tokens"] = sd((batch, seq), i32)
        if cfg.family == "vlm":
            out["image_embeds"] = sd((batch, cfg.frontend_tokens, cfg.d_model), f32)
        if kind == "train":
            out["labels"] = sd((batch, seq), i32)
        return out
    if kind == "decode":
        out["tokens"] = sd((batch, 1), i32)
        return out
    raise ValueError(kind)
