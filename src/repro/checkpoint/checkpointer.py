"""Checkpoint/restart substrate (fault-tolerance deliverable).

Layout per step:

    <dir>/step_000123/
        manifest.json      step, flat key list, shapes/dtypes, extra state
        arrays.npz         flattened '/'-joined-path -> ndarray
        _COMMITTED         written last: restore only sees complete saves

Features: atomic commit marker, keep_n garbage collection, optional
background-thread (async) save so the train loop never blocks on disk,
extra-state dict (data-pipeline position, RNG, runtime info) carried in the
manifest.  Arrays are gathered to host (fully replicated or addressable)
— the multi-host generalization shards the npz per process, noted in
DESIGN.md.
"""

from __future__ import annotations

import json
import os
import shutil
import threading
import time

import jax
import numpy as np


def _flatten(tree, prefix=""):
    out = {}
    if isinstance(tree, dict):
        for k, v in tree.items():
            out.update(_flatten(v, f"{prefix}{k}/"))
    elif isinstance(tree, (list, tuple)):
        for i, v in enumerate(tree):
            out.update(_flatten(v, f"{prefix}{i}/"))
        if hasattr(tree, "_fields"):  # NamedTuple
            pass
    else:
        out[prefix[:-1]] = tree
    return out


def _tree_like(template, flat, prefix=""):
    if isinstance(template, dict):
        return {k: _tree_like(v, flat, f"{prefix}{k}/") for k, v in template.items()}
    if isinstance(template, tuple) and hasattr(template, "_fields"):
        vals = [
            _tree_like(v, flat, f"{prefix}{i}/") for i, v in enumerate(template)
        ]
        return type(template)(*vals)
    if isinstance(template, (list, tuple)):
        vals = [
            _tree_like(v, flat, f"{prefix}{i}/") for i, v in enumerate(template)
        ]
        return type(template)(vals)
    return flat[prefix[:-1]]


class Checkpointer:
    def __init__(self, directory: str, keep_n: int = 3, async_save: bool = False):
        self.dir = directory
        self.keep_n = keep_n
        self.async_save = async_save
        self._thread: threading.Thread | None = None
        self._error: BaseException | None = None
        os.makedirs(directory, exist_ok=True)

    # ------------------------------------------------------------- save
    def save(self, step: int, tree, extra: dict | None = None):
        flat = _flatten(tree)
        host = {k: np.asarray(jax.device_get(v)) for k, v in flat.items()}
        if self.async_save:
            # joins the previous save AND surfaces its failure here — a
            # background _write that died must not stay silent (the train
            # loop would keep believing checkpoints exist)
            self.wait()
            self._thread = threading.Thread(
                target=self._guarded_write, args=(step, host, extra or {}),
                daemon=True,
            )
            self._thread.start()
        else:
            self._write(step, host, extra or {})

    def _guarded_write(self, step: int, host: dict, extra: dict):
        try:
            self._write(step, host, extra)
        except BaseException as e:  # re-raised on wait() / next save()
            self._error = e

    def _write(self, step: int, host: dict, extra: dict):
        path = self._path(step)
        tmp = path + ".tmp"
        if os.path.exists(tmp):
            shutil.rmtree(tmp)
        os.makedirs(tmp)
        np.savez(os.path.join(tmp, "arrays.npz"), **host)
        manifest = {
            "step": step,
            "time": time.time(),
            "keys": sorted(host),
            "shapes": {k: list(v.shape) for k, v in host.items()},
            "dtypes": {k: str(v.dtype) for k, v in host.items()},
            "extra": extra,
        }
        with open(os.path.join(tmp, "manifest.json"), "w") as f:
            json.dump(manifest, f)
        open(os.path.join(tmp, "_COMMITTED"), "w").close()
        if os.path.exists(path):
            shutil.rmtree(path)
        os.rename(tmp, path)
        self._gc()

    def wait(self):
        if self._thread is not None:
            self._thread.join()
            self._thread = None
        if self._error is not None:
            err, self._error = self._error, None
            raise RuntimeError(f"async checkpoint save failed: {err!r}") from err

    # ---------------------------------------------------------- restore
    def latest_step(self) -> int | None:
        steps = []
        for d in os.listdir(self.dir):
            if d.startswith("step_") and os.path.exists(
                os.path.join(self.dir, d, "_COMMITTED")
            ):
                steps.append(int(d.split("_")[1]))
        return max(steps) if steps else None

    def restore(self, template, step: int | None = None):
        """Restore into the structure of ``template``; returns (tree, extra)."""
        self.wait()
        if step is None:
            step = self.latest_step()
            if step is None:
                raise FileNotFoundError(f"no committed checkpoints in {self.dir}")
        path = self._path(step)
        with open(os.path.join(path, "manifest.json")) as f:
            manifest = json.load(f)
        with np.load(os.path.join(path, "arrays.npz")) as z:
            flat = {k: z[k] for k in z.files}
        tree = _tree_like(template, flat)
        return tree, manifest["extra"]

    # --------------------------------------------------------------- gc
    def _path(self, step: int) -> str:
        return os.path.join(self.dir, f"step_{step:09d}")

    def _gc(self):
        steps = sorted(
            int(d.split("_")[1])
            for d in os.listdir(self.dir)
            if d.startswith("step_") and not d.endswith(".tmp")
        )
        for s in steps[: -self.keep_n]:
            shutil.rmtree(self._path(s), ignore_errors=True)
