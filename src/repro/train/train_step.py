"""Train-step builder: loss -> grads -> clip -> AdamW, distribution-aware.

Features (all selectable, all exercised by the dry-run matrix):

  * microbatching — gradient accumulation over a leading microbatch axis
    via ``lax.scan`` (keeps peak activation memory at one microbatch);
  * remat — scan-over-layers checkpointing inside the model (models/);
  * grad compression — gradients computed against a bf16 view of the
    parameters, so the data-parallel reduction moves half the bytes; the
    AdamW update still reads fp32 master weights;
  * FSDP — parameter/optimizer sharding over the ``data`` axis comes from
    the ``fsdp`` sharding rule set; XLA then emits reduce-scatter +
    all-gather instead of all-reduce.
"""

from __future__ import annotations

import dataclasses
import functools
from typing import Any, Callable

import jax
import jax.numpy as jnp

from repro.models.config import ArchConfig
from repro.models import transformer as M
from repro.train.optimizer import AdamWConfig, adamw_update


@dataclasses.dataclass(frozen=True)
class TrainSettings:
    microbatches: int = 1
    remat: bool = True
    grad_compression: bool = False   # bf16 gradient reduction
    opt: AdamWConfig = dataclasses.field(default_factory=AdamWConfig)


def _split_micro(batch: dict, n: int) -> dict:
    def f(x):
        b = x.shape[0]
        assert b % n == 0, f"batch {b} not divisible by {n} microbatches"
        return x.reshape(n, b // n, *x.shape[1:])

    return jax.tree_util.tree_map(f, batch)


def loss_and_grads(cfg: ArchConfig, settings: TrainSettings, params, batch):
    """Microbatched (loss, grads); grads dtype bf16 if compression is on."""

    def loss_fn(p, mb):
        loss, parts = M.train_loss(cfg, p, mb, remat=settings.remat)
        return loss, parts

    view = params
    if settings.grad_compression:
        view = jax.tree_util.tree_map(
            lambda p: p.astype(jnp.bfloat16) if p.dtype == jnp.float32 else p,
            params,
        )

    grad_fn = jax.value_and_grad(loss_fn, has_aux=True)

    if settings.microbatches == 1:
        (loss, parts), grads = grad_fn(view, batch)
        return loss, grads, parts

    micro = _split_micro(batch, settings.microbatches)

    def acc_fn(carry, mb):
        acc, loss_sum = carry
        (loss, _parts), grads = grad_fn(view, mb)
        acc = jax.tree_util.tree_map(jnp.add, acc, grads)
        return (acc, loss_sum + loss), None

    zeros = jax.tree_util.tree_map(jnp.zeros_like, view)
    (grads, loss_sum), _ = jax.lax.scan(
        acc_fn, (zeros, jnp.float32(0)), micro
    )
    inv = 1.0 / settings.microbatches
    grads = jax.tree_util.tree_map(lambda g: (g * inv).astype(g.dtype), grads)
    loss = loss_sum * inv
    return loss, grads, {"ce": loss, "aux": jnp.float32(0)}


def build_train_step(cfg: ArchConfig, settings: TrainSettings | None = None):
    """Returns train_step(params, opt_state, batch) -> (params', opt', metrics).

    Pure function of its inputs — jit/pjit it with whatever shardings the
    launcher chose (see launch/train.py and launch/dryrun.py).
    """
    settings = settings or TrainSettings()

    def train_step(params, opt_state, batch):
        loss, grads, parts = loss_and_grads(cfg, settings, params, batch)
        new_params, new_opt, om = adamw_update(
            settings.opt, params, grads, opt_state
        )
        metrics = {"loss": loss, **parts, **om}
        return new_params, new_opt, metrics

    return train_step


def build_eval_step(cfg: ArchConfig):
    def eval_step(params, batch):
        loss, parts = M.train_loss(cfg, params, batch, remat=False)
        return {"loss": loss, **parts}

    return eval_step
