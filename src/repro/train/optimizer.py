"""AdamW + schedules, from scratch (no optax in this stack).

Optimizer state is a pytree congruent with the parameters, so whatever
sharding the parameters use (replicated or FSDP) applies to ``m``/``v``
automatically under pjit.
"""

from __future__ import annotations

import dataclasses
from typing import NamedTuple

import jax
import jax.numpy as jnp


class AdamWState(NamedTuple):
    step: jnp.ndarray   # () int32
    m: object           # pytree like params
    v: object           # pytree like params


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    lr_peak: float = 3e-4
    warmup_steps: int = 100
    total_steps: int = 10_000
    lr_min_ratio: float = 0.1
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: float = 1.0


def cosine_schedule(cfg: AdamWConfig, step):
    step = step.astype(jnp.float32)
    warm = cfg.lr_peak * step / max(cfg.warmup_steps, 1)
    prog = jnp.clip(
        (step - cfg.warmup_steps) / max(cfg.total_steps - cfg.warmup_steps, 1),
        0.0, 1.0,
    )
    cos = cfg.lr_peak * (
        cfg.lr_min_ratio + (1 - cfg.lr_min_ratio) * 0.5 * (1 + jnp.cos(jnp.pi * prog))
    )
    return jnp.where(step < cfg.warmup_steps, warm, cos)


def adamw_init(params) -> AdamWState:
    zeros = lambda t: jax.tree_util.tree_map(jnp.zeros_like, t)
    return AdamWState(step=jnp.int32(0), m=zeros(params), v=zeros(params))


def global_norm(tree) -> jnp.ndarray:
    leaves = jax.tree_util.tree_leaves(tree)
    return jnp.sqrt(
        sum(jnp.sum(jnp.square(l.astype(jnp.float32))) for l in leaves)
    )


def clip_by_global_norm(grads, max_norm):
    norm = global_norm(grads)
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(norm, 1e-9))
    return jax.tree_util.tree_map(
        lambda g: (g.astype(jnp.float32) * scale).astype(g.dtype), grads
    ), norm


def adamw_update(cfg: AdamWConfig, params, grads, state: AdamWState):
    """One AdamW step.  Returns (new_params, new_state, metrics)."""
    grads, gnorm = clip_by_global_norm(grads, cfg.clip_norm)
    step = state.step + 1
    lr = cosine_schedule(cfg, step)
    b1t = 1.0 - cfg.b1 ** step.astype(jnp.float32)
    b2t = 1.0 - cfg.b2 ** step.astype(jnp.float32)

    def upd(p, g, m, v):
        gf = g.astype(jnp.float32)
        m2 = cfg.b1 * m + (1 - cfg.b1) * gf
        v2 = cfg.b2 * v + (1 - cfg.b2) * jnp.square(gf)
        mh = m2 / b1t
        vh = v2 / b2t
        delta = mh / (jnp.sqrt(vh) + cfg.eps) + cfg.weight_decay * p.astype(jnp.float32)
        return (p.astype(jnp.float32) - lr * delta).astype(p.dtype), m2, v2

    flat_p, treedef = jax.tree_util.tree_flatten(params)
    flat_g = treedef.flatten_up_to(grads)
    flat_m = treedef.flatten_up_to(state.m)
    flat_v = treedef.flatten_up_to(state.v)
    out = [upd(p, g, m, v) for p, g, m, v in zip(flat_p, flat_g, flat_m, flat_v)]
    new_p = jax.tree_util.tree_unflatten(treedef, [o[0] for o in out])
    new_m = jax.tree_util.tree_unflatten(treedef, [o[1] for o in out])
    new_v = jax.tree_util.tree_unflatten(treedef, [o[2] for o in out])
    return new_p, AdamWState(step, new_m, new_v), {"lr": lr, "grad_norm": gnorm}
