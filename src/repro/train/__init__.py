from repro.train.optimizer import adamw_init, adamw_update, cosine_schedule  # noqa: F401
from repro.train.train_step import build_train_step  # noqa: F401
