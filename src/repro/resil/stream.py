"""Crash-safe stream driver: run a scheduling stream, survive kills.

A thin CLI over :meth:`~repro.sched.scheduler.OnlineScheduler.run_stream`
that wires the crash-safety loop end to end: a Poisson job stream plus an
optional endpoint-churn failure campaign (seeded MTBF/MTTR lifetimes from
:mod:`repro.resil.processes`), periodic stream-state checkpoints through
the checkpoint substrate, and ``--resume`` to pick up after a kill.  The
final ``StreamResult.summary()`` goes to ``--out`` as sorted JSON, so a
killed-and-resumed run can be compared bit-for-bit against an
uninterrupted one (the kill-and-resume test pins exactly that).

``--trace DIR`` activates :mod:`repro.obs.trace` for the run, producing a
store-friendly trace directory (manifest + ``events.jsonl`` with
``sched.*`` events, fragmentation gauges and heartbeats, closed by
``trace.end``) that the fleet watcher / dashboard can tail live.

    python -m repro.resil.stream --jobs 40 --mttr 20 --churn 4 \
        --ckpt /tmp/ck --every 4 --out /tmp/a.json
    python -m repro.resil.stream ... --crash-at 30   # exits 137 mid-stream
    python -m repro.resil.stream ... --resume        # finishes the stream
    python -m repro.resil.stream ... --trace /tmp/fleet/run0   # traced
"""

from __future__ import annotations

import argparse
import json
import sys

from repro.core.hyperx import HyperX
from repro.resil.processes import (
    exponential_lifetimes,
    sample_components,
    to_failure_events,
)
from repro.sched.jobs import poisson_stream
from repro.sched.scheduler import OnlineScheduler


def build_parser() -> argparse.ArgumentParser:
    p = argparse.ArgumentParser(
        prog="repro.resil.stream",
        description="crash-safe online-scheduler stream driver",
    )
    p.add_argument("--n", type=int, default=4, help="HyperX switches/dim")
    p.add_argument("--q", type=int, default=2, help="HyperX dimensions")
    p.add_argument("--jobs", type=int, default=40, help="jobs in the stream")
    p.add_argument("--rate", type=float, default=0.5, help="arrival rate")
    p.add_argument("--seed", type=int, default=0)
    p.add_argument("--strategy", default="diagonal")
    p.add_argument("--policy", default="first_fit")
    p.add_argument("--mttr", type=float, default=None,
                   help="scheduler MTTR repair-timer mean (default: off)")
    p.add_argument("--backoff", type=float, default=0.0,
                   help="requeue backoff base (0 = legacy queue-head)")
    p.add_argument("--max-retries", type=int, default=None)
    p.add_argument("--shrink", action="store_true",
                   help="shrink-to-fit degraded placement fallback")
    p.add_argument("--churn", type=int, default=0,
                   help="endpoints subjected to MTBF/MTTR churn")
    p.add_argument("--churn-mtbf", type=float, default=40.0)
    p.add_argument("--churn-mttr", type=float, default=10.0)
    p.add_argument("--horizon", type=float, default=200.0,
                   help="churn campaign horizon (stream time units)")
    p.add_argument("--ckpt", default=None, help="checkpoint directory")
    p.add_argument("--every", type=int, default=8,
                   help="checkpoint every N processed timestamps")
    p.add_argument("--resume", action="store_true",
                   help="resume from the latest committed checkpoint")
    p.add_argument("--crash-at", type=float, default=None,
                   help="hard-exit (137) at the first event past this time")
    p.add_argument("--out", default=None, help="write summary JSON here")
    p.add_argument("--trace", default=None, metavar="DIR",
                   help="emit a repro.obs trace of the stream here")
    p.add_argument("--heartbeat-every", type=int, default=16,
                   help="sched.heartbeat every N event-loop ticks")
    return p


def run(argv=None) -> int:
    args = build_parser().parse_args(argv)
    topo = HyperX(n=args.n, q=args.q)
    jobs = poisson_stream(args.jobs, rate=args.rate, seed=args.seed)

    failures = []
    if args.churn > 0:
        comps = sample_components(topo, n_endpoints=args.churn,
                                  seed=args.seed)
        events = exponential_lifetimes(
            comps, mtbf=args.churn_mtbf, mttr=args.churn_mttr,
            horizon=int(args.horizon), seed=args.seed,
        )
        failures = to_failure_events(events)

    sched = OnlineScheduler(
        topo, strategy=args.strategy, policy=args.policy, seed=args.seed,
        mttr=args.mttr, backoff_base=args.backoff,
        max_retries=args.max_retries, shrink_to_fit=args.shrink,
    )
    if args.trace:
        from repro.obs import trace as obs_trace

        obs_trace.configure(
            args.trace, tool="resil.stream", n=args.n, q=args.q,
            jobs=args.jobs, seed=args.seed, strategy=args.strategy,
            policy=args.policy, churn=args.churn,
        )
    try:
        result = sched.run_stream(
            jobs, failures=failures,
            checkpoint_dir=args.ckpt, checkpoint_every=args.every,
            resume=args.resume, crash_at=args.crash_at,
            heartbeat_every=args.heartbeat_every,
        )
    finally:
        if args.trace:
            obs_trace.disable()  # lands trace.end so watchers stop cleanly
    payload = json.dumps(result.summary(), sort_keys=True)
    if args.out:
        with open(args.out, "w") as f:
            f.write(payload + "\n")
    else:
        print(payload)
    return 0


if __name__ == "__main__":
    sys.exit(run())
