"""Fault-injection & resilience subsystem.

Three layers, mirroring the repo's engine / sched split:

  * :mod:`repro.resil.epochs` — the epoch-schedule representation
    ``(epoch_start[E], link_ok[E, S, q*n])`` that the engine consumes:
    time-varying fault masks carried in ``WorkloadTables`` and switched
    mid-flight by one gather per cycle (``E = 1`` is bit-identical to the
    static path, trace-counter-pinned in ``tests/test_resil.py``).
  * :mod:`repro.resil.processes` — seeded exponential / Weibull
    MTBF -> MTTR failure-and-repair timelines over links, switches and
    endpoints (plus deterministic scripted campaigns and correlated
    whole-switch / cable-bundle modes), lowered to epoch schedules for
    the engine and to :class:`~repro.sched.scheduler.FailureEvent`
    streams for the scheduler.
  * :mod:`repro.resil.stream` — the crash-safe scheduler-stream driver:
    ``python -m repro.resil.stream`` periodically checkpoints
    ``OnlineScheduler.run_stream`` state through
    :class:`~repro.checkpoint.checkpointer.Checkpointer` and ``--resume``
    reproduces the uninterrupted run's metrics bit-identically (pinned by
    a kill-and-resume subprocess test).
"""

from repro.resil.epochs import (
    FaultSchedule,
    apply_schedule,
    schedule_from_masks,
    static_schedule,
)
from repro.resil.processes import (
    FaultEvent,
    exponential_lifetimes,
    sample_components,
    scripted_campaign,
    to_epoch_schedule,
    to_failure_events,
)

__all__ = [
    "FaultSchedule",
    "apply_schedule",
    "schedule_from_masks",
    "static_schedule",
    "FaultEvent",
    "exponential_lifetimes",
    "sample_components",
    "scripted_campaign",
    "to_epoch_schedule",
    "to_failure_events",
]
