"""Epoch-structured fault schedules: the engine's time-varying fault axis.

A :class:`FaultSchedule` is the lowered form every fault process reduces
to: ``epoch_start`` (cycle each epoch begins; epoch 0 starts at cycle 0)
and one ``(S, q*n)`` directed-link health mask per epoch (see
:mod:`repro.route.faults` for the mask layout).  The schedule travels on
``Workload.fault_schedule`` into ``WorkloadTables`` — padded to a
power-of-two epoch count so fault grids still batch one-compile-one-call
per shape bucket — and the engine's cycle kernel switches masks
mid-flight with one gather on the current epoch index.  In-flight packets
survive a flip through the existing escalation / deroute machinery; what
strands anyway is counted by the new ``SimResult`` fields.

A one-epoch schedule is exactly a static mask: the engine's ``E = 1``
path is bit-identical to the pre-epoch kernel (trace-counter-pinned in
``tests/test_resil.py``).
"""

from __future__ import annotations

import dataclasses
from typing import TYPE_CHECKING, Sequence

import numpy as np

from repro.core.hyperx import HyperX
from repro.route import faults

if TYPE_CHECKING:  # pragma: no cover
    from repro.core.traffic import Workload


@dataclasses.dataclass(frozen=True)
class FaultSchedule:
    """Per-workload epoch schedule of directed-link health masks.

    ``epoch_start`` — (NE,) int64 cycle each epoch begins; must start at 0
    and be strictly increasing.  ``link_ok`` — (NE, S, q*n) bool, True =
    healthy.  Epoch ``e`` is active for cycles in
    ``[epoch_start[e], epoch_start[e+1])``; the last epoch runs forever.
    """

    epoch_start: np.ndarray
    link_ok: np.ndarray

    def __post_init__(self):
        starts = np.asarray(self.epoch_start, dtype=np.int64)
        masks = np.asarray(self.link_ok, dtype=bool)
        if starts.ndim != 1 or starts.size == 0:
            raise ValueError(f"epoch_start must be 1-D non-empty, got "
                             f"shape {starts.shape}")
        if masks.ndim != 3 or masks.shape[0] != starts.size:
            raise ValueError(
                f"link_ok must be (NE, S, q*n) with NE={starts.size}, "
                f"got shape {masks.shape}"
            )
        if starts[0] != 0:
            raise ValueError(f"epoch 0 must start at cycle 0, got {starts[0]}")
        if starts.size > 1 and not (np.diff(starts) > 0).all():
            raise ValueError(f"epoch starts must be strictly increasing: "
                             f"{starts.tolist()}")
        object.__setattr__(self, "epoch_start", starts)
        object.__setattr__(self, "link_ok", masks)

    @property
    def NE(self) -> int:
        return int(self.epoch_start.size)

    def epoch_at(self, t: int) -> int:
        """Index of the epoch active at cycle ``t``."""
        return int(np.searchsorted(self.epoch_start, t, side="right") - 1)

    def mask_at(self, t: int) -> np.ndarray:
        """The (S, q*n) mask active at cycle ``t``."""
        return self.link_ok[self.epoch_at(t)]


def static_schedule(
    topo: HyperX, link_ok: np.ndarray | None = None
) -> FaultSchedule:
    """One-epoch schedule — semantically identical to a static mask
    (and lowered to the engine's bit-identical ``E = 1`` path)."""
    mask = faults.no_faults(topo) if link_ok is None else link_ok
    return FaultSchedule(
        epoch_start=np.zeros(1, dtype=np.int64),
        link_ok=np.asarray(mask, dtype=bool)[None],
    )


def schedule_from_masks(
    topo: HyperX,
    entries: Sequence[tuple[int, np.ndarray]],
) -> FaultSchedule:
    """Build a schedule from ``(start_cycle, mask)`` pairs.

    Entries are sorted by start cycle; a healthy epoch 0 is prepended when
    the earliest entry starts after cycle 0, and duplicate start cycles
    keep the last-given mask (event-sourcing semantics).
    """
    if not entries:
        return static_schedule(topo)
    expect = (topo.num_switches, topo.q * topo.n)
    rows: dict[int, np.ndarray] = {}
    for start, mask in sorted(entries, key=lambda e: int(e[0])):
        mask = np.asarray(mask, dtype=bool)
        if mask.shape != expect:
            raise ValueError(
                f"mask shape {mask.shape} != {expect} for {topo}"
            )
        rows[int(start)] = mask
    if min(rows) > 0:
        rows = {0: faults.no_faults(topo), **rows}
    starts = np.asarray(sorted(rows), dtype=np.int64)
    return FaultSchedule(
        epoch_start=starts,
        link_ok=np.stack([rows[int(s)] for s in starts]),
    )


def apply_schedule(wl: "Workload", schedule: FaultSchedule) -> "Workload":
    """A copy of ``wl`` carrying the epoch schedule (lowered into the
    engine's ``WorkloadTables`` by the prepare step).  Composes with a
    static ``wl.link_ok`` mask: the engine ANDs both, so permanent faults
    plus dynamic churn stack."""
    expect = (wl.topo.num_switches, wl.topo.q * wl.topo.n)
    if schedule.link_ok.shape[1:] != expect:
        raise ValueError(
            f"schedule masks are {schedule.link_ok.shape[1:]}, "
            f"workload topology needs {expect}"
        )
    return dataclasses.replace(wl, fault_schedule=schedule)
