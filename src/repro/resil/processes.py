"""Failure-and-repair processes over network components.

Components fail and repair on seeded timelines; everything lowers to the
two consumers' native forms:

  * :func:`to_epoch_schedule` — an engine :class:`~repro.resil.epochs.
    FaultSchedule` (epoch starts + per-epoch link masks, replaying the
    event stream and coarsening deterministically past ``max_epochs``);
  * :func:`to_failure_events` — scheduler
    :class:`~repro.sched.scheduler.FailureEvent` streams (endpoint-kind
    events only; the scheduler operates on endpoints).

Component kinds and their correlated failure domains:

  * ``("link", (a, b))``     — one cable: BOTH directions die together;
  * ``("switch", (s,))``     — whole switch: all ``q*n`` outgoing directed
    ports plus every incoming direction (power-off);
  * ``("endpoint", (e,))``   — node loss: takes its co-packaged cable
    (deterministic per endpoint id, via
    :func:`repro.route.faults.faults_from_endpoints`);
  * ``("bundle", (s, d))``   — cable bundle: every cable of switch ``s``
    in dimension ``d`` (the shared-conduit failure mode).

Lifetimes: :func:`exponential_lifetimes` draws alternating
time-to-failure (mean ``mtbf``) and time-to-repair (mean ``mttr``)
intervals per component — exponential by default, Weibull when
``weibull_shape`` is given (scale chosen so the mean stays ``mtbf`` /
``mttr``).  Each component gets its own ``np.random.default_rng([seed,
index])`` stream, so adding a component never perturbs the others.
:func:`scripted_campaign` builds the same event stream from an explicit
script for deterministic regression scenarios.
"""

from __future__ import annotations

import dataclasses
import math
from typing import Sequence

import numpy as np

from repro.core.hyperx import HyperX
from repro.resil.epochs import FaultSchedule
from repro.route import faults
from repro.route.topology import dst_switch_table, self_port_mask

KINDS = ("link", "switch", "endpoint", "bundle")

Component = tuple  # (kind, ident) — e.g. ("link", (0, 1))


@dataclasses.dataclass(frozen=True, order=True)
class FaultEvent:
    """One component state change at an integer cycle."""

    time: int
    kind: str      # one of KINDS
    ident: tuple   # component identity (see module docstring)
    up: bool       # True = repair, False = failure


def _check_component(comp: Component) -> Component:
    kind, ident = comp
    if kind not in KINDS:
        raise ValueError(f"unknown component kind {kind!r}; one of {KINDS}")
    return kind, tuple(int(x) for x in np.atleast_1d(np.asarray(ident)))


def sample_components(
    topo: HyperX,
    n_links: int = 0,
    n_switches: int = 0,
    n_endpoints: int = 0,
    n_bundles: int = 0,
    seed: int = 0,
) -> list[Component]:
    """Draw a deterministic component set to subject to churn."""
    rng = np.random.default_rng(seed)
    out: list[Component] = []
    if n_links:
        cables = topo.link_array()
        take = rng.choice(len(cables), size=min(n_links, len(cables)),
                         replace=False)
        out += [("link", tuple(int(x) for x in cables[i])) for i in take]
    if n_switches:
        take = rng.choice(topo.num_switches,
                          size=min(n_switches, topo.num_switches),
                          replace=False)
        out += [("switch", (int(s),)) for s in take]
    if n_endpoints:
        take = rng.choice(topo.num_endpoints,
                          size=min(n_endpoints, topo.num_endpoints),
                          replace=False)
        out += [("endpoint", (int(e),)) for e in take]
    if n_bundles:
        pairs = [(s, d) for s in range(topo.num_switches)
                 for d in range(topo.q)]
        take = rng.choice(len(pairs), size=min(n_bundles, len(pairs)),
                          replace=False)
        out += [("bundle", pairs[i]) for i in take]
    return out


def exponential_lifetimes(
    components: Sequence[Component],
    mtbf: float,
    mttr: float,
    horizon: int,
    seed: int = 0,
    weibull_shape: float | None = None,
) -> list[FaultEvent]:
    """Alternating fail/repair timelines per component up to ``horizon``.

    Returns the merged, time-sorted event stream.  ``weibull_shape`` k
    switches both draws to Weibull(k) with the scale set so means stay
    ``mtbf``/``mttr`` (k < 1 = infant mortality, k > 1 = wear-out).
    """
    if mtbf <= 0 or mttr <= 0 or horizon <= 0:
        raise ValueError(
            f"mtbf/mttr/horizon must be positive, got {mtbf}/{mttr}/{horizon}"
        )

    def draw(rng: np.random.Generator, mean: float) -> float:
        if weibull_shape is None:
            return float(rng.exponential(mean))
        scale = mean / math.gamma(1.0 + 1.0 / weibull_shape)
        return float(scale * rng.weibull(weibull_shape))

    events: list[FaultEvent] = []
    for i, comp in enumerate(components):
        kind, ident = _check_component(comp)
        rng = np.random.default_rng([seed, i])
        t = 0.0
        while True:
            t += max(draw(rng, mtbf), 1.0)
            if t >= horizon:
                break
            events.append(FaultEvent(int(round(t)), kind, ident, up=False))
            t += max(draw(rng, mttr), 1.0)
            if t >= horizon:
                break
            events.append(FaultEvent(int(round(t)), kind, ident, up=True))
    return sorted(events)


def scripted_campaign(
    script: Sequence[tuple[int, str, str, Sequence[int]]],
) -> list[FaultEvent]:
    """Deterministic campaign from ``(cycle, action, kind, ident)`` rows,
    where ``action`` is ``"fail"`` or ``"repair"``."""
    events = []
    for cycle, action, kind, ident in script:
        if action not in ("fail", "repair"):
            raise ValueError(f"unknown action {action!r} (fail|repair)")
        kind, ident = _check_component((kind, ident))
        events.append(FaultEvent(int(cycle), kind, ident,
                                 up=(action == "repair")))
    return sorted(events)


# ----------------------------------------------------------------- lowering
def _component_mask(topo: HyperX, kind: str, ident: tuple) -> np.ndarray:
    """The (S, q*n) healthy mask with exactly this component down."""
    if kind == "link":
        return faults.fail_links(topo, [ident])
    if kind == "switch":
        return faults.fail_switches(topo, list(ident))
    if kind == "endpoint":
        return faults.faults_from_endpoints(topo, list(ident), seed=0)
    # bundle: every cable of switch s in dimension d
    s, d = ident
    coords = topo.all_switch_coords()
    valid = self_port_mask(coords, topo.n, topo.q)
    dst = dst_switch_table(coords, topo.n, topo.q).reshape(valid.shape)
    n = topo.n
    pairs = [
        (int(s), int(dst[s, d * n + v]))
        for v in range(n)
        if valid[s, d * n + v]
    ]
    return faults.fail_links(topo, pairs)


def to_epoch_schedule(
    topo: HyperX,
    events: Sequence[FaultEvent],
    max_epochs: int = 16,
    base_link_ok: np.ndarray | None = None,
) -> FaultSchedule:
    """Replay an event stream into an engine epoch schedule.

    Every cycle where the down-component set changes opens a new epoch;
    when that exceeds ``max_epochs`` the boundary list is coarsened
    deterministically (epoch 0 always kept, the rest evenly sampled), so
    the schedule stays bucket-friendly for long campaigns.
    ``base_link_ok`` ANDs a permanent fault mask under the churn.
    """
    if max_epochs < 1:
        raise ValueError(f"max_epochs must be >= 1, got {max_epochs}")
    down: dict[tuple[str, tuple], int] = {}
    boundaries: list[tuple[int, tuple]] = [(0, ())]
    events = sorted(events)
    i = 0
    while i < len(events):
        t = events[i].time
        while i < len(events) and events[i].time == t:
            ev = events[i]
            key = (ev.kind, ev.ident)
            c = down.get(key, 0) + (-1 if ev.up else 1)
            if c <= 0:
                down.pop(key, None)
            else:
                down[key] = c
            i += 1
        state = tuple(sorted(down))
        if t <= 0:
            boundaries[0] = (0, state)
        elif state != boundaries[-1][1]:
            boundaries.append((int(t), state))
    if len(boundaries) > max_epochs:
        idx = np.unique(np.round(
            np.linspace(0, len(boundaries) - 1, max_epochs)
        ).astype(int))
        boundaries = [boundaries[j] for j in idx]
    base = (faults.no_faults(topo) if base_link_ok is None
            else np.asarray(base_link_ok, dtype=bool))
    mask_cache: dict[tuple[str, tuple], np.ndarray] = {}
    masks, starts = [], []
    for t, state in boundaries:
        mask = base.copy()
        for key in state:
            if key not in mask_cache:
                mask_cache[key] = _component_mask(topo, *key)
            mask &= mask_cache[key]
        starts.append(t)
        masks.append(mask)
    return FaultSchedule(
        epoch_start=np.asarray(starts, dtype=np.int64),
        link_ok=np.stack(masks),
    )


def to_failure_events(
    events: Sequence[FaultEvent],
    time_scale: float = 1.0,
):
    """Endpoint-kind events as scheduler ``FailureEvent``s.

    Pairs each endpoint failure with its next repair (``repair_at`` stays
    None for failures that never repair in-stream); ``time_scale``
    converts engine cycles to scheduler time units.
    """
    from repro.sched.scheduler import FailureEvent as SchedFailure

    out = []
    open_fail: dict[tuple, int] = {}
    rows: list[tuple[int, tuple, int | None]] = []
    for ev in sorted(events):
        if ev.kind != "endpoint":
            continue
        if not ev.up:
            if ev.ident not in open_fail:
                open_fail[ev.ident] = len(rows)
                rows.append((ev.time, ev.ident, None))
        else:
            i = open_fail.pop(ev.ident, None)
            if i is not None:
                rows[i] = (rows[i][0], rows[i][1], ev.time)
    for t_down, ident, t_up in rows:
        out.append(SchedFailure(
            time=t_down * time_scale,
            endpoints=ident,
            repair_at=None if t_up is None else t_up * time_scale,
        ))
    return out
