"""Roofline-term extraction from compiled dry-run artifacts.

Three terms per (arch x shape x mesh), in seconds (TPU v5e-class):

    compute    = HLO_FLOPs / (chips * 197e12)          [bf16 MXU peak]
    memory     = HLO_bytes / (chips * 819e9)           [HBM]
    collective = collective_operand_bytes / (chips * 50e9)  [per-link ICI]

FLOPs/bytes come from ``compiled.cost_analysis()``; collective bytes are
NOT in cost_analysis, so we parse the optimized HLO text and sum operand
sizes of all-reduce / all-gather / reduce-scatter / all-to-all /
collective-permute ops.  ``MODEL_FLOPS = 6*N*D`` (6*N_active*D for MoE)
gives the useful-compute ratio that catches remat/redundancy waste.

The allocation-aware variant scales the collective term by the placement's
partition bandwidth (min(1, PB) of injection bandwidth) — the paper's
Lesson 2 applied to the roofline.
"""

from __future__ import annotations

import dataclasses

PEAK_FLOPS = 197e12      # bf16 per chip
HBM_BW = 819e9           # bytes/s per chip
LINK_BW = 50e9           # bytes/s per ICI link

@dataclasses.dataclass
class Roofline:
    """All HLO-derived quantities are PER DEVICE (the SPMD module is the
    per-device program); model_flops is the global step's useful FLOPs."""

    arch: str
    shape: str
    mesh: str
    chips: int
    hlo_flops: float          # per-device dot FLOPs (trip-count aware)
    hlo_bytes: float          # per-device HBM traffic
    coll_bytes: float         # per-device collective operand bytes
    coll_breakdown: dict
    coll_counts: dict
    model_flops: float        # global 6*N*D (or 2*N*D serve) useful FLOPs
    peak_bytes_per_chip: float | None = None
    cost_analysis_raw: dict | None = None

    @property
    def compute_s(self) -> float:
        return self.hlo_flops / PEAK_FLOPS

    @property
    def memory_s(self) -> float:
        return self.hlo_bytes / HBM_BW

    @property
    def collective_s(self) -> float:
        return self.coll_bytes / LINK_BW

    @property
    def bottleneck(self) -> str:
        t = {
            "compute": self.compute_s,
            "memory": self.memory_s,
            "collective": self.collective_s,
        }
        return max(t, key=t.get)

    @property
    def useful_ratio(self) -> float:
        per_dev = self.model_flops / self.chips
        return per_dev / self.hlo_flops if self.hlo_flops else 0.0

    @property
    def roofline_fraction(self) -> float:
        """useful-FLOPs-at-peak time / dominating term — the perf score."""
        ideal = self.model_flops / self.chips / PEAK_FLOPS
        dom = max(self.compute_s, self.memory_s, self.collective_s)
        return ideal / dom if dom else 0.0

    def collective_s_allocated(self, pb: float) -> float:
        return self.coll_bytes / (min(1.0, pb) * LINK_BW)

    def row(self) -> dict:
        return {
            "arch": self.arch, "shape": self.shape, "mesh": self.mesh,
            "chips": self.chips,
            "hlo_flops": self.hlo_flops, "hlo_bytes": self.hlo_bytes,
            "coll_bytes": self.coll_bytes,
            "coll_breakdown": self.coll_breakdown,
            "coll_counts": self.coll_counts,
            "compute_s": self.compute_s, "memory_s": self.memory_s,
            "collective_s": self.collective_s,
            "bottleneck": self.bottleneck,
            "model_flops": self.model_flops,
            "useful_ratio": self.useful_ratio,
            "roofline_fraction": self.roofline_fraction,
            "peak_bytes_per_chip": self.peak_bytes_per_chip,
            "cost_analysis_raw": self.cost_analysis_raw,
        }


def model_flops_for(cfg, shape) -> float:
    """6*N*D per processed token (N_active for MoE); decode counts the one
    new token per sequence."""
    n = cfg.active_param_count()
    if shape.kind == "train":
        tokens = shape.global_batch * shape.seq_len
        return 6.0 * n * tokens
    if shape.kind == "prefill":
        tokens = shape.global_batch * shape.seq_len
        return 2.0 * n * tokens
    return 2.0 * n * shape.global_batch  # decode: one token per sequence


def from_compiled(arch, shape_name, mesh_name, chips, compiled, model_flops,
                  hlo_text=None) -> Roofline:
    from repro.launch.hlo_analysis import analyze

    text = hlo_text if hlo_text is not None else compiled.as_text()
    a = analyze(text)
    try:
        ca = compiled.cost_analysis()
        ca = ca[0] if isinstance(ca, list) else ca
        raw = {k: float(ca[k]) for k in ("flops", "bytes accessed") if k in ca}
    except Exception:
        raw = None
    peak = None
    try:
        ma = compiled.memory_analysis()
        peak = float(
            ma.temp_size_in_bytes + ma.argument_size_in_bytes
            + ma.output_size_in_bytes - ma.alias_size_in_bytes
        )
    except Exception:
        pass
    return Roofline(
        arch=arch, shape=shape_name, mesh=mesh_name, chips=chips,
        hlo_flops=a["flops"], hlo_bytes=a["bytes"],
        coll_bytes=a["coll_bytes"], coll_breakdown=a["coll_breakdown"],
        coll_counts=a["coll_counts"], model_flops=model_flops,
        peak_bytes_per_chip=peak, cost_analysis_raw=raw,
    )
