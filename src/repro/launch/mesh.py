"""Production meshes.

``make_production_mesh`` is a FUNCTION (not a module constant) so importing
this module never touches jax device state; the dry-run launcher sets the
host-device count env var before any jax import.

``make_allocated_mesh`` additionally orders the device list by one of the
paper's allocation strategies over the HyperX fleet (fabric.placement), so
mesh axes land on physical endpoints with known PB/distance properties.
"""

from __future__ import annotations


def make_production_mesh(*, multi_pod: bool = False):
    import jax

    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_allocated_mesh(strategy: str = "diagonal", *, multi_pod: bool = False,
                        seed: int = 0):
    """(Mesh, HyperXPlacement) with allocation-ordered devices."""
    from repro.fabric.placement import make_placed_mesh

    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return make_placed_mesh(strategy, shape, axes, seed=seed)
