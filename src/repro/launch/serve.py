"""Serving driver: batched prefill+decode with the ServeEngine.

    PYTHONPATH=src python -m repro.launch.serve --arch qwen3_0_6b --reduced \
        --batch 4 --prompt-len 32 --gen 16
"""

from __future__ import annotations

import argparse
import time


def main(argv=None):
    p = argparse.ArgumentParser()
    p.add_argument("--arch", required=True)
    p.add_argument("--reduced", action="store_true")
    p.add_argument("--batch", type=int, default=4)
    p.add_argument("--prompt-len", type=int, default=32)
    p.add_argument("--gen", type=int, default=16)
    p.add_argument("--max-len", type=int, default=None)
    p.add_argument("--greedy", action="store_true", default=True)
    args = p.parse_args(argv)

    import jax.numpy as jnp

    from repro.configs import get_config
    from repro.data.pipeline import SyntheticLM
    from repro.models import transformer as M
    from repro.models.module import init as init_params
    from repro.serve import ServeEngine

    import jax

    cfg = get_config(args.arch, reduced=args.reduced)
    params = init_params(jax.random.PRNGKey(0), M.model_specs(cfg))
    max_len = args.max_len or (args.prompt_len + args.gen + 8)
    eng = ServeEngine(cfg, params, max_len=max_len)
    data = SyntheticLM(cfg, seed=3)
    batch = {"tokens": jnp.asarray(
        data.next_batch(args.batch, args.prompt_len)["tokens"]
    )}
    if cfg.family == "vlm":
        import numpy as np

        batch["image_embeds"] = jnp.asarray(
            data.batch_at(0, args.batch, args.prompt_len)["image_embeds"]
        )
    t0 = time.time()
    out = eng.generate(batch, steps=args.gen, greedy=args.greedy)
    dt = time.time() - t0
    tok_s = args.batch * args.gen / dt
    print(f"[serve] {cfg.name}: {args.batch}x{args.gen} tokens in "
          f"{dt:.2f}s ({tok_s:.1f} tok/s)")
    print("sample:", out[0][:12].tolist())
    return out


if __name__ == "__main__":
    main()
