import os
os.environ["XLA_FLAGS"] = (
    "--xla_force_host_platform_device_count=512 "
    + os.environ.get("XLA_FLAGS", "")
)

"""Multi-pod dry-run: lower + compile every (arch x shape x mesh) cell.

The two lines above run before ANY other import (jax locks the device
count on first init).  For each cell this launcher:

  1. builds the production mesh (16x16 single-pod or 2x16x16 multi-pod),
  2. constructs abstract (ShapeDtypeStruct) parameters, optimizer state,
     batches and decode caches — no allocation anywhere,
  3. assigns shardings from the logical-axis rules (FSDP for training,
     TP+weight-sharding for serving),
  4. ``jit(step).lower(...).compile()`` and prints memory_analysis() /
     cost_analysis(),
  5. extracts the roofline terms (launch/roofline.py) and appends a JSON
     row to the output file.

Usage:
    python -m repro.launch.dryrun --arch deepseek-67b --shape train_4k
    python -m repro.launch.dryrun --all --mesh both --out results/dryrun.jsonl
"""

import argparse
import dataclasses
import json
import sys
import time
import traceback


def _shard_if(size, mesh_axes, want):
    """mesh axis tuple for a dim of ``size``: use ``want`` axes if divisible."""
    sel = []
    prod = 1
    for ax in want:
        if ax in mesh_axes:
            p = prod * mesh_axes[ax]
            if size % p == 0:
                sel.append(ax)
                prod = p
    return tuple(sel) if sel else None


def batch_shardings(cfg, mesh, specs):
    import jax
    from jax.sharding import NamedSharding, PartitionSpec as P

    axes = dict(zip(mesh.axis_names, mesh.devices.shape))

    def one(k, s):
        b = s.shape[0]
        data_axes = _shard_if(b, axes, ("pod", "data"))
        parts = [data_axes] + [None] * (len(s.shape) - 1)
        return NamedSharding(mesh, P(*parts))

    return {k: one(k, s) for k, s in specs.items()}


def cache_shardings(cfg, mesh, cache_tree):
    """Decode-cache shardings by leaf role (see DESIGN.md §Distribution)."""
    import jax
    from jax.sharding import NamedSharding, PartitionSpec as P

    axes = dict(zip(mesh.axis_names, mesh.devices.shape))

    def one(path, leaf):
        keys = [p.key for p in path if hasattr(p, "key")]
        name = keys[-1]
        nd = len(leaf.shape)
        parts = [None] * nd
        if name == "index":
            return NamedSharding(mesh, P())
        if name in ("k", "v"):            # (..., B, T, kv, dh)
            b, t, kv = nd - 4, nd - 3, nd - 2
            parts[b] = _shard_if(leaf.shape[b], axes, ("pod", "data"))
            if axes.get("model") and leaf.shape[kv] % axes["model"] == 0:
                parts[kv] = "model"
            elif axes.get("model") and leaf.shape[t] % axes["model"] == 0:
                parts[t] = "model"
        elif name in ("ckv", "krope"):    # (..., B, T, E)
            b, t = nd - 3, nd - 2
            parts[b] = _shard_if(leaf.shape[b], axes, ("pod", "data"))
            if axes.get("model") and leaf.shape[t] % axes["model"] == 0:
                parts[t] = "model"
        elif name == "conv":              # (..., B, W, C)
            b, c = nd - 3, nd - 1
            parts[b] = _shard_if(leaf.shape[b], axes, ("pod", "data"))
            if axes.get("model") and leaf.shape[c] % axes["model"] == 0:
                parts[c] = "model"
        elif name == "state":
            b = 1 if nd > 2 else 0
            parts[b] = _shard_if(leaf.shape[b], axes, ("pod", "data"))
            tp = nd - 3 if nd >= 4 else nd - 1   # ssm heads / lru width
            if axes.get("model") and leaf.shape[tp] % axes["model"] == 0:
                parts[tp] = "model"
        return NamedSharding(mesh, P(*parts))

    import jax.tree_util as jtu

    return jtu.tree_map_with_path(one, cache_tree)


def choose_settings(cfg, shape, grad_compression=False):
    from repro.train.train_step import TrainSettings

    if cfg.family == "moe":
        mb = 16  # §Perf iteration 4: halves activation peak, terms flat
    elif cfg.d_model >= 4096:
        mb = 8
    else:
        mb = 4
    return TrainSettings(microbatches=mb, remat=True,
                         grad_compression=grad_compression)


def serve_rule_set(cfg, n_model_shards=16) -> str:
    bf16_bytes = 2 * cfg.param_count()
    return "fsdp" if bf16_bytes / n_model_shards > 6e9 else "base"


def lower_cell(arch: str, shape_name: str, multi_pod: bool,
               settings_override=None, rule_set_override=None,
               verbose=True):
    """Lower + compile one cell; returns the roofline row dict."""
    import jax
    import jax.numpy as jnp
    from jax.sharding import NamedSharding, PartitionSpec as P

    from repro.configs import get_config
    from repro.data.pipeline import make_batch_specs
    from repro.launch.mesh import make_production_mesh
    from repro.launch import roofline as RL
    from repro.models import transformer as M
    from repro.models.module import abstract, is_spec
    from repro.sharding.partitioning import activation_mesh, tree_shardings
    from repro.train.optimizer import AdamWState
    from repro.train.train_step import build_train_step

    cfg = get_config(arch)
    if cfg.family == "moe":
        # hierarchical dispatch groups = data-parallel shards (§Perf it. 1)
        cfg = dataclasses.replace(cfg, moe_groups=32 if multi_pod else 16)
    shape = cfg.shape(shape_name)
    mesh_name = "2x16x16" if multi_pod else "16x16"
    t0 = time.time()
    if shape.skip:
        return {"arch": cfg.name, "shape": shape_name, "mesh": mesh_name,
                "status": "skip", "reason": shape.skip}

    mesh = make_production_mesh(multi_pod=multi_pod)
    chips = mesh.devices.size
    specs = M.model_specs(cfg)
    repl = NamedSharding(mesh, P())

    if shape.kind == "train":
        rule_set = rule_set_override or "fsdp"
        settings = settings_override or choose_settings(cfg, shape)
        params_ab = abstract(specs)
        params_sh = tree_shardings(specs, mesh, rule_set)
        opt_ab = AdamWState(
            step=jax.ShapeDtypeStruct((), jnp.int32),
            m=params_ab, v=params_ab,
        )
        opt_sh = AdamWState(step=repl, m=params_sh, v=params_sh)
        bspecs = make_batch_specs(cfg, shape.global_batch, shape.seq_len, "train")
        bsh = batch_shardings(cfg, mesh, bspecs)
        step_fn = build_train_step(cfg, settings)

        def wrapped(params, opt, batch):
            with activation_mesh(mesh, rule_set):
                return step_fn(params, opt, batch)

        jitted = jax.jit(
            wrapped,
            in_shardings=(params_sh, opt_sh, bsh),
            out_shardings=(params_sh, opt_sh, None),
            donate_argnums=(0, 1),
        )
        with mesh:
            lowered = jitted.lower(params_ab, opt_ab, bspecs)
            compiled = lowered.compile()
    else:
        rule_set = rule_set_override or serve_rule_set(cfg)
        # serving weights in bf16
        bf_specs = jax.tree_util.tree_map(
            lambda s: dataclasses.replace(s, dtype="bfloat16")
            if s.dtype == "float32" else s,
            specs, is_leaf=is_spec,
        )
        params_ab = abstract(bf_specs)
        params_sh = tree_shardings(specs, mesh, rule_set)
        if shape.kind == "prefill":
            bspecs = make_batch_specs(cfg, shape.global_batch, shape.seq_len,
                                      "prefill")
            bsh = batch_shardings(cfg, mesh, bspecs)

            if cfg.encoder_only:
                def serve_fn(params, batch):
                    with activation_mesh(mesh, rule_set):
                        return M.forward_train(cfg, params, batch, remat=False)[0]
            else:
                def serve_fn(params, batch):
                    with activation_mesh(mesh, rule_set):
                        return M.prefill(cfg, params, batch,
                                         max_len=shape.seq_len)

            jitted = jax.jit(serve_fn, in_shardings=(params_sh, bsh))
            with mesh:
                lowered = jitted.lower(params_ab, bspecs)
                compiled = lowered.compile()
        else:  # decode: one new token against a seq_len-deep cache
            caches_ab = jax.eval_shape(
                lambda: M.init_caches(cfg, shape.global_batch, shape.seq_len)
            )
            csh = cache_shardings(cfg, mesh, caches_ab)
            bspecs = make_batch_specs(cfg, shape.global_batch, shape.seq_len,
                                      "decode")
            bsh = batch_shardings(cfg, mesh, bspecs)
            idx_ab = jax.ShapeDtypeStruct((), jnp.int32)

            def serve_fn(params, tokens, caches, index):
                with activation_mesh(mesh, rule_set):
                    return M.decode_step(cfg, params, tokens, caches, index)

            jitted = jax.jit(
                serve_fn,
                in_shardings=(params_sh, bsh["tokens"], csh, repl),
                donate_argnums=(2,),
            )
            with mesh:
                lowered = jitted.lower(params_ab, bspecs["tokens"], caches_ab,
                                       idx_ab)
                compiled = lowered.compile()

    text = compiled.as_text()
    mf = RL.model_flops_for(cfg, shape)
    rl = RL.from_compiled(cfg.name, shape_name, mesh_name, chips, compiled,
                          mf, hlo_text=text)
    row = rl.row()
    row.update(status="ok", rule_set=rule_set,
               compile_s=round(time.time() - t0, 1))
    if shape.kind == "train":
        row["microbatches"] = settings.microbatches
    if verbose:
        print(f"== {cfg.name} {shape_name} {mesh_name} ==")
        print(compiled.memory_analysis())      # proves it fits
        ca = compiled.cost_analysis()
        ca = ca[0] if isinstance(ca, list) else ca
        print({k: ca[k] for k in ("flops", "bytes accessed") if k in ca})
        print(json.dumps({k: row[k] for k in (
            "compute_s", "memory_s", "collective_s", "bottleneck",
            "useful_ratio", "roofline_fraction", "peak_bytes_per_chip",
        )}, default=str))
    return row


def main(argv=None):
    p = argparse.ArgumentParser()
    p.add_argument("--arch", default=None)
    p.add_argument("--shape", default=None)
    p.add_argument("--mesh", choices=("single", "multi", "both"),
                   default="single")
    p.add_argument("--all", action="store_true")
    p.add_argument("--out", default=None, help="append JSONL rows here")
    args = p.parse_args(argv)

    from repro.configs import ARCHS, get_config

    archs = ARCHS if args.all or args.arch is None else [args.arch]
    meshes = {"single": [False], "multi": [True], "both": [False, True]}[args.mesh]
    done = set()
    if args.out and os.path.exists(args.out):
        with open(args.out) as f:
            for line in f:
                try:
                    r = json.loads(line)
                    if r.get("status") in ("ok", "skip"):
                        done.add((r["arch"], r["shape"], r["mesh"]))
                except json.JSONDecodeError:
                    pass
    rows = []
    for arch in archs:
        cfg = get_config(arch)
        shapes = ([args.shape] if args.shape
                  else [c.name for c in cfg.shapes()])
        for shape in shapes:
            for mp in meshes:
                key = (cfg.name, shape, "2x16x16" if mp else "16x16")
                if key in done:
                    continue
                try:
                    row = lower_cell(arch, shape, mp)
                except Exception as e:
                    traceback.print_exc()
                    row = {
                        "arch": cfg.name, "shape": shape,
                        "mesh": "2x16x16" if mp else "16x16",
                        "status": "error", "error": f"{type(e).__name__}: {e}",
                    }
                rows.append(row)
                if args.out:
                    os.makedirs(os.path.dirname(args.out) or ".", exist_ok=True)
                    with open(args.out, "a") as f:
                        f.write(json.dumps(row, default=str) + "\n")
    bad = [r for r in rows if r.get("status") == "error"]
    print(f"\n{len(rows)} cells: {len(rows) - len(bad)} ok/skip, {len(bad)} errors")
    return 1 if bad else 0


if __name__ == "__main__":
    sys.exit(main())
