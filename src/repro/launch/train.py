"""Training driver: allocation-aware mesh + fault-tolerant train loop.

Single-process reference driver (the CPU container); the same loop runs
under multi-host jax.distributed with per-host data slices.  Integrates:

  * FleetRuntime — HyperX allocation as placement + repair policy,
  * Checkpointer — periodic async checkpoint, resume on restart/failure,
  * StragglerMonitor — per-step timing, eviction proposals,
  * failure injection (--fail-at N) to exercise the repair path for real.

Example (CPU smoke scale):
    PYTHONPATH=src python -m repro.launch.train --arch qwen3_0_6b --reduced \
        --steps 30 --batch 8 --seq 64 --mesh-shape 1,2 --ckpt /tmp/ck
"""

from __future__ import annotations

import argparse
import time


def main(argv=None):
    p = argparse.ArgumentParser()
    p.add_argument("--arch", required=True)
    p.add_argument("--reduced", action="store_true")
    p.add_argument("--steps", type=int, default=50)
    p.add_argument("--batch", type=int, default=8)
    p.add_argument("--seq", type=int, default=64)
    p.add_argument("--microbatches", type=int, default=1)
    p.add_argument("--mesh-shape", default="1,1",
                   help="data,model (must divide available devices)")
    p.add_argument("--strategy", default="diagonal",
                   help="HyperX allocation strategy for placement")
    p.add_argument("--ckpt", default=None)
    p.add_argument("--ckpt-every", type=int, default=20)
    p.add_argument("--fail-at", type=int, default=None,
                   help="inject an endpoint failure at this step")
    p.add_argument("--grad-compression", action="store_true")
    p.add_argument("--lr", type=float, default=3e-3)
    p.add_argument("--log-every", type=int, default=5)
    args = p.parse_args(argv)

    import jax
    import jax.numpy as jnp
    import numpy as np

    from repro.checkpoint import Checkpointer
    from repro.configs import get_config
    from repro.data.pipeline import SyntheticLM
    from repro.models import transformer as M
    from repro.models.module import init as init_params
    from repro.runtime import FleetRuntime, StragglerMonitor
    from repro.sharding.partitioning import activation_mesh, tree_shardings
    from repro.train.optimizer import AdamWConfig, adamw_init
    from repro.train.train_step import TrainSettings, build_train_step

    cfg = get_config(args.arch, reduced=args.reduced)
    mesh_shape = tuple(int(x) for x in args.mesh_shape.split(","))
    ndev = len(jax.devices())
    use_mesh = int(np.prod(mesh_shape)) > 1 and int(np.prod(mesh_shape)) <= ndev

    runtime = FleetRuntime(mesh_shape, ("data", "model"),
                           strategy=args.strategy)
    print(f"[launch] {cfg.name} placement={args.strategy} "
          f"mesh={mesh_shape} endpoints="
          f"{runtime.placement.endpoints.reshape(-1)[:8].tolist()}...")

    settings = TrainSettings(
        microbatches=args.microbatches, remat=False,
        grad_compression=args.grad_compression,
        opt=AdamWConfig(lr_peak=args.lr, warmup_steps=5,
                        total_steps=args.steps),
    )
    specs = M.model_specs(cfg)
    step_fn = build_train_step(cfg, settings)
    data = SyntheticLM(cfg, seed=0)
    ck = Checkpointer(args.ckpt, async_save=True) if args.ckpt else None
    mon = StragglerMonitor()

    def make_mesh_and_jit():
        if use_mesh:
            devs = np.array(jax.devices()[: int(np.prod(mesh_shape))])
            order = runtime.placement.device_order() % len(devs)
            mesh = jax.sharding.Mesh(
                devs[order].reshape(mesh_shape), ("data", "model")
            )
            p_sh = tree_shardings(specs, mesh, "base")

            def wrapped(params, opt, batch):
                with activation_mesh(mesh, "base"):
                    return step_fn(params, opt, batch)

            return mesh, jax.jit(wrapped, donate_argnums=(0, 1))
        return None, jax.jit(step_fn, donate_argnums=(0, 1))

    mesh, jitted = make_mesh_and_jit()
    params = init_params(jax.random.PRNGKey(0), specs)
    opt = adamw_init(params)
    start_step = 0
    if ck and ck.latest_step() is not None:
        (restored, extra) = ck.restore({"params": params, "opt": opt})
        params, opt = restored["params"], restored["opt"]
        data.load_state_dict(extra["data"])
        start_step = extra["step"] + 1
        print(f"[resume] from checkpoint step {extra['step']}")

    losses = []
    for step in range(start_step, args.steps):
        if args.fail_at is not None and step == args.fail_at:
            victim = int(runtime.placement.endpoints.reshape(-1)[0])
            ev = runtime.fail([victim])
            print(f"[fault] endpoint {victim} died -> {ev['action']}; "
                  f"restoring from checkpoint")
            if ck and ck.latest_step() is not None:
                (restored, extra) = ck.restore({"params": params, "opt": opt})
                params, opt = restored["params"], restored["opt"]
                data.load_state_dict(extra["data"])
            mesh, jitted = make_mesh_and_jit()  # re-lower on new placement

        batch = jax.tree_util.tree_map(
            jnp.asarray, data.next_batch(args.batch, args.seq)
        )
        t0 = time.time()
        params, opt, metrics = jitted(params, opt, batch)
        loss = float(metrics["loss"])
        dt = time.time() - t0
        mon.record(0, dt)
        losses.append(loss)
        if step % args.log_every == 0:
            print(f"step {step:5d} loss {loss:.4f} "
                  f"lr {float(metrics['lr']):.2e} "
                  f"gnorm {float(metrics['grad_norm']):.2f} {dt*1e3:.0f}ms")
        if ck and step and step % args.ckpt_every == 0:
            ck.save(step, {"params": params, "opt": opt},
                    extra={"step": step, "data": data.state_dict(),
                           "generation": runtime.job.generation})
    if ck:
        ck.wait()
    print(f"[done] first loss {losses[0]:.4f} -> last {losses[-1]:.4f}")
    return losses


if __name__ == "__main__":
    main()
