"""Static analyzer for optimized HLO text — the dry-run 'profiler'.

XLA:CPU's ``compiled.cost_analysis()`` counts while-loop bodies ONCE, which
under-reports every scanned quantity (layers, microbatches, attention
chunks) by its trip count.  This module parses the optimized HLO module,
propagates ``known_trip_count`` multipliers through nested while loops, and
produces trip-aware totals:

  * ``flops``       — 2*M*N*K over every dot (the MXU term),
  * ``bytes``       — HBM traffic: operand+result bytes of top-level
                      instructions in executed computations (fusion bodies
                      are on-chip and excluded; dynamic-update-slice counts
                      the update, not the aliased buffer),
  * ``coll_bytes``  — operand bytes of all-reduce / all-gather /
                      reduce-scatter / all-to-all / collective-permute,
                      by kind and in total.

It is also the §Perf profiling tool: ``per_computation`` breaks each term
down by (computation x op kind) so hillclimbs can see exactly which scanned
region owns the dominant roofline term.
"""

from __future__ import annotations

import dataclasses
import json
import re
from collections import defaultdict

_DTYPE_BYTES = {
    "pred": 1, "s4": 1, "u4": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2,
    "bf16": 2, "f16": 2, "f8e4m3fn": 1, "f8e5m2": 1,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8,
    "c64": 8, "c128": 16, "token": 0,
}

_SHAPE_RE = re.compile(r"\b([a-z][a-z0-9]*)\[([0-9,]*)\]")
_INSTR_RE = re.compile(
    r"^\s*(?:ROOT\s+)?%([\w.\-]+)\s*=\s*(.*?)\s*([a-z][a-zA-Z0-9\-]*)\("
)
_COMP_RE = re.compile(r"^(ENTRY\s+)?%?([\w.\-]+)\s*(?:\(.*\))?\s*->.*{\s*$")
_OPERAND_RE = re.compile(r"%([\w.\-]+)")
_TRIP_RE = re.compile(r'"known_trip_count":\{"n":"(\d+)"\}')
_CALLS_RE = re.compile(r"\b(?:calls|to_apply|body|condition)=%?([\w.\-]+)")

COLLECTIVES = (
    "all-reduce", "all-gather", "reduce-scatter", "all-to-all",
    "collective-permute",
)
_SKIP_BYTES = {
    "parameter", "constant", "get-tuple-element", "tuple", "bitcast",
    "after-all", "while", "conditional", "call", "partition-id",
    "replica-id", "get-dimension-size",
}


def _type_bytes(type_str: str) -> int:
    total = 0
    for dt, dims in _SHAPE_RE.findall(type_str):
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES.get(dt, 4)
    return total


def _type_dims(type_str: str) -> list[int]:
    m = _SHAPE_RE.search(type_str)
    if not m:
        return []
    return [int(d) for d in m.group(2).split(",") if d]


@dataclasses.dataclass
class Instr:
    name: str
    type_str: str
    opcode: str
    operands: list
    line: str

    @property
    def bytes(self) -> int:
        return _type_bytes(self.type_str)


@dataclasses.dataclass
class Comp:
    name: str
    instrs: dict
    order: list


def parse_hlo(text: str):
    comps: dict[str, Comp] = {}
    entry = None
    cur = None
    for line in text.splitlines():
        if cur is None:
            m = _COMP_RE.match(line)
            # headers never contain a spaced assignment (instruction lines
            # do); '=' alone also appears in /*index=5*/ type comments.
            if m and " = " not in line.split(" {")[0]:
                cur = Comp(m.group(2), {}, [])
                if m.group(1):
                    entry = m.group(2)
            continue
        if line.startswith("}"):
            comps[cur.name] = cur
            cur = None
            continue
        m = _INSTR_RE.match(line)
        if m:
            name, type_str, opcode = m.groups()
            # operands: inside the balanced parens right after the opcode
            start = m.end() - 1
            depth, end = 0, len(line)
            for i in range(start, len(line)):
                if line[i] == "(":
                    depth += 1
                elif line[i] == ")":
                    depth -= 1
                    if depth == 0:
                        end = i
                        break
            operands = _OPERAND_RE.findall(line[start:end + 1])
            ins = Instr(name, type_str, opcode, operands, line)
            cur.instrs[name] = ins
            cur.order.append(name)
    return comps, entry


def _operand_bytes(ins: Instr, comp: Comp, global_idx) -> int:
    total = 0
    for op in ins.operands:
        src = comp.instrs.get(op) or global_idx.get(op)
        if src is not None:
            total += src.bytes
    return total


def _dot_flops(ins: Instr, comp: Comp, global_idx) -> float:
    out_elems = 1
    for d in _type_dims(ins.type_str):
        out_elems *= d
    m = re.search(r"lhs_contracting_dims=\{([0-9,]*)\}", ins.line)
    contract = 1
    if m and ins.operands:
        lhs = comp.instrs.get(ins.operands[0]) or global_idx.get(ins.operands[0])
        if lhs is not None:
            dims = _type_dims(lhs.type_str)
            for i in m.group(1).split(","):
                if i and int(i) < len(dims):
                    contract *= dims[int(i)]
    return 2.0 * out_elems * contract


def _fusion_bytes(ins: Instr, comp: Comp, comps, global_idx) -> int:
    """HBM traffic of one fusion: effective operand bytes + result bytes.

    Refinements over naive operand+result:
      * a fused parameter consumed ONLY by dynamic-slice ops contributes
        the slice size (e.g. one layer's weights gathered from the stacked
        scan buffer), not the whole buffer;
      * a fusion whose root is dynamic-update-slice writes the update (the
        buffer is aliased in place).
    """
    called = _CALLS_RE.findall(ins.line)
    fc = comps.get(called[0]) if called else None
    if fc is None:
        return _operand_bytes(ins, comp, global_idx) + ins.bytes
    # map fused parameter index -> effective bytes
    users = defaultdict(list)
    for iname in fc.order:
        fi = fc.instrs[iname]
        for op in fi.operands:
            users[op].append(fi)
    # fused dynamic-update-slices whose buffer operand flows straight from
    # a parameter of the fusion's own output shape are in-place on the
    # aliased buffer (XLA buffer assignment): traffic = 2 x update slice.
    dus_params = {}
    dus_updates = 0
    for iname in fc.order:
        fi = fc.instrs[iname]
        if fi.opcode != "dynamic-update-slice" or not fi.operands:
            continue
        buf = fc.instrs.get(fi.operands[0])
        # the buffer may pass through convert/bitcast wrappers
        hops = 0
        while buf is not None and buf.opcode in ("convert", "bitcast", "copy") \
                and buf.operands and hops < 3:
            buf = fc.instrs.get(buf.operands[0])
            hops += 1
        upd = fc.instrs.get(fi.operands[1]) if len(fi.operands) > 1 else None
        if buf is not None and buf.opcode == "parameter" and \
                _type_dims(buf.type_str) == _type_dims(ins.type_str):
            dus_params[buf.name] = True
            dus_updates += upd.bytes if upd is not None else 0

    eff = []
    for iname in fc.order:
        fi = fc.instrs[iname]
        if fi.opcode != "parameter":
            continue
        if fi.name in dus_params:
            eff.append(0)  # aliased in place; counted via dus_updates
            continue
        us = users.get(fi.name, [])
        if us and all(u.opcode == "dynamic-slice" for u in us):
            eff.append(sum(u.bytes for u in us))
        else:
            eff.append(fi.bytes)
    total_in = sum(eff)
    out_b = 2 * dus_updates if dus_params else ins.bytes
    return total_in + out_b


def analyze(text: str) -> dict:
    comps, entry = parse_hlo(text)
    global_idx = {}
    for c in comps.values():
        for ins in c.instrs.values():
            global_idx.setdefault(ins.name, ins)

    # computations reachable as fusion bodies are on-chip: excluded from the
    # top-level walk (we walk entry + while/call/cond bodies explicitly)
    flops = 0.0
    byts = 0.0
    coll = defaultdict(float)
    coll_n = defaultdict(int)
    per_comp = defaultdict(lambda: {"flops": 0.0, "bytes": 0.0, "coll": 0.0,
                                    "mult": 0})

    def visit(comp_name: str, mult: float, seen):
        comp = comps.get(comp_name)
        if comp is None:
            return
        nonlocal flops, byts
        pc = per_comp[comp_name]
        pc["mult"] += mult
        for name in comp.order:
            ins = comp.instrs[name]
            op = ins.opcode
            if op == "while":
                trips = 1
                mt = _TRIP_RE.search(ins.line)
                if mt:
                    trips = int(mt.group(1))
                refs = _CALLS_RE.findall(ins.line)
                for r in refs:
                    visit(r, mult * trips, seen)
                continue
            if op in ("call", "conditional"):
                for r in _CALLS_RE.findall(ins.line):
                    visit(r, mult, seen)
                continue
            if op == "fusion":
                fb = _fusion_bytes(ins, comp, comps, global_idx)
                called = _CALLS_RE.findall(ins.line)
                for cn in called:
                    fc = comps.get(cn)
                    if fc is None:
                        continue
                    # dots inside fusions still execute on the MXU
                    for iname in fc.order:
                        fi = fc.instrs[iname]
                        if fi.opcode == "dot":
                            df = _dot_flops(fi, fc, global_idx) * mult
                            flops += df
                            pc["flops"] += df
                byts += fb * mult
                pc["bytes"] += fb * mult
                continue
            is_coll = next((c for c in COLLECTIVES
                            if op == c or op == c + "-start"), None)
            if is_coll:
                cb = _operand_bytes(ins, comp, global_idx)
                coll[is_coll] += cb * mult
                coll_n[is_coll] += int(mult)
                pc["coll"] += cb * mult
                byts += (cb + ins.bytes) * mult
                pc["bytes"] += (cb + ins.bytes) * mult
                continue
            if op == "dot":
                df = _dot_flops(ins, comp, global_idx) * mult
                flops += df
                pc["flops"] += df
                b = (_operand_bytes(ins, comp, global_idx) + ins.bytes) * mult
                byts += b
                pc["bytes"] += b
                continue
            if op in _SKIP_BYTES or op.endswith("-done"):
                continue
            if op in ("dynamic-update-slice",):
                upd = (comp.instrs.get(ins.operands[1]) or
                       global_idx.get(ins.operands[1])) if len(ins.operands) > 1 else None
                b = 2 * (upd.bytes if upd else 0) * mult
            elif op == "dynamic-slice":
                b = 2 * ins.bytes * mult
            else:
                b = (_operand_bytes(ins, comp, global_idx) + ins.bytes) * mult
            byts += b
            pc["bytes"] += b

    visit(entry, 1.0, set())
    return {
        "flops": flops,
        "bytes": byts,
        "coll_bytes": float(sum(coll.values())),
        "coll_breakdown": dict(coll),
        "coll_counts": dict(coll_n),
        "per_computation": {
            k: v for k, v in sorted(
                per_comp.items(), key=lambda kv: -max(
                    kv[1]["flops"] / 197e12, kv[1]["bytes"] / 819e9)
            )[:12]
        },
        "entry": entry,
    }


def main():
    import sys

    with open(sys.argv[1]) as f:
        out = analyze(f.read())
    out.pop("per_computation")
    print(json.dumps(out, indent=2))


if __name__ == "__main__":
    main()
