"""In-sim telemetry probes: static spec, device accumulators, host view.

The probe layer has three pieces, mirroring the engine's static/dynamic
split:

  * :class:`TelemetrySpec` — a frozen, hashable description of *what* to
    accumulate (window count / length, latency bins).  It is part of the
    engine compile key (``SimEngine(..., telemetry=spec)`` /
    ``get_engine``'s memo key), so enabling telemetry builds a *different*
    jitted step — the default ``telemetry=None`` engine is byte-for-byte
    the pre-telemetry kernel: identical trace counts and bit-identical
    outputs (pinned in ``tests/test_obs.py``).
  * :class:`TelemetryState` — the device accumulators, a NamedTuple pytree
    that rides in the ``lax.while_loop`` carry next to ``SimState``.  Every
    leaf has a static shape derived from the spec + static tables, so
    telemetry survives ``vmap`` / ``shard_map`` lanes exactly like the
    base outputs (``run_grid`` just gains extra leading batch axes).
  * :class:`Telemetry` — the host-side view attached to
    ``SimResult.telemetry``: numpy arrays plus derived accessors
    (per-link / per-dimension utilization, hottest links, occupancy
    histograms, latency series) and a compact JSON-able :meth:`summary`
    for the trace log.

Window semantics: cycle ``t`` lands in window ``min(t // window,
n_windows - 1)`` — the last window absorbs any overflow past
``n_windows * window`` cycles, and the per-window ``cycles`` counter
records how many cycles actually accumulated there, so normalisation is
exact even for the partial final window.
"""

from __future__ import annotations

import dataclasses
from typing import NamedTuple

import jax.numpy as jnp
import numpy as np

I32 = jnp.int32


@dataclasses.dataclass(frozen=True)
class TelemetrySpec:
    """Static description of the in-sim probes (part of the compile key).

    n_windows — number of time windows in every windowed series;
    window    — packet-times per window (last window absorbs overflow);
    lat_bins  — log2 buckets of the ejection-latency histogram
                (bin b counts latencies in [2^b, 2^(b+1)), clamped).
    """

    n_windows: int = 64
    window: int = 256
    lat_bins: int = 16

    def __post_init__(self):
        if self.n_windows < 1 or self.window < 1 or self.lat_bins < 1:
            raise ValueError(f"degenerate TelemetrySpec {self}")


class TelemetryState(NamedTuple):
    """Device accumulators (W = n_windows; all int32 unless noted)."""

    link_util: jnp.ndarray    # (W, S, OUT) grants per output port per window
    vc_occ: jnp.ndarray       # (W, P*(CAP+1)) per-pool occupancy histogram,
                              # one sample of every queue per cycle
    deroutes: jnp.ndarray     # (W,) non-minimal moves granted
    escalations: jnp.ndarray  # (W,) forced fault-escape deroutes granted
    inflight: jnp.ndarray     # (W,) sum over cycles of in-network packets
    cycles: jnp.ndarray       # (W,) cycles accumulated into each window
    injected: jnp.ndarray     # (W,) packets injected
    delivered: jnp.ndarray    # (W,) target packets delivered
    lat_sum: jnp.ndarray      # (W,) float32 latency sum of deliveries
    lat_hist: jnp.ndarray     # (lat_bins,) log2 ejection-latency histogram
    epoch_flips: jnp.ndarray  # (W,) fault-epoch transitions observed
    dead_links: jnp.ndarray   # (W,) sum over cycles of dead directed links


def init_telemetry(
    spec: TelemetrySpec, S: int, OUT: int, P: int, CAP: int
) -> TelemetryState:
    """Zeroed accumulators for one run (shapes static under jit)."""
    W = spec.n_windows

    def z(shape, dtype=I32):
        return jnp.zeros(shape, dtype=dtype)

    return TelemetryState(
        link_util=z((W, S, OUT)),
        vc_occ=z((W, P * (CAP + 1))),
        deroutes=z(W),
        escalations=z(W),
        inflight=z(W),
        cycles=z(W),
        injected=z(W),
        delivered=z(W),
        lat_sum=z(W, dtype=jnp.float32),
        lat_hist=z(spec.lat_bins),
        epoch_flips=z(W),
        dead_links=z(W),
    )


@dataclasses.dataclass(frozen=True, eq=False)
class Telemetry:
    """Host-side telemetry view (attached to ``SimResult.telemetry``).

    Arrays are numpy; ``q``/``n``/``conc`` and the ``port_dim``/``port_val``
    maps come from the engine's static tables so links can be named.
    Network output ports are ``0 .. q*n-1``; ports ``q*n .. OUT-1`` are
    ejection ports (utilization accessors exclude them unless asked).
    """

    spec: TelemetrySpec
    S: int
    OUT: int
    P: int
    CAP: int
    q: int
    n: int
    conc: int
    port_dim: np.ndarray      # (q*n,) dimension addressed by each net port
    port_val: np.ndarray      # (q*n,) coordinate value addressed
    link_util: np.ndarray     # (W, S, OUT)
    vc_occ: np.ndarray        # (W, P, CAP+1)
    deroutes: np.ndarray      # (W,)
    escalations: np.ndarray   # (W,)
    inflight: np.ndarray      # (W,)
    cycles: np.ndarray        # (W,)
    injected: np.ndarray      # (W,)
    delivered: np.ndarray     # (W,)
    lat_sum: np.ndarray       # (W,)
    lat_hist: np.ndarray      # (lat_bins,)
    epoch_flips: np.ndarray   # (W,)
    dead_links: np.ndarray    # (W,)

    # ------------------------------------------------------------- derived
    @property
    def total_cycles(self) -> int:
        return int(self.cycles.sum())

    @property
    def net_ports(self) -> int:
        return self.q * self.n

    def link_utilization(self, include_ejection: bool = False) -> np.ndarray:
        """(S, ports) fraction of cycles each output link carried a packet
        (sustained rate is 1 pkt/cycle; the 2x crossbar speedup can push
        individual windows slightly above 1)."""
        tot = max(self.total_cycles, 1)
        util = self.link_util.sum(axis=0) / tot
        return util if include_ejection else util[:, : self.net_ports]

    def link_series(self) -> np.ndarray:
        """(W, S, net_ports) per-window network-link utilization."""
        cyc = np.maximum(self.cycles, 1)[:, None, None]
        return self.link_util[:, :, : self.net_ports] / cyc

    def dim_utilization(self) -> np.ndarray:
        """(q,) mean network-link utilization per HyperX dimension."""
        util = self.link_utilization()
        return np.asarray([
            util[:, self.port_dim == d].mean() for d in range(self.q)
        ])

    def hottest_links(self, k: int = 5) -> list[dict]:
        """Top-k network links by total grants, as labelled rows."""
        util = self.link_utilization()
        grants = self.link_util.sum(axis=0)[:, : self.net_ports]
        flat = np.argsort(util, axis=None)[::-1][:k]
        rows = []
        for f in flat:
            s, p = int(f // self.net_ports), int(f % self.net_ports)
            rows.append({
                "switch": s,
                "port": p,
                "dim": int(self.port_dim[p]),
                "val": int(self.port_val[p]),
                "grants": int(grants[s, p]),
                "util": round(float(util[s, p]), 4),
            })
        return rows

    def queue_occupancy(self) -> np.ndarray:
        """(P, CAP+1) occupancy histogram summed over all windows."""
        return self.vc_occ.sum(axis=0)

    def mean_inflight(self) -> np.ndarray:
        """(W,) mean in-network packet population per window."""
        return self.inflight / np.maximum(self.cycles, 1)

    def mean_dead_links(self) -> np.ndarray:
        """(W,) mean dead directed-link count per window."""
        return self.dead_links / np.maximum(self.cycles, 1)

    def mean_latency(self) -> np.ndarray:
        """(W,) mean delivery latency per window (NaN where idle)."""
        with np.errstate(invalid="ignore", divide="ignore"):
            return np.where(
                self.delivered > 0, self.lat_sum / np.maximum(self.delivered, 1),
                np.nan,
            )

    # ------------------------------------------------------------- summary
    def summary(self, label: str = "", top_links: int = 32) -> dict:
        """Compact JSON-able digest for the trace event log."""
        util = self.link_utilization()
        series = self.link_series().mean(axis=(1, 2))
        occ = self.queue_occupancy()
        return {
            "label": label,
            "cycles": self.total_cycles,
            "windows": int((self.cycles > 0).sum()),
            "window_len": self.spec.window,
            "util_mean": round(float(util.mean()), 5),
            "util_max": round(float(util.max()), 5),
            "dim_util": [round(float(u), 5) for u in self.dim_utilization()],
            "util_series": [round(float(u), 5) for u in series],
            "top_links": self.hottest_links(top_links),
            "occ_hist": occ.astype(int).tolist(),
            "inflight_mean": [round(float(x), 2) for x in self.mean_inflight()],
            "deroutes": int(self.deroutes.sum()),
            "escalations": int(self.escalations.sum()),
            "injected": int(self.injected.sum()),
            "delivered": int(self.delivered.sum()),
            "lat_hist": self.lat_hist.astype(int).tolist(),
            "epoch_flips": int(self.epoch_flips.sum()),
            "dead_links_mean": round(float(self.mean_dead_links().mean()), 3),
            "lat_mean": round(
                float(self.lat_sum.sum()) / max(int(self.delivered.sum()), 1), 3
            ),
        }


def to_host(tel: TelemetryState, spec: TelemetrySpec, st) -> Telemetry:
    """Materialise device accumulators into the host view.

    ``st`` is the engine's :class:`~repro.core.engine.tables.StaticTables`
    (duck-typed here to avoid an import cycle: obs must not import the
    engine at module scope)."""
    return Telemetry(
        spec=spec, S=st.S, OUT=st.OUT, P=st.P, CAP=st.CAP,
        q=st.q, n=st.n, conc=st.conc,
        port_dim=np.asarray(st.port_dim, dtype=np.int64),
        port_val=np.asarray(st.port_val, dtype=np.int64),
        link_util=np.asarray(tel.link_util),
        vc_occ=np.asarray(tel.vc_occ).reshape(
            spec.n_windows, st.P, st.CAP + 1
        ),
        deroutes=np.asarray(tel.deroutes),
        escalations=np.asarray(tel.escalations),
        inflight=np.asarray(tel.inflight),
        cycles=np.asarray(tel.cycles),
        injected=np.asarray(tel.injected),
        delivered=np.asarray(tel.delivered),
        lat_sum=np.asarray(tel.lat_sum),
        lat_hist=np.asarray(tel.lat_hist),
        epoch_flips=np.asarray(tel.epoch_flips),
        dead_links=np.asarray(tel.dead_links),
    )
