"""Persistent event store: append-aware tailing + bounded windowed rollups.

An :class:`EventStore` ingests one or more trace directories (the
``events.jsonl`` + ``manifest.json`` layout :mod:`repro.obs.trace` runs
write) *incrementally*:

  * **Tailing.**  A per-file byte offset marks how far each
    ``events.jsonl`` has been consumed; :meth:`EventStore.poll` reads only
    newly appended **complete** lines.  A truncated final line — a live
    writer mid-``write`` or a crashed run — is left un-consumed until its
    newline arrives, so a follower and a one-shot reader of the finished
    file fold the exact same event sequence (the parity the watcher pins).
  * **Run keying.**  Every ``trace.start`` event opens a new run keyed
    ``<dir-basename>/<run_id>`` (append-mode logs may hold several runs);
    ``config_hash`` joins from the directory's manifest.
  * **Rollups.**  Events compact into per-run :class:`RunRollup`s:
    fixed-width sim-time windows of scheduler counters and
    fragmentation/queue gauges per stream (the last window absorbs
    overflow, mirroring ``TelemetrySpec``), link/dimension-utilization
    digests from ``sim.telemetry`` events, heartbeat cadence,
    ``bench.module`` wall-time gauges, and ``obs.alert`` records — so a
    thousands-of-jobs trace replays and resumes in memory proportional to
    the window count, never the event count.
  * **Checkpoints.**  With ``checkpoint_dir`` set, the whole store state
    (rollups + tail offsets + subscriber rule state) snapshots through
    :class:`repro.checkpoint.Checkpointer` every ``checkpoint_every``
    consumed events, *inside* the consume loop — a killed ingest resumes
    from the last committed snapshot and re-derives byte-identical rollup
    CSVs (pinned by a kill-and-resume test), and a restored store answers
    insights queries without re-reading the raw event log.

Rollup CSVs (:meth:`EventStore.write_csvs`) are pure functions of the
consumed event sequence: iteration is sorted, accumulation is sequential,
rounding happens only at render time.
"""

from __future__ import annotations

import dataclasses
import json
import os
import pickle

import numpy as np

_CHUNK = 1 << 20  # tail-read granularity (bounds memory on huge backlogs)

# scheduler event kinds folded into windowed counters (column order is the
# CSV contract) and the remainder tracked as totals only
_WINDOW_KINDS = ("arrive", "start", "depart", "fail", "migrate", "requeue")
_TOTAL_KINDS = _WINDOW_KINDS + (
    "repair", "evict", "giveup", "degrade", "straggle", "requeue",
    "checkpoint", "resume", "heartbeat", "summary",
)


@dataclasses.dataclass(frozen=True)
class StoreSpec:
    """Static rollup shape (sim-time window width / count, link top-k)."""

    window: float = 20.0
    n_windows: int = 64
    top_links: int = 10

    def __post_init__(self):
        if self.window <= 0 or self.n_windows < 1 or self.top_links < 1:
            raise ValueError(f"degenerate StoreSpec {self}")

    def window_of(self, t_sim: float) -> int:
        return min(int(t_sim // self.window), self.n_windows - 1)


class _StreamRollup:
    """Windowed counters + gauges for one scheduler stream of one run."""

    def __init__(self, spec: StoreSpec):
        W = spec.n_windows
        self.counts = {k: [0] * W for k in _WINDOW_KINDS}
        self.frag_sum = [0.0] * W
        self.frag_cnt = [0] * W
        self.frag_max = [0.0] * W
        self.queued_sum = [0.0] * W
        self.running_sum = [0.0] * W
        self.totals = {k: 0 for k in _TOTAL_KINDS}
        self.last_frag = 0.0
        self.last_queued = 0
        self.last_running = 0
        self.summary: dict = {}

    def fold(self, spec: StoreSpec, kind: str, ev: dict):
        if kind in self.totals:
            self.totals[kind] += 1
        t_sim = ev.get("t_sim")
        w = spec.window_of(float(t_sim)) if t_sim is not None else None
        if kind in self.counts and w is not None:
            self.counts[kind][w] += 1
        if kind == "frag" and w is not None:
            v = float(ev.get("value", 0.0))
            self.frag_sum[w] += v
            self.frag_cnt[w] += 1
            self.frag_max[w] = max(self.frag_max[w], v)
            self.queued_sum[w] += float(ev.get("queued", 0))
            self.running_sum[w] += float(ev.get("running", 0))
            self.last_frag = v
            self.last_queued = int(ev.get("queued", 0))
            self.last_running = int(ev.get("running", 0))
        elif kind == "summary":
            self.summary = {
                k: ev[k] for k in (
                    "jobs", "span", "utilization", "frag_mean", "frag_max",
                    "mean_queue", "snapshots",
                ) if k in ev
            }


class RunRollup:
    """Everything the store keeps about one run (bounded, picklable)."""

    def __init__(self, key: str, spec: StoreSpec, trace_dir: str = "",
                 config_hash: str = ""):
        self.key = key
        self.spec = spec
        self.trace_dir = trace_dir
        self.config_hash = config_hash
        self.events = 0
        self.ended = False
        self.last_t = 0.0           # wall seconds since the run's trace start
        self.streams: dict[str, _StreamRollup] = {}
        self.telemetry: dict[str, dict] = {}   # label -> last digest scalars
        self.links: dict[str, list[dict]] = {}  # label -> top-k link rows
        self.bench: dict[str, float] = {}      # module -> wall seconds
        self.heartbeats = 0
        self.last_heartbeat_t: float | None = None
        self.max_heartbeat_gap = 0.0
        self.alerts = 0

    def _stream(self, name: str) -> _StreamRollup:
        sr = self.streams.get(name)
        if sr is None:
            sr = self.streams[name] = _StreamRollup(self.spec)
        return sr

    # ------------------------------------------------------------- folding
    def fold(self, ev: dict):
        self.events += 1
        self.last_t = float(ev.get("t", self.last_t))
        name = str(ev.get("name", ""))
        if name == "trace.end":
            self.ended = True
        elif name == "sched.heartbeat":
            t = float(ev.get("t", 0.0))
            if self.last_heartbeat_t is not None:
                self.max_heartbeat_gap = max(
                    self.max_heartbeat_gap, t - self.last_heartbeat_t
                )
            self.last_heartbeat_t = t
            self.heartbeats += 1
            self._stream(str(ev.get("stream", "-"))).totals["heartbeat"] += 1
        elif name.startswith("sched."):
            kind = name.split(".", 1)[1]
            self._stream(str(ev.get("stream", "-"))).fold(self.spec, kind, ev)
        elif ev.get("type") == "telemetry":
            label = str(ev.get("label", ""))
            self.telemetry[label] = {
                k: ev[k] for k in (
                    "cycles", "util_mean", "util_max", "deroutes",
                    "escalations", "injected", "delivered", "lat_mean",
                    "epoch_flips", "dead_links_mean",
                ) if k in ev
            }
            self.telemetry[label]["dim_util"] = "|".join(
                str(u) for u in ev.get("dim_util", [])
            )
            self.links[label] = [
                dict(row) for row in ev.get("top_links", [])[: self.spec.top_links]
            ]
        elif name == "bench.module" and ev.get("type") == "gauge":
            self.bench[str(ev.get("module", ""))] = float(ev.get("value", 0.0))


class EventStore:
    """Ingests trace dirs into rollups; optionally checkpointed + persistent.

    ``store_dir`` (optional) is the store's own directory: fired alerts are
    appended to ``<store_dir>/alerts.jsonl`` there (rewritten from state on
    resume, so the log matches the rollups).  ``subscribe(fn)`` registers a
    per-event callback ``fn(run_key, event_dict)`` — the watcher's alert
    rules hang here; callbacks may stash picklable state in
    :attr:`extra_state`, which rides inside every checkpoint so rule
    hysteresis survives a kill exactly like the rollups do.
    """

    def __init__(
        self,
        spec: StoreSpec | None = None,
        store_dir: str | None = None,
        checkpoint_dir: str | None = None,
        checkpoint_every: int = 1000,
        resume: bool = False,
    ):
        self.spec = spec or StoreSpec()
        self.dir = store_dir
        self.tails: dict[str, _Tail] = {}
        self.runs: dict[str, RunRollup] = {}
        self.alerts: list[dict] = []
        self.total_events = 0
        self.extra_state: dict = {}
        self._subs: list = []
        self._ckpt = None
        self.checkpoint_every = max(int(checkpoint_every), 1)
        self.restored = False
        if store_dir is not None:
            os.makedirs(store_dir, exist_ok=True)
            if checkpoint_dir is None:
                checkpoint_dir = os.path.join(store_dir, "ckpt")
        if checkpoint_dir is not None:
            from repro.checkpoint import Checkpointer

            self._ckpt = Checkpointer(checkpoint_dir)
            if resume and self._ckpt.latest_step() is not None:
                blob, _extra = self._ckpt.restore({"pickle": None})
                state = pickle.loads(
                    np.asarray(blob["pickle"], dtype=np.uint8).tobytes()
                )
                self.spec = state["spec"]
                self.tails = state["tails"]
                self.runs = state["runs"]
                self.alerts = state["alerts"]
                self.total_events = state["total_events"]
                self.extra_state = state["extra_state"]
                self.restored = True
                self._rewrite_alert_log()

    # ---------------------------------------------------------- directories
    def add_dir(self, trace_dir: str):
        """Register a trace directory for tailing (idempotent)."""
        trace_dir = os.path.abspath(trace_dir)
        if trace_dir not in self.tails:
            self.tails[trace_dir] = _Tail(
                path=os.path.join(trace_dir, "events.jsonl"),
                base=os.path.basename(trace_dir.rstrip(os.sep)) or trace_dir,
            )

    def ingest(self, *trace_dirs: str) -> int:
        """Register directories and consume everything currently readable."""
        for d in trace_dirs:
            self.add_dir(d)
        return self.poll()

    def subscribe(self, fn):
        self._subs.append(fn)

    # -------------------------------------------------------------- tailing
    def poll(self) -> int:
        """Consume newly appended complete lines from every registered dir.

        Returns the number of events folded this call.  A final line with
        no trailing newline is never consumed (its offset stays put), so a
        crashed writer's torn tail is invisible rather than fatal.
        """
        n = 0
        for d in sorted(self.tails):
            n += self._poll_tail(d, self.tails[d])
        return n

    def _poll_tail(self, trace_dir: str, tail: "_Tail") -> int:
        try:
            size = os.path.getsize(tail.path)
        except OSError:
            return 0
        if size < tail.offset:
            tail.offset = 0  # file was replaced/truncated: replay it
        if size == tail.offset:
            return 0
        n = 0
        with open(tail.path, "rb") as f:
            f.seek(tail.offset)
            carry = b""
            while True:
                buf = f.read(_CHUNK)
                if not buf:
                    break
                carry += buf
                while True:
                    nl = carry.find(b"\n")
                    if nl < 0:
                        break
                    line, carry = carry[:nl], carry[nl + 1:]
                    tail.offset += nl + 1
                    n += self._consume_line(trace_dir, tail, line)
        return n

    def _consume_line(self, trace_dir: str, tail: "_Tail", line: bytes) -> int:
        line = line.strip()
        if not line:
            return 0
        try:
            ev = json.loads(line)
        except (json.JSONDecodeError, UnicodeDecodeError):
            return 0  # offset already advanced: corrupt lines are skipped
        if not isinstance(ev, dict):
            return 0
        run = self._route(trace_dir, tail, ev)
        run.fold(ev)
        key = run.key
        for fn in self._subs:
            fn(key, ev)
        self.total_events += 1
        if self._ckpt is not None \
                and self.total_events % self.checkpoint_every == 0:
            self.save_checkpoint()
        return 1

    def _route(self, trace_dir: str, tail: "_Tail", ev: dict) -> RunRollup:
        if ev.get("name") == "trace.start":
            tail.runs_seen += 1
            rid = str(ev.get("run_id") or f"run{tail.runs_seen}")
            tail.run_key = f"{tail.base}/{rid}"
        if not tail.run_key:  # events before any trace.start
            tail.run_key = f"{tail.base}/-"
        run = self.runs.get(tail.run_key)
        if run is None:
            run = self.runs[tail.run_key] = RunRollup(
                tail.run_key, self.spec, trace_dir=trace_dir,
                config_hash=_manifest_hash(trace_dir),
            )
        return run

    def ended(self) -> bool:
        """True once every registered dir's *current* run saw trace.end."""
        return bool(self.tails) and all(
            t.run_key and self.runs[t.run_key].ended
            for t in self.tails.values() if t.run_key
        ) and all(t.run_key for t in self.tails.values())

    # --------------------------------------------------------------- alerts
    def record_alert(self, run_key: str, rule: str, value, threshold,
                     t: float, **attrs):
        """Append one ``obs.alert`` into the store (rollups + durable log)."""
        alert = {"type": "alert", "name": "obs.alert", "run": run_key,
                 "rule": rule, "value": value, "threshold": threshold,
                 "t": round(float(t), 6)}
        alert.update(attrs)
        self.alerts.append(alert)
        run = self.runs.get(run_key)
        if run is not None:
            run.alerts += 1
        if self.dir is not None:
            with open(os.path.join(self.dir, "alerts.jsonl"), "a",
                      encoding="utf-8") as f:
                f.write(json.dumps(alert, sort_keys=True) + "\n")
        return alert

    def _rewrite_alert_log(self):
        """On resume: make the durable alert log match the restored state
        (alerts fired after the checkpoint will deterministically re-fire)."""
        if self.dir is None:
            return
        with open(os.path.join(self.dir, "alerts.jsonl"), "w",
                  encoding="utf-8") as f:
            for alert in self.alerts:
                f.write(json.dumps(alert, sort_keys=True) + "\n")

    # ---------------------------------------------------------- checkpoints
    def save_checkpoint(self):
        if self._ckpt is None:
            raise RuntimeError("EventStore has no checkpoint_dir")
        state = {
            "spec": self.spec, "tails": self.tails, "runs": self.runs,
            "alerts": self.alerts, "total_events": self.total_events,
            "extra_state": self.extra_state,
        }
        buf = np.frombuffer(pickle.dumps(state), dtype=np.uint8)
        self._ckpt.save(self.total_events, {"pickle": buf},
                        extra={"events": self.total_events,
                               "runs": len(self.runs)})

    # ---------------------------------------------------------------- views
    def rollup_rows(self) -> dict[str, list[dict]]:
        """Every rollup table as dict rows (the CSV/dashboard contract)."""
        spec = self.spec
        runs_rows, stream_rows, window_rows = [], [], []
        tel_rows, link_rows, bench_rows = [], [], []
        for key in sorted(self.runs):
            run = self.runs[key]
            runs_rows.append({
                "run": key, "config_hash": run.config_hash,
                "events": run.events, "streams": len(run.streams),
                "heartbeats": run.heartbeats,
                "max_heartbeat_gap_s": round(run.max_heartbeat_gap, 3),
                "alerts": run.alerts, "ended": run.ended,
                "last_t": round(run.last_t, 3),
            })
            for sname in sorted(run.streams):
                sr = run.streams[sname]
                row = {"run": key, "stream": sname}
                row.update({
                    {"arrive": "arrived", "start": "started",
                     "depart": "finished", "fail": "failures",
                     "migrate": "migrations", "requeue": "requeues",
                     }.get(k, k): sr.totals[k]
                    for k in ("arrive", "start", "depart", "fail",
                              "migrate", "requeue", "giveup", "degrade",
                              "heartbeat")
                })
                row["frag_last"] = round(sr.last_frag, 4)
                row["queued_last"] = sr.last_queued
                row["running_last"] = sr.last_running
                for k in ("utilization", "frag_mean", "frag_max",
                          "mean_queue"):
                    row[k] = sr.summary.get(k, "")
                stream_rows.append(row)
                for w in range(spec.n_windows):
                    active = sr.frag_cnt[w] or any(
                        sr.counts[k][w] for k in _WINDOW_KINDS
                    )
                    if not active:
                        continue
                    cnt = max(sr.frag_cnt[w], 1)
                    window_rows.append({
                        "run": key, "stream": sname, "window": w,
                        "t_lo": round(w * spec.window, 3),
                        "t_hi": round((w + 1) * spec.window, 3),
                        "arrived": sr.counts["arrive"][w],
                        "started": sr.counts["start"][w],
                        "finished": sr.counts["depart"][w],
                        "failures": sr.counts["fail"][w],
                        "migrations": sr.counts["migrate"][w],
                        "requeues": sr.counts["requeue"][w],
                        "frag_mean": round(sr.frag_sum[w] / cnt, 4),
                        "frag_max": round(sr.frag_max[w], 4),
                        "queued_mean": round(sr.queued_sum[w] / cnt, 3),
                        "running_mean": round(sr.running_sum[w] / cnt, 3),
                    })
            for label in sorted(run.telemetry):
                tel_rows.append({"run": key, "label": label,
                                 **run.telemetry[label]})
                for link in run.links.get(label, []):
                    link_rows.append({"run": key, "label": label, **link})
            for module in sorted(run.bench):
                bench_rows.append({"run": key, "module": module,
                                   "seconds": round(run.bench[module], 4)})
        alert_rows = [
            {"run": a.get("run", ""), "rule": a.get("rule", ""),
             "t": a.get("t", ""), "value": a.get("value", ""),
             "threshold": a.get("threshold", ""),
             "stream": a.get("stream", a.get("label", ""))}
            for a in self.alerts
        ]
        return {
            "runs": runs_rows, "streams": stream_rows,
            "sched_windows": window_rows, "telemetry": tel_rows,
            "links": link_rows, "alerts": alert_rows, "bench": bench_rows,
        }

    def write_csvs(self, out_dir: str) -> dict[str, str]:
        """Write every non-empty rollup table to ``out_dir``; returns paths.

        Byte-identical across kill-and-resume and one-shot-vs-follow (the
        tests pin both): rows derive only from folded state.
        """
        from repro.obs.report import csv_text

        os.makedirs(out_dir, exist_ok=True)
        written = {}
        for name, rows in self.rollup_rows().items():
            if not rows:
                continue
            path = os.path.join(out_dir, f"{name}.csv")
            with open(path, "w", newline="") as f:
                f.write(csv_text(rows))
            written[name] = path
        return written

    def status_line(self) -> str:
        """One-line rolling gauge digest (the watcher's follow output)."""
        parts = [f"events={self.total_events} runs={len(self.runs)} "
                 f"alerts={len(self.alerts)}"]
        for key in sorted(self.runs):
            run = self.runs[key]
            for sname in sorted(run.streams):
                sr = run.streams[sname]
                parts.append(
                    f"{sname}[run={sr.last_running} q={sr.last_queued} "
                    f"frag={sr.last_frag:.2f}]"
                )
        return " ".join(parts)


@dataclasses.dataclass
class _Tail:
    """Per-file tailing state (picklable; rides in the checkpoint)."""

    path: str
    base: str
    offset: int = 0
    run_key: str = ""
    runs_seen: int = 0


def _manifest_hash(trace_dir: str) -> str:
    try:
        with open(os.path.join(trace_dir, "manifest.json")) as f:
            return str(json.load(f).get("config_hash", ""))
    except (OSError, json.JSONDecodeError):
        return ""


def open_store(
    trace_dirs=(),
    spec: StoreSpec | None = None,
    store_dir: str | None = None,
    checkpoint_dir: str | None = None,
    checkpoint_every: int = 1000,
    resume: bool = False,
) -> EventStore:
    """Construct (or resume) a store and register ``trace_dirs``."""
    store = EventStore(
        spec=spec, store_dir=store_dir, checkpoint_dir=checkpoint_dir,
        checkpoint_every=checkpoint_every, resume=resume,
    )
    for d in trace_dirs:
        store.add_dir(d)
    return store
