"""Live fleet watcher: follow trace dirs, evaluate alert rules, roll up.

    PYTHONPATH=src python -m repro.obs.watch TRACE_DIR... [--follow]
        [--csv DIR] [--store DIR] [--resume] [--interval S]
        [--util-max X] [--frag X] [--fails N] [--stall S]

A :class:`FleetWatcher` wraps an :class:`~repro.obs.store.EventStore` and
evaluates declarative :class:`AlertRule`\\ s **per consumed event**, so
one-shot mode (consume everything, exit) and follow mode (poll a live
``sched`` / ``resil.stream`` run until its ``trace.end``) produce
*identical* rollups and alerts on the same trace — chunking never changes
the folded sequence (pinned in ``tests/test_obs_store.py``).

Rule kinds (all hysteretic — fire on the below→above crossing, re-arm when
the signal drops back under the threshold):

  * ``util_max``  — a ``sim.telemetry`` digest's ``util_max`` exceeds the
    threshold (a saturating link);
  * ``frag``      — a ``sched.frag`` gauge spikes over the threshold for
    its stream (fragmentation emergency);
  * ``fails``     — every N-th ``sched.fail``/``sched.giveup`` of a run
    (repeated job failures under churn);
  * ``stall``     — the wall-clock gap between consecutive
    ``sched.heartbeat`` events exceeds the threshold (a wedged stream;
    data-driven, so one-shot replay flags historic stalls identically).

Fired alerts append ``obs.alert`` records back into the store (rollup
counters + the durable ``alerts.jsonl``).  Rule hysteresis state lives in
``store.extra_state`` and therefore rides inside every store checkpoint:
a killed-and-resumed watch re-fires exactly the alerts an uninterrupted
one would.
"""

from __future__ import annotations

import argparse
import dataclasses
import os
import sys
import time

from repro.obs.store import EventStore, StoreSpec, open_store


@dataclasses.dataclass(frozen=True)
class AlertRule:
    """One declarative alert: ``kind`` selects the signal, ``threshold``
    the level.  ``name`` labels the fired ``obs.alert`` records."""

    name: str
    kind: str  # "util_max" | "frag" | "fails" | "stall"
    threshold: float

    def __post_init__(self):
        if self.kind not in ("util_max", "frag", "fails", "stall"):
            raise ValueError(f"unknown alert-rule kind {self.kind!r}")
        if self.threshold <= 0:
            raise ValueError(f"alert threshold must be > 0, got {self}")


def default_rules(util_max: float = 0.95, frag: float = 0.75,
                  fails: int = 5, stall: float = 30.0) -> tuple[AlertRule, ...]:
    return (
        AlertRule("util_saturation", "util_max", util_max),
        AlertRule("frag_spike", "frag", frag),
        AlertRule("repeated_failures", "fails", float(fails)),
        AlertRule("stalled_stream", "stall", stall),
    )


class FleetWatcher:
    """Evaluates alert rules over a store's event feed; one-shot or follow."""

    def __init__(self, store: EventStore, rules=None, echo: bool = False,
                 out=None):
        self.store = store
        self.rules = tuple(default_rules() if rules is None else rules)
        self.echo = echo
        self.out = out or sys.stdout
        # hysteresis state lives in the store so checkpoints carry it
        self._state = store.extra_state.setdefault("watch_rules", {})
        store.subscribe(self._on_event)

    # ------------------------------------------------------ rule evaluation
    def _on_event(self, run_key: str, ev: dict):
        name = str(ev.get("name", ""))
        for rule in self.rules:
            if rule.kind == "util_max" and ev.get("type") == "telemetry":
                self._hysteresis(
                    rule, (run_key, rule.name, ev.get("label", "")),
                    float(ev.get("util_max", 0.0)), run_key, ev,
                    label=str(ev.get("label", "")),
                )
            elif rule.kind == "frag" and name == "sched.frag":
                self._hysteresis(
                    rule, (run_key, rule.name, ev.get("stream", "-")),
                    float(ev.get("value", 0.0)), run_key, ev,
                    stream=str(ev.get("stream", "-")),
                )
            elif rule.kind == "fails" and name in ("sched.fail",
                                                   "sched.giveup"):
                key = (run_key, rule.name)
                count = self._state.get(key, 0) + 1
                self._state[key] = count
                if count % max(int(rule.threshold), 1) == 0:
                    self._fire(rule, run_key, count, ev,
                               stream=str(ev.get("stream", "-")))
            elif rule.kind == "stall" and name == "sched.heartbeat":
                key = (run_key, rule.name)
                last = self._state.get(key)
                t = float(ev.get("t", 0.0))
                self._state[key] = t
                if last is not None and t - last > rule.threshold:
                    self._fire(rule, run_key, round(t - last, 3), ev,
                               stream=str(ev.get("stream", "-")))

    def _hysteresis(self, rule: AlertRule, key, value: float, run_key: str,
                    ev: dict, **attrs):
        armed = self._state.get(key, True)
        if value > rule.threshold and armed:
            self._state[key] = False
            self._fire(rule, run_key, value, ev, **attrs)
        elif value <= rule.threshold and not armed:
            self._state[key] = True

    def _fire(self, rule: AlertRule, run_key: str, value, ev: dict, **attrs):
        alert = self.store.record_alert(
            run_key, rule.name, value, rule.threshold,
            t=float(ev.get("t", 0.0)), **attrs,
        )
        if self.echo:
            print(f"# ALERT {rule.name}: {value} > {rule.threshold} "
                  f"({run_key})", file=self.out)
        return alert

    # -------------------------------------------------------------- driving
    def poll(self) -> int:
        return self.store.poll()

    def run_once(self) -> int:
        """Consume everything currently readable (the one-shot mode)."""
        return self.poll()

    def follow(self, interval: float = 0.5, idle_timeout: float | None = None,
               max_wall: float | None = None) -> int:
        """Poll until every followed run ends (or goes idle/time-bounded).

        Returns total events consumed.  Termination: all current runs saw
        ``trace.end``; OR no new events for ``idle_timeout`` seconds; OR
        ``max_wall`` seconds elapsed.  A wall-clock-quiet *live* stream is
        reported on stderr but never folded into rollups — rollups stay a
        pure function of the event stream (the one-shot parity pin).
        """
        total = 0
        idle = 0.0
        t0 = time.monotonic()
        while True:
            n = self.poll()
            total += n
            if n and self.echo:
                print(f"# watch: {self.store.status_line()}", file=self.out)
            if self.store.ended():
                break
            if n == 0:
                idle += interval
                if idle_timeout is not None and idle >= idle_timeout:
                    print(f"# watch: idle for {idle:.1f}s, stopping "
                          f"(no trace.end seen)", file=sys.stderr)
                    break
            else:
                idle = 0.0
            if max_wall is not None and time.monotonic() - t0 >= max_wall:
                print(f"# watch: max wall time reached", file=sys.stderr)
                break
            time.sleep(interval)
        return total


# --------------------------------------------------------------------- CLI
def build_parser() -> argparse.ArgumentParser:
    p = argparse.ArgumentParser(
        prog="repro.obs.watch",
        description="fleet watcher: tail trace dirs into rollups + alerts",
    )
    p.add_argument("dirs", nargs="+", metavar="TRACE_DIR")
    p.add_argument("--follow", action="store_true",
                   help="poll live dirs until trace.end (default: one-shot)")
    p.add_argument("--interval", type=float, default=0.5)
    p.add_argument("--idle-timeout", type=float, default=60.0,
                   help="stop following after this many quiet seconds")
    p.add_argument("--max-wall", type=float, default=None)
    p.add_argument("--csv", default=None, metavar="DIR",
                   help="write rollup CSVs here when done")
    p.add_argument("--store", default=None, metavar="DIR",
                   help="store directory (alerts.jsonl + checkpoints)")
    p.add_argument("--ckpt", default=None, metavar="DIR",
                   help="checkpoint directory (default: STORE/ckpt)")
    p.add_argument("--every", type=int, default=1000,
                   help="checkpoint every N consumed events")
    p.add_argument("--resume", action="store_true",
                   help="resume from the latest committed store checkpoint")
    p.add_argument("--window", type=float, default=20.0)
    p.add_argument("--n-windows", type=int, default=64)
    p.add_argument("--util-max", type=float, default=0.95)
    p.add_argument("--frag", type=float, default=0.75)
    p.add_argument("--fails", type=int, default=5)
    p.add_argument("--stall", type=float, default=30.0)
    p.add_argument("--quiet", action="store_true")
    p.add_argument("--crash-after", type=int, default=None,
                   help=argparse.SUPPRESS)  # kill-and-resume test hook
    return p


def run(argv=None) -> int:
    args = build_parser().parse_args(argv)
    store = open_store(
        args.dirs,
        spec=StoreSpec(window=args.window, n_windows=args.n_windows),
        store_dir=args.store,
        checkpoint_dir=args.ckpt,
        checkpoint_every=args.every,
        resume=args.resume,
    )
    if args.crash_after is not None:
        target = int(args.crash_after)

        def _crash(run_key, ev):
            if store.total_events + 1 >= target:
                os._exit(137)  # hard kill AFTER checkpoints up to here

        store.subscribe(_crash)
    watcher = FleetWatcher(
        store,
        rules=default_rules(util_max=args.util_max, frag=args.frag,
                            fails=args.fails, stall=args.stall),
        echo=not args.quiet,
    )
    if args.follow:
        watcher.follow(interval=args.interval,
                       idle_timeout=args.idle_timeout,
                       max_wall=args.max_wall)
    else:
        watcher.run_once()
    if store._ckpt is not None:
        store.save_checkpoint()
    if args.csv:
        for name, path in sorted(store.write_csvs(args.csv).items()):
            print(f"# {name}: {path}")
    if not args.quiet:
        print(f"# watch: {store.status_line()}")
    return 0


if __name__ == "__main__":
    sys.exit(run())
