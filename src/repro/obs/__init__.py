"""Observability subsystem: in-sim telemetry, run tracing, fleet reports.

Three layers (DESIGN.md §Observability):

  * :mod:`.probes` — :class:`TelemetrySpec` / :class:`Telemetry`: static
    probe specs that join the engine compile key (default off = the
    bit-identical pre-telemetry kernel) and the windowed time series the
    enabled kernel accumulates (per-link/per-dimension utilization,
    per-pool queue-occupancy histograms, deroute/escalation counts,
    in-flight population, ejection-latency histograms);
  * :mod:`.trace` — host-side span/event JSONL logging + run manifest,
    zero-cost when no tracer is configured;
  * :mod:`.report` — renders a trace directory into CSV tables and a
    markdown fleet report (``python -m repro.obs.report TRACE_DIR``).
"""

from repro.obs import trace
from repro.obs.probes import (
    Telemetry,
    TelemetrySpec,
    TelemetryState,
    init_telemetry,
)


def __getattr__(name):
    # lazy: `python -m repro.obs.report` would otherwise warn that the
    # module is already in sys.modules before runpy executes it
    if name == "report":
        import importlib

        return importlib.import_module("repro.obs.report")
    raise AttributeError(f"module 'repro.obs' has no attribute {name!r}")

__all__ = [
    "Telemetry",
    "TelemetrySpec",
    "TelemetryState",
    "init_telemetry",
    "report",
    "trace",
]
