"""Observability subsystem: telemetry, tracing, reports, fleet service.

Layers (DESIGN.md §Observability, §Fleet service):

  * :mod:`.probes` — :class:`TelemetrySpec` / :class:`Telemetry`: static
    probe specs that join the engine compile key (default off = the
    bit-identical pre-telemetry kernel) and the windowed time series the
    enabled kernel accumulates (per-link/per-dimension utilization,
    per-pool queue-occupancy histograms, deroute/escalation counts,
    in-flight population, ejection-latency histograms);
  * :mod:`.trace` — host-side span/event JSONL logging + run manifest,
    zero-cost when no tracer is configured;
  * :mod:`.report` — renders a trace directory into CSV tables and a
    markdown fleet report (``python -m repro.obs.report TRACE_DIR``);
  * :mod:`.store` — persistent :class:`EventStore`: append-aware tailing
    of live trace dirs into bounded windowed rollups, checkpointed;
  * :mod:`.watch` — :class:`FleetWatcher` CLI: follow live runs,
    evaluate declarative alert rules (``python -m repro.obs.watch``);
  * :mod:`.insights` — queryable placement/queue recommendations from
    live ledger state + store rollups;
  * :mod:`.dashboard` — store rollups → markdown/HTML fleet dashboard
    (``python -m repro.obs.dashboard``).
"""

from repro.obs import trace
from repro.obs.probes import (
    Telemetry,
    TelemetrySpec,
    TelemetryState,
    init_telemetry,
)

_LAZY = ("report", "store", "watch", "insights", "dashboard")


def __getattr__(name):
    # lazy: `python -m repro.obs.<mod>` would otherwise warn that the
    # module is already in sys.modules before runpy executes it
    if name in _LAZY:
        import importlib

        return importlib.import_module(f"repro.obs.{name}")
    raise AttributeError(f"module 'repro.obs' has no attribute {name!r}")

__all__ = [
    "Telemetry",
    "TelemetrySpec",
    "TelemetryState",
    "init_telemetry",
    "dashboard",
    "insights",
    "report",
    "store",
    "trace",
    "watch",
]
