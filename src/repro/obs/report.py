"""Fleet report generator: render a trace directory into tables + markdown.

    PYTHONPATH=src python -m repro.obs.report TRACE_DIR [--out DIR]

Consumes the ``events.jsonl`` + ``manifest.json`` a :mod:`repro.obs.trace`
run produced — no simulation is re-run — and renders:

  * ``report.md``          — manifest header + every summary table;
  * ``spans.csv``          — span aggregation (count / total / mean / max);
  * ``sched.csv``          — scheduler event counts + fragmentation /
                             utilization summary per stream;
  * ``link_heatmap.csv``   — per-(label, switch, port) network link load
                             from every ``sim.telemetry`` event (the
                             per-strategy heatmap data);
  * ``latency.csv``        — log2 ejection-latency histograms per label;
  * ``queue_occupancy.csv``— per-pool queue-occupancy histograms per label;
  * ``device_timeline.csv``— per-grid device timings + ``jax.profiler``
                             trace locations from a ``benchmarks.perf
                             --profile`` run.

Every table is also queryable in-process (:func:`span_rows`,
:func:`sched_rows`, :func:`telemetry_events`, :func:`link_heatmap_rows`,
:func:`hottest_links`) so examples and tests can consume the same data the
CLI renders.
"""

from __future__ import annotations

import argparse
import csv
import io
import json
import os
import sys


# ------------------------------------------------------------------ loading
def load_trace(trace_dir: str) -> tuple[dict, list[dict]]:
    """Read (manifest, events) from a trace directory.

    Unparsable JSONL lines are skipped (a crashed run may truncate the
    final line) — reports should degrade, not raise.
    """
    manifest: dict = {}
    mpath = os.path.join(trace_dir, "manifest.json")
    if os.path.exists(mpath):
        try:
            with open(mpath) as f:
                manifest = json.load(f)
        except (OSError, json.JSONDecodeError):
            manifest = {}
    events: list[dict] = []
    epath = os.path.join(trace_dir, "events.jsonl")
    if os.path.exists(epath):
        with open(epath) as f:
            for line in f:
                line = line.strip()
                if not line:
                    continue
                try:
                    ev = json.loads(line)
                except json.JSONDecodeError:
                    continue
                if isinstance(ev, dict):
                    events.append(ev)
    return manifest, events


def split_runs(events: list[dict]) -> list[tuple[str, list[dict]]]:
    """Split an event list on ``trace.start`` boundaries.

    ``events.jsonl`` is opened in append mode, so re-``configure``-ing into
    the same directory aggregates several runs into one file; every table
    must be computed per run, not over the blended log.  Returns
    ``[(run_id, events), ...]`` in file order — a single-run file (or one
    with no ``trace.start`` at all) comes back as one group.
    """
    runs: list[tuple[str, list[dict]]] = []
    cur_id, cur = "", []
    for ev in events:
        if ev.get("name") == "trace.start":
            if cur:
                runs.append((cur_id, cur))
            cur_id = str(ev.get("run_id") or f"run{len(runs) + 1}")
            cur = []
        cur.append(ev)
    if cur:
        runs.append((cur_id, cur))
    return runs or [("", [])]


# ------------------------------------------------------------------- tables
def span_rows(events: list[dict]) -> list[dict]:
    """Aggregate span events by name: count, total/mean/max duration."""
    agg: dict[str, list[float]] = {}
    for ev in events:
        if ev.get("type") == "span" and "dur_s" in ev:
            agg.setdefault(ev["name"], []).append(float(ev["dur_s"]))
    rows = []
    for name in sorted(agg, key=lambda n: -sum(agg[n])):
        durs = agg[name]
        rows.append({
            "span": name, "count": len(durs),
            "total_s": round(sum(durs), 4),
            "mean_s": round(sum(durs) / len(durs), 4),
            "max_s": round(max(durs), 4),
        })
    return rows


def sched_rows(events: list[dict]) -> list[dict]:
    """Per-stream scheduler digest: event counts + fragmentation stats.

    Streams are keyed by the ``stream`` attribute the scheduler stamps on
    its events (strategy/policy label); events without one aggregate
    under ``"-"``.
    """
    streams: dict[str, dict] = {}

    def row(key):
        return streams.setdefault(key, {
            "stream": key, "arrived": 0, "started": 0, "backfilled": 0,
            "finished": 0, "migrations": 0, "requeues": 0, "failures": 0,
            "frag_mean": "", "frag_max": "", "utilization": "",
        })

    frags: dict[str, list[float]] = {}
    for ev in events:
        name = ev.get("name", "")
        if not name.startswith("sched."):
            continue
        key = str(ev.get("stream", "-"))
        r = row(key)
        kind = name.split(".", 1)[1]
        if kind == "arrive":
            r["arrived"] += 1
        elif kind == "start":
            r["started"] += 1
            if ev.get("backfilled"):
                r["backfilled"] += 1
        elif kind == "depart":
            r["finished"] += 1
        elif kind == "migrate":
            r["migrations"] += 1
        elif kind == "requeue":
            r["requeues"] += 1
        elif kind == "fail":
            r["failures"] += 1
        elif kind == "frag":
            frags.setdefault(key, []).append(float(ev.get("value", 0.0)))
        elif kind == "summary":
            for field in ("utilization", "frag_mean", "frag_max"):
                if field in ev:
                    r[field] = round(float(ev[field]), 4)
    for key, vals in frags.items():
        r = row(key)
        if r["frag_mean"] == "":
            r["frag_mean"] = round(sum(vals) / len(vals), 4)
        if r["frag_max"] == "":
            r["frag_max"] = round(max(vals), 4)
    return [streams[k] for k in sorted(streams)]


def telemetry_events(events: list[dict]) -> list[dict]:
    """The ``sim.telemetry`` digests, in emission order."""
    return [ev for ev in events if ev.get("type") == "telemetry"]


def utilization_rows(events: list[dict]) -> list[dict]:
    """One row per telemetry digest: headline utilization / behavior."""
    rows = []
    for ev in telemetry_events(events):
        rows.append({
            "label": ev.get("label", ""),
            "cycles": ev.get("cycles", 0),
            "util_mean": ev.get("util_mean", ""),
            "util_max": ev.get("util_max", ""),
            "dim_util": "|".join(str(u) for u in ev.get("dim_util", [])),
            "deroutes": ev.get("deroutes", 0),
            "escalations": ev.get("escalations", 0),
            "injected": ev.get("injected", 0),
            "delivered": ev.get("delivered", 0),
            "lat_mean": ev.get("lat_mean", ""),
        })
    return rows


def link_heatmap_rows(events: list[dict]) -> list[dict]:
    """Flatten every digest's top links into per-strategy heatmap data."""
    rows = []
    for ev in telemetry_events(events):
        for link in ev.get("top_links", []):
            rows.append({"label": ev.get("label", ""), **link})
    return rows


def hottest_links(source, k: int = 5) -> list[dict]:
    """Top-k hottest network links.

    ``source`` is either a host :class:`~repro.obs.probes.Telemetry`
    object (delegates to its accessor) or a ``sim.telemetry`` event dict
    (slices its recorded ``top_links``).
    """
    if hasattr(source, "hottest_links"):
        return source.hottest_links(k)
    return list(source.get("top_links", []))[:k]


def device_timeline_rows(events: list[dict]) -> list[dict]:
    """Per-grid device timelines from a ``benchmarks.perf --profile`` run.

    One row per ``perf.grid_metrics`` event: the headline timings next to
    the ``xprof`` directory holding the raw ``jax.profiler`` trace for
    that grid (open it with any perfetto/tensorboard viewer).
    """
    rows = []
    for ev in events:
        if ev.get("name") != "perf.grid_metrics":
            continue
        rows.append({
            "grid": ev.get("grid", ""),
            "lanes": ev.get("lanes", ""),
            "compile_s": ev.get("compile_s", ""),
            "device_s": ev.get("device_s", ""),
            "wall_first_s": ev.get("wall_first_s", ""),
            "wall_repeat_s": ev.get("wall_repeat_s", ""),
            "cycles_per_s": ev.get("cycles_per_s", ""),
            "bucket_hit_rate": ev.get("bucket_hit_rate", ""),
            "xprof": ev.get("xprof", ""),
        })
    return rows


def latency_rows(events: list[dict]) -> list[dict]:
    rows = []
    for ev in telemetry_events(events):
        for b, cnt in enumerate(ev.get("lat_hist", [])):
            rows.append({
                "label": ev.get("label", ""), "bin": b,
                "lat_lo": 2 ** b, "lat_hi": 2 ** (b + 1), "count": cnt,
            })
    return rows


def queue_occupancy_rows(events: list[dict]) -> list[dict]:
    rows = []
    for ev in telemetry_events(events):
        for pool, hist in enumerate(ev.get("occ_hist", [])):
            for occ, cnt in enumerate(hist):
                rows.append({
                    "label": ev.get("label", ""), "pool": pool,
                    "occupancy": occ, "samples": cnt,
                })
    return rows


# ---------------------------------------------------------------- rendering
def _csv_text(rows: list[dict]) -> str:
    out = io.StringIO()
    w = csv.DictWriter(out, fieldnames=list(rows[0].keys()))
    w.writeheader()
    for r in rows:
        w.writerow(r)
    return out.getvalue()


def _md_table(rows: list[dict]) -> str:
    if not rows:
        return "_no data_\n"
    cols = list(rows[0].keys())
    lines = ["| " + " | ".join(cols) + " |",
             "| " + " | ".join("---" for _ in cols) + " |"]
    for r in rows:
        lines.append("| " + " | ".join(str(r.get(c, "")) for c in cols) + " |")
    return "\n".join(lines) + "\n"


# public aliases: the dashboard and the event store reuse these builders
csv_text = _csv_text
md_table = _md_table


def _run_tables(events: list[dict], heading: str = "##") -> list[str]:
    """The per-run table sections (shared by single- and multi-run paths)."""
    parts = []
    tel = utilization_rows(events)
    if tel:
        parts.append(f"\n{heading} Link utilization (per strategy)\n")
        parts.append(_md_table(tel))
        parts.append(f"\n{heading}# Hottest links\n")
        hot = []
        for ev in telemetry_events(events):
            for link in hottest_links(ev, 5):
                hot.append({"label": ev.get("label", ""), **link})
        parts.append(_md_table(hot))
    sched = sched_rows(events)
    if sched:
        parts.append(f"\n{heading} Scheduler streams (fragmentation & churn)\n")
        parts.append(_md_table(sched))
    timelines = device_timeline_rows(events)
    if timelines:
        parts.append(f"\n{heading} Device timelines (perf profile)\n")
        parts.append(_md_table(timelines))
    spans = span_rows(events)
    if spans:
        parts.append(f"\n{heading} Span timings\n")
        parts.append(_md_table(spans))
    return parts


def render_markdown(manifest: dict, events: list[dict]) -> str:
    """The full fleet report as markdown text.

    An append-mode trace directory may hold several runs; tables are split
    on ``trace.start`` boundaries and the run count is surfaced up front —
    a blended multi-run table would silently aggregate unrelated streams.
    """
    runs = split_runs(events)
    parts = ["# Run report\n"]
    if manifest:
        keys = ("run_id", "git_rev", "backend", "devices", "lane_backend",
                "jax", "config_hash")
        parts.append("## Manifest\n")
        parts.append(_md_table([{k: manifest.get(k, "") for k in keys}]))
    if len(runs) > 1:
        parts.append(f"\n## Runs ({len(runs)})\n")
        parts.append(_md_table([
            {"run": rid or f"run{i + 1}", "events": len(evs)}
            for i, (rid, evs) in enumerate(runs)
        ]))
        for i, (rid, evs) in enumerate(runs):
            parts.append(f"\n## Run {rid or f'run{i + 1}'}\n")
            parts.extend(_run_tables(evs, heading="###"))
    else:
        parts.extend(_run_tables(events))
    parts.append(f"\n_{len(events)} events across {len(runs)} run(s)._\n")
    return "\n".join(parts)


def write_report(trace_dir: str, out_dir: str | None = None) -> dict[str, str]:
    """Render every table for one trace directory; returns written paths."""
    out_dir = out_dir or os.path.join(trace_dir, "report")
    os.makedirs(out_dir, exist_ok=True)
    manifest, events = load_trace(trace_dir)
    runs = split_runs(events)
    written: dict[str, str] = {}

    def emit_csv(name, fn):
        if len(runs) > 1:  # split per run; a leading run column labels rows
            rows = [
                {"run": rid or f"run{i + 1}", **row}
                for i, (rid, evs) in enumerate(runs)
                for row in fn(evs)
            ]
        else:
            rows = fn(events)
        if not rows:
            return
        path = os.path.join(out_dir, f"{name}.csv")
        with open(path, "w", newline="") as f:
            f.write(_csv_text(rows))
        written[name] = path

    emit_csv("spans", span_rows)
    emit_csv("sched", sched_rows)
    emit_csv("utilization", utilization_rows)
    emit_csv("link_heatmap", link_heatmap_rows)
    emit_csv("latency", latency_rows)
    emit_csv("queue_occupancy", queue_occupancy_rows)
    emit_csv("device_timeline", device_timeline_rows)
    md = os.path.join(out_dir, "report.md")
    with open(md, "w") as f:
        f.write(render_markdown(manifest, events))
    written["report"] = md
    return written


def main(argv=None) -> int:
    p = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    p.add_argument("trace_dir", help="directory with events.jsonl")
    p.add_argument("--out", default=None,
                   help="output directory (default: TRACE_DIR/report)")
    args = p.parse_args(argv)
    if not os.path.exists(os.path.join(args.trace_dir, "events.jsonl")):
        print(f"# obs.report: no events.jsonl under {args.trace_dir}",
              file=sys.stderr)
        return 2
    written = write_report(args.trace_dir, args.out)
    for name, path in sorted(written.items()):
        print(f"# {name}: {path}")
    return 0


if __name__ == "__main__":
    sys.exit(main())
