"""Fleet dashboard: render store rollups as markdown + self-contained HTML.

    PYTHONPATH=src python -m repro.obs.dashboard TRACE_DIR... [--out DIR]
        [--ckpt DIR] [--refresh S] [--follow] [--interval S]

One-shot: ingest the trace dirs (or restore a checkpointed store with
``--ckpt``) and write ``dashboard.md`` + ``dashboard.html``.  ``--follow``
keeps polling and re-rendering until the traced runs end, and
``--refresh`` stamps the HTML with a ``<meta http-equiv="refresh">`` so a
browser pointed at the file live-updates — together they are the "leave a
browser open on the fleet" mode.  Tables reuse the report generator's
builders (:func:`repro.obs.report.md_table`), so the dashboard and the
post-hoc report render the same rows the same way.
"""

from __future__ import annotations

import argparse
import html
import os
import sys
import time

from repro.obs.store import EventStore, open_store

_BLOCKS = "▁▂▃▄▅▆▇█"

_CSS = """
body{font-family:-apple-system,'Segoe UI',Roboto,sans-serif;margin:2em;
     background:#fafafa;color:#1a1a1a;max-width:72em}
h1{font-size:1.4em}h2{font-size:1.1em;margin-top:1.6em}
table{border-collapse:collapse;font-size:0.85em;margin:0.5em 0}
th,td{border:1px solid #ccc;padding:0.25em 0.6em;text-align:right}
th{background:#ececec}td:first-child,th:first-child{text-align:left}
.alert td{background:#fde8e8}
.spark{font-family:monospace;letter-spacing:-1px;text-align:left}
footer{margin-top:2em;color:#777;font-size:0.8em}
"""


def sparkline(values, lo: float = 0.0, hi: float | None = None) -> str:
    """Unicode block sparkline of a numeric series (deterministic)."""
    vals = [float(v) for v in values]
    if not vals:
        return ""
    hi = max(vals) if hi is None else hi
    span = max(hi - lo, 1e-9)
    out = []
    for v in vals:
        i = int((min(max(v, lo), hi) - lo) / span * (len(_BLOCKS) - 1))
        out.append(_BLOCKS[i])
    return "".join(out)


def _frag_sparks(store: EventStore) -> list[dict]:
    """Per-stream fragmentation + queue-depth sparklines over the windows."""
    rows = []
    for key in sorted(store.runs):
        run = store.runs[key]
        for sname in sorted(run.streams):
            sr = run.streams[sname]
            active = [w for w in range(store.spec.n_windows)
                      if sr.frag_cnt[w]]
            if not active:
                continue
            hi = active[-1] + 1
            frag = [sr.frag_sum[w] / max(sr.frag_cnt[w], 1)
                    for w in range(hi)]
            queued = [sr.queued_sum[w] / max(sr.frag_cnt[w], 1)
                      for w in range(hi)]
            rows.append({
                "run": key, "stream": sname,
                "frag": sparkline(frag, hi=1.0),
                "queued": sparkline(queued),
                "windows": hi,
            })
    return rows


def _sections(store: EventStore) -> list[tuple[str, list[dict]]]:
    """(title, rows) sections in render order; empty sections are skipped."""
    rows = store.rollup_rows()
    links = sorted(rows["links"],
                   key=lambda r: -float(r.get("util", 0.0)))[:15]
    return [
        ("Runs", rows["runs"]),
        ("Scheduler streams (utilization & fragmentation)", rows["streams"]),
        ("Fragmentation / queue-depth timelines", _frag_sparks(store)),
        ("Link utilization (per strategy)", rows["telemetry"]),
        ("Hottest links", links),
        ("Alerts", rows["alerts"][-20:]),
        ("Benchmark module wall times", rows["bench"]),
    ]


def render_markdown(store: EventStore) -> str:
    from repro.obs.report import md_table

    parts = [
        "# Fleet dashboard\n",
        f"_{store.total_events} events · {len(store.runs)} run(s) · "
        f"{len(store.alerts)} alert(s)._\n",
    ]
    for title, rows in _sections(store):
        if not rows:
            continue
        parts.append(f"\n## {title}\n")
        parts.append(md_table(rows))
    return "\n".join(parts)


def _html_table(rows: list[dict], alert: bool = False) -> str:
    cols = list(rows[0].keys())
    out = ["<table>", "<tr>" + "".join(f"<th>{html.escape(str(c))}</th>"
                                       for c in cols) + "</tr>"]
    for r in rows:
        cls = ' class="alert"' if alert else ""
        cells = "".join(
            f'<td class="spark">{html.escape(str(r.get(c, "")))}</td>'
            if isinstance(r.get(c), str) and set(r[c]) <= set(_BLOCKS)
            and r[c] else
            f"<td>{html.escape(str(r.get(c, '')))}</td>"
            for c in cols
        )
        out.append(f"<tr{cls}>{cells}</tr>")
    out.append("</table>")
    return "\n".join(out)


def render_html(store: EventStore, refresh: float | None = None) -> str:
    meta = (f'<meta http-equiv="refresh" content="{refresh:g}">'
            if refresh else "")
    parts = [
        "<!doctype html><html><head><meta charset='utf-8'>",
        meta,
        "<title>Fleet dashboard</title>",
        f"<style>{_CSS}</style></head><body>",
        "<h1>Fleet dashboard</h1>",
        f"<p>{store.total_events} events · {len(store.runs)} run(s) · "
        f"{len(store.alerts)} alert(s)</p>",
    ]
    for title, rows in _sections(store):
        if not rows:
            continue
        parts.append(f"<h2>{html.escape(title)}</h2>")
        parts.append(_html_table(rows, alert=title == "Alerts"))
    parts.append("<footer>rendered by repro.obs.dashboard</footer>")
    parts.append("</body></html>")
    return "\n".join(parts)


def write_dashboard(store: EventStore, out_dir: str,
                    refresh: float | None = None) -> dict[str, str]:
    """Render both artifacts into ``out_dir``; returns written paths."""
    os.makedirs(out_dir, exist_ok=True)
    paths = {}
    md = os.path.join(out_dir, "dashboard.md")
    with open(md, "w") as f:
        f.write(render_markdown(store))
    paths["markdown"] = md
    hp = os.path.join(out_dir, "dashboard.html")
    with open(hp, "w") as f:
        f.write(render_html(store, refresh=refresh))
    paths["html"] = hp
    return paths


# --------------------------------------------------------------------- CLI
def build_parser() -> argparse.ArgumentParser:
    p = argparse.ArgumentParser(
        prog="repro.obs.dashboard",
        description="render store rollups into a fleet dashboard",
    )
    p.add_argument("dirs", nargs="*", metavar="TRACE_DIR")
    p.add_argument("--ckpt", default=None,
                   help="restore a checkpointed EventStore instead of "
                        "(or in addition to) ingesting trace dirs")
    p.add_argument("--out", default=None,
                   help="output dir (default: first TRACE_DIR/dashboard)")
    p.add_argument("--refresh", type=float, default=None,
                   help="HTML meta-refresh seconds (live browser view)")
    p.add_argument("--follow", action="store_true",
                   help="keep polling + re-rendering until runs end")
    p.add_argument("--interval", type=float, default=2.0)
    p.add_argument("--idle-timeout", type=float, default=60.0)
    p.add_argument("--window", type=float, default=20.0)
    p.add_argument("--n-windows", type=int, default=64)
    return p


def run(argv=None) -> int:
    from repro.obs.store import StoreSpec

    args = build_parser().parse_args(argv)
    if not args.dirs and not args.ckpt:
        print("# obs.dashboard: need TRACE_DIR(s) or --ckpt",
              file=sys.stderr)
        return 2
    store = open_store(
        args.dirs, spec=StoreSpec(window=args.window,
                                  n_windows=args.n_windows),
        checkpoint_dir=args.ckpt, resume=args.ckpt is not None,
    )
    out = args.out or (os.path.join(args.dirs[0], "dashboard")
                       if args.dirs else "dashboard")
    store.poll()
    paths = write_dashboard(store, out, refresh=args.refresh)
    idle = 0.0
    while args.follow and not store.ended():
        time.sleep(args.interval)
        n = store.poll()
        idle = 0.0 if n else idle + args.interval
        if n:
            write_dashboard(store, out, refresh=args.refresh)
        if idle >= args.idle_timeout:
            break
    write_dashboard(store, out, refresh=args.refresh)
    for name, path in sorted(paths.items()):
        print(f"# {name}: {path}")
    return 0


if __name__ == "__main__":
    sys.exit(run())
