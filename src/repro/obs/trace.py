"""Host-side run tracing: structured span/event JSONL logs + run manifest.

One :class:`Tracer` owns a trace directory:

  * ``events.jsonl`` — one JSON object per line.  Every event carries
    ``t`` (seconds since the tracer started), ``type`` (``"event"`` |
    ``"span"`` | ``"counter"`` | ``"gauge"`` | ``"telemetry"``) and
    ``name``; spans add ``dur_s``; counters/gauges add ``value``; any
    extra keyword attributes ride along verbatim.
  * ``manifest.json`` — the run manifest: schema version, run id, git
    rev, jax version/backend/device count, engine ``lane_backend``,
    python/platform, caller extras, and a ``config_hash`` over all of it.

The module-level API (:func:`span`, :func:`event`, :func:`counter`,
:func:`gauge`) routes through one process-global tracer configured with
:func:`configure` and is **zero-cost when off**: with no tracer active,
``span`` returns one shared ``nullcontext`` singleton and the emitters
return immediately — instrumented hot paths (the engine dispatchers, the
scheduler event loop) pay a single global load and a falsy check.
"""

from __future__ import annotations

import contextlib
import hashlib
import json
import os
import platform
import subprocess
import threading
import time

SCHEMA = 1

_NULL = contextlib.nullcontext()
_tracer: "Tracer | None" = None


def _git_rev() -> str:
    root = os.path.dirname(os.path.dirname(os.path.dirname(
        os.path.dirname(os.path.abspath(__file__)))))
    try:
        return subprocess.run(
            ["git", "rev-parse", "--short", "HEAD"], cwd=root,
            capture_output=True, text=True, check=True, timeout=10,
        ).stdout.strip()
    except Exception:
        return "unknown"


def manifest_dict(**extra) -> dict:
    """The run manifest: host/backend provenance + caller extras.

    Also used standalone by ``benchmarks/perf.py`` so BENCH snapshots
    carry the same provenance block as trace directories.
    """
    import jax

    from repro.core.engine.runner import default_lane_backend

    info = {
        "schema": SCHEMA,
        "git_rev": _git_rev(),
        "jax": jax.__version__,
        "backend": jax.default_backend(),
        "devices": jax.local_device_count(),
        "lane_backend": default_lane_backend(),
        "python": platform.python_version(),
        "platform": platform.platform(),
    }
    info.update(extra)
    blob = json.dumps(
        {k: v for k, v in sorted(info.items())}, sort_keys=True, default=str
    )
    info["config_hash"] = hashlib.sha256(blob.encode()).hexdigest()[:16]
    return info


def _json_default(o):
    item = getattr(o, "item", None)  # numpy scalars
    if callable(item):
        return item()
    if hasattr(o, "tolist"):
        return o.tolist()
    return str(o)


class Tracer:
    """Writes one run's event log + manifest under ``trace_dir``."""

    def __init__(self, trace_dir: str, run_id: str | None = None, **extra):
        os.makedirs(trace_dir, exist_ok=True)
        self.dir = trace_dir
        self.run_id = run_id or time.strftime("%Y%m%d-%H%M%S")
        self.path = os.path.join(trace_dir, "events.jsonl")
        self._f = open(self.path, "a", encoding="utf-8")
        self._closed = False
        self._lock = threading.Lock()
        self._t0 = time.perf_counter()
        self.manifest = manifest_dict(run_id=self.run_id, **extra)
        self._write_manifest()
        self.event("trace.start", run_id=self.run_id)

    def _write_manifest(self):
        with open(os.path.join(self.dir, "manifest.json"), "w") as f:
            json.dump(self.manifest, f, indent=2, sort_keys=True,
                      default=_json_default)
            f.write("\n")

    def annotate(self, **fields):
        """Merge late-bound fields (e.g. the realized lane_backend) into
        the manifest and rewrite it."""
        self.manifest.update(fields)
        self._write_manifest()

    # ------------------------------------------------------------ emitters
    def event(self, name: str, **attrs):
        ev = {"t": round(time.perf_counter() - self._t0, 6),
              "type": attrs.pop("type", "event"), "name": name}
        ev.update(attrs)
        line = json.dumps(ev, default=_json_default)
        with self._lock:
            # post-close emits are safe no-ops: an in-flight span() held
            # across disable()/configure() must not raise "I/O operation
            # on closed file" when it finally exits (regression-pinned)
            if self._closed:
                return
            self._f.write(line + "\n")
            self._f.flush()

    def counter(self, name: str, value, **attrs):
        self.event(name, type="counter", value=value, **attrs)

    def gauge(self, name: str, value, **attrs):
        self.event(name, type="gauge", value=value, **attrs)

    @contextlib.contextmanager
    def span(self, name: str, **attrs):
        t0 = time.perf_counter()
        try:
            yield self
        finally:
            self.event(name, type="span",
                       dur_s=round(time.perf_counter() - t0, 6), **attrs)

    @property
    def closed(self) -> bool:
        return self._closed

    def close(self):
        if self._closed:
            return
        self.event("trace.end")
        with self._lock:
            self._closed = True
            self._f.close()


# ------------------------------------------------------- module-level API
def configure(trace_dir: str, run_id: str | None = None, **extra) -> Tracer:
    """Activate tracing into ``trace_dir`` (closing any previous tracer)."""
    global _tracer
    if _tracer is not None:
        _tracer.close()
    _tracer = Tracer(trace_dir, run_id=run_id, **extra)
    return _tracer


def disable():
    """Deactivate tracing (all module-level calls become no-ops again)."""
    global _tracer
    if _tracer is not None:
        _tracer.close()
    _tracer = None


def active() -> Tracer | None:
    return _tracer


def span(name: str, **attrs):
    """A timing span context manager; the shared no-op when tracing is off."""
    t = _tracer
    return _NULL if t is None else t.span(name, **attrs)


def event(name: str, **attrs):
    t = _tracer
    if t is not None:
        t.event(name, **attrs)


def counter(name: str, value, **attrs):
    t = _tracer
    if t is not None:
        t.counter(name, value, **attrs)


def gauge(name: str, value, **attrs):
    t = _tracer
    if t is not None:
        t.gauge(name, value, **attrs)


def log_telemetry(label: str, telemetry, **attrs):
    """Emit a compact ``sim.telemetry`` event from a host Telemetry view."""
    t = _tracer
    if t is not None and telemetry is not None:
        t.event("sim.telemetry", type="telemetry",
                **telemetry.summary(label), **attrs)
