"""Queryable recommendation API: "which strategy/queue for a B-block job?"

Two complementary query surfaces:

  * :func:`recommend` answers from **live machine state**: a
    :class:`~repro.sched.ledger.BlockLedger`'s current occupancy decides
    placeability/contiguity/fragmentation per candidate strategy, and
    (``simulate=True``) a hypothetical co-resident snapshot per strategy —
    the current tenants plus the new job — refreshes an interference grid
    through :func:`repro.sched.bridge.evaluate_snapshots` (one engine, one
    batched device call for *all* candidates).  Results are **memoized on
    a snapshot hash** over the ledger occupancy + query parameters, so
    repeated queries against an unchanged machine never re-simulate
    (``Insight.cached`` says which path answered; pinned in tests).
  * :func:`queue_outlook` / :func:`recommend_queue` answer from **history**:
    an :class:`~repro.obs.store.EventStore`'s rollups (typically restored
    from a checkpoint — no raw event log needed) rank the observed
    scheduler streams by recent fragmentation, queue depth and failure
    pressure, the "which queue absorbs this job best" half of the ROADMAP
    fleet-service question.
"""

from __future__ import annotations

import copy
import dataclasses
import hashlib
from typing import Mapping, Sequence

from repro.core.hyperx import HyperX
from repro.sched.ledger import BlockLedger
from repro.sched.scheduler import Snapshot

_DEFAULT_STRATEGIES = ("diagonal", "rectangular", "row", "full_spread")
_MEMO: dict[str, "Insight"] = {}
_MEMO_CAP = 128
_HYPO_JOB = 1 << 30  # job id for the hypothetical placement


@dataclasses.dataclass(frozen=True)
class Candidate:
    """One strategy's answer for the queried job."""

    strategy: str
    placeable: bool
    contiguous: bool
    free_slots: int
    frag: float                      # current frag in this strategy's frame
    avg_latency: float | None = None  # predicted under co-resident load
    avg_hops: float | None = None
    completed: bool | None = None


@dataclasses.dataclass(frozen=True)
class Insight:
    """A ranked recommendation (best candidate first)."""

    blocks: int
    key: str                  # the memo/snapshot hash
    cached: bool              # True when answered from the memo
    simulated: bool
    candidates: tuple[Candidate, ...]

    @property
    def best(self) -> Candidate | None:
        return self.candidates[0] if self.candidates else None


def snapshot_key(
    ledger: BlockLedger,
    blocks: int,
    strategies: Sequence[str],
    kernel: str,
    kernels: Mapping[int, str] | None,
    mode: str,
    seeds: Sequence[int],
    horizon: int,
    simulate: bool,
) -> str:
    """Hash of everything the answer depends on: machine occupancy + query."""
    h = hashlib.sha256()
    topo = ledger.topo
    h.update(repr((topo.n, topo.q, topo.concentration, ledger.strategy.name,
                   ledger.policy, ledger.seed, ledger.allow_scatter,
                   int(blocks), tuple(strategies), kernel,
                   tuple(sorted((kernels or {}).items())), mode,
                   tuple(int(s) for s in seeds), int(horizon),
                   bool(simulate))).encode())
    h.update(ledger.free.tobytes())
    h.update(ledger.failed.tobytes())
    for jid in sorted(ledger.jobs):
        job = ledger.jobs[jid]
        h.update(repr((jid, job.slots, job.contiguous)).encode())
        h.update(job.partition.endpoints.tobytes())
    return h.hexdigest()[:16]


def recommend(
    topo: HyperX,
    ledger: BlockLedger,
    blocks: int,
    strategies: Sequence[str] = _DEFAULT_STRATEGIES,
    kernel: str = "all_to_all",
    kernels: Mapping[int, str] | None = None,
    mode: str = "omniwar",
    seeds: Sequence[int] = (0,),
    horizon: int = 30_000,
    simulate: bool = True,
) -> Insight:
    """Rank candidate strategies for placing a ``blocks``-block job *now*.

    ``kernel`` is the new job's traffic kernel; ``kernels`` maps resident
    job ids to theirs (default ``all_to_all`` — the conservative
    worst-case collective).  Ranking: placeable before not, contiguous
    before scattered, then predicted ``avg_latency`` under the co-resident
    interference simulation, then current fragmentation.

    The ledger is never mutated (hypothetical placements run on a copy).
    """
    if blocks < 1:
        raise ValueError(f"need a positive block count, got {blocks}")
    key = snapshot_key(ledger, blocks, strategies, kernel, kernels, mode,
                       seeds, horizon, simulate)
    hit = _MEMO.get(key)
    if hit is not None:
        return dataclasses.replace(hit, cached=True)

    fits: dict[str, Candidate] = {}
    snaps: dict[str, list[Snapshot]] = {}
    for strat in strategies:
        free = ledger.free_slots(strat)
        found = ledger.find_slots(blocks, strat) \
            if blocks <= ledger.num_slots else None
        frag = ledger.fragmentation(strat)
        if found is None:
            fits[strat] = Candidate(
                strategy=strat, placeable=False, contiguous=False,
                free_slots=int(free.sum()), frag=round(frag, 4),
            )
            continue
        _, contiguous = found
        fits[strat] = Candidate(
            strategy=strat, placeable=True, contiguous=contiguous,
            free_slots=int(free.sum()), frag=round(frag, 4),
        )
        if simulate:
            hypo = copy.deepcopy(ledger)
            hypo.place(blocks, strategy=strat, job_id=_HYPO_JOB)
            snaps[strat] = [Snapshot(
                time=0.0, trigger=_HYPO_JOB,
                jobs=tuple(
                    (jid,
                     kernel if jid == _HYPO_JOB
                     else (kernels or {}).get(jid, "all_to_all"),
                     hypo.jobs[jid].partition)
                    for jid in sorted(hypo.jobs)
                ),
                failed_endpoints=tuple(
                    int(e) for e in ledger.failed.nonzero()[0]
                ),
            )]

    if snaps:
        from repro.sched.bridge import evaluate_snapshots

        rows, _stats = evaluate_snapshots(
            topo, snaps, seeds=seeds, horizon=horizon, mode=mode,
            churn_faults=True,
        )
        by_strat: dict[str, list[dict]] = {}
        for row in rows:
            by_strat.setdefault(row["key"], []).append(row)
        for strat, srows in by_strat.items():
            lat = sum(r["avg_latency"] for r in srows) / len(srows)
            hops = sum(r["avg_hops"] for r in srows) / len(srows)
            fits[strat] = dataclasses.replace(
                fits[strat],
                avg_latency=round(lat, 3), avg_hops=round(hops, 4),
                completed=all(r["completed"] for r in srows),
            )

    ranked = sorted(
        fits.values(),
        key=lambda c: (
            not c.placeable, not c.contiguous,
            c.avg_latency if c.avg_latency is not None else float("inf"),
            c.frag, c.strategy,
        ),
    )
    insight = Insight(blocks=blocks, key=key, cached=False,
                      simulated=bool(snaps), candidates=tuple(ranked))
    if len(_MEMO) >= _MEMO_CAP:
        _MEMO.pop(next(iter(_MEMO)))
    _MEMO[key] = insight
    return insight


def clear_memo():
    _MEMO.clear()


# ---------------------------------------------------------- store-backed
def queue_outlook(store) -> list[dict]:
    """Rank observed scheduler streams from an EventStore's rollups.

    One row per (run, stream) with the recent pressure signals and a
    composite ``score`` (lower = more headroom): fragmentation + queue
    depth + failure pressure.  Works on a checkpoint-restored store — no
    raw event log is touched.
    """
    rows = []
    for key in sorted(store.runs):
        run = store.runs[key]
        for sname in sorted(run.streams):
            sr = run.streams[sname]
            arrived = max(sr.totals["arrive"], 1)
            fail_rate = (sr.totals["fail"] + sr.totals["giveup"]) / arrived
            frag = sr.summary.get("frag_mean", sr.last_frag)
            score = float(frag) + 0.1 * sr.last_queued + fail_rate
            rows.append({
                "run": key, "stream": sname,
                "arrived": sr.totals["arrive"],
                "finished": sr.totals["depart"],
                "failures": sr.totals["fail"],
                "frag": round(float(frag), 4),
                "queued": sr.last_queued,
                "running": sr.last_running,
                "utilization": sr.summary.get("utilization", ""),
                "fail_rate": round(fail_rate, 4),
                "score": round(score, 4),
            })
    rows.sort(key=lambda r: (r["score"], r["run"], r["stream"]))
    return rows


def recommend_queue(store, blocks: int = 1) -> dict | None:
    """The best stream (strategy/policy queue) for a new job, from history.

    Returns the top :func:`queue_outlook` row annotated with a human
    reason, or ``None`` when the store has seen no scheduler streams.
    """
    outlook = queue_outlook(store)
    if not outlook:
        return None
    best = dict(outlook[0])
    best["blocks"] = blocks
    best["reason"] = (
        f"lowest pressure score {best['score']} "
        f"(frag {best['frag']}, queued {best['queued']}, "
        f"fail_rate {best['fail_rate']})"
    )
    return best
