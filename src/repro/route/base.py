"""Routing-policy contract + registry.

A :class:`RoutingPolicy` is a *declaration*, not an object with behaviour:
it names the static predicates the cycle kernel specializes on (candidate
set shape, Valiant intermediates, injection adaptivity) and declares its
hop-indexed VC budget.  The engine's :func:`~repro.core.engine.tables.
build_static_tables` resolves the policy by name through the registry and
bakes the predicates into the jitted step function as trace constants —
everything per-workload (fault masks, intermediate pools) still travels in
``WorkloadTables`` as device arguments, so routing x strategy x fault
grids batch exactly like any other scenario axis.

Deadlock freedom: every packet occupies VC ``min(hops_taken + 1, V - 1)``,
so the buffer dependency graph is acyclic as long as no packet ever takes
more than ``V - 1`` hops.  :meth:`RoutingPolicy.vc_budget` is each
policy's declaration of that worst case — minimal phases contribute at
most ``q`` hops each (one per unaligned dimension), Valiant-style
policies have two phases, and every policy may additionally spend up to
``m`` deroutes (adaptive Omni-WAR deroutes and fault-escalation deroutes
decrement the same per-packet budget, the constraint 2404.04315 builds
its fault-tolerant VC schedule around).
"""

from __future__ import annotations

import dataclasses


@dataclasses.dataclass(frozen=True)
class RoutingPolicy:
    """Static declaration of one table-driven routing policy.

    Attributes:
      name: registry key (the engine's ``mode=`` string).
      adaptive_deroutes: Omni-WAR-style candidate set — non-minimal ports
        in unaligned dimensions are legal while the per-packet deroute
        budget lasts.  When False the candidate set is minimal-only, with
        deroutes *escalated* (still budget-bounded) only when every
        minimal port of the current switch is dead.
      uses_intermediate: packets may carry a Valiant intermediate switch;
        the kernel routes minimally to the intermediate, then minimally
        to the destination (hop counter and VCs keep increasing across
        the phase change).
      adaptive_injection: UGAL — the minimal vs Valiant path is chosen
        per packet at injection from the local queue-occupancy signal.
    """

    name: str
    adaptive_deroutes: bool
    uses_intermediate: bool
    adaptive_injection: bool
    description: str = ""

    def default_deroutes(self, q: int) -> int:
        """Default per-packet deroute budget m: one per dimension per
        minimal phase.  min/omniwar keep the seed engine's q; Valiant
        policies get 2q — their two phases each need escape headroom, or
        packets strand budget-empty at dead links mid-phase."""
        return (2 if self.uses_intermediate else 1) * q

    def vc_budget(self, q: int, m: int) -> int:
        """Hop-indexed VC count V = worst-case hops + 1.

        ``q`` topology dimensions (max minimal hops per phase), ``m``
        deroute budget.  min/omniwar: q + m + 1 (identical to the seed
        engine); val/ugal add a second minimal phase: 2q + m + 1.
        """
        phases = 2 if self.uses_intermediate else 1
        return phases * q + m + 1

    def max_hops(self, q: int, m: int) -> int:
        """Worst-case network hops under this policy (== vc_budget - 1)."""
        return self.vc_budget(q, m) - 1


# --------------------------------------------------------------- registry
_REGISTRY: dict[str, RoutingPolicy] = {}


def register_policy(policy: RoutingPolicy) -> RoutingPolicy:
    """Add a policy to the registry (returns it, decorator-style)."""
    if policy.name in _REGISTRY:
        raise ValueError(f"routing policy {policy.name!r} already registered")
    _REGISTRY[policy.name] = policy
    return policy


def available_policies() -> tuple[str, ...]:
    """Registered policy names, sorted."""
    return tuple(sorted(_REGISTRY))


def get_policy(name: str) -> RoutingPolicy:
    """Look a policy up by name; unknown names list what IS registered."""
    try:
        return _REGISTRY[name]
    except KeyError:
        raise ValueError(
            f"unknown routing mode {name!r}; registered policies: "
            f"{', '.join(available_policies()) or '(none)'}"
        ) from None
