"""Link-fault masks for fault-aware routing.

A fault mask is an ``(S, q*n)`` bool array over the engine's dense
*directed* network ports (True = healthy): port ``d*n + v`` of switch
``s`` is the link toward coordinate value ``v`` in dimension ``d``.
Self-loop ports (``v == coords[s, d]``) are never candidates and stay
True.  The mask is **per-workload device data**: it rides in
``WorkloadTables`` (see ``Workload.link_ok``), so a fault-scenario grid
batches through one compilation and one device call per shape bucket like
any other workload axis.

Kernel semantics (all policies): candidate sets exclude dead links; when a
minimal-only policy (min/val/ugal) finds every minimal port of the current
switch dead, deroutes *escalate* — non-minimal ports in unaligned
dimensions become legal while the per-packet budget ``m`` lasts.  The
budget bound keeps worst-case hops inside each policy's declared VC
budget, preserving hop-indexed-VC deadlock freedom under faults
(arXiv 2404.04315's key constraint).  Omni-WAR needs no escalation: its
candidate set already contains the deroutes.
"""

from __future__ import annotations

import dataclasses
from typing import TYPE_CHECKING, Iterable, Sequence

import numpy as np

from repro.core.hyperx import HyperX
from repro.route.topology import dst_switch_table, self_port_mask

if TYPE_CHECKING:  # pragma: no cover
    from repro.core.traffic import Workload


def no_faults(topo: HyperX) -> np.ndarray:
    """All-healthy mask — the default every workload gets."""
    return np.ones((topo.num_switches, topo.q * topo.n), dtype=bool)


def fail_links(
    topo: HyperX,
    links: Iterable[tuple[int, int]],
    mask: np.ndarray | None = None,
    bidirectional: bool = True,
) -> np.ndarray:
    """Kill switch-to-switch links given as (src, dst) switch-id pairs.

    Pairs must be at Hamming distance exactly 1.  ``bidirectional``
    (default) kills the reverse direction too — a dead cable, the common
    failure unit.  Mutates and returns ``mask`` (fresh all-healthy mask
    when None).
    """
    if mask is None:
        mask = no_faults(topo)
    coords = topo.all_switch_coords()
    n = topo.n
    for a, b in links:
        diff = np.flatnonzero(coords[a] != coords[b])
        if len(diff) != 1:
            raise ValueError(
                f"switches {a} and {b} are not neighbours "
                f"(Hamming distance {len(diff)})"
            )
        d = int(diff[0])
        mask[a, d * n + coords[b, d]] = False
        if bidirectional:
            mask[b, d * n + coords[a, d]] = False
    return mask


def fail_switches(topo: HyperX, switches: Sequence[int]) -> np.ndarray:
    """Kill every link touching the given switches (switch power-off)."""
    mask = no_faults(topo)
    switches = np.asarray(switches, dtype=np.int64)
    mask[switches, :] = False
    # incoming directions: any port whose destination is a dead switch
    dst = dst_switch_table(topo.all_switch_coords(), topo.n, topo.q)
    dead = np.zeros(topo.num_switches, dtype=bool)
    dead[switches] = True
    mask[dead[dst].reshape(mask.shape)] = False
    return mask


def random_link_faults(
    topo: HyperX, rate: float, seed: int = 0
) -> np.ndarray:
    """Fail each undirected cable independently with probability ``rate``."""
    if not 0.0 <= rate <= 1.0:
        raise ValueError(f"fault rate must be in [0, 1], got {rate}")
    rng = np.random.default_rng(seed)
    cables = topo.link_array()                      # (L, 2) undirected
    dead = cables[rng.random(len(cables)) < rate]
    return fail_links(topo, [tuple(map(int, c)) for c in dead])


def faults_from_endpoints(
    topo: HyperX,
    endpoints: Sequence[int],
    links_per_endpoint: int = 1,
    seed: int = 0,
) -> np.ndarray:
    """Network faults implied by endpoint failures (scheduler churn).

    Failure domains are co-packaged: an endpoint failure (node loss)
    takes ``links_per_endpoint`` cables adjacent to its switch with it —
    chosen deterministically per endpoint id, so every strategy facing
    the same physical churn sees the same dead network.  A switch whose
    endpoints have ALL failed is treated as powered off entirely.
    """
    mask = no_faults(topo)
    endpoints = np.asarray(endpoints, dtype=np.int64)
    if endpoints.size == 0:
        return mask
    coords = topo.all_switch_coords()
    valid = self_port_mask(coords, topo.n, topo.q)
    dst = dst_switch_table(coords, topo.n, topo.q).reshape(valid.shape)
    for ep in np.unique(endpoints):
        sw = int(ep) // topo.concentration
        ports = np.flatnonzero(valid[sw])
        rng = np.random.default_rng(seed + int(ep))
        for p in rng.choice(ports, size=min(links_per_endpoint, len(ports)),
                            replace=False):
            fail_links(topo, [(sw, int(dst[sw, p]))], mask=mask)
    switches, counts = np.unique(
        endpoints // topo.concentration, return_counts=True
    )
    fully_dead = switches[counts >= topo.concentration]
    if fully_dead.size:
        mask &= fail_switches(topo, fully_dead)
    return mask


# ------------------------------------------------------------- derived data
def intermediate_pool(
    topo: HyperX, link_ok: np.ndarray
) -> tuple[np.ndarray, int]:
    """Healthy Valiant-intermediate switches as a fixed-shape device table.

    A switch qualifies while it keeps at least one healthy real (non-self)
    port in each direction — enterable and exitable.  Returns
    ``(pool, count)`` where ``pool`` is (S,) int32, the qualifying ids
    cyclically repeated to length S: the *shape* is static (one compile
    per topology) while the *values* are per-workload, so fault grids
    vmap without retracing.
    """
    link_ok = np.asarray(link_ok, dtype=bool)
    coords = topo.all_switch_coords()
    valid = self_port_mask(coords, topo.n, topo.q)
    out_ok = (link_ok & valid).any(axis=1)
    dst = dst_switch_table(coords, topo.n, topo.q).reshape(valid.shape)
    in_ok = np.zeros(topo.num_switches, dtype=bool)
    healthy_dirs = link_ok & valid
    np.logical_or.at(in_ok, dst[healthy_dirs], True)
    ids = np.flatnonzero(out_ok & in_ok)
    if ids.size == 0:
        ids = np.array([0], dtype=np.int64)   # degenerate machine; unused
    pool = np.resize(ids, topo.num_switches).astype(np.int32)
    return pool, int(min(ids.size, topo.num_switches))


def is_connected(topo: HyperX, link_ok: np.ndarray) -> bool:
    """True when every switch is reachable from switch 0 over healthy
    directed links — the sanity check fault-injection tests use."""
    coords = topo.all_switch_coords()
    valid = self_port_mask(coords, topo.n, topo.q)
    dst = dst_switch_table(coords, topo.n, topo.q).reshape(valid.shape)
    ok = np.asarray(link_ok, dtype=bool) & valid
    seen = np.zeros(topo.num_switches, dtype=bool)
    seen[0] = True
    frontier = [0]
    while frontier:
        s = frontier.pop()
        for t in dst[s][ok[s]]:
            if not seen[t]:
                seen[t] = True
                frontier.append(int(t))
    return bool(seen.all())


def apply_faults(wl: "Workload", link_ok: np.ndarray) -> "Workload":
    """A copy of ``wl`` carrying the fault mask (lowered into
    ``WorkloadTables.link_ok`` by the engine's prepare step)."""
    link_ok = np.asarray(link_ok, dtype=bool)
    expect = (wl.topo.num_switches, wl.topo.q * wl.topo.n)
    if link_ok.shape != expect:
        raise ValueError(
            f"fault mask shape {link_ok.shape} != {expect} for {wl.topo}"
        )
    return dataclasses.replace(wl, link_ok=link_ok)
