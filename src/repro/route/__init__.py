"""Pluggable routing-policy subsystem.

Public surface:

  * :class:`RoutingPolicy` — the static policy declaration (candidate-set
    shape, Valiant intermediates, injection adaptivity, VC budget);
  * :func:`get_policy` / :func:`register_policy` /
    :func:`available_policies` — the registry the engine resolves
    ``mode=`` strings through (unknown modes raise with the registered
    names);
  * :mod:`repro.route.policies` — the shipped policies: ``min``,
    ``omniwar`` (bit-identical migrations of the seed engine's inline
    modes), ``val`` (Valiant random-intermediate) and ``ugal`` (UGAL-L
    occupancy-adaptive min-vs-Valiant at injection);
  * :mod:`repro.route.faults` — per-workload link-fault masks
    (``Workload.link_ok`` -> ``WorkloadTables``), fault generators, the
    Valiant intermediate pool, and connectivity checks;
  * :mod:`repro.route.topology` — vectorized neighbour/port tables shared
    by the engine and ``LinkSpace``.

Policies compile to the candidate-port/VC tables the vmapped step kernel
consumes; per-workload fault state travels as device arguments, so a
routing x strategy x fault grid is still one compilation and one device
call per shape bucket (trace-counter-pinned in ``tests/test_route.py``).
"""

from repro.route.base import (
    RoutingPolicy,
    available_policies,
    get_policy,
    register_policy,
)
from repro.route.faults import (
    apply_faults,
    fail_links,
    fail_switches,
    faults_from_endpoints,
    intermediate_pool,
    is_connected,
    no_faults,
    random_link_faults,
)
from repro.route.policies import MIN, OMNIWAR, UGAL, VAL
from repro.route.topology import (
    dst_switch_table,
    neighbor_tables,
    port_layout,
    self_port_mask,
)

__all__ = [
    "MIN",
    "OMNIWAR",
    "UGAL",
    "VAL",
    "RoutingPolicy",
    "apply_faults",
    "available_policies",
    "dst_switch_table",
    "fail_links",
    "fail_switches",
    "faults_from_endpoints",
    "get_policy",
    "intermediate_pool",
    "is_connected",
    "neighbor_tables",
    "no_faults",
    "port_layout",
    "random_link_faults",
    "register_policy",
    "self_port_mask",
]
