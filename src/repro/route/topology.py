"""Vectorized port/neighbour table construction.

The seed engine built its neighbour tables with O(S * q * n) nested Python
loops (and ``LinkSpace`` repeated the same loops for its ``dst_switch``
table).  The broadcast form here computes the same tables in a handful of
numpy ops from the mixed-radix switch id decomposition:

    switch_id = sum_d coords[:, d] * n**(q-1-d)

so the neighbour reached through port (d, v) — "set dimension d to value
v" — is ``id + (v - coords[:, d]) * n**(q-1-d)``.  Parity with the loop
construction is pinned by ``tests/test_route.py``.
"""

from __future__ import annotations

import numpy as np


def port_layout(n: int, q: int) -> tuple[np.ndarray, np.ndarray]:
    """(q*n,) dimension and value addressed by each dense network port."""
    d_idx = np.repeat(np.arange(q), n)
    v_idx = np.tile(np.arange(n), q)
    return d_idx, v_idx


def neighbor_tables(
    coords: np.ndarray, n: int, q: int
) -> tuple[np.ndarray, np.ndarray]:
    """Neighbour switch + arrival port per dense network port.

    Args:
      coords: (S, q) switch coordinates, slowest dimension first.

    Returns:
      nbr:           (S, q*n) switch reached through port d*n + v
                     (== self when v == coords[s, d]: the invalid
                     self-loop ports, never legal candidates);
      in_port_at_nb: (S, q*n) the port of that neighbour the packet
                     arrives on (dimension d, value = sender's coord).
    """
    coords = np.asarray(coords)
    w = n ** np.arange(q - 1, -1, -1)                  # mixed-radix weights
    base = coords @ w                                  # (S,) switch ids
    d_idx, v_idx = port_layout(n, q)
    wd = w[d_idx]                                      # (q*n,)
    nbr = base[:, None] + (v_idx[None, :] - coords[:, d_idx]) * wd[None, :]
    in_port_at_nb = d_idx[None, :] * n + coords[:, d_idx]
    return nbr.astype(np.int64), in_port_at_nb.astype(np.int64)


def dst_switch_table(coords: np.ndarray, n: int, q: int) -> np.ndarray:
    """(S, q, n) destination switch for every (src, dim, value) link id —
    the vectorized form of ``LinkSpace.dst_switch``."""
    nbr, _ = neighbor_tables(coords, n, q)
    return nbr.reshape(-1, q, n)


def self_port_mask(coords: np.ndarray, n: int, q: int) -> np.ndarray:
    """(S, q*n) bool — True where port (d, v) is a real link (v != own
    coordinate); the dense layout's self-loop ports are False."""
    d_idx, v_idx = port_layout(n, q)
    return v_idx[None, :] != np.asarray(coords)[:, d_idx]
