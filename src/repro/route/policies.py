"""The four shipped routing policies.

``min`` and ``omniwar`` reproduce the seed engine's two inline modes
bit-identically (regression-pinned by ``tests/test_route.py``); ``val``
and ``ugal`` add Valiant-style non-minimal load balancing:

  * **min** — minimal only: one candidate port per unaligned dimension
    (the port whose value matches the destination coordinate).  Under
    faults, deroutes escalate (budget-bounded) when every minimal port
    of the current switch is dead.
  * **omniwar** — Omni-WAR (McDonald et al., SC'19): any port of an
    unaligned dimension is a candidate while the per-packet deroute
    budget m lasts; choice by occupancy + deroute-penalty cost.
  * **val** — Valiant: every packet draws a uniform random intermediate
    switch from the healthy pool at injection, routes minimally to it,
    then minimally to the destination.  Classic worst-case load
    balancing at the price of ~2x hops.
  * **ugal** — UGAL-L: at injection the packet compares (queue occupancy
    x path length) of its best minimal port against its best port toward
    a sampled Valiant intermediate — the same congestion signal the
    in-network adaptive cost uses — and commits to whichever is cheaper.
    In flight it behaves like ``val`` (minimal per phase).
"""

from __future__ import annotations

from repro.route.base import RoutingPolicy, register_policy

MIN = register_policy(RoutingPolicy(
    name="min",
    adaptive_deroutes=False,
    uses_intermediate=False,
    adaptive_injection=False,
    description="minimal-only (fault escalation deroutes when cut)",
))

OMNIWAR = register_policy(RoutingPolicy(
    name="omniwar",
    adaptive_deroutes=True,
    uses_intermediate=False,
    adaptive_injection=False,
    description="Omni-WAR adaptive deroutes (budget m)",
))

VAL = register_policy(RoutingPolicy(
    name="val",
    adaptive_deroutes=False,
    uses_intermediate=True,
    adaptive_injection=False,
    description="Valiant random-intermediate, minimal per phase",
))

UGAL = register_policy(RoutingPolicy(
    name="ugal",
    adaptive_deroutes=False,
    uses_intermediate=True,
    adaptive_injection=True,
    description="UGAL-L: min-vs-Valiant chosen at injection by occupancy",
))
