"""The paper's own experimental machine: 8x8 HyperX, 8 endpoints/switch,
512 endpoints, Omni-WAR routing, partitions of 64 (Table 2 / Sec. 6.2)."""

import dataclasses


@dataclasses.dataclass(frozen=True)
class PaperConfig:
    n: int = 8
    q: int = 2
    concentration: int = 8
    packet_flits: int = 16
    input_buffer_pkts: int = 8
    output_buffer_pkts: int = 4
    vcs_per_port: int = 4
    deroute_penalty_phits: int = 64
    max_deroutes: int = 2          # m = q
    app_sizes: tuple = (64, 128, 256)
    strategies: tuple = (
        "row", "diagonal", "full_spread", "rectangular", "l_shape",
        "random_endpoint", "random_switch",
    )


def config() -> PaperConfig:
    return PaperConfig()


def reduced() -> PaperConfig:
    return dataclasses.replace(config(), n=4, concentration=4)
