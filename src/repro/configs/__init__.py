"""Architecture registry: one module per assigned architecture.

``get_config(name)`` returns the full published config; ``get_config(name,
reduced=True)`` returns the family-preserving smoke-test reduction (small
depth/width/experts, tiny vocab) used by CPU tests.  The full configs are
exercised only through the dry-run (ShapeDtypeStruct, no allocation).
"""

from __future__ import annotations

import importlib

ARCHS = [
    "deepseek_67b",
    "qwen3_0_6b",
    "internlm2_1_8b",
    "olmo_1b",
    "mamba2_1_3b",
    "hubert_xlarge",
    "deepseek_v2_236b",
    "qwen3_moe_30b_a3b",
    "llama_3_2_vision_90b",
    "recurrentgemma_9b",
]

# CLI ids (--arch) use dashes, module names use underscores
def _norm(name: str) -> str:
    return name.replace("-", "_").replace(".", "_")


def get_config(name: str, reduced: bool = False):
    mod = importlib.import_module(f"repro.configs.{_norm(name)}")
    return mod.reduced() if reduced else mod.config()


def all_configs(reduced: bool = False):
    return {a: get_config(a, reduced) for a in ARCHS}
