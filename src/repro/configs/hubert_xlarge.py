"""hubert-xlarge [audio]: 48L d_model=1280 16H (MHA) d_ff=5120 vocab=504
— encoder-only; the CNN waveform frontend is a STUB (precomputed frame
embeddings per the assignment) [arXiv:2106.07447]."""

import dataclasses

from repro.models.config import ArchConfig


def config() -> ArchConfig:
    return ArchConfig(
        name="hubert-xlarge", family="audio",
        n_layers=48, d_model=1280, n_heads=16, n_kv=16,
        d_ff=5120, vocab=504, encoder_only=True, frame_input=True,
    )


def reduced() -> ArchConfig:
    return dataclasses.replace(
        config(), n_layers=2, d_model=64, n_heads=4, n_kv=4, d_head=16,
        d_ff=128, vocab=32,
    )
