"""qwen3-moe-30b-a3b [moe]: 48L d_model=2048 32H (GQA kv=4)
d_ff(expert)=768 vocab=151936, 128 experts top-8 [hf:Qwen/Qwen3-30B-A3B]."""

import dataclasses

from repro.models.config import ArchConfig


def config() -> ArchConfig:
    return ArchConfig(
        name="qwen3-moe-30b-a3b", family="moe",
        n_layers=48, d_model=2048, n_heads=32, n_kv=4, d_head=128,
        d_ff=6144, vocab=151936, qk_norm=True,
        n_experts=128, top_k=8, n_shared=0, d_ff_expert=768,
    )


def reduced() -> ArchConfig:
    return dataclasses.replace(
        config(), n_layers=2, d_model=64, n_heads=4, n_kv=2, d_head=16,
        d_ff=128, vocab=256, n_experts=8, top_k=2, d_ff_expert=32,
    )
