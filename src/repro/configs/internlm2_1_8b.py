"""internlm2-1.8b [dense]: 24L d_model=2048 16H (GQA kv=8) d_ff=8192
vocab=92544 — GQA [arXiv:2403.17297]."""

import dataclasses

from repro.models.config import ArchConfig


def config() -> ArchConfig:
    return ArchConfig(
        name="internlm2-1.8b", family="dense",
        n_layers=24, d_model=2048, n_heads=16, n_kv=8,
        d_ff=8192, vocab=92544,
    )


def reduced() -> ArchConfig:
    return dataclasses.replace(
        config(), n_layers=2, d_model=64, n_heads=4, n_kv=2, d_head=16,
        d_ff=128, vocab=256,
    )
