"""deepseek-67b [dense]: 95L d_model=8192 64H (GQA kv=8) d_ff=22016
vocab=102400 — llama-arch [arXiv:2401.02954]."""

import dataclasses

from repro.models.config import ArchConfig


def config() -> ArchConfig:
    return ArchConfig(
        name="deepseek-67b", family="dense",
        n_layers=95, d_model=8192, n_heads=64, n_kv=8,
        d_ff=22016, vocab=102400,
    )


def reduced() -> ArchConfig:
    return dataclasses.replace(
        config(), n_layers=2, d_model=64, n_heads=4, n_kv=2, d_head=16,
        d_ff=128, vocab=256,
    )
