"""mamba2-1.3b [ssm]: 48L d_model=2048 attention-free, vocab=50280,
ssm_state=128 — SSD state-space duality [arXiv:2405.21060]."""

import dataclasses

from repro.models.config import ArchConfig


def config() -> ArchConfig:
    return ArchConfig(
        name="mamba2-1.3b", family="ssm",
        n_layers=48, d_model=2048, n_heads=1, n_kv=1, d_head=64,
        d_ff=0, vocab=50280,
        ssm_state=128, ssm_expand=2, ssm_head_dim=64, ssm_chunk=256,
        tie_embeddings=True,
    )


def reduced() -> ArchConfig:
    return dataclasses.replace(
        config(), n_layers=2, d_model=64, vocab=256,
        ssm_state=16, ssm_head_dim=16, ssm_chunk=8,
    )
