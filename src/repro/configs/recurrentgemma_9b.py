"""recurrentgemma-9b [hybrid]: 38L d_model=4096 16H (MQA kv=1) d_ff=12288
vocab=256000 — RG-LRU + local attention, pattern 2 recurrent : 1 attention,
window 2048 [arXiv:2402.19427]."""

import dataclasses

from repro.models.config import ArchConfig


def config() -> ArchConfig:
    return ArchConfig(
        name="recurrentgemma-9b", family="hybrid",
        n_layers=38, d_model=4096, n_heads=16, n_kv=1, d_head=256,
        d_ff=12288, vocab=256000,
        rglru_pattern=2, window=2048, lru_width=4096,
    )


def reduced() -> ArchConfig:
    return dataclasses.replace(
        config(), n_layers=8, d_model=64, n_heads=4, n_kv=1, d_head=16,
        d_ff=128, vocab=256, window=16, lru_width=64,
    )
