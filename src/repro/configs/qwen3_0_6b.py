"""qwen3-0.6b [dense]: 28L d_model=1024 16H (GQA kv=8) d_ff=3072
vocab=151936 — qk_norm, GQA [hf:Qwen/Qwen3-8B family]."""

import dataclasses

from repro.models.config import ArchConfig


def config() -> ArchConfig:
    return ArchConfig(
        name="qwen3-0.6b", family="dense",
        n_layers=28, d_model=1024, n_heads=16, n_kv=8, d_head=128,
        d_ff=3072, vocab=151936, qk_norm=True, tie_embeddings=True,
    )


def reduced() -> ArchConfig:
    return dataclasses.replace(
        config(), n_layers=2, d_model=64, n_heads=4, n_kv=2, d_head=16,
        d_ff=128, vocab=256,
    )
