"""llama-3.2-vision-90b [vlm]: 100L d_model=8192 64H (GQA kv=8)
d_ff=28672 vocab=128256 — gated cross-attention image layers 1:4 with the
vision patch frontend STUBBED (precomputed patch embeddings)
[hf:meta-llama/Llama-3.2-90B-Vision]."""

import dataclasses

from repro.models.config import ArchConfig


def config() -> ArchConfig:
    return ArchConfig(
        name="llama-3.2-vision-90b", family="vlm",
        n_layers=100, d_model=8192, n_heads=64, n_kv=8,
        d_ff=28672, vocab=128256,
        cross_attn_every=4, frontend_tokens=1601,
    )


def reduced() -> ArchConfig:
    return dataclasses.replace(
        config(), n_layers=5, d_model=64, n_heads=4, n_kv=2, d_head=16,
        d_ff=128, vocab=256, cross_attn_every=4, frontend_tokens=16,
    )
