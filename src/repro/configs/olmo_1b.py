"""olmo-1b [dense]: 16L d_model=2048 16H (kv=16, MHA) d_ff=8192
vocab=50304 — non-parametric LN [arXiv:2402.00838]."""

import dataclasses

from repro.models.config import ArchConfig


def config() -> ArchConfig:
    return ArchConfig(
        name="olmo-1b", family="dense",
        n_layers=16, d_model=2048, n_heads=16, n_kv=16,
        d_ff=8192, vocab=50304, nonparam_ln=True, tie_embeddings=True,
    )


def reduced() -> ArchConfig:
    return dataclasses.replace(
        config(), n_layers=2, d_model=64, n_heads=4, n_kv=4, d_head=16,
        d_ff=128, vocab=256,
    )
