"""deepseek-v2-236b [moe]: 60L d_model=5120 128H d_ff(expert)=1536
vocab=102400, MLA kv_lora=512, 2 shared + 160 routed experts top-6
[arXiv:2405.04434]."""

import dataclasses

from repro.models.config import ArchConfig


def config() -> ArchConfig:
    return ArchConfig(
        name="deepseek-v2-236b", family="moe",
        n_layers=60, d_model=5120, n_heads=128, n_kv=128, d_head=128,
        d_ff=12288, vocab=102400,
        n_experts=160, top_k=6, n_shared=2, d_ff_expert=1536,
        kv_lora=512, q_lora=1536, rope_head_dim=64,
    )


def reduced() -> ArchConfig:
    return dataclasses.replace(
        config(), n_layers=2, d_model=64, n_heads=4, n_kv=4, d_head=16,
        d_ff=128, vocab=256, n_experts=8, top_k=2, n_shared=1,
        d_ff_expert=32, kv_lora=32, q_lora=48, rope_head_dim=8,
    )
